# Convenience entry points (see scripts/ci.sh for the definitions).
.PHONY: test smoke plan plan-smoke fault-smoke obs-smoke dist-smoke \
	health-smoke bench-overhead bench-refresh bench-state bench-conv \
	bench-plan bench-elastic bench-obs bench-sync bench-health \
	bench-quality bench-check

test:
	./scripts/ci.sh

smoke:
	./scripts/ci.sh smoke

# Budget-driven memory planner (coap-plan/v1): table + artifact + exact
# byte verification against the constructed optimizer. Override knobs:
#   make plan ARCH=llama-1b BUDGET=40GB
ARCH ?= llama-1b
BUDGET ?= 40GB
plan:
	PYTHONPATH=src python -m repro.launch.plan --arch $(ARCH) \
		--budget $(BUDGET) --verify

# Plans all 11 registry archs under an auto budget and byte-verifies each.
plan-smoke:
	./scripts/ci.sh plan-smoke

# Elastic/fault-injection smoke: the replan->migrate->resume control loop
# (supervisor kill/shrink/torn-checkpoint scenarios) under interpret-mode
# kernels. Part of the default `make test` path via scripts/ci.sh.
fault-smoke:
	./scripts/ci.sh fault-smoke

# Observability smoke: tracer/registry/calibration unit layer + a traced
# 10-step run whose spans, heartbeat counters and fleet_status view are
# all checked. Part of the default `make test` path via scripts/ci.sh.
obs-smoke:
	./scripts/ci.sh obs-smoke

# Compressed cross-pod sync smoke: fp32 + quantized 2-pod equivalence,
# the sync_codes int8 collective, stagger/override cadence parity and the
# wire-format gate, on the 8-device CPU test mesh under interpret-mode
# kernels. Part of the default `make test` path via scripts/ci.sh.
dist-smoke:
	./scripts/ci.sh dist-smoke

# Projection-health smoke: journal/verdict unit layer (injected numeric
# pathologies firing RANK_STARVED/QUANT_SATURATED/...), solver feedback,
# plus a health-journaled 10-step run checked through heartbeat gauges and
# the fleet_status health column. Part of the default `make test` path.
health-smoke:
	./scripts/ci.sh health-smoke

# Regenerates BENCH_overhead.json (fused vs unfused 8-bit traffic + launch
# counts on LLaMA-1B shapes) alongside the overhead CSV rows.
bench-overhead:
	PYTHONPATH=src:. python benchmarks/run.py --only overhead

# Regenerates BENCH_refresh.json (staggered vs synchronized worst-step
# refresh cost + fused vs unfused Eqn-6 bytes on LLaMA-1B shapes).
bench-refresh:
	PYTHONPATH=src:. python benchmarks/run.py --only refresh

# Regenerates BENCH_state.json (per-step state bytes moved: per-leaf
# stack/scatter vs pre-stacked bucket storage, LLaMA-1B bucket structure,
# plus the measured whole-step cost_analysis comparison).
bench-state:
	PYTHONPATH=src:. python benchmarks/run.py --only state

# Regenerates BENCH_conv.json (conv/Tucker-2 refresh: worst-step bytes and
# per-step launch counts, bucketed+staggered vs the per-leaf synchronized
# loop, on the conv-heavy reference tree).
bench-conv:
	PYTHONPATH=src:. python benchmarks/run.py --only conv

# Regenerates BENCH_plan.json (planned LLaMA-1B paper vectors: fp32/q8
# reductions vs the AdamW baseline + exact predicted-vs-accounted bytes).
bench-plan:
	PYTHONPATH=src:. python benchmarks/run.py --only plan

# Regenerates BENCH_elastic.json (preempted-resume latency breakdown for
# the 8->4 shrink scenario: checkpoint restore vs stacked_state.migrate vs
# train-step recompile under the replanned layout).
bench-elastic:
	PYTHONPATH=src:. python benchmarks/run.py --only elastic

# Regenerates BENCH_obs.json (span-tracing hot-path overhead: disabled and
# enabled per-span cost vs a traced smoke run's measured step time, gated
# at <3% tracing / <0.1% disabled).
bench-obs:
	PYTHONPATH=src:. python benchmarks/run.py --only obs

# Regenerates BENCH_sync.json (cross-pod wire bytes/step on the LLaMA-1B
# bucket structure: full-G fp32 vs r-rank fp32 vs r-rank int8+scales, with
# the >=3x int8-vs-fp32-compressed gate enforced by
# tests/test_benchmarks_sync.py).
bench-sync:
	PYTHONPATH=src:. python benchmarks/run.py --only sync

# Regenerates BENCH_obs.json's `health` block (per-row record cost +
# per-call observe_state cost vs a health-journaled run's measured step
# time, gated at <1% overhead AND zero extra G round-trips outside
# refresh steps).
bench-health:
	PYTHONPATH=src:. python benchmarks/run.py --only health

# Regenerates BENCH_quality.json (eval-CE rank ladder, each run
# health-journaled: the ranks whose runs fire RANK_STARVED should be
# exactly the ranks whose quality visibly degrades vs AdamW).
bench-quality:
	PYTHONPATH=src:. python benchmarks/run.py --only quality

# Compares the newest artifacts/bench_history.jsonl row (appended by
# `python -m benchmarks.run --record`) against the previous one; fails on
# any >20% regression of a gated ratio in its bad direction.
bench-check:
	PYTHONPATH=src:. python -m benchmarks.ledger --check
