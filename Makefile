# Convenience entry points (see scripts/ci.sh for the definitions).
.PHONY: test smoke bench-overhead bench-refresh bench-state bench-conv

test:
	./scripts/ci.sh

smoke:
	./scripts/ci.sh smoke

# Regenerates BENCH_overhead.json (fused vs unfused 8-bit traffic + launch
# counts on LLaMA-1B shapes) alongside the overhead CSV rows.
bench-overhead:
	PYTHONPATH=src:. python benchmarks/run.py --only overhead

# Regenerates BENCH_refresh.json (staggered vs synchronized worst-step
# refresh cost + fused vs unfused Eqn-6 bytes on LLaMA-1B shapes).
bench-refresh:
	PYTHONPATH=src:. python benchmarks/run.py --only refresh

# Regenerates BENCH_state.json (per-step state bytes moved: per-leaf
# stack/scatter vs pre-stacked bucket storage, LLaMA-1B bucket structure,
# plus the measured whole-step cost_analysis comparison).
bench-state:
	PYTHONPATH=src:. python benchmarks/run.py --only state

# Regenerates BENCH_conv.json (conv/Tucker-2 refresh: worst-step bytes and
# per-step launch counts, bucketed+staggered vs the per-leaf synchronized
# loop, on the conv-heavy reference tree).
bench-conv:
	PYTHONPATH=src:. python benchmarks/run.py --only conv
