#!/usr/bin/env bash
# Tier-1 gate + interpret-mode kernel smoke + plan smoke.
#
#   ./scripts/ci.sh              full tier-1 suite, then both smokes
#   ./scripts/ci.sh smoke        kernel smoke only (fast signal on kernel edits)
#   ./scripts/ci.sh plan-smoke   plan smoke only (planner/accounting edits)
#   ./scripts/ci.sh fault-smoke  elastic/fault-injection smoke (train/ edits)
#   ./scripts/ci.sh obs-smoke    observability smoke (obs/ + fleet_status edits)
#   ./scripts/ci.sh dist-smoke   compressed cross-pod sync smoke (distributed/ edits)
#   ./scripts/ci.sh health-smoke projection-health smoke (obs/health + solver feedback)
#
# The smoke subset re-runs the fused-kernel correctness tests with the
# actual Pallas bodies under interpret mode (REPRO_PALLAS=interpret routes
# every kernels/ops dispatch through pl.pallas_call(interpret=True) instead
# of the jnp ref oracle), plus an end-to-end quantized optimizer step.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

smoke() {
  echo "== interpret-mode kernel smoke =="
  # test_kernels.py covers every kernel body (incl. the fused Eqn-6 refresh
  # kernel — normalize-aware first-grid-phase variant and VMEM guard
  # included — pinned against the correlation.loss_and_grad oracle); the
  # refresh/bucketing picks drive the staggered schedule, the fused Eqn-6
  # route and the quantized dense path end-to-end through pallas interpret;
  # the stacked-state picks run the pre-stacked bucket storage (A/B parity
  # vs per-leaf, int8 included, plus a cross-mode checkpoint restore)
  # through the same interpret-mode kernels; the conv-bucketing picks run
  # the stacked-bucket/v2 conv path (one launch per conv bucket, staggered
  # Tucker-2 refresh, per-leaf A/B parity incl. the int8 flat codec)
  # through the interpret-mode quantizer bodies.
  REPRO_PALLAS=interpret python -m pytest -q \
    tests/test_kernels.py \
    tests/test_bucketing.py::test_mixed_tree_full_optimizer_runs \
    tests/test_bucketing.py::test_q8_state_holds_no_fp32_moments \
    tests/test_refresh.py::test_staggered_cadence_every_leaf_period_t_u \
    "tests/test_refresh.py::test_bf16_gradients_stream_without_numeric_drift" \
    "tests/test_stacked_state.py::test_stacked_matches_per_leaf" \
    tests/test_stacked_state.py::test_stacked_bf16_gradient_streaming_parity \
    "tests/test_stacked_state.py::test_checkpoint_cross_mode_restore[True-float32]" \
    "tests/test_conv_bucketing.py::test_conv_bucketed_matches_per_leaf" \
    tests/test_conv_bucketing.py::test_conv_staggered_cadence_period_t_u \
    "tests/test_conv_bucketing.py::test_conv_stacked_state_matches_per_leaf[True]"
}

plan_smoke() {
  echo "== plan smoke (all registry archs) =="
  # Plans every registry architecture under an auto budget and verifies
  # each plan's predicted optimizer-state bytes against
  # accounting.abstract_state_bytes of the actually-constructed optimizer
  # (must match EXACTLY; eval_shape only — no allocation even at 314B).
  # interpret mode keeps the kernels/ops dispatch honest about which
  # backend a planned run would use.
  REPRO_PALLAS=interpret python -m repro.launch.plan \
    --all --budget auto --verify --out ""
  # The paper's budgeted vectors: 40GB fp32 and a q8-forcing 12.5GB budget
  # on LLaMA-1B, both byte-verified.
  REPRO_PALLAS=interpret python -m repro.launch.plan \
    --arch llama-1b --budget 40GB --verify
  REPRO_PALLAS=interpret python -m repro.launch.plan \
    --arch llama-1b --budget 12.5GB --verify
}

fault_smoke() {
  echo "== elastic/fault-injection smoke =="
  # The preemption-native control loop end-to-end: seeded kill + topology
  # shrink 8->4 with a replanned (quantizing) layout, checkpoint restore
  # with stacked_state.migrate, torn-checkpoint fallback via crc32, the
  # notice-drain zero-lost-steps contract, and the launch/train.py --watch
  # supervisor CLI driving the same path.
  REPRO_PALLAS=interpret python -m pytest -q \
    tests/test_elastic.py::test_kill_shrink_replan_resume_converges \
    tests/test_elastic.py::test_torn_checkpoint_falls_back_to_older \
    tests/test_elastic.py::test_migrate_quantize_flip_roundtrip \
    tests/test_elastic.py::test_drain_zero_lost_steps_vs_reactive_rollback \
    "tests/test_checkpoint_edges.py::test_torn_write_fails_loudly_naming_file[True]"

  echo "== out-of-process fault smoke (real SIGKILL) =="
  # The exec worker model: spawned worker processes supervised purely
  # through the heartbeat file — a REAL SIGKILL mid-run (on CPU), the
  # 8->4 shrink replan/migrate across the process boundary, an injected
  # preemption notice drained with zero lost steps, plus the fast
  # fake-worker escalation-ladder checks and the fleet plan-consensus
  # protocol.
  REPRO_PALLAS=interpret python -m pytest -q \
    tests/test_elastic_process.py \
    tests/test_fleet.py
}

obs_smoke() {
  echo "== observability smoke (traced run -> spans + registry + fleet_status) =="
  # Unit layer: tracer round-trip/Perfetto schema, registry merge,
  # calibration parity, fleet_status on synthetic journals.
  REPRO_PALLAS=interpret python -m pytest -q \
    tests/test_obs.py -k "not end_to_end"
  # End-to-end: a traced 10-step elastic run must emit well-formed spans
  # with refresh attribution, ride its registry snapshot in the
  # heartbeat, and be parseable by fleet_status --json.
  REPRO_PALLAS=interpret python - <<'PY'
import json, os, tempfile

from repro.configs import get_smoke
from repro.core.api import OptimizerConfig
from repro.data.synthetic import SyntheticLM
from repro.launch import fleet_status
from repro.models.model import build_model
from repro.obs.trace import export_perfetto, read_trace
from repro.train.elastic import ElasticConfig, ElasticSupervisor, Topology

tmp = tempfile.mkdtemp(prefix="obs_smoke_")
cfg = get_smoke("tinyllama-1.1b")
model = build_model(cfg)
data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.2)
sup = ElasticSupervisor(
    model, lambda step, host: data.batch(step, batch=4, seq=16, host=host),
    ElasticConfig(
        ckpt_dir=tmp, total_steps=10,
        topology=(Topology(1, 10**12),),
        solve_kw=dict(min_dim=16, t_update=4, lam=2, stagger_groups=2),
        ckpt_every=5, log_every=2,
        heartbeat_path=os.path.join(tmp, "heartbeat.json"),
        metrics_path=os.path.join(tmp, "metrics.jsonl"),
        events_path=os.path.join(tmp, "events.jsonl"),
        trace_path=os.path.join(tmp, "trace.jsonl"),
        host_id="obs-smoke",
    ),
    ocfg=OptimizerConfig(name="coap-adamw", learning_rate=1e-3),
)
state = sup.run()
assert int(state.step) == 10, int(state.step)

rows = read_trace(os.path.join(tmp, "trace.jsonl"))
names = {r["name"] for r in rows}
assert {"elastic/attempt", "elastic/replan", "loop/step",
        "loop/checkpoint"} <= names, names
steps = [r for r in rows if r["name"] == "loop/step"]
assert len(steps) == 10 and all("dur" in r for r in steps)
assert (steps[0].get("attrs") or {}).get("refresh"), "no refresh attribution"
doc = export_perfetto(os.path.join(tmp, "trace.jsonl"),
                      os.path.join(tmp, "perfetto.json"))
assert doc["traceEvents"] and doc["displayTimeUnit"] == "ms"

hb = json.load(open(os.path.join(tmp, "heartbeat.json")))
assert hb["counters"].get("ckpt/save", 0) >= 1, hb
assert hb["phase"] == "train"

view = fleet_status.collect([tmp], None)
h = view["hosts"][0]
assert h["status"] == "alive" and h["step"] == 9, h
json.dumps(view, default=str)  # the --json document serializes
print(fleet_status.render(view))
print("obs smoke OK:", len(rows), "trace rows,",
      len(hb["counters"]), "counters")
PY
}

health_smoke() {
  echo "== projection-health smoke (journaled run -> verdicts + fleet_status) =="
  # Unit layer: journal reader edges, injected numeric pathologies firing
  # their typed verdicts end-to-end through real optimizers, the solver's
  # health-report feedback (incl. the bit-identical health-blind path),
  # and the fleet_status health column.
  REPRO_PALLAS=interpret python -m pytest -q tests/test_health.py
  # End-to-end: a health-journaled 10-step elastic run must append
  # per-bucket refresh + sample rows, mirror them as health/ gauges in the
  # heartbeat, and surface an analyzable health column in fleet_status.
  REPRO_PALLAS=interpret python - <<'PY'
import json, os, tempfile

from repro.configs import get_smoke
from repro.core.api import OptimizerConfig
from repro.data.synthetic import SyntheticLM
from repro.launch import fleet_status
from repro.obs.health import read_health
from repro.train.elastic import ElasticConfig, ElasticSupervisor, Topology

tmp = tempfile.mkdtemp(prefix="health_smoke_")
cfg = get_smoke("tinyllama-1.1b")
from repro.models.model import build_model
model = build_model(cfg)
data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.2)
sup = ElasticSupervisor(
    model, lambda step, host: data.batch(step, batch=4, seq=16, host=host),
    ElasticConfig(
        ckpt_dir=tmp, total_steps=10,
        topology=(Topology(1, 10**12),),
        solve_kw=dict(min_dim=16, t_update=4, lam=2, stagger_groups=2),
        ckpt_every=5, log_every=2,
        heartbeat_path=os.path.join(tmp, "heartbeat.json"),
        metrics_path=os.path.join(tmp, "metrics.jsonl"),
        events_path=os.path.join(tmp, "events.jsonl"),
        health_path=os.path.join(tmp, "health.jsonl"),
        health_every=2,
        host_id="health-smoke",
    ),
    ocfg=OptimizerConfig(name="coap-adamw", learning_rate=1e-3),
)
state = sup.run()
assert int(state.step) == 10, int(state.step)

rows = read_health(os.path.join(tmp, "health.jsonl"))
assert rows, "health journal is empty"
events = {r["event"] for r in rows}
assert "refresh" in events, events
# Zero-extra-G contract, per bucket: after the step-0 init refresh, rows
# land on at most stagger_groups=2 residues mod t_update=4, each residue
# exactly 4-periodic — i.e. rows appear ONLY where the staggered refresh
# schedule touches G, never in between.
per_bucket = {}
for r in rows:
    if r["event"] == "refresh":
        per_bucket.setdefault(r["bucket"], []).append(r["step"])
assert per_bucket, "no refresh rows"
for bucket, steps in per_bucket.items():
    steps = sorted(set(steps))
    assert steps[0] == 0, (bucket, steps)  # the init refresh
    sched = steps[1:]
    assert sched, (bucket, steps)
    residues = {s % 4 for s in sched}
    assert len(residues) <= 2, (bucket, steps)
    for res in residues:
        run = [s for s in sched if s % 4 == res]
        assert all(b - a == 4 for a, b in zip(run, run[1:])), (bucket, steps)

hb = json.load(open(os.path.join(tmp, "heartbeat.json")))
gauges = hb.get("gauges") or {}
assert any(k.startswith("health/") for k in gauges), sorted(gauges)[:5]

view = fleet_status.collect([tmp], None)
h = view["hosts"][0]
assert h["health"] is not None and "verdicts" in h["health"], h["health"]
print(fleet_status.render(view))
print("health smoke OK:", len(rows), "journal rows,",
      sum(1 for k in gauges if k.startswith('health/')), "health gauges,",
      "verdicts:", h["health"]["verdicts"] or "none")
PY
}

dist_smoke() {
  echo "== compressed cross-pod sync smoke (CPU test mesh) =="
  # The distributed/compression.py parity surface on the 8-device CPU test
  # mesh: fp32 + QUANTIZED 2-pod equivalence (bit-exact int8 codes where
  # pmean is the identity), the sync_codes int8 collective (telescoping EF
  # + end-to-end), stagger/override cadence parity vs the core transform,
  # and the loud structural ValueErrors. The in-subprocess tests force
  # their own device count; interpret mode keeps the codec bodies honest
  # for the in-process schedule tests. The wire-format gate rides along
  # (BENCH_sync.json methodology).
  REPRO_PALLAS=interpret python -m pytest -q \
    tests/test_distributed.py::test_crosspod_compression_matches_uncompressed \
    tests/test_distributed.py::test_crosspod_conv_compression_matches_uncompressed \
    tests/test_distributed.py::test_crosspod_quantized_matches_single_pod \
    tests/test_distributed.py::test_crosspod_sync_codes_int8_collective \
    tests/test_distributed.py::test_compressed_stagger_cadence_matches_core \
    tests/test_distributed.py::test_compressed_per_bucket_t_update_override_matches_core \
    tests/test_distributed.py::test_compressed_perleaf_reordered_state_raises \
    tests/test_distributed.py::test_compressed_sync_codes_requires_ef_sidecar \
    tests/test_bucketing.py::test_compressed_update_accepts_quantized_states \
    tests/test_benchmarks_sync.py
}

if [[ "${1:-}" == "smoke" ]]; then
  smoke
  exit 0
fi
if [[ "${1:-}" == "plan-smoke" ]]; then
  plan_smoke
  exit 0
fi
if [[ "${1:-}" == "fault-smoke" ]]; then
  fault_smoke
  exit 0
fi
if [[ "${1:-}" == "obs-smoke" ]]; then
  obs_smoke
  exit 0
fi
if [[ "${1:-}" == "dist-smoke" ]]; then
  dist_smoke
  exit 0
fi
if [[ "${1:-}" == "health-smoke" ]]; then
  health_smoke
  exit 0
fi

echo "== tier-1 suite =="
python -m pytest -x -q
smoke
plan_smoke
fault_smoke
obs_smoke
dist_smoke
health_smoke
