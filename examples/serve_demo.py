"""Serving demo: train a tiny model until it learns the synthetic Markov
table, then serve batched greedy generations and verify they follow the
learned transition structure.

  PYTHONPATH=src python examples/serve_demo.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.optim import apply_updates
from repro.serve import ServeConfig, ServeEngine


def main():
    cfg = dataclasses.replace(get_smoke("tinyllama-1.1b"), vocab_size=64,
                              dtype=jnp.float32)
    model = build_model(cfg)
    data = SyntheticLM(vocab=64, order=1, noise=0.02)
    tx = make_optimizer(OptimizerConfig(name="coap-adamw", learning_rate=3e-3,
                                        rank=16, t_update=10, lam=4, min_dim=16))
    params = model.init(jax.random.key(0))
    state = tx.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        u, s = tx.update(g, s, p)
        return apply_updates(p, u), s, loss

    for i in range(300):
        params, state, loss = step(params, state, data.batch(i, 16, 32))
    print(f"trained to loss {float(loss):.3f} (floor {data.ce_floor():.3f})")

    engine = ServeEngine(model, params, ServeConfig(max_new_tokens=12))
    prompts = [[5, int(data.table[0][5])], [17, int(data.table[0][17])]]
    outs = engine.generate(prompts)
    correct = total = 0
    for o in outs:
        print("generated:", o)
        for a, b in zip(o[:-1], o[1:]):
            total += 1
            correct += int(b == int(data.table[0][a]))
    print(f"markov-consistency of generations: {correct}/{total}")


if __name__ == "__main__":
    main()
