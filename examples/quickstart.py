"""Quickstart: COAP in 40 lines — project a model's gradients into low-rank
space, train, and compare optimizer memory against AdamW.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.accounting import optimizer_state_bytes
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.optim import apply_updates


def main():
    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    data = SyntheticLM(vocab=cfg.vocab_size, order=2, noise=0.1)

    for name in ["adamw", "coap-adamw", "8bit-coap-adamw"]:
        tx = make_optimizer(OptimizerConfig(
            name=name, learning_rate=3e-3, rank=16, t_update=10, lam=4,
            min_dim=16,
        ))
        state = tx.init(params)
        mem = optimizer_state_bytes(state)

        @jax.jit
        def step(p, s, batch):
            (loss, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
            upd, s = tx.update(g, s, p)
            return apply_updates(p, upd), s, loss

        p = params
        for i in range(30):
            p, state, loss = step(p, state, data.batch(i, 8, 64))
        print(f"{name:18s} optimizer_state={mem.total_bytes/1e6:7.2f} MB "
              f"loss@30={float(loss):.3f}")
    print(f"(irreducible CE floor: {data.ce_floor():.3f})")


if __name__ == "__main__":
    main()
