"""End-to-end driver: pre-train a ~100M-param LLaMA-family model with COAP
for a few hundred steps on the synthetic-Markov corpus, with checkpointing,
fault tolerance, and CEU/PPL metrics (the paper's Table-5 setup, CPU-sized).

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs.llama_1b import CONFIG as LLAMA_1B
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.optim import warmup_cosine_schedule
from repro.train.loop import TrainLoop, TrainLoopConfig
from repro.train.metrics import ppl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="coap-adamw")
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--stacked-state", action="store_true",
                    help="store optimizer state pre-stacked per bucket "
                         "(core/stacked_state.py; checkpoints stay "
                         "restorable into either layout)")
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    # ~100M-param llama-family config (vocab trimmed for byte-level data)
    cfg = dataclasses.replace(
        LLAMA_1B, n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=max(4, args.d_model // 64),
        d_ff=int(args.d_model * 8 / 3) // 64 * 64, vocab_size=256,
        head_dim=64, dtype=jnp.float32, remat=False,
    )
    model = build_model(cfg)
    n_params = cfg.n_params()
    print(f"model: {n_params/1e6:.1f}M params, optimizer {args.optimizer} "
          f"rank {args.rank} (paper recipe T_u=40 λ=5)")

    data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.05)
    tx = make_optimizer(OptimizerConfig(
        name=args.optimizer,
        learning_rate=warmup_cosine_schedule(8e-3, 20, args.steps),
        rank=args.rank, t_update=40, lam=5, min_dim=64, grad_clip=None,
        stacked_state=args.stacked_state,
    ))
    loop = TrainLoop(
        model, tx,
        lambda step, host: data.batch(step, args.batch, args.seq, host),
        TrainLoopConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
            metrics_path="artifacts/train_lm_metrics.jsonl", log_every=20,
        ),
    )
    state = loop.run()
    last = loop.logger.history[-1]
    print(f"final: step={int(state.step)} loss={last['loss']:.4f} "
          f"ppl={ppl(last['loss']):.2f} (floor ppl≈{ppl(data.ce_floor()):.2f})")


if __name__ == "__main__":
    main()
