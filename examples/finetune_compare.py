"""Optimizer shoot-out (paper Fig 3 / Table 6 style): fine-tune the same
pre-trained checkpoint with AdamW / COAP / GaLore / Flora / 8-bit COAP and
report eval CE, CEU, optimizer memory, and wall-clock.

  PYTHONPATH=src python examples/finetune_compare.py [--steps 120]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.accounting import optimizer_state_bytes
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.optim import apply_updates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke("llama-1b"), dtype=jnp.float32)
    model = build_model(cfg)
    data = SyntheticLM(vocab=cfg.vocab_size, order=2, noise=0.1)

    # "pre-train" briefly to get a common starting checkpoint
    base = model.init(jax.random.key(0))
    tx0 = make_optimizer(OptimizerConfig(name="adamw", learning_rate=3e-3))
    s0 = tx0.init(base)

    @jax.jit
    def pre_step(p, s, b):
        (_, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        u, s = tx0.update(g, s, p)
        return apply_updates(p, u), s

    for i in range(40):
        base, s0 = pre_step(base, s0, data.batch(i, 8, 64))

    print(f"{'optimizer':20s} {'opt MB':>8s} {'eval CE':>8s} {'CEU':>10s} "
          f"{'steps/s':>8s}")
    for name in ["adamw", "coap-adamw", "galore-adamw", "flora-adamw",
                 "8bit-coap-adamw"]:
        tx = make_optimizer(OptimizerConfig(
            name=name, learning_rate=1e-3, rank=16, t_update=10, lam=4,
            min_dim=32,
        ))
        params, state = base, tx.init(base)
        mem = optimizer_state_bytes(state).total_bytes / 1e6

        @jax.jit
        def step(p, s, b):
            (loss, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
            u, s = tx.update(g, s, p)
            ceu = sum(jnp.sum(jnp.abs(x)) for x in jax.tree_util.tree_leaves(u))
            return apply_updates(p, u), s, loss, ceu

        ceu_total, t0 = 0.0, time.perf_counter()
        for i in range(args.steps):
            params, state, loss, ceu = step(params, state,
                                            data.batch(1000 + i, 8, 64))
            ceu_total += float(ceu)
        dt = time.perf_counter() - t0
        ces = []
        for i in range(5):
            _, m = jax.jit(model.loss)(params, data.batch(90_000 + i, 8, 64))
            ces.append(float(m["ce"]))
        print(f"{name:20s} {mem:8.2f} {sum(ces)/5:8.4f} {ceu_total:10.1f} "
              f"{args.steps/dt:8.1f}")
    print(f"(ce floor {data.ce_floor():.4f})")


if __name__ == "__main__":
    main()
