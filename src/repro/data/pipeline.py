"""Host-sharded, double-buffered data pipeline.

Each host produces only its shard of the global batch (indexed by
``host_index``/``host_count`` — on a real multi-host pod these come from
``jax.process_index()``); a background thread prefetches the next batch while
the current step runs (compute/IO overlap). Batches are pure functions of
(step, host), so a restart at step N replays the identical stream — the
property checkpoint/restart tests assert.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp


class DataPipeline:
    def __init__(
        self,
        batch_fn: Callable[[int, int], Dict],  # (step, host) -> batch shard
        start_step: int = 0,
        host_index: Optional[int] = None,
        host_count: Optional[int] = None,
        prefetch: int = 2,
    ):
        self.batch_fn = batch_fn
        self.host_index = (
            host_index if host_index is not None else jax.process_index()
        )
        self.host_count = (
            host_count if host_count is not None else jax.process_count()
        )
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.batch_fn(step, self.host_index)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
