"""Byte-level tokenizer (vocab 256 + specials) for real-text examples."""
from __future__ import annotations

from typing import List

BOS, EOS, PAD = 256, 257, 258
VOCAB_SIZE = 259


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    bos, eos, pad = BOS, EOS, PAD

    def encode(self, text: str, add_bos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8", errors="replace"))
        return ([BOS] if add_bos else []) + ids

    def decode(self, ids) -> str:
        data = bytes(i for i in ids if 0 <= int(i) < 256)
        return data.decode("utf-8", errors="replace")
