"""Data substrate: deterministic synthetic LM streams, byte tokenizer, and
a host-sharded double-buffered pipeline (restart-exact: batch(step, host) is
a pure function, so fault-tolerant resumes replay identically)."""
from repro.data.synthetic import SyntheticLM, synthetic_batch
from repro.data.tokenizer import ByteTokenizer
from repro.data.pipeline import DataPipeline

__all__ = ["SyntheticLM", "synthetic_batch", "ByteTokenizer", "DataPipeline"]
