"""Deterministic synthetic LM data.

Two generators:
  * ``synthetic_batch`` — hash-based uniform tokens (throughput/dry-run use).
  * ``SyntheticLM``     — a learnable-order Markov stream: each next token is
    a fixed random function of the previous k tokens plus noise. Cross-entropy
    has a known floor, so convergence benchmarks (paper Tables 5/7, Fig 3)
    measure *learning*, not memorized noise.

Everything is a pure function of (step, host_index, seed) — restart-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch(step: int, batch: int, seq: int, vocab: int,
                    seed: int = 0, host: int = 0) -> Dict[str, jnp.ndarray]:
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(seed), step), host
    )
    tokens = jax.random.randint(key, (batch, seq + 1), 0, vocab, jnp.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


@dataclasses.dataclass
class SyntheticLM:
    """Order-k Markov source with additive noise.

    next = (W[t-1] + 31·t[t-2] + ... ) mod vocab   with prob (1-noise)
    next ~ Uniform(vocab)                          with prob noise

    The irreducible CE is ≈ noise·log(V) + H(noise); a model that learns the
    table reaches it, a model that doesn't sits at log(V).
    """

    vocab: int = 256
    order: int = 2
    noise: float = 0.1
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.table = rng.integers(0, self.vocab, size=(self.order, self.vocab))

    def batch(self, step: int, batch: int, seq: int, host: int = 0):
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + host
        )
        toks = np.zeros((batch, seq + 1), np.int64)
        toks[:, : self.order] = rng.integers(0, self.vocab,
                                             size=(batch, self.order))
        for t in range(self.order, seq + 1):
            det = np.zeros(batch, np.int64)
            for k in range(self.order):
                det += self.table[k][toks[:, t - 1 - k]]
            det %= self.vocab
            rand = rng.integers(0, self.vocab, size=batch)
            use_rand = rng.random(batch) < self.noise
            toks[:, t] = np.where(use_rand, rand, det)
        toks = jnp.asarray(toks, jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def ce_floor(self) -> float:
        """Irreducible cross-entropy in nats."""
        p_correct = (1.0 - self.noise) + self.noise / self.vocab
        h = -(p_correct * np.log(p_correct)
              + (self.vocab - 1) * (self.noise / self.vocab)
              * np.log(self.noise / self.vocab))
        return float(h)
