"""repro: production-grade JAX framework reproducing COAP
(Correlation-Aware Gradient Projection, Xiao et al. 2024) with multi-pod
distribution, a 10-architecture model zoo, and Pallas TPU kernels."""

__version__ = "1.0.0"
