"""GradientTransformation protocol and generic combinators.

Mirrors the optax design: a transformation is an (init, update) pair over
pytrees. ``update(grads, state, params) -> (updates, new_state)``; the caller
applies ``params + updates``. All state is an explicit pytree so it can be
sharded with pjit, checkpointed, and byte-accounted.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

OptState = Any
Params = Any
Updates = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]
ScalarOrSchedule = Union[float, Schedule]


class GradientTransformation(NamedTuple):
    """An (init, update) pair, optax-style."""

    init: Callable[[Params], OptState]
    update: Callable[[Updates, OptState, Optional[Params]], tuple]


class EmptyState(NamedTuple):
    pass


def identity() -> GradientTransformation:
    def init_fn(params):
        del params
        return EmptyState()

    def update_fn(updates, state, params=None):
        del params
        return updates, state

    return GradientTransformation(init_fn, update_fn)


class ChainState(NamedTuple):
    states: tuple


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transformations left-to-right (optax.chain semantics)."""

    def init_fn(params):
        return ChainState(tuple(t.init(params) for t in transforms))

    def update_fn(updates, state, params=None):
        new_states = []
        for t, s in zip(transforms, state.states):
            updates, new_s = t.update(updates, s, params)
            new_states.append(new_s)
        return updates, ChainState(tuple(new_states))

    return GradientTransformation(init_fn, update_fn)


def apply_updates(params: Params, updates: Updates) -> Params:
    """``params + updates`` leafwise, preserving param dtypes."""
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params,
        updates,
        is_leaf=lambda x: x is None,
    )


def tree_zeros_like(params: Params, dtype=None) -> Params:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params
    )


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.asarray(0.0)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


class ClipState(NamedTuple):
    pass


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init_fn(params):
        del params
        return ClipState()

    def update_fn(updates, state, params=None):
        del params
        g_norm = global_norm(updates)
        scale_factor = jnp.minimum(1.0, max_norm / (g_norm + 1e-16))
        updates = jax.tree_util.tree_map(
            lambda u: (u.astype(jnp.float32) * scale_factor).astype(u.dtype), updates
        )
        return updates, state

    return GradientTransformation(init_fn, update_fn)


class AddWeightDecayState(NamedTuple):
    pass


def add_decayed_weights(
    weight_decay: float, mask: Optional[Callable[[Params], Any]] = None
) -> GradientTransformation:
    """Adds ``weight_decay * param`` to updates (decoupled weight decay)."""

    def init_fn(params):
        del params
        return AddWeightDecayState()

    def update_fn(updates, state, params=None):
        if params is None:
            raise ValueError("add_decayed_weights requires params")
        if mask is not None:
            m = mask(params)
            updates = jax.tree_util.tree_map(
                lambda u, p, mi: u + weight_decay * p.astype(u.dtype) if mi else u,
                updates,
                params,
                m,
            )
        else:
            updates = jax.tree_util.tree_map(
                lambda u, p: u + weight_decay * p.astype(u.dtype), updates, params
            )
        return updates, state

    return GradientTransformation(init_fn, update_fn)


class ScaleState(NamedTuple):
    pass


def scale(step_size: float) -> GradientTransformation:
    def init_fn(params):
        del params
        return ScaleState()

    def update_fn(updates, state, params=None):
        del params
        updates = jax.tree_util.tree_map(lambda u: u * step_size, updates)
        return updates, state

    return GradientTransformation(init_fn, update_fn)


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray


def scale_by_schedule(schedule: Schedule) -> GradientTransformation:
    def init_fn(params):
        del params
        return ScaleByScheduleState(count=jnp.zeros([], jnp.int32))

    def update_fn(updates, state, params=None):
        del params
        step_size = schedule(state.count)
        updates = jax.tree_util.tree_map(lambda u: u * step_size, updates)
        return updates, ScaleByScheduleState(count=state.count + 1)

    return GradientTransformation(init_fn, update_fn)


def scale_by_learning_rate(
    learning_rate: ScalarOrSchedule, *, flip_sign: bool = True
) -> GradientTransformation:
    sign = -1.0 if flip_sign else 1.0
    if callable(learning_rate):
        return scale_by_schedule(lambda c: sign * learning_rate(c))
    return scale(sign * learning_rate)
