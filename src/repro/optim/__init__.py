"""Optimizer substrate: an optax-like GradientTransformation library.

Built in-repo (no optax dependency) so the COAP projection machinery in
``repro.core`` can integrate with Adam/AdamW/Adafactor as first-class
transformations, and so optimizer state pytrees are fully visible to the
checkpointing / sharding / memory-accounting layers.
"""
from repro.optim.transform import (
    GradientTransformation,
    OptState,
    chain,
    identity,
    apply_updates,
    clip_by_global_norm,
    add_decayed_weights,
    scale,
    scale_by_schedule,
    tree_zeros_like,
)
from repro.optim.adamw import adam, adamw, scale_by_adam
from repro.optim.adafactor import adafactor, scale_by_adafactor
from repro.optim.sgd import sgd, momentum
from repro.optim.schedules import (
    constant_schedule,
    cosine_decay_schedule,
    warmup_cosine_schedule,
    linear_schedule,
)

__all__ = [
    "GradientTransformation",
    "OptState",
    "chain",
    "identity",
    "apply_updates",
    "clip_by_global_norm",
    "add_decayed_weights",
    "scale",
    "scale_by_schedule",
    "tree_zeros_like",
    "adam",
    "adamw",
    "scale_by_adam",
    "adafactor",
    "scale_by_adafactor",
    "sgd",
    "momentum",
    "constant_schedule",
    "cosine_decay_schedule",
    "warmup_cosine_schedule",
    "linear_schedule",
]
