"""Adafactor (Shazeer & Stern 2018) as a GradientTransformation.

Implements the factored second moment of paper Eqn 3: for a 2-D weight the
``mn`` second-moment matrix is replaced by row/col accumulators ``R (m,1)``
and ``C (1,n)`` with ``V_hat = (R C) / mean(R)``. 1-D (and scalar) params fall
back to an unfactored second moment. Matches the paper's Adafactor baseline
(β2 schedule ``1 - t^{-decay}``; no first moment by default).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.transform import (
    GradientTransformation,
    add_decayed_weights,
    chain,
    scale_by_learning_rate,
)


class FactoredState(NamedTuple):
    count: jnp.ndarray
    row: Any  # pytree: (m,) per 2-D leaf, None-sentinel zeros for 1-D
    col: Any
    nu: Any  # unfactored fallback for <2-D leaves
    mu: Any  # optional first moment (zeros-pytree if disabled)


def _decay_rate(count, decay: float):
    t = count.astype(jnp.float32) + 1.0
    return 1.0 - t ** (-decay)


def scale_by_adafactor(
    b2_decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    b1: Optional[float] = None,
    factored_dims: int = 2,
) -> GradientTransformation:
    """RMS-normalized factored second-moment scaling.

    Args:
      b2_decay: exponent of the ``1 - t^-decay`` beta2 schedule (paper's γ).
      b1: first-moment coefficient; ``None`` disables the first moment
        (classic Adafactor).
    """

    def _is_factored(p):
        return p.ndim >= factored_dims

    def init_fn(params):
        def row_init(p):
            if _is_factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        def col_init(p):
            if _is_factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        def nu_init(p):
            if _is_factored(p):
                return jnp.zeros((1,), jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def mu_init(p):
            if b1 is None:
                return jnp.zeros((1,), jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        return FactoredState(
            count=jnp.zeros([], jnp.int32),
            row=jax.tree_util.tree_map(row_init, params),
            col=jax.tree_util.tree_map(col_init, params),
            nu=jax.tree_util.tree_map(nu_init, params),
            mu=jax.tree_util.tree_map(mu_init, params),
        )

    def update_fn(updates, state, params=None):
        del params
        count = state.count + 1
        b2 = _decay_rate(state.count, b2_decay)

        def upd(g, r, c, v, m):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _is_factored(g):
                new_r = b2 * r + (1.0 - b2) * jnp.sum(g2, axis=-1)
                new_c = b2 * c + (1.0 - b2) * jnp.sum(g2, axis=-2)
                # V_hat = RC / mean(R)   (paper Eqn 3 rearranged)
                mean_r = jnp.mean(new_r, axis=-1, keepdims=True)
                vhat = (
                    new_r[..., :, None] * new_c[..., None, :] / (mean_r[..., None] + eps)
                )
                new_v = v
            else:
                new_v = b2 * v + (1.0 - b2) * g2
                vhat = new_v
                new_r, new_c = r, c
            u = g32 / jnp.sqrt(vhat + eps)
            # Update clipping (Adafactor sec. 6): divide by max(1, RMS(u)/d).
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if b1 is not None:
                new_m = b1 * m + (1.0 - b1) * u
                u = new_m
            else:
                new_m = m
            return u.astype(g.dtype), new_r, new_c, new_v, new_m

        flat_u, treedef = jax.tree_util.tree_flatten(updates)
        flat_r = treedef.flatten_up_to(state.row)
        flat_c = treedef.flatten_up_to(state.col)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_m = treedef.flatten_up_to(state.mu)
        outs = [upd(*args) for args in zip(flat_u, flat_r, flat_c, flat_v, flat_m)]
        new_updates = treedef.unflatten([o[0] for o in outs])
        new_state = FactoredState(
            count=count,
            row=treedef.unflatten([o[1] for o in outs]),
            col=treedef.unflatten([o[2] for o in outs]),
            nu=treedef.unflatten([o[3] for o in outs]),
            mu=treedef.unflatten([o[4] for o in outs]),
        )
        return new_updates, new_state

    return GradientTransformation(init_fn, update_fn)


def adafactor(
    learning_rate,
    b2_decay: float = 0.8,
    b1: Optional[float] = None,
    weight_decay: float = 0.0,
    clip_threshold: float = 1.0,
) -> GradientTransformation:
    txs = [scale_by_adafactor(b2_decay=b2_decay, b1=b1, clip_threshold=clip_threshold)]
    if weight_decay:
        txs.append(add_decayed_weights(weight_decay))
    txs.append(scale_by_learning_rate(learning_rate))
    return chain(*txs)
