"""SGD / momentum as GradientTransformations."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.transform import (
    GradientTransformation,
    chain,
    scale_by_learning_rate,
    tree_zeros_like,
)


class MomentumState(NamedTuple):
    trace: Any


def momentum(decay: float = 0.9, nesterov: bool = False) -> GradientTransformation:
    def init_fn(params):
        return MomentumState(trace=tree_zeros_like(params))

    def update_fn(updates, state, params=None):
        del params
        trace = jax.tree_util.tree_map(
            lambda t, g: decay * t + g.astype(t.dtype), state.trace, updates
        )
        if nesterov:
            updates = jax.tree_util.tree_map(
                lambda t, g: decay * t + g.astype(t.dtype), trace, updates
            )
        else:
            updates = trace
        return updates, MomentumState(trace=trace)

    return GradientTransformation(init_fn, update_fn)


def sgd(learning_rate, momentum_decay: float = 0.0, nesterov: bool = False):
    if momentum_decay:
        return chain(
            momentum(momentum_decay, nesterov), scale_by_learning_rate(learning_rate)
        )
    return scale_by_learning_rate(learning_rate)
