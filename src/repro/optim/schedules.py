"""Learning-rate schedules (jnp-traceable: step index -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(value: float):
    def schedule(count):
        return jnp.asarray(value, jnp.float32)

    return schedule


def linear_schedule(init_value: float, end_value: float, transition_steps: int):
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / max(1, transition_steps), 0.0, 1.0)
        return init_value + frac * (end_value - init_value)

    return schedule


def cosine_decay_schedule(init_value: float, decay_steps: int, alpha: float = 0.0):
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / max(1, decay_steps), 0.0, 1.0)
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return init_value * ((1.0 - alpha) * cosine + alpha)

    return schedule


def warmup_cosine_schedule(
    peak_value: float,
    warmup_steps: int,
    decay_steps: int,
    end_value: float = 0.0,
    init_value: float = 0.0,
):
    """Linear warmup then cosine decay — the LLaMA-pretraining default."""

    def schedule(count):
        count = count.astype(jnp.float32)
        warm = init_value + (peak_value - init_value) * count / max(1, warmup_steps)
        frac = jnp.clip(
            (count - warmup_steps) / max(1, decay_steps - warmup_steps), 0.0, 1.0
        )
        cos = end_value + 0.5 * (peak_value - end_value) * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(count < warmup_steps, warm, cos)

    return schedule
