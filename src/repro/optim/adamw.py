"""Adam / AdamW as GradientTransformations (Eqn 2 of the paper)."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim.transform import (
    GradientTransformation,
    add_decayed_weights,
    chain,
    scale_by_learning_rate,
    tree_zeros_like,
)


class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def scale_by_adam(
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    mu_dtype=None,
) -> GradientTransformation:
    """Standard bias-corrected Adam moment scaling (paper Eqn 2)."""

    def init_fn(params):
        mu = tree_zeros_like(params, dtype=mu_dtype)
        nu = tree_zeros_like(params, dtype=mu_dtype)
        return ScaleByAdamState(count=jnp.zeros([], jnp.int32), mu=mu, nu=nu)

    def update_fn(updates, state, params=None):
        del params
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1.0 - b1) * g.astype(m.dtype), state.mu, updates
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1.0 - b2) * jnp.square(g.astype(v.dtype)),
            state.nu,
            updates,
        )
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        new_updates = jax.tree_util.tree_map(
            lambda m, v: (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu
        )
        return new_updates, ScaleByAdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init_fn, update_fn)


def adam(
    learning_rate,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    mu_dtype=None,
) -> GradientTransformation:
    return chain(
        scale_by_adam(b1=b1, b2=b2, eps=eps, mu_dtype=mu_dtype),
        scale_by_learning_rate(learning_rate),
    )


def adamw(
    learning_rate,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    mask=None,
    mu_dtype=None,
) -> GradientTransformation:
    return chain(
        scale_by_adam(b1=b1, b2=b2, eps=eps, mu_dtype=mu_dtype),
        add_decayed_weights(weight_decay, mask=mask),
        scale_by_learning_rate(learning_rate),
    )
