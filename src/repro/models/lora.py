"""LoRA baseline (paper Tables 2/5/6): low-rank *weight* adapters.

Model-agnostic functional form: for every selected 2-D (optionally stacked)
weight ``W0 (…, m, n)`` train adapters ``A (…, r, n)``, ``B (…, m, r)`` with

    W_eff = W0 + (alpha / r) · B @ A          (B zero-init ⇒ W_eff == W0)

``lora_merge`` produces the effective params for ANY zoo model, so the same
loss/serve code runs; gradients flow only into the adapter tree. This is
the comparison point the paper draws: LoRA constrains the *update* to rank
r (capacity loss — Tables 2/5 show +150 FID / +3.7 PPL at pre-training),
while COAP keeps full-rank updates and compresses only the optimizer state.
It also grows the *model* memory by the adapters (paper: +36–48%).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.projector import ProjectionRules, path_str


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 16.0
    # reuse the projection shape policy: 2-D-matrix leaves above min_dim
    min_dim: int = 128

    def rules(self) -> ProjectionRules:
        return ProjectionRules(rank=self.rank, min_dim=self.min_dim,
                               project_conv=False)


def _adapted(cfg: LoRAConfig, path: str, leaf) -> bool:
    spec = cfg.rules().spec_for(path, leaf.shape)
    return spec.kind == "project"


def lora_init(key, params, cfg: LoRAConfig):
    """Adapter tree congruent with params: {A,B} dicts per adapted leaf,
    None elsewhere. A ~ N(0, 1/r), B = 0 (standard LoRA init)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = []
    for idx, (kp, leaf) in enumerate(flat):
        if _adapted(cfg, path_str(kp), leaf):
            lead = leaf.shape[:-2]
            m, n = leaf.shape[-2], leaf.shape[-1]
            r = min(cfg.rank, m, n)
            a = jax.random.normal(
                jax.random.fold_in(key, idx), lead + (r, n), jnp.float32
            ) / jnp.sqrt(r)
            b = jnp.zeros(lead + (m, r), jnp.float32)
            leaves.append({"A": a, "B": b})
        else:
            leaves.append(None)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def lora_merge(params, adapters, cfg: LoRAConfig):
    """W_eff = W0 + (alpha/r)·B@A leafwise (broadcasts over stack axes)."""
    def merge(p, ad):
        if ad is None:
            return p
        scale = cfg.alpha / ad["A"].shape[-2]
        delta = scale * jnp.einsum("...mr,...rn->...mn", ad["B"], ad["A"])
        return (p.astype(jnp.float32) + delta).astype(p.dtype)

    return jax.tree_util.tree_map(
        merge, params, adapters, is_leaf=lambda x: x is None or (
            isinstance(x, dict) and set(x) == {"A", "B"})
    )


def make_lora_loss(model, frozen_params, cfg: LoRAConfig) -> Callable:
    """loss(adapters, batch) — gradients flow only into the adapter tree."""

    def loss_fn(adapters, batch):
        merged = lora_merge(frozen_params, adapters, cfg)
        return model.loss(merged, batch)

    return loss_fn


def adapter_bytes(adapters) -> int:
    return sum(x.nbytes for x in jax.tree_util.tree_leaves(adapters))
