"""Attention: GQA (w/ RoPE, M-RoPE, sliding-window, logit softcap), MLA,
cross-attention, and dense/rolling KV caches for decode.

Weights stay 2-D ((d_model, H*hd) etc.) so the COAP projector treats them
exactly like the paper's per-layer matrices; head structure is a reshape at
apply time. Caches are explicit pytrees threaded through serve steps.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import ParamDef

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Param defs
# ---------------------------------------------------------------------------
def gqa_defs(d_model: int, n_heads: int, n_kv: int, head_dim: int,
             qkv_bias: bool = False):
    defs = {
        "wq": ParamDef((d_model, n_heads * head_dim), "fan_in", ("embed", "heads")),
        "wk": ParamDef((d_model, n_kv * head_dim), "fan_in", ("embed", "heads")),
        "wv": ParamDef((d_model, n_kv * head_dim), "fan_in", ("embed", "heads")),
        "wo": ParamDef((n_heads * head_dim, d_model), "fan_in", ("heads", "embed")),
    }
    if qkv_bias:
        defs["wq_bias"] = ParamDef((n_heads * head_dim,), "zeros", ("heads",))
        defs["wk_bias"] = ParamDef((n_kv * head_dim,), "zeros", ("heads",))
        defs["wv_bias"] = ParamDef((n_kv * head_dim,), "zeros", ("heads",))
    return defs


def mla_defs(d_model: int, n_heads: int, q_lora: int, kv_lora: int,
             qk_nope: int, qk_rope: int, v_head: int):
    """DeepSeek/MiniCPM3-style Multi-head Latent Attention. The KV path is
    compressed to ``kv_lora + qk_rope`` per token — that compressed latent IS
    the cache."""
    return {
        "wq_a": ParamDef((d_model, q_lora), "fan_in", ("embed", "lora")),
        "q_a_norm": L.rmsnorm_def(q_lora),
        "wq_b": ParamDef((q_lora, n_heads * (qk_nope + qk_rope)), "fan_in",
                         ("lora", "heads")),
        "wkv_a": ParamDef((d_model, kv_lora + qk_rope), "fan_in", ("embed", "lora")),
        "kv_a_norm": L.rmsnorm_def(kv_lora),
        "wkv_b": ParamDef((kv_lora, n_heads * (qk_nope + v_head)), "fan_in",
                          ("lora", "heads")),
        "wo": ParamDef((n_heads * v_head, d_model), "fan_in", ("heads", "embed")),
    }


# ---------------------------------------------------------------------------
# Masks & core attention
# ---------------------------------------------------------------------------
def _causal_mask(q_len, kv_len, q_offset, window: Optional[int] = None):
    """(q_len, kv_len) boolean keep-mask. q_offset = absolute position of
    query 0 (for decode). window = sliding-window size (None = full)."""
    q_pos = q_offset + jnp.arange(q_len)[:, None]
    k_pos = jnp.arange(kv_len)[None, :]
    keep = k_pos <= q_pos
    if window is not None:
        keep = keep & (k_pos > q_pos - window)
    return keep


def _attend_chunked(q, k, v, *, q_offset, window, softcap, scale,
                    q_chunk=512, kv_chunk=1024, causal=True):
    """Flash-style memory-efficient attention (Rabe & Staats / FlashAttention
    schedule in pure JAX): lax.scan over query blocks x online-softmax scan
    over KV blocks. The (T, S) score matrix never materializes in HBM — per
    step only a (q_chunk, kv_chunk) tile is live. This is the §Perf fix for
    the memory-bound train/prefill cells (EXPERIMENTS.md); on real TPU the
    same schedule becomes a Pallas kernel, here XLA fuses the tile ops.

    q: (B,T,H,hd); k/v: (B,S,K,hd). Returns (B,T,H,hd) like _attend.
    """
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    group = h // kh
    q_chunk = min(q_chunk, t)
    kv_chunk = min(kv_chunk, s)
    tp = (-t) % q_chunk
    sp = (-s) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, tp), (0, 0), (0, 0))) if tp else q
    kp = jnp.pad(k, ((0, 0), (0, sp), (0, 0), (0, 0))) if sp else k
    vp = jnp.pad(v, ((0, 0), (0, sp), (0, 0), (0, 0))) if sp else v
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    qb = qp.reshape(b, nq, q_chunk, kh, group, hd)
    kb = kp.reshape(b, nk, kv_chunk, kh, hd)
    vb = vp.reshape(b, nk, kv_chunk, kh, hd)

    q_pos_base = q_offset + jnp.arange(q_chunk)
    k_pos_base = jnp.arange(kv_chunk)

    def one_q_block(qi, q_blk):
        q_pos = q_pos_base + qi * q_chunk  # (qc,)

        def kv_step(carry, inp):
            m_prev, l_prev, acc = carry
            ki, k_blk, v_blk = inp
            k_pos = k_pos_base + ki * kv_chunk
            logits = jnp.einsum(
                "bqkgh,bskh->bkgqs", q_blk, k_blk,
                preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                logits = softcap * jnp.tanh(logits / softcap)
            keep = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                keep &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                keep &= k_pos[None, :] > q_pos[:, None] - window
            keep &= (k_pos < s)[None, :]
            logits = jnp.where(keep[None, None, None, :, :], logits, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kh, group, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, group, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, group, q_chunk, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)),
        )
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # (b, qc, kh, group, hd)

    outs = jax.lax.map(
        lambda args: one_q_block(*args),
        (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)),
    )  # (nq, b, qc, kh, group, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, kh, group, hd)
    return out[:, :t].reshape(b, t, h, hd).astype(v.dtype)


def _flash_or_tagged(q, k, v, scale, window, softcap):
    """attn_impl='flash': the Pallas flash kernel on TPU (or under
    REPRO_PALLAS=interpret); elsewhere the naive math inside a
    PALLAS_FLASH_REGION named_scope, which the roofline analyzer accounts at
    kernel boundaries (the validated-kernel's true HBM traffic)."""
    import os

    backend = jax.default_backend()
    if backend == "tpu" or os.environ.get("REPRO_PALLAS") == "interpret":
        from repro.kernels.flash_attention import attend_flash

        return attend_flash(q, k, v, scale=scale, window=window,
                            softcap=softcap, interpret=backend != "tpu")
    with jax.named_scope("PALLAS_FLASH_REGION"):
        t = q.shape[1]
        mask = _causal_mask(t, k.shape[1], 0, window)
        return _attend(q, k, v, mask, softcap, scale)


def _attend(q, k, v, mask, softcap: Optional[float], scale: float):
    """q: (B,T,H,hd) k/v: (B,S,K,hd[v]) grouped; mask: (T,S) or (B,T,S)."""
    b, t, h, hd = q.shape
    s, kheads = k.shape[1], k.shape[2]
    group = h // kheads
    qg = q.reshape(b, t, kheads, group, hd)
    # bf16 operands + fp32 accumulation (MXU-native); upcasting the INPUTS
    # instead forces every upstream tensor (incl. saved scan residuals) to
    # fp32 via XLA's reduce_precision folding — measured 2x HBM waste.
    logits = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask.ndim == 2:
        mask_b = mask[None, None, None, :, :]
    else:
        mask_b = mask[:, None, None, :, :]
    logits = jnp.where(mask_b, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", probs.astype(v.dtype), v)
    return out.reshape(b, t, h, -1)


# ---------------------------------------------------------------------------
# GQA forward (train/prefill and cached decode)
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: Any  # (B, S_max, K, hd) — rolling buffer when window is set
    v: Any
    length: Any  # scalar int32: tokens already in cache


def gqa_init_cache(batch, max_len, n_kv, head_dim, dtype, window=None):
    size = min(max_len, window) if window else max_len
    return KVCache(
        k=jnp.zeros((batch, size, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, size, n_kv, head_dim), dtype),
        length=jnp.zeros([], jnp.int32),
    )


def gqa_apply(params, x, positions, *, n_heads, n_kv, head_dim,
              rope_theta=1e4, window=None, softcap=None, mrope_sections=None,
              cache: Optional[KVCache] = None, qkv_bias=False,
              attn_impl: str = "naive"):
    """Returns (out, new_cache). cache=None ⇒ train/prefill over full x."""
    b, t, _ = x.shape
    q = L.linear_apply(params, x, "wq").reshape(b, t, n_heads, head_dim)
    k = L.linear_apply(params, x, "wk").reshape(b, t, n_kv, head_dim)
    v = L.linear_apply(params, x, "wv").reshape(b, t, n_kv, head_dim)

    if mrope_sections is not None:
        q = L.apply_mrope(q, positions, rope_theta, mrope_sections)
        k = L.apply_mrope(k, positions, rope_theta, mrope_sections)
    else:
        q = L.apply_rope(q, positions, rope_theta)
        k = L.apply_rope(k, positions, rope_theta)

    scale = 1.0 / (head_dim**0.5)
    if cache is None:
        if attn_impl == "flash" and t >= 512:
            out = _flash_or_tagged(q, k, v, scale, window, softcap)
        elif attn_impl == "chunked" and t >= 512:
            out = _attend_chunked(q, k, v, q_offset=0, window=window,
                                  softcap=softcap, scale=scale)
        else:
            mask = _causal_mask(t, t, 0, window)
            out = _attend(q, k, v, mask, softcap, scale)
        new_cache = None
    else:
        # Decode: append t (usually 1) tokens at cache.length, attend over
        # the buffer. With a sliding window the buffer is rolling (mod size).
        size = cache.k.shape[1]
        if window:
            # Rolling ring buffer. When t >= size only the last `size` tokens
            # can remain: write exactly those (unique slots). NOTE: ring
            # prefill attention is exact only for queries whose full window
            # survives — the serve engine prefills in ≤window chunks.
            if t >= size:
                k_w, v_w = k[:, -size:], v[:, -size:]
                start = cache.length + t - size
                idx = (start + jnp.arange(size)) % size
            else:
                k_w, v_w = k, v
                idx = (cache.length + jnp.arange(t)) % size
            new_k = cache.k.at[:, idx].set(k_w.astype(cache.k.dtype))
            new_v = cache.v.at[:, idx].set(v_w.astype(cache.v.dtype))
            # per-query keep mask over ring slots
            slot_pos = _ring_positions(cache.length + t, size)  # (size,)
            q_pos = cache.length + jnp.arange(t)  # (t,)
            mask = (
                (slot_pos[None, :] >= 0)
                & (slot_pos[None, :] <= q_pos[:, None])
                & (slot_pos[None, :] > q_pos[:, None] - window)
            )  # (t, size)
        else:
            new_k = _dyn_append(cache.k, k, cache.length)
            new_v = _dyn_append(cache.v, v, cache.length)
            kv_pos = jnp.arange(size)
            q_pos = cache.length + jnp.arange(t)
            mask = kv_pos[None, :] <= q_pos[:, None]  # (T, S)
        out = _attend(q, new_k, new_v, mask, softcap, scale)
        new_cache = KVCache(k=new_k, v=new_v, length=cache.length + t)
    out = out.reshape(b, t, n_heads * head_dim)
    return L.linear_apply({"w": params["wo"]}, out, "w"), new_cache


def _dyn_append(buf, new, start):
    """Write ``new`` (B,t,...) into ``buf`` (B,S,...) at row ``start``."""
    return jax.lax.dynamic_update_slice(
        buf, new.astype(buf.dtype), (0, start, 0, 0)
    )


def _ring_positions(length, size):
    """Absolute position stored in each ring slot (-1 if empty).

    Slot s holds absolute position p where p ≡ s (mod size) and p is the
    largest such p < length.
    """
    s = jnp.arange(size)
    full_cycles = (length - 1 - s) // size
    pos = s + full_cycles * size
    return jnp.where(length > 0, jnp.where(pos >= 0, pos, -1), -1)


# ---------------------------------------------------------------------------
# MLA forward
# ---------------------------------------------------------------------------
class MLACache(NamedTuple):
    c_kv: Any  # (B, S, kv_lora) compressed latents
    k_rope: Any  # (B, S, qk_rope)
    length: Any


def mla_init_cache(batch, max_len, kv_lora, qk_rope, dtype):
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, kv_lora), dtype),
        k_rope=jnp.zeros((batch, max_len, qk_rope), dtype),
        length=jnp.zeros([], jnp.int32),
    )


def mla_absorbed_decode(params, x, positions, cache: MLACache, *, n_heads,
                        q_lora, kv_lora, qk_nope, qk_rope, v_head,
                        rope_theta=1e4):
    """Absorbed-matmul MLA decode (DeepSeek-V2 trick; §Perf hillclimb).

    The naive decode expands k/v = c_kv @ W_kv_b over ALL cached positions
    every step — O(S·H·(nope+v)·r) FLOPs and a (B,S,H,·) intermediate that
    dominated the minicpm3 decode_32k roofline. Absorbing W_uk into the
    query (q_lat = q_nope·W_ukᵀ) lets attention run directly in the
    compressed latent space: scores O(S·H·r), context O(S·H·r), and W_uv is
    applied once to the (B,1,H,r) context. Exact same math (verified in
    tests/test_models_attention.py::test_mla_absorbed_matches_naive).
    """
    b, t, _ = x.shape
    q_a = L.rmsnorm(x @ params["wq_a"].astype(x.dtype), params["q_a_norm"])
    q = (q_a @ params["wq_b"].astype(x.dtype)).reshape(
        b, t, n_heads, qk_nope + qk_rope
    )
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]
    q_pe = L.apply_rope(q_pe, positions, rope_theta)

    kv_a = x @ params["wkv_a"].astype(x.dtype)
    c_kv_new = L.rmsnorm(kv_a[..., :kv_lora], params["kv_a_norm"])
    k_pe_new = L.apply_rope(kv_a[..., kv_lora:][:, :, None, :], positions,
                            rope_theta)[:, :, 0, :]
    c_kv_all = jax.lax.dynamic_update_slice(
        cache.c_kv, c_kv_new.astype(cache.c_kv.dtype), (0, cache.length, 0))
    k_pe_all = jax.lax.dynamic_update_slice(
        cache.k_rope, k_pe_new.astype(cache.k_rope.dtype),
        (0, cache.length, 0))
    new_cache = MLACache(c_kv_all, k_pe_all, cache.length + t)

    # W_kv_b (kv_lora, H*(nope+v)) -> W_uk (r,H,nope), W_uv (r,H,v)
    w_kv_b = params["wkv_b"].astype(x.dtype).reshape(
        kv_lora, n_heads, qk_nope + v_head)
    w_uk, w_uv = w_kv_b[..., :qk_nope], w_kv_b[..., qk_nope:]

    q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, w_uk)  # absorb W_uk
    s_lat = jnp.einsum("bthr,bsr->bhts", q_lat, c_kv_all)
    s_pe = jnp.einsum("bthp,bsp->bhts", q_pe, k_pe_all)
    scale = 1.0 / ((qk_nope + qk_rope) ** 0.5)
    logits = (s_lat + s_pe).astype(jnp.float32) * scale
    kv_pos = jnp.arange(c_kv_all.shape[1])
    q_pos = cache.length + jnp.arange(t)
    mask = kv_pos[None, :] <= q_pos[:, None]  # (t, S)
    logits = jnp.where(mask[None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhts,bsr->bthr", probs, c_kv_all)
    out = jnp.einsum("bthr,rhv->bthv", ctx, w_uv)  # absorb W_uv
    out = out.reshape(b, t, n_heads * v_head)
    return out @ params["wo"].astype(x.dtype), new_cache


def mla_apply(params, x, positions, *, n_heads, q_lora, kv_lora, qk_nope,
              qk_rope, v_head, rope_theta=1e4, cache: Optional[MLACache] = None,
              absorbed_decode: bool = False):
    if cache is not None and absorbed_decode:
        return mla_absorbed_decode(
            params, x, positions, cache, n_heads=n_heads, q_lora=q_lora,
            kv_lora=kv_lora, qk_nope=qk_nope, qk_rope=qk_rope, v_head=v_head,
            rope_theta=rope_theta,
        )
    b, t, _ = x.shape
    # Q path
    q_a = L.rmsnorm(x @ params["wq_a"].astype(x.dtype), params["q_a_norm"])
    q = (q_a @ params["wq_b"].astype(x.dtype)).reshape(
        b, t, n_heads, qk_nope + qk_rope
    )
    q_nope, q_pe = q[..., :qk_nope], q[..., qk_nope:]
    q_pe = L.apply_rope(q_pe, positions, rope_theta)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    # KV path: compress, cache the latent + rope key
    kv_a = x @ params["wkv_a"].astype(x.dtype)  # (B,T,kv_lora+qk_rope)
    c_kv = L.rmsnorm(kv_a[..., :kv_lora], params["kv_a_norm"])
    k_pe = L.apply_rope(kv_a[..., kv_lora:][:, :, None, :], positions,
                        rope_theta)[:, :, 0, :]

    if cache is not None:
        c_kv_all = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, cache.length, 0)
        )
        k_pe_all = jax.lax.dynamic_update_slice(
            cache.k_rope, k_pe.astype(cache.k_rope.dtype), (0, cache.length, 0)
        )
        s = c_kv_all.shape[1]
        kv_pos = jnp.arange(s)
        q_pos = cache.length + jnp.arange(t)
        mask = kv_pos[None, :] <= q_pos[:, None]
        new_cache = MLACache(c_kv_all, k_pe_all, cache.length + t)
    else:
        c_kv_all, k_pe_all = c_kv, k_pe
        mask = _causal_mask(t, t, 0)
        new_cache = None

    s = c_kv_all.shape[1]
    kv = (c_kv_all @ params["wkv_b"].astype(x.dtype)).reshape(
        b, s, n_heads, qk_nope + v_head
    )
    k_nope, v = kv[..., :qk_nope], kv[..., qk_nope:]
    k_pe_b = jnp.broadcast_to(k_pe_all[:, :, None, :], (b, s, n_heads, qk_rope))
    k_full = jnp.concatenate([k_nope, k_pe_b], axis=-1)

    scale = 1.0 / ((qk_nope + qk_rope) ** 0.5)
    out = _attend(q_full, k_full, v, mask, None, scale)
    out = out.reshape(b, t, n_heads * v_head)
    return out @ params["wo"].astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------
def cross_defs(d_model: int, n_heads: int, head_dim: int):
    return {
        "wq": ParamDef((d_model, n_heads * head_dim), "fan_in", ("embed", "heads")),
        "wk": ParamDef((d_model, n_heads * head_dim), "fan_in", ("embed", "heads")),
        "wv": ParamDef((d_model, n_heads * head_dim), "fan_in", ("embed", "heads")),
        "wo": ParamDef((n_heads * head_dim, d_model), "fan_in", ("heads", "embed")),
    }


def cross_apply(params, x, enc_out, *, n_heads, head_dim):
    b, t, _ = x.shape
    s = enc_out.shape[1]
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, t, n_heads, head_dim)
    k = (enc_out @ params["wk"].astype(x.dtype)).reshape(b, s, n_heads, head_dim)
    v = (enc_out @ params["wv"].astype(x.dtype)).reshape(b, s, n_heads, head_dim)
    mask = jnp.ones((t, s), bool)
    out = _attend(q, k, v, mask, None, 1.0 / (head_dim**0.5))
    return out.reshape(b, t, n_heads * head_dim) @ params["wo"].astype(x.dtype)
