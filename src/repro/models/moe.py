"""Top-k MoE with capacity-bounded scatter/gather dispatch (grok-1, mixtral).

Dispatch strategy matters enormously at scale: the classic one-hot einsum
dispatch (flaxformer-style ``einsum('td,tec->ecd')``) is O(T·E·C·D) compute
and materializes a (T, E, C) tensor — at train_4k's 1M global tokens that is
~200x the useful FLOPs and terabytes of temporaries (measured in our first
grok-1 dry-run; see EXPERIMENTS.md §Perf). We instead:

  1. route: top-k logits -> expert ids + gates              O(T·E)
  2. position-in-expert via cumsum over a (T·k, E) one-hot  O(T·k·E)
  3. scatter-add tokens into the (E·C [+1 overflow], D) buffer   O(T·k·D)
  4. dense per-expert FFN on (E, C, D)                      O(E·C·D·F)
  5. gather back + combine with gates                       O(T·k·D)

Over-capacity routings land in a dead overflow slot (token dropped — same
semantics as the einsum dispatch). Expert weights are (E, d_in, d_out):
the COAP projector treats E as a stack axis — one projection per expert
(DESIGN.md §7).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.models.layers import ParamDef, swiglu


def moe_defs(d_model: int, d_ff: int, n_experts: int):
    # 'moe_embed' (vs 'embed'): expert weights keep d_model REPLICATED over
    # 'data' — sharding it there makes every expert einsum contract a
    # sharded dim, i.e. a multi-GB all-reduce per layer per microbatch
    # (measured: 85% of grok-1's collective term; EXPERIMENTS.md §Perf).
    # Token capacity shards over 'data' instead (constraints in moe_apply).
    return {
        "router": ParamDef((d_model, n_experts), "fan_in", ("embed", None)),
        "gate": ParamDef((n_experts, d_model, d_ff), "fan_in",
                         ("experts", "moe_embed", "ffn")),
        "up": ParamDef((n_experts, d_model, d_ff), "fan_in",
                       ("experts", "moe_embed", "ffn")),
        "down": ParamDef((n_experts, d_ff, d_model), "fan_in",
                         ("experts", "ffn", "moe_embed")),
    }


EINSUM_DISPATCH_MAX_TOKENS = 4096  # decode-sized: one-hot einsum wins


def _moe_einsum_dispatch(params, tokens, gates, top_idx, *, n_experts,
                         top_k, capacity):
    """Classic one-hot einsum dispatch — O(T·E·C·D) but collective-friendly
    and cheap at decode-sized T (measured 3x better than scatter there)."""
    e = n_experts
    n_tok, d = tokens.shape
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (T,k,E)
    mask = jnp.max(onehot, axis=1)
    pos_in_expert = jnp.cumsum(mask, axis=0) * mask - 1.0
    keep = (pos_in_expert < capacity) & (mask > 0)
    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos_in_expert, -1).astype(jnp.int32), capacity,
        dtype=tokens.dtype,
    )  # (T,E,C)
    weights = jnp.einsum("tk,tke->te", gates.astype(jnp.float32), onehot)
    dispatch = pos_oh
    combine = weights[..., None].astype(tokens.dtype) * pos_oh
    expert_in = jnp.einsum("td,tec->ecd", tokens, dispatch)
    g = jnp.einsum("ecd,edf->ecf", expert_in, params["gate"].astype(tokens.dtype))
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["up"].astype(tokens.dtype))
    h = swiglu(g, u)
    expert_out = jnp.einsum("ecf,efd->ecd", h,
                            params["down"].astype(tokens.dtype))
    return jnp.einsum("ecd,tec->td", expert_out, combine)


def moe_apply(params, x, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, D). Returns (out, aux_loss)."""
    b, t, d = x.shape
    tokens = x.reshape(b * t, d)
    n_tok = b * t
    e = n_experts
    logits = tokens @ params["router"].astype(tokens.dtype)  # (T, E)

    top_vals, top_idx = jax.lax.top_k(logits, top_k)  # (T, k)
    gates = jax.nn.softmax(top_vals.astype(jnp.float32), axis=-1)  # (T, k)

    # Load-balancing auxiliary loss (Switch-style).
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot_tk = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # (T, k, E)
    density = jnp.mean(jnp.max(onehot_tk, axis=1), axis=0)
    aux_loss = e * jnp.sum(density * jnp.mean(probs, axis=0))

    capacity = max(1, int(capacity_factor * n_tok * top_k / e))
    capacity = min(capacity, n_tok)

    if n_tok <= EINSUM_DISPATCH_MAX_TOKENS:
        out = _moe_einsum_dispatch(params, tokens, gates, top_idx,
                                   n_experts=e, top_k=top_k,
                                   capacity=capacity)
        return out.reshape(b, t, d), aux_loss

    # Position of each (token, k) routing inside its expert's buffer:
    # cumulative count over the routing-major flattened sequence.
    oh_flat = onehot_tk.reshape(n_tok * top_k, e)  # (T·k, E)
    pos_all = jnp.cumsum(oh_flat, axis=0) - oh_flat  # count before me
    pos = jnp.sum(pos_all * oh_flat, axis=-1).reshape(n_tok, top_k)  # (T, k)
    expert_id = top_idx  # (T, k)
    keep = pos < capacity
    dead = e * capacity  # overflow slot for dropped routings
    dest = jnp.where(keep, expert_id * capacity + pos.astype(jnp.int32), dead)

    # Scatter tokens into expert buffers (k scatters of (T, D)).
    buf = jnp.zeros((e * capacity + 1, d), tokens.dtype)
    for kk in range(top_k):
        buf = buf.at[dest[:, kk]].add(tokens)
    expert_in = buf[: e * capacity].reshape(e, capacity, d)

    g = jnp.einsum("ecd,edf->ecf", expert_in, params["gate"].astype(tokens.dtype))
    u = jnp.einsum("ecd,edf->ecf", expert_in, params["up"].astype(tokens.dtype))
    h = swiglu(g, u)
    expert_out = jnp.einsum("ecf,efd->ecd", h,
                            params["down"].astype(tokens.dtype))
    out_flat = jnp.concatenate(
        [expert_out.reshape(e * capacity, d),
         jnp.zeros((1, d), expert_out.dtype)], axis=0
    )

    # Gather back and combine with gates.
    out = jnp.zeros_like(tokens)
    for kk in range(top_k):
        out = out + gates[:, kk, None].astype(tokens.dtype) * out_flat[dest[:, kk]]
    return out.reshape(b, t, d), aux_loss


def moe_apply_local_ep(params, x, *, n_experts: int, top_k: int,
                       capacity_factor: float = 1.25):
    """Local-expert dispatch via shard_map (§Perf: grok-1 hillclimb).

    The pjit-auto dispatch lets XLA pick the collective schedule for the
    token scatter/expert einsums; at 1M tokens it picks multi-GB activation
    all-reduces per layer (85% of grok-1's collective term) — and a naive
    capacity-over-'data' constraint is worse (full replication, measured
    3x). Production MoE systems instead keep dispatch LOCAL: shard_map over
    the batch axes, every shard routes its own tokens into its own capacity
    buffer (capacity enforced per shard — the standard per-device-capacity
    semantics), experts' weights replicated over 'data' ('moe_embed' rule)
    and TP-sharded over 'model' in the auto domain. Zero cross-'data'
    collectives in the forward; expert-grad psums are inserted by shard_map
    AD (replicated-input cotangents).
    """
    from repro.distributed import sharding as shd
    from jax.sharding import PartitionSpec as P

    mesh = shd.current_mesh()
    manual = tuple(a for a in ("pod", "data") if mesh is not None
                   and a in mesh.axis_names)
    b = x.shape[0]
    total = 1
    for a in manual:
        total *= mesh.shape[a]
    tokens_per_shard = (b // max(total, 1)) * x.shape[1]
    if (mesh is None or not manual or b % total != 0 or total == 1
            or tokens_per_shard < 1024):
        # decode-sized workloads: the dense dispatch is cheap and the auto
        # partitioner does better than a manual shard_map (measured 3-7x
        # regressions on decode_32k; EXPERIMENTS.md §Perf iteration log).
        return moe_apply(params, x, n_experts=n_experts, top_k=top_k,
                         capacity_factor=capacity_factor)

    def local_fn(p, x_l):
        out, aux = moe_apply(p, x_l, n_experts=n_experts, top_k=top_k,
                             capacity_factor=capacity_factor)
        for ax in manual:
            aux = jax.lax.pmean(aux, ax)
        return out, aux

    bspec = manual if len(manual) > 1 else manual[0]
    return compat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), P(bspec, None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False, axis_names=set(manual),
    )(params, x)
