"""Decoder stacks: scan-over-layers blocks for every assigned family.

One compiled layer body per architecture (lax.scan over stacked params, with
jax.checkpoint remat inside the scan) — this keeps dry-run compile time and
HLO size independent of depth (80-layer qwen2-vl compiles the same graph
size as 22-layer tinyllama).

Families:
  * dense / moe / vlm:  [RMSNorm → GQA|MLA → +res → RMSNorm → MLP|MoE → +res]
  * ssm (mamba2):       [RMSNorm → Mamba2 → +res]
  * hybrid (zamba2):    mamba2 layers with ONE shared transformer block
                        applied every ``attn_every`` layers (flag-driven
                        lax.cond inside the scan; the shared block's KV cache
                        is a (n_apps, ...) buffer indexed by a scan-carried
                        counter). Shared weights ⇒ one COAP projector.
  * audio (whisper):    bidirectional encoder over precomputed mel-frame
                        embeddings (stub frontend) + causal decoder with
                        cross-attention (enc K/V recomputed from enc_out).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as S
from repro.models import moe as E


# ---------------------------------------------------------------------------
# Per-layer defs
# ---------------------------------------------------------------------------
def attn_block_defs(cfg: ArchConfig):
    hd = cfg.resolved_head_dim
    defs = {"ln1": L.rmsnorm_def(cfg.d_model), "ln2": L.rmsnorm_def(cfg.d_model)}
    if cfg.mla:
        defs["attn"] = A.mla_defs(cfg.d_model, cfg.n_heads, cfg.q_lora_rank,
                                  cfg.kv_lora_rank, cfg.qk_nope_dim,
                                  cfg.qk_rope_dim, cfg.v_head_dim)
    else:
        defs["attn"] = A.gqa_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd,
                                  qkv_bias=cfg.qkv_bias)
    if cfg.n_experts:
        defs["moe"] = E.moe_defs(cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        defs["mlp"] = L.mlp_defs(cfg.d_model, cfg.d_ff)
    return defs


def ssm_block_defs(cfg: ArchConfig):
    return {
        "ln": L.rmsnorm_def(cfg.d_model),
        "ssm": S.mamba2_defs(cfg.d_model, expand=cfg.ssm_expand,
                             head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                             n_groups=cfg.ssm_groups, conv_kernel=cfg.ssm_conv),
    }


def _attn_apply(cfg: ArchConfig, params, h, positions, cache):
    if cfg.mla:
        return A.mla_apply(
            params, h, positions, n_heads=cfg.n_heads, q_lora=cfg.q_lora_rank,
            kv_lora=cfg.kv_lora_rank, qk_nope=cfg.qk_nope_dim,
            qk_rope=cfg.qk_rope_dim, v_head=cfg.v_head_dim,
            rope_theta=cfg.rope_theta, cache=cache,
            absorbed_decode=cfg.mla_absorbed_decode,
        )
    return A.gqa_apply(
        params, h, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        window=cfg.sliding_window, softcap=cfg.logit_softcap,
        mrope_sections=cfg.mrope_sections, cache=cache, qkv_bias=cfg.qkv_bias,
        attn_impl=cfg.attn_impl,
    )


def attn_block_apply(cfg: ArchConfig, params, h, positions, cache=None):
    """Returns (h, new_cache, aux_loss)."""
    a_out, new_cache = _attn_apply(
        cfg, params["attn"], L.rmsnorm(h, params["ln1"], cfg.norm_eps),
        positions, cache,
    )
    h = h + a_out
    hn = L.rmsnorm(h, params["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        moe_fn = (E.moe_apply_local_ep if cfg.moe_impl == "local_ep"
                  else E.moe_apply)
        m_out, aux = moe_fn(params["moe"], hn, n_experts=cfg.n_experts,
                            top_k=cfg.top_k,
                            capacity_factor=cfg.capacity_factor)
    else:
        m_out, aux = L.mlp_apply(params["mlp"], hn), jnp.zeros([], jnp.float32)
    return h + m_out, new_cache, aux


def ssm_block_apply(cfg: ArchConfig, params, h, cache=None):
    out, new_cache = S.mamba2_apply(
        params["ssm"], L.rmsnorm(h, params["ln"], cfg.norm_eps),
        expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
        n_groups=cfg.ssm_groups, conv_kernel=cfg.ssm_conv, chunk=cfg.ssm_chunk,
        cache=cache,
    )
    return h + out, new_cache


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------
_REMAT_POLICIES = {
    # save weight-matmul outputs (no batch dims), recompute attention scores
    # and elementwise — the memory/compute sweet spot for long sequences
    "dots_no_batch": "dots_with_no_batch_dims_saveable",
    "dots": "dots_saveable",         # saves attention scores too (fast bwd)
    "nothing": "nothing_saveable",   # minimal memory, max recompute
}


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat:
        policy = getattr(jax.checkpoint_policies,
                         _REMAT_POLICIES.get(cfg.remat_policy, "dots_with_no_batch_dims_saveable"))
        return jax.checkpoint(fn, policy=policy)
    return fn


def uniform_stack_defs(cfg: ArchConfig):
    block = ssm_block_defs(cfg) if cfg.family == "ssm" else attn_block_defs(cfg)
    return L.stack_defs(block, cfg.n_layers)


def uniform_stack_apply(cfg: ArchConfig, stacked, h, positions, caches=None):
    """caches: pytree stacked on axis 0 (or None). Returns (h, caches, aux)."""
    decode = caches is not None

    if cfg.family == "ssm":

        def body(carry, xs):
            hh = carry
            if decode:
                p, c = xs
                hh, new_c = ssm_block_apply(cfg, p, hh, c)
            else:
                p, new_c = xs, 0.0
                hh, _ = ssm_block_apply(cfg, p, hh, None)
            return hh, new_c

        body = _maybe_remat(body, cfg) if not decode else body
        h, new_caches = jax.lax.scan(
            body, h, (stacked, caches) if decode else stacked
        )
        return h, (new_caches if decode else None), jnp.zeros([], jnp.float32)

    def body(carry, xs):
        hh, aux = carry
        if decode:
            p, c = xs
            hh, new_c, a = attn_block_apply(cfg, p, hh, positions, c)
        else:
            p = xs
            hh, new_c, a = attn_block_apply(cfg, p, hh, positions, None)
            new_c = 0.0
        return (hh, aux + a), new_c

    wrapped = _maybe_remat(body, cfg) if not decode else body
    (h, aux), new_caches = jax.lax.scan(
        wrapped, (h, jnp.zeros([], jnp.float32)),
        (stacked, caches) if decode else stacked,
    )
    return h, (new_caches if decode else None), aux


# ---------------------------------------------------------------------------
# Hybrid (zamba2): mamba backbone + one shared attention block
# ---------------------------------------------------------------------------
def hybrid_defs(cfg: ArchConfig):
    return {
        "ssm_layers": L.stack_defs(ssm_block_defs(cfg), cfg.n_layers),
        "shared_attn": attn_block_defs(cfg),
    }


def hybrid_flags(cfg: ArchConfig) -> jnp.ndarray:
    """True after layers attn_every-1, 2·attn_every-1, ... (static pattern)."""
    idx = jnp.arange(cfg.n_layers)
    return (idx % cfg.attn_every) == (cfg.attn_every - 1)


def hybrid_n_apps(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def hybrid_apply(cfg: ArchConfig, params, h, positions, caches=None):
    """caches = {'ssm': stacked(L), 'kv': stacked(n_apps)} or None."""
    flags = hybrid_flags(cfg)
    decode = caches is not None
    shared = params["shared_attn"]

    def apply_shared(operand):
        hh, kv_all, app_idx = operand
        if decode:
            cache_i = jax.tree_util.tree_map(
                lambda c: jax.lax.dynamic_index_in_dim(c, app_idx, 0, False),
                kv_all,
            )
        else:
            cache_i = None
        hh2, new_ci, _ = attn_block_apply(cfg, shared, hh, positions, cache_i)
        if decode:
            kv_all = jax.tree_util.tree_map(
                lambda c, ci: jax.lax.dynamic_update_index_in_dim(c, ci, app_idx, 0),
                kv_all, new_ci,
            )
        return hh2, kv_all, app_idx + 1

    def body(carry, xs):
        hh, kv_all, app_idx = carry
        if decode:
            (p, ssm_c), flag = xs
            hh, new_ssm_c = ssm_block_apply(cfg, p, hh, ssm_c)
        else:
            p, flag = xs
            hh, new_ssm_c = ssm_block_apply(cfg, p, hh, None)
            new_ssm_c = 0.0
        hh, kv_all, app_idx = jax.lax.cond(
            flag, apply_shared, lambda o: o, (hh, kv_all, app_idx)
        )
        return (hh, kv_all, app_idx), new_ssm_c

    if decode:
        kv0 = caches["kv"]
        xs = ((params["ssm_layers"], caches["ssm"]), flags)
    else:
        # dummy zero-length KV for structure parity in train mode
        kv0 = A.gqa_init_cache(h.shape[0], 0, cfg.n_kv_heads,
                               cfg.resolved_head_dim, cfg.dtype)
        kv0 = jax.tree_util.tree_map(lambda c: c[None], kv0)
        xs = (params["ssm_layers"], flags)

    wrapped = _maybe_remat(body, cfg) if not decode else body
    (h, kv_final, _), new_ssm = jax.lax.scan(
        wrapped, (h, kv0, jnp.zeros([], jnp.int32)), xs
    )
    new_caches = {"ssm": new_ssm, "kv": kv_final} if decode else None
    return h, new_caches, jnp.zeros([], jnp.float32)


# ---------------------------------------------------------------------------
# Whisper-style encoder-decoder
# ---------------------------------------------------------------------------
def encoder_block_defs(cfg: ArchConfig):
    hd = cfg.resolved_head_dim
    return {
        "ln1": L.rmsnorm_def(cfg.d_model),
        "attn": A.gqa_defs(cfg.d_model, cfg.n_heads, cfg.n_heads, hd),
        "ln2": L.rmsnorm_def(cfg.d_model),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, gated=False),
    }


def decoder_block_defs(cfg: ArchConfig):
    hd = cfg.resolved_head_dim
    return {
        "ln1": L.rmsnorm_def(cfg.d_model),
        "attn": A.gqa_defs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, hd),
        "ln_x": L.rmsnorm_def(cfg.d_model),
        "cross": A.cross_defs(cfg.d_model, cfg.n_heads, hd),
        "ln2": L.rmsnorm_def(cfg.d_model),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, gated=False),
    }


def encdec_defs(cfg: ArchConfig):
    return {
        "encoder": L.stack_defs(encoder_block_defs(cfg), cfg.encoder_layers),
        "decoder": L.stack_defs(decoder_block_defs(cfg), cfg.n_layers),
        "enc_ln": L.rmsnorm_def(cfg.d_model),
    }


def encoder_apply(cfg: ArchConfig, params, enc_embeds):
    """Bidirectional self-attention over (stub) frame embeddings."""
    hd = cfg.resolved_head_dim
    b, t, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))

    def body(hh, p):
        x = L.rmsnorm(hh, p["ln1"], cfg.norm_eps)
        q = (x @ p["attn"]["wq"].astype(x.dtype)).reshape(b, t, cfg.n_heads, hd)
        k = (x @ p["attn"]["wk"].astype(x.dtype)).reshape(b, t, cfg.n_heads, hd)
        v = (x @ p["attn"]["wv"].astype(x.dtype)).reshape(b, t, cfg.n_heads, hd)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        mask = jnp.ones((t, t), bool)  # bidirectional
        o = A._attend(q, k, v, mask, None, 1.0 / hd**0.5)
        hh = hh + o.reshape(b, t, -1) @ p["attn"]["wo"].astype(x.dtype)
        hh = hh + L.mlp_apply(p["mlp"], L.rmsnorm(hh, p["ln2"], cfg.norm_eps),
                              gated=False)
        return hh, None

    body_fn = _maybe_remat(lambda c, x: body(c, x), cfg)
    h, _ = jax.lax.scan(body_fn, enc_embeds, params["encoder"])
    return L.rmsnorm(h, params["enc_ln"], cfg.norm_eps)


def decoder_apply(cfg: ArchConfig, params, h, positions, enc_out, caches=None):
    decode = caches is not None
    hd = cfg.resolved_head_dim

    def body(carry, xs):
        hh = carry
        if decode:
            p, c = xs
        else:
            p, c = xs, None
        a_out, new_c = A.gqa_apply(
            p["attn"], L.rmsnorm(hh, p["ln1"], cfg.norm_eps), positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=hd,
            rope_theta=cfg.rope_theta, cache=c,
        )
        hh = hh + a_out
        hh = hh + A.cross_apply(
            p["cross"], L.rmsnorm(hh, p["ln_x"], cfg.norm_eps), enc_out,
            n_heads=cfg.n_heads, head_dim=hd,
        )
        hh = hh + L.mlp_apply(p["mlp"], L.rmsnorm(hh, p["ln2"], cfg.norm_eps),
                              gated=False)
        return hh, (new_c if decode else 0.0)

    wrapped = _maybe_remat(body, cfg) if not decode else body
    h, new_caches = jax.lax.scan(
        wrapped, h, (params["decoder"], caches) if decode else params["decoder"]
    )
    return h, (new_caches if decode else None), jnp.zeros([], jnp.float32)
