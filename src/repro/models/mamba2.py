"""Mamba2 (SSD — state-space duality) blocks: chunked train scan + O(1) decode.

Follows the SSD formulation (Dao & Gu 2024, arXiv:2405.21060): per head h
with scalar decay ``a_t = exp(-softplus(dt_t)·exp(A_log_h))``... concretely

    S_t = a_t · S_{t-1} + dt_t · B_t ⊗ x_t          S ∈ R^{P×N}
    y_t = C_tᵀ S_t + D_h · x_t

Training uses the chunked algorithm: within a chunk the quadratic
"attention-like" form (C B^T ⊙ decay) runs on the MXU; across chunks a
lax.scan carries the (H, P, N) state — O(T·L²) intra + O(T/L) sequential
steps, the TPU-native layout of the paper's kernel (DESIGN.md §3).

Decode is the recurrence verbatim: state (B, H, P, N) + a rolling conv
window — this is what makes ``long_500k`` O(1)-per-token for mamba2/zamba2.

Weights are 2-D projections (in/out/B/C/dt) — all COAP-projected; the
per-channel A_log, D, dt_bias and the depthwise conv are dense-Adam leaves
(DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, rmsnorm, rmsnorm_def


def mamba2_dims(d_model: int, expand: int, head_dim: int, d_state: int,
                n_groups: int = 1):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    # in_proj packs [z, x, B, C, dt]
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    return d_inner, n_heads, d_in_proj


def mamba2_defs(d_model: int, expand: int = 2, head_dim: int = 64,
                d_state: int = 128, n_groups: int = 1, conv_kernel: int = 4):
    d_inner, n_heads, d_in_proj = mamba2_dims(d_model, expand, head_dim,
                                              d_state, n_groups)
    conv_dim = d_inner + 2 * n_groups * d_state
    return {
        "in_proj": ParamDef((d_model, d_in_proj), "fan_in", ("embed", "ffn")),
        "conv_w": ParamDef((conv_kernel, conv_dim), "fan_in", (None, "ffn")),
        "conv_b": ParamDef((conv_dim,), "zeros", ("ffn",)),
        "a_log": ParamDef((n_heads,), "ssm_a", (None,)),
        "d_skip": ParamDef((n_heads,), "ones", (None,)),
        "dt_bias": ParamDef((n_heads,), "ssm_dt", (None,)),
        "out_norm": rmsnorm_def(d_inner),
        "out_proj": ParamDef((d_inner, d_model), "fan_in", ("ffn", "embed")),
    }


class SSMCache(NamedTuple):
    conv: Any  # (B, K-1, conv_dim) rolling conv inputs
    state: Any  # (B, H, P, N) SSM state


def mamba2_init_cache(batch, d_model, *, expand=2, head_dim=64, d_state=128,
                      n_groups=1, conv_kernel=4, dtype=jnp.float32):
    d_inner, n_heads, _ = mamba2_dims(d_model, expand, head_dim, d_state, n_groups)
    conv_dim = d_inner + 2 * n_groups * d_state
    return SSMCache(
        conv=jnp.zeros((batch, conv_kernel - 1, conv_dim), dtype),
        state=jnp.zeros((batch, n_heads, head_dim, d_state), jnp.float32),
    )


def _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner : 2 * d_inner]
    b = zxbcdt[..., 2 * d_inner : 2 * d_inner + n_groups * d_state]
    c = zxbcdt[..., 2 * d_inner + n_groups * d_state : 2 * d_inner + 2 * n_groups * d_state]
    dt = zxbcdt[..., -n_heads:]
    return z, x, b, c, dt


def _causal_conv_train(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv over time. xbc: (B, T, C); conv_w: (K, C)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # (B, T+K-1, C)
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i][None, None, :] for i in range(k)
    )
    out = out + conv_b[None, None, :]
    new_state = xp[:, -(k - 1) :, :]
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype), new_state


def _ssd_chunked(x, dt, a_log, b, c, d_skip, chunk: int):
    """Chunked SSD scan.

    x: (B,T,H,P) dt: (B,T,H) b,c: (B,T,G,N). Returns y (B,T,H,P).
    """
    bsz, t, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert t % chunk == 0, (t, chunk)
    nc = t // chunk
    heads_per_group = h // g

    dt = jax.nn.softplus(dt.astype(jnp.float32))  # (B,T,H)
    decay = dt * -jnp.exp(a_log.astype(jnp.float32))[None, None, :]  # log a_t
    xdt = x.astype(jnp.float32) * dt[..., None]  # dt-weighted input

    def to_chunks(v):
        return v.reshape(bsz, nc, chunk, *v.shape[2:])

    xc, dc = to_chunks(xdt), to_chunks(decay)
    bc_, cc = to_chunks(b.astype(jnp.float32)), to_chunks(c.astype(jnp.float32))
    # broadcast groups to heads
    bc_h = jnp.repeat(bc_, heads_per_group, axis=3)  # (B,NC,L,H,N)
    cc_h = jnp.repeat(cc, heads_per_group, axis=3)

    cum = jnp.cumsum(dc, axis=2)  # (B,NC,L,H) cumulative log-decay
    # Intra-chunk (quadratic, MXU): decay from j to i = exp(cum_i - cum_j)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,NC,L_i,L_j,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    gamma = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bklhn,bkjhn->bkljh", cc_h, bc_h)  # (B,NC,L_i,L_j,H)
    y_intra = jnp.einsum("bkljh,bkljh,bkjhp->bklhp", scores, gamma, xc)

    # Chunk-final states: S_k = Σ_j exp(cum_L - cum_j) B_j x_jᵀ
    tail = cum[:, :, -1:, :] - cum  # (B,NC,L,H)
    s_chunk = jnp.einsum("bkjh,bkjhn,bkjhp->bkhpn", jnp.exp(tail), bc_h, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,NC,H)

    # Inter-chunk: scan carrying (B,H,P,N)
    def scan_body(s_prev, inp):
        s_c, dec = inp  # (B,H,P,N), (B,H)
        y_prev_state = s_prev  # state entering this chunk
        s_new = dec[:, :, None, None] * s_prev + s_c
        return s_new, y_prev_state

    s_init = jnp.zeros((bsz, h, p, n), jnp.float32)
    _, s_before = jax.lax.scan(
        scan_body,
        s_init,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )  # (NC,B,H,P,N): state at the START of each chunk
    s_before = jnp.moveaxis(s_before, 0, 1)  # (B,NC,H,P,N)

    # Inter-chunk output: y_i += C_i · exp(cum_i) · S_start
    y_inter = jnp.einsum("bklh,bklhn,bkhpn->bklhp", jnp.exp(cum), cc_h, s_before)

    y = (y_intra + y_inter).reshape(bsz, t, h, p)
    y = y + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]
    return y


def mamba2_apply(params, x, *, expand=2, head_dim=64, d_state=128, n_groups=1,
                 conv_kernel=4, chunk=256,
                 cache: Optional[SSMCache] = None) -> Tuple[Any, Optional[SSMCache]]:
    """x: (B, T, D). cache=None ⇒ training (chunked); else single/few-step
    decode via the recurrence."""
    bsz, t, d_model = x.shape
    d_inner, n_heads, _ = mamba2_dims(d_model, expand, head_dim, d_state, n_groups)
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xin, b, c, dt = _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads)
    xbc = jnp.concatenate([xin, b, c], axis=-1)

    if cache is None:
        pad = (-t) % chunk
        xbc_conv, _ = _causal_conv_train(xbc, params["conv_w"], params["conv_b"])
        xin_c = xbc_conv[..., :d_inner].reshape(bsz, t, n_heads, head_dim)
        b_c = xbc_conv[..., d_inner : d_inner + n_groups * d_state].reshape(
            bsz, t, n_groups, d_state
        )
        c_c = xbc_conv[..., d_inner + n_groups * d_state :].reshape(
            bsz, t, n_groups, d_state
        )
        if pad:
            def padt(v):
                return jnp.pad(v, [(0, 0), (0, pad)] + [(0, 0)] * (v.ndim - 2))
            xin_c, b_c, c_c, dt_p = padt(xin_c), padt(b_c), padt(c_c), padt(dt)
        else:
            dt_p = dt
        dt_full = dt_p + params["dt_bias"].astype(dt_p.dtype)[None, None, :]
        y = _ssd_chunked(xin_c, dt_full, params["a_log"], b_c, c_c,
                         params["d_skip"], chunk)
        y = y[:, :t]
        new_cache = None
    else:
        # Recurrent decode (t small, usually 1): roll conv window + state.
        k = conv_kernel
        conv_in = jnp.concatenate([cache.conv.astype(xbc.dtype), xbc], axis=1)
        # conv_in length = t + k - 1; out[j] = Σ_i w[i]·conv_in[j+i]
        conv_out = sum(
            conv_in[:, i : i + t, :]
            * params["conv_w"][i][None, None, :].astype(xbc.dtype)
            for i in range(k)
        )
        conv_out = conv_out + params["conv_b"][None, None, :].astype(xbc.dtype)
        xbc_conv = jax.nn.silu(conv_out.astype(jnp.float32)).astype(xbc.dtype)
        new_conv = conv_in[:, -(k - 1) :, :]

        xin_c = xbc_conv[..., :d_inner].reshape(bsz, t, n_heads, head_dim)
        b_c = xbc_conv[..., d_inner : d_inner + n_groups * d_state].reshape(
            bsz, t, n_groups, d_state
        )
        c_c = xbc_conv[..., d_inner + n_groups * d_state :].reshape(
            bsz, t, n_groups, d_state
        )
        dt_full = jax.nn.softplus(
            (dt + params["dt_bias"][None, None, :]).astype(jnp.float32)
        )
        a = jnp.exp(
            dt_full * -jnp.exp(params["a_log"].astype(jnp.float32))[None, None, :]
        )  # (B,T,H)
        hpg = n_heads // n_groups
        b_h = jnp.repeat(b_c, hpg, axis=2).astype(jnp.float32)
        c_h = jnp.repeat(c_c, hpg, axis=2).astype(jnp.float32)

        def step(s, inp):
            a_t, bx_t, c_t, x_t, dt_t = inp
            s_new = a_t[:, :, None, None] * s + (
                dt_t[:, :, None, None]
                * jnp.einsum("bhp,bhn->bhpn", x_t.astype(jnp.float32), bx_t)
            )
            y_t = jnp.einsum("bhpn,bhn->bhp", s_new, c_t)
            return s_new, y_t

        seq = (
            jnp.moveaxis(a, 1, 0),
            jnp.moveaxis(b_h, 1, 0),
            jnp.moveaxis(c_h, 1, 0),
            jnp.moveaxis(xin_c, 1, 0),
            jnp.moveaxis(dt_full, 1, 0),
        )
        s_final, ys = jax.lax.scan(step, cache.state, seq)
        y = jnp.moveaxis(ys, 0, 1)  # (B,T,H,P)
        y = y + xin_c.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[
            None, None, :, None
        ]
        new_cache = SSMCache(conv=new_conv, state=s_final)

    y = y.reshape(bsz, t, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                params["out_norm"])
    return y @ params["out_proj"].astype(x.dtype), new_cache
