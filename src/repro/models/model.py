"""LMModel: one uniform bundle (init / apply / loss / prefill / decode /
param_specs / cache machinery) over all assigned architecture families."""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as S
from repro.models import transformer as T

LOSS_CHUNK = 1024  # tokens per lm-head chunk (bounds live logits memory)


class LMModel:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ defs
    def defs(self):
        cfg = self.cfg
        d: Dict[str, Any] = {}
        if not cfg.embed_inputs:
            d["embed"] = L.embed_defs(cfg.vocab_size, cfg.d_model)
        if cfg.family == "audio":
            d["stack"] = T.encdec_defs(cfg)
        elif cfg.family == "hybrid":
            d["stack"] = T.hybrid_defs(cfg)
        else:
            d["stack"] = T.uniform_stack_defs(cfg)
        d["final_norm"] = L.rmsnorm_def(cfg.d_model)
        if not cfg.tie_embeddings:
            d["lm_head"] = {
                "w": L.ParamDef((cfg.d_model, cfg.vocab_size), "fan_in",
                                ("embed", "vocab"))
            }
        return d

    def init(self, key):
        return L.materialize(self.defs(), key)

    def abstract_params(self):
        return L.abstract_params(self.defs())

    def param_specs(self, mesh, rules=shd.PARAM_RULES):
        return shd.param_specs(self.defs(), mesh, rules)

    # ----------------------------------------------------------------- embed
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.embed_inputs:
            h = batch["embeds"].astype(cfg.dtype)
        else:
            h = L.embed_apply(params["embed"], batch["tokens"], cfg.dtype)
        return h

    def _positions(self, batch, h):
        if "positions" in batch:
            return batch["positions"]
        b, t = h.shape[0], h.shape[1]
        return jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    # ----------------------------------------------------------------- apply
    def apply(self, params, batch, caches=None):
        """Returns (hidden, new_caches, aux). Readout happens in loss/logits
        so big-vocab logits never materialize wholesale."""
        cfg = self.cfg
        L.set_pure_bf16(cfg.bf16_elementwise)
        h = self._embed_in(params, batch)
        h = shd.constrain(h, ("batch", "seq_data" if h.shape[0] == 1 else None, None))
        positions = self._positions(batch, h)
        if cfg.family == "audio":
            if "enc_embeds" in batch:  # train / prefill: run the encoder
                enc_out = T.encoder_apply(cfg, params["stack"],
                                          batch["enc_embeds"].astype(cfg.dtype))
            else:  # decode: reuse the cached encoder output
                enc_out = caches["enc_out"]
            dec_caches = caches["kv"] if caches is not None else None
            h, new_kv, aux = T.decoder_apply(cfg, params["stack"], h, positions,
                                             enc_out, dec_caches)
            new_caches = {"kv": new_kv, "enc_out": enc_out} if caches is not None else None
        elif cfg.family == "hybrid":
            h, new_caches, aux = T.hybrid_apply(cfg, params["stack"], h,
                                                positions, caches)
        else:
            h, new_caches, aux = T.uniform_stack_apply(cfg, params["stack"], h,
                                                       positions, caches)
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return h, new_caches, aux

    def _readout(self, params, h):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return L.unembed_apply(params["embed"], h)
        return h @ params["lm_head"]["w"].astype(h.dtype)

    def logits(self, params, batch, caches=None):
        h, new_caches, aux = self.apply(params, batch, caches)
        return self._readout(params, h), new_caches, aux

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch):
        """Next-token CE (labels pre-shifted by the pipeline), computed in
        LOSS_CHUNK-token slices so the (tokens, vocab) logits never fully
        materialize (matters at vocab 152k × 1M tokens)."""
        cfg = self.cfg
        h, _, aux = self.apply(params, batch)
        b, t, d = h.shape
        labels = batch["labels"]
        flat_h = h.reshape(b * t, d)
        flat_y = labels.reshape(b * t)
        n = flat_h.shape[0]
        chunk = min(LOSS_CHUNK, n)
        pad = (-n) % chunk
        if pad:
            flat_h = jnp.concatenate([flat_h, jnp.zeros((pad, d), flat_h.dtype)])
            flat_y = jnp.concatenate([flat_y, -jnp.ones((pad,), flat_y.dtype)])
        hc = flat_h.reshape(-1, chunk, d)
        yc = flat_y.reshape(-1, chunk)

        def chunk_loss(carry, xs):
            hh, yy = xs
            logits = self._readout(params, hh[None])[0].astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(yy, 0)[:, None], axis=-1
            )[:, 0]
            valid = yy >= 0
            ce = jnp.where(valid, logz - gold, 0.0)
            return carry + jnp.sum(ce), jnp.sum(valid)

        body = jax.checkpoint(chunk_loss)
        total, counts = jax.lax.scan(body, jnp.zeros([], jnp.float32), (hc, yc))
        n_valid = jnp.maximum(jnp.sum(counts), 1)
        ce = total / n_valid
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux, "tokens": n_valid}

    # ---------------------------------------------------------------- caches
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        hd = cfg.resolved_head_dim

        def stacked(tree, n):
            return jax.tree_util.tree_map(lambda c: jnp.stack([c] * n), tree)

        if cfg.family == "ssm":
            one = S.mamba2_init_cache(
                batch, cfg.d_model, expand=cfg.ssm_expand,
                head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                n_groups=cfg.ssm_groups, conv_kernel=cfg.ssm_conv,
                dtype=cfg.dtype,
            )
            return stacked(one, cfg.n_layers)
        if cfg.family == "hybrid":
            ssm_one = S.mamba2_init_cache(
                batch, cfg.d_model, expand=cfg.ssm_expand,
                head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                n_groups=cfg.ssm_groups, conv_kernel=cfg.ssm_conv,
                dtype=cfg.dtype,
            )
            kv_one = A.gqa_init_cache(batch, max_len, cfg.n_kv_heads, hd,
                                      cfg.dtype, cfg.sliding_window)
            return {
                "ssm": stacked(ssm_one, cfg.n_layers),
                "kv": stacked(kv_one, T.hybrid_n_apps(cfg)),
            }
        if cfg.family == "audio":
            kv_one = A.gqa_init_cache(batch, max_len, cfg.n_kv_heads, hd,
                                      cfg.dtype)
            enc_t = cfg.encoder_seq or 1500
            return {
                "kv": stacked(kv_one, cfg.n_layers),
                "enc_out": jnp.zeros((batch, enc_t, cfg.d_model), cfg.dtype),
            }
        if cfg.mla:
            one = A.mla_init_cache(batch, max_len, cfg.kv_lora_rank,
                                   cfg.qk_rope_dim, cfg.dtype)
            return stacked(one, cfg.n_layers)
        one = A.gqa_init_cache(batch, max_len, cfg.n_kv_heads, hd, cfg.dtype,
                               cfg.sliding_window)
        return stacked(one, cfg.n_layers)

    def cache_shapes(self, batch: int, max_len: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def cache_specs(self, mesh, batch: int):
        """PartitionSpecs for the cache pytree: batch over (pod,data) when it
        divides, else sequence over data (long_500k B=1)."""
        baxes = shd.batch_axes(mesh)
        total = 1
        for a in baxes:
            total *= mesh.shape[a]
        batch_ok = batch % total == 0 and total > 1
        bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

        def one(kp, x):
            from repro.core.projector import path_str

            path = path_str(kp)
            shape = x.shape
            spec: list = [None] * len(shape)
            if len(shape) == 0:
                return jax.sharding.PartitionSpec()
            if "enc_out" in path:  # (B, enc_t, d): no layer axis
                if batch_ok:
                    spec[0] = bspec
                return jax.sharding.PartitionSpec(*spec)
            # stacked caches: axis0 = layers; batch = axis 1
            if len(shape) == 1:  # per-layer lengths
                return jax.sharding.PartitionSpec(None)
            if batch_ok:
                spec[1] = bspec
            elif (len(shape) >= 3 and "data" in mesh.axis_names
                  and shape[2] % mesh.shape["data"] == 0):
                spec[2] = "data"  # sequence-parallel KV
            # shard kv-heads/ssm-heads over model when divisible
            if (len(shape) >= 4 and "model" in mesh.axis_names
                    and shape[3] % mesh.shape["model"] == 0):
                spec[3] = "model"
            return jax.sharding.PartitionSpec(*spec)

        return jax.tree_util.tree_map_with_path(one, self.cache_shapes(batch, 8))

    # --------------------------------------------------------------- serving
    def prefill(self, params, batch, max_len: int):
        """Run the full prompt, building caches sized max_len."""
        b = (batch.get("tokens", batch.get("embeds"))).shape[0]
        caches = self.init_cache(b, max_len)
        logits, new_caches, _ = self.logits(params, batch, caches)
        return logits[:, -1:], new_caches

    def decode_step(self, params, caches, batch):
        logits, new_caches, _ = self.logits(params, batch, caches)
        return logits, new_caches


def build_model(cfg: ArchConfig) -> LMModel:
    return LMModel(cfg)
