"""Parameter definitions, norms, embeddings, RoPE/M-RoPE, and MLPs.

Parameters are declared as ``ParamDef`` trees (shape + initializer + logical
axes). ``materialize`` turns a def-tree into arrays; ``spec_tree`` turns it
into ``PartitionSpec``s via the mesh rules in ``repro.distributed.sharding``.
All weight matrices are stored 2-D (optionally with leading stack axes) so
the COAP projector sees exactly the per-layer matrices the paper projects.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    init: str  # 'normal:<std>' | 'zeros' | 'ones' | 'fan_in' | 'ssm_a' | 'ssm_dt'
    axes: Tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    dtype: Any = jnp.float32


def is_param_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_array(key, d: ParamDef):
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init.startswith("normal:"):
        std = float(d.init.split(":")[1])
        return (std * jax.random.normal(key, d.shape)).astype(d.dtype)
    if d.init == "fan_in":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = 1.0 / math.sqrt(fan_in)
        return (std * jax.random.normal(key, d.shape)).astype(d.dtype)
    if d.init == "ssm_a":  # mamba2: A_log = log(uniform[1,16])
        u = jax.random.uniform(key, d.shape, minval=1.0, maxval=16.0)
        return jnp.log(u).astype(d.dtype)
    if d.init == "ssm_dt":  # mamba2: dt_bias = inv_softplus(uniform[1e-3,1e-1])
        u = jax.random.uniform(key, d.shape, minval=math.log(1e-3), maxval=math.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(d.dtype)
    raise ValueError(f"unknown init {d.init}")


def materialize(defs, key):
    """Def-tree -> param-tree with per-leaf folded keys (deterministic)."""
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=is_param_def)
    arrays = [
        _init_array(jax.random.fold_in(key, i), d) for i, d in enumerate(leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, arrays)


def abstract_params(defs):
    """Def-tree -> ShapeDtypeStruct tree (no allocation; dry-run path)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_param_def
    )


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacked-layer axis to every ParamDef in the tree."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, d.init, (axis_name,) + d.axes, d.dtype),
        defs,
        is_leaf=is_param_def,
    )


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------
# Elementwise-precision switch (§Perf iteration "bf16 elementwise"): the
# baseline upcasts every norm/activation to fp32, which doubles the HBM
# traffic of the backward elementwise chains — the dominant memory-term
# contributor measured on glm4-9b train_4k. With the pure-bf16 path only the
# variance reduction stays fp32 (numerics validated in
# tests/test_models_layers.py::test_bf16_elementwise_close). Set per-model
# from ArchConfig.bf16_elementwise at trace time (single-threaded tracing).
_PURE_BF16 = {"enabled": False}


def set_pure_bf16(flag: bool):
    _PURE_BF16["enabled"] = bool(flag)


def rmsnorm_def(dim: int) -> ParamDef:
    return ParamDef((dim,), "ones", (None,))


def rmsnorm(x, scale, eps=1e-6):
    if _PURE_BF16["enabled"] and x.dtype != jnp.float32:
        var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * inv * scale.astype(x.dtype)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def swiglu(gate, up):
    if _PURE_BF16["enabled"] and gate.dtype != jnp.float32:
        return jax.nn.silu(gate) * up
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x):
    if _PURE_BF16["enabled"] and x.dtype != jnp.float32:
        return jax.nn.gelu(x)
    return jax.nn.gelu(x.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------
def embed_defs(vocab: int, dim: int, std=0.02):
    return {"embedding": ParamDef((vocab, dim), f"normal:{std}", ("vocab", "embed"))}


def embed_apply(params, tokens, dtype):
    return params["embedding"].astype(dtype)[tokens]


def unembed_apply(params, x):
    """Tied readout: x @ Eᵀ."""
    e = params["embedding"].astype(x.dtype)
    return jnp.einsum("btd,vd->btv", x, e)


# ---------------------------------------------------------------------------
# Dense (2-D weight; reshape head structure in the caller)
# ---------------------------------------------------------------------------
def linear_defs(d_in: int, d_out: int, in_axis="embed", out_axis="ffn",
                name: str = "w", bias: bool = False):
    defs = {name: ParamDef((d_in, d_out), "fan_in", (in_axis, out_axis))}
    if bias:
        defs[name + "_bias"] = ParamDef((d_out,), "zeros", (out_axis,))
    return defs


def linear_apply(params, x, name: str = "w"):
    w = params[name].astype(x.dtype)
    y = x @ w
    b = params.get(name + "_bias")
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# RoPE and M-RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, T, H, hd); positions: (B, T) int32. Angles always fp32; the
    rotation itself runs in x.dtype under the pure-bf16 mode so no h-sized
    fp32 tensor exists in the forward (they were being saved as fp32 scan
    residuals — measured §Perf)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,T,hd/2)
    mul_dtype = x.dtype if (_PURE_BF16["enabled"] and
                            x.dtype != jnp.float32) else jnp.float32
    cos = jnp.cos(angles)[:, :, None, :].astype(mul_dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(mul_dtype)
    x1, x2 = jnp.split(x.astype(mul_dtype), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Sequence[int]):
    """Qwen2-VL multimodal RoPE. positions3: (3, B, T) — (temporal, h, w)
    position ids; ``sections`` splits the hd/2 frequency bands between the
    three position streams (e.g. (16, 24, 24) for hd=128)."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(hd, theta)  # (half,)
    angle_parts = []
    start = 0
    for s_idx, sec in enumerate(sections):
        f = freqs[start : start + sec]
        pos = positions3[s_idx].astype(jnp.float32)  # (B, T)
        angle_parts.append(pos[..., None] * f)
        start += sec
    angles = jnp.concatenate(angle_parts, axis=-1)  # (B,T,half)
    mul_dtype = x.dtype if (_PURE_BF16["enabled"] and
                            x.dtype != jnp.float32) else jnp.float32
    cos = jnp.cos(angles)[:, :, None, :].astype(mul_dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(mul_dtype)
    x1, x2 = jnp.split(x.astype(mul_dtype), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU / GELU MLP
# ---------------------------------------------------------------------------
def mlp_defs(d_model: int, d_ff: int, gated: bool = True):
    defs = {
        "up": ParamDef((d_model, d_ff), "fan_in", ("embed", "ffn")),
        "down": ParamDef((d_ff, d_model), "fan_in", ("ffn", "embed")),
    }
    if gated:
        defs["gate"] = ParamDef((d_model, d_ff), "fan_in", ("embed", "ffn"))
    return defs


def mlp_apply(params, x, gated: bool = True):
    up = x @ params["up"].astype(x.dtype)
    if gated:
        gate = x @ params["gate"].astype(x.dtype)
        h = swiglu(gate, up)
    else:
        h = gelu(up)
    return h @ params["down"].astype(x.dtype)
