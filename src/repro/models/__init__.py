"""Model substrate: the 10 assigned architectures + the paper's LLaMA-1B.

Everything is functional (explicit param pytrees, init/apply pairs) with
logical-axis metadata carried alongside every parameter so the distributed
layer can lay any architecture out on the (pod, data, model) mesh without
per-model sharding code. Decoder stacks are lax.scan-over-layers with
configurable remat, so XLA compiles one layer body regardless of depth.
"""
