"""Batched serving engine: chunked prefill + jitted greedy/temperature decode.

The engine drives the model-zoo cache machinery (dense KV, rolling SWA ring,
MLA latents, SSM state): prompts are prefilled in ≤window chunks (exactness
for rolling caches — see models/attention.py), then tokens decode one step
at a time with a single compiled ``decode_step`` for the whole batch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import LMModel


@dataclasses.dataclass
class ServeConfig:
    max_prompt_len: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0  # 0 = greedy
    prefill_chunk: int = 0  # 0 = auto (window size or full prompt)
    eos_id: Optional[int] = None
    seed: int = 0


class ServeEngine:
    def __init__(self, model: LMModel, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda p, b, c: model.logits(p, b, c)[:2],
        )

    def _pad_prompts(self, prompts: List[List[int]]) -> Tuple[jnp.ndarray, jnp.ndarray]:
        max_len = max(len(p) for p in prompts)
        b = len(prompts)
        toks = jnp.zeros((b, max_len), jnp.int32)
        lens = jnp.asarray([len(p) for p in prompts], jnp.int32)
        for i, p in enumerate(prompts):
            toks = toks.at[i, : len(p)].set(jnp.asarray(p, jnp.int32))
        return toks, lens

    def generate(self, prompts: List[List[int]]) -> List[List[int]]:
        """Greedy/temperature generation for a batch of token prompts."""
        cfg = self.cfg
        model = self.model
        toks, lens = self._pad_prompts(prompts)
        b, t = toks.shape
        max_len = t + cfg.max_new_tokens
        caches = model.init_cache(b, max_len)

        window = model.cfg.sliding_window
        chunk = cfg.prefill_chunk or (min(window, t) if window else t)
        # chunked prefill (ring-exact for SWA)
        pos0 = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
        logits = None
        for s in range(0, t, chunk):
            e = min(s + chunk, t)
            batch = {"tokens": toks[:, s:e], "positions": pos0[:, s:e]}
            logits, caches = self._prefill(self.params, batch, caches)

        out = [list(p) for p in prompts]
        cur = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)
        key = jax.random.key(cfg.seed)
        done = [False] * b
        for step in range(cfg.max_new_tokens):
            for i in range(b):
                if not done[i]:
                    tok = int(cur[i])
                    out[i].append(tok)
                    if cfg.eos_id is not None and tok == cfg.eos_id:
                        done[i] = True
            if all(done):
                break
            pos = jnp.full((b, 1), t + step, jnp.int32)
            batch = {"tokens": cur[:, None], "positions": pos}
            logits, caches = self._decode(self.params, caches, batch)
            last = logits[:, -1, :].astype(jnp.float32)
            if cfg.temperature > 0:
                key, sub = jax.random.split(key)
                cur = jax.random.categorical(sub, last / cfg.temperature, -1)
            else:
                cur = jnp.argmax(last, -1)
            cur = cur.astype(jnp.int32)
        return out
