"""Serving: batched prefill + decode engine over the model zoo's caches."""
from repro.serve.engine import ServeEngine, ServeConfig

__all__ = ["ServeEngine", "ServeConfig"]
