"""Process-wide counter/gauge registry (stdlib-only).

One home for the telemetry that used to live as scattered one-off state:
Eqn-6 VMEM fallbacks (``kernels/ops``), torn/skipped checkpoints and
crash-budget charges (``train/elastic``), drain vs reactive kills
(``ProcessSupervisor``). Everything is a named counter or gauge behind
one snapshot API; the snapshot rides in heartbeat payloads (so a
supervisor — and ``launch/fleet_status`` — sees a worker's counters
without extra channels) and in dryrun artifacts.

Naming convention: ``<subsystem>/<event>[/<detail>]``, e.g.
``eqn6/fallback/2048x2048x512``, ``ckpt/torn``, ``supervisor/kill``,
``fleet/adopted``. Gauges carry point-in-time values; the reserved gauge
``phase`` is the worker's current lifecycle phase (``boot`` → ``replan``
→ ``restore`` → ``migrate`` → ``train`` → ``final_eval``).

Counters from different processes merge by summation
(:func:`merge_snapshots`); gauges are per-process state, and the merge
winner is the DETERMINISTIC newest: each snapshot stamps ``ts`` (wall
clock at snapshot time) and ``host`` (``REPRO_HOST_ID``), and a gauge is
taken from the snapshot with the lexicographically largest
``(ts, host, input-position)``. Snapshots missing the stamps (older
artifacts) default to ``(-inf, "")`` so the historical
later-input-wins behavior is preserved for them.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Dict, Iterable, Optional


class Registry:
    """Thread-safe named counters + gauges with a snapshot API."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, Any] = {}

    # -- writes --------------------------------------------------------------
    def inc(self, name: str, n: float = 1.0) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + n

    def set_gauge(self, name: str, value: Any) -> None:
        with self._lock:
            self._gauges[name] = value

    def set_phase(self, phase: str) -> None:
        """The reserved lifecycle gauge every worker keeps current — what
        ``fleet_status`` reports as the host's phase."""
        self.set_gauge("phase", phase)

    # -- reads ---------------------------------------------------------------
    def get(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    def gauge(self, name: str, default: Any = None) -> Any:
        with self._lock:
            return self._gauges.get(name, default)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """A point-in-time copy: ``{"counters": {...}, "gauges": {...},
        "ts": ..., "host": ...}``. Counters are ints when integral so the
        snapshot JSON stays tidy; the ``(ts, host)`` stamp is what makes
        cross-process gauge merging deterministic (newest wins, host id
        breaks wall-clock ties)."""
        with self._lock:
            counters = {
                k: (int(v) if float(v).is_integer() else float(v))
                for k, v in self._counters.items()
            }
            return {
                "counters": counters,
                "gauges": dict(self._gauges),
                "ts": time.time(),
                "host": os.environ.get("REPRO_HOST_ID", ""),
            }

    def reset(self) -> None:
        """Test isolation; production registries live for the process."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()


def merge_snapshots(
    snaps: Iterable[Optional[Dict[str, Dict[str, Any]]]],
) -> Dict[str, Dict[str, Any]]:
    """Combine snapshots from several processes: counters sum (order
    independent — associative and commutative by construction), and each
    gauge is taken from the snapshot with the largest ``(ts, host,
    input-position)``. The explicit stamp makes the result a function of
    the snapshot CONTENTS, not the iteration order fleet_status happened
    to glob heartbeat files in; unstamped snapshots sort as ``(-inf, "")``
    so within an all-unstamped input the historical later-input-wins
    behavior is unchanged. ``None`` entries (host never reported) are
    skipped."""
    counters: Dict[str, float] = {}
    gauges: Dict[str, Any] = {}
    gauge_rank: Dict[str, tuple] = {}
    for idx, s in enumerate(snaps):
        if not s:
            continue
        for k, v in (s.get("counters") or {}).items():
            counters[k] = counters.get(k, 0.0) + float(v)
        try:
            ts = float(s.get("ts", float("-inf")))
        except (TypeError, ValueError):
            ts = float("-inf")
        rank = (ts, str(s.get("host", "") or ""), idx)
        for k, v in (s.get("gauges") or {}).items():
            if k not in gauge_rank or rank >= gauge_rank[k]:
                gauge_rank[k] = rank
                gauges[k] = v
    counters_out = {
        k: (int(v) if float(v).is_integer() else float(v))
        for k, v in counters.items()
    }
    return {"counters": counters_out, "gauges": gauges}


_REGISTRY = Registry()


def get_registry() -> Registry:
    """THE process-wide registry (one per process, like a logger root)."""
    return _REGISTRY
