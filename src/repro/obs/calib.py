"""Measured-cost feedback loop: recorded spans → ``coap-calib/v1``.

Closes ROADMAP item 1's cost-fitting half: the planner's roofline model
(``plan/cost.py``) predicts seconds from analytic chip constants; a
traced run records what steps ACTUALLY took (``loop/step`` spans with
per-step refresh-group attribution). This module aggregates those spans
against the plan's per-bucket byte/flop split and fits the two roofline
constants — effective HBM bandwidth and peak FLOPS — by non-negative
least squares on the *additive* relaxation

    t_step  ≈  bytes · (1/BW)  +  flops · (1/F)

using both hot-step samples (no refresh work) and refresh-step samples
(hot + the refreshing groups' event terms): the two populations mix
bytes and flops differently, which is what makes the two constants
separately identifiable. A single scalar measured/analytic ratio would
scale every candidate equally and never change a ranking; fitting BW
and F independently can.

The result is a versioned ``coap-calib/v1`` artifact that
``plan/cost.Calibration.load`` picks up (explicit path →
``REPRO_COAP_CALIB`` env → ``artifacts/calib/coap-calib.json``), after
which ``plan.solve()`` ranks candidates by fitted seconds. No artifact →
analytic constants → bit-identical plans.

This is the one jax-aware obs module (it re-derives the planned refresh
schedule); ``obs/trace`` / ``obs/registry`` / ``launch/fleet_status``
stay stdlib-only.
"""
from __future__ import annotations

import json
import os
from typing import Any, Callable, Dict, List, Optional

from repro.obs.trace import read_trace

CALIB_DEFAULT_PATH = os.path.join("artifacts", "calib", "coap-calib.json")


def planned_refresh_schedule(
    plan, params, ocfg
) -> Callable[[int], List[Dict[str, Any]]]:
    """The refresh-group schedule a planned run will follow, as a pure
    host-side function ``step -> [events]`` (each event:
    ``{bucket, phase, size, frac, kind}`` with kind ``eqn6`` | ``recal``).

    Derived from the SAME primitives the jitted update uses
    (``coap_adam.bucket_phases`` + the ``_sched_preds`` predicates over
    the exact planned config), so the attribution the loop attaches to
    its step spans matches what the kernel dispatch actually did —
    including the whole-bucket Eqn-7 initialization at step 0.
    """
    from repro.core import stacked_state
    from repro.core.coap_adam import _phase_groups, bucket_phases
    from repro.plan import apply as plan_apply

    cfg = plan_apply.planned_config(plan, ocfg)
    layout = stacked_state.layout_for_tree(cfg.rules.spec_for, params)
    phases = bucket_phases(cfg, layout)
    t_u_of = {}
    for b in plan.buckets:
        for p in b.paths:
            t_u_of[p] = int(b.t_update)
    lam = max(1, int(plan.globals_.lam))
    sched = []
    for bi in sorted(phases):
        info = layout.buckets[bi]
        t_u = max(1, t_u_of.get(info.paths[0], plan.globals_.t_update))
        count = len(info.indices)
        sched.append((bi, t_u, count, _phase_groups(list(phases[bi]))))

    def events_at(step: int) -> List[Dict[str, Any]]:
        out = []
        for bi, t_u, count, groups in sched:
            if step == 0:
                # Mandatory whole-bucket Eqn-7 init for everyone at t=0.
                out.append({"bucket": bi, "phase": 0, "size": count,
                            "frac": 1.0, "kind": "recal"})
                continue
            for _, size, ph in groups:
                if (step + ph) % t_u != 0:
                    continue
                kind = "recal" if (step + ph) % (lam * t_u) == 0 else "eqn6"
                out.append({
                    "bucket": bi, "phase": int(ph), "size": int(size),
                    "frac": size / max(1, count), "kind": kind,
                })
        return out

    return events_at


def _fit_nnls_2(samples: List[Dict[str, float]]):
    """Non-negative least squares for ``t ≈ x·bytes + y·flops`` (x, y ≥ 0)
    via the 2×2 normal equations; a negative coordinate falls back to the
    better-residual single-variable fit."""
    sbb = sum(s["bytes"] ** 2 for s in samples)
    sff = sum(s["flops"] ** 2 for s in samples)
    sbf = sum(s["bytes"] * s["flops"] for s in samples)
    sbt = sum(s["bytes"] * s["t"] for s in samples)
    sft = sum(s["flops"] * s["t"] for s in samples)

    def residual(x: float, y: float) -> float:
        return sum(
            (s["t"] - x * s["bytes"] - y * s["flops"]) ** 2 for s in samples
        )

    det = sbb * sff - sbf * sbf
    if det > 0:
        x = (sbt * sff - sft * sbf) / det
        y = (sft * sbb - sbt * sbf) / det
        if x >= 0 and y >= 0:
            return x, y, residual(x, y)
    xb = sbt / sbb if sbb > 0 else 0.0
    yf = sft / sff if sff > 0 else 0.0
    cands = [(max(0.0, xb), 0.0), (0.0, max(0.0, yf))]
    x, y = min(cands, key=lambda c: residual(*c))
    return x, y, residual(x, y)


def build_from_trace(
    trace_path: str,
    plan,
    out_path: Optional[str] = None,
    min_samples: int = 4,
) -> Dict[str, Any]:
    """Fit a ``coap-calib/v1`` artifact from a traced run's ``loop/step``
    spans and the plan they ran under. Returns the artifact dict (and
    writes it atomically to ``out_path`` when given).

    Compile-tagged spans (first step of an attempt — jit trace+compile
    dominates) are excluded. Raises ``ValueError`` below ``min_samples``
    usable spans: a fit from almost nothing would silently steer the
    planner.
    """
    import jax.numpy as jnp

    from repro.plan import cost as pcost
    from repro.train.fleet import plan_digest

    rows = read_trace(trace_path)
    steps = [
        r for r in rows
        if r.get("name") == "loop/step" and r.get("ph", "X") == "X"
        and not (r.get("attrs") or {}).get("compile")
    ]
    if len(steps) < min_samples:
        raise ValueError(
            f"build_from_trace: only {len(steps)} usable loop/step spans in "
            f"{trace_path} (need >= {min_samples}) — trace a longer run"
        )

    calib = pcost.Calibration.load()
    g = plan.globals_
    state_itemsize = jnp.dtype(g.state_dtype).itemsize
    splits = []
    for b in plan.buckets:
        splits.append(pcost.bucket_step_cost(
            b.kind, b.shape, b.spec, b.count,
            quantize=b.quantize, t_update=b.t_update, lam=g.lam,
            eqn6_steps=g.eqn6_steps, stacked_state=g.stacked_state,
            state_itemsize=state_itemsize,
            grad_itemsize=jnp.dtype(b.dtype).itemsize,
            calib=calib,
        ))
    hot_bytes = sum(c["hot_bytes"] for c in splits)
    hot_flops = sum(c["hot_flops"] for c in splits)

    samples = []
    n_refresh = 0
    for r in steps:
        attrs = r.get("attrs") or {}
        ev = attrs.get("refresh") or []
        bytes_ = hot_bytes
        flops = hot_flops
        for e in ev:
            bi = int(e.get("bucket", -1))
            if not (0 <= bi < len(splits)):
                continue
            c = splits[bi]
            frac = float(e.get("frac", 1.0))
            term = "recal" if e.get("kind") == "recal" else "eqn6"
            bytes_ += c[f"{term}_event_bytes"] * frac
            flops += c[f"{term}_event_flops"] * frac
        if ev:
            n_refresh += 1
        samples.append({
            "t": float(r["dur"]), "bytes": bytes_, "flops": flops,
        })

    x, y, res = _fit_nnls_2(samples)
    artifact = {
        "codec": pcost.CALIB_CODEC,
        # 1/x and 1/y are the fitted roofline constants; a coordinate the
        # fit zeroed (that term never bound) is recorded as None and
        # Calibration.load keeps the analytic constant for it.
        "hbm_bw": (1.0 / x) if x > 0 else None,
        "peak_flops": (1.0 / y) if y > 0 else None,
        "analytic": {
            "hbm_bw": pcost.HBM_BW, "peak_flops": pcost.PEAK_FLOPS,
        },
        "n_samples": len(samples),
        "n_refresh_samples": n_refresh,
        "residual_rms_s": (res / len(samples)) ** 0.5,
        "mean_step_s": sum(s["t"] for s in samples) / len(samples),
        "source": trace_path,
        "plan_digest": plan_digest(plan.to_dict()),
    }
    if out_path:
        save_calib(out_path, artifact)
    return artifact


def save_calib(path: str, artifact: Dict[str, Any]) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(artifact, f, indent=1)
    os.replace(tmp, path)


def load_calib(path: str) -> Dict[str, Any]:
    """Read + version-check a coap-calib artifact (loud, unlike the
    silently-optional consumption inside ``Calibration.load``)."""
    from repro.plan.cost import CALIB_CODEC

    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or data.get("codec") != CALIB_CODEC:
        raise ValueError(
            f"{path}: not a {CALIB_CODEC} artifact "
            f"(codec={data.get('codec') if isinstance(data, dict) else data!r})"
        )
    return data


def main(argv=None) -> int:
    """``python -m repro.obs.calib --trace trace.jsonl --plan plan.json``
    — fit and write the artifact from a recorded run."""
    import argparse

    ap = argparse.ArgumentParser(description="fit coap-calib/v1 from a trace")
    ap.add_argument("--trace", required=True)
    ap.add_argument("--plan", required=True,
                    help="the coap-plan/v1 the traced run executed under")
    ap.add_argument("--out", default=CALIB_DEFAULT_PATH)
    args = ap.parse_args(argv)

    from repro.plan.artifact import load_plan

    artifact = build_from_trace(args.trace, load_plan(args.plan),
                                out_path=args.out)
    print(json.dumps(artifact, indent=1))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
