"""Projection-health telemetry: numerics observability for the COAP math.

The tracer (``obs/trace.py``) and registry (``obs/registry.py``) see
wall-clock and counters; this module watches whether the projection is
silently degrading training. Per-bucket metrics come from two channels:

* **Refresh boundaries** (device-side, inside the optimizer's jitted
  update): the full gradient ``G`` is already materialized in the refresh
  branch, so captured energy ``‖G·P‖²_F/‖G‖²_F``, the Eqn-6 objective
  residual ``‖G − G P Pᵀ‖²_F/‖G‖²_F`` (via the trace identity — no m×n
  intermediate), and the subspace-drift proxy ``‖P̂_oldᵀP̂_new‖²_F/r``
  (column-normalized) cost only a few extra reductions. The emit lives
  under the same ``lax.cond`` as the refresh itself and ships scalars
  through ``jax.debug.callback`` — non-refresh steps execute NOTHING, so
  enabling health telemetry adds exactly zero extra HBM round-trips of
  ``G`` outside refresh steps (certified by ``BENCH_obs.json``'s
  ``health`` block).
* **Sampled step cadence** (host-side, :func:`observe_state`): int8
  moment-codec saturation/scale health and relative quant error, plus the
  ``sync_codes`` EF-sidecar norm trajectory, computed from the OPTIMIZER
  STATE alone — structurally no gradient access.

Rows append to a ``health.jsonl`` journal next to the trace (same
torn-write-tolerant format), and every metric mirrors into the process
registry as a ``health/<bucket>/<metric>`` gauge so it rides heartbeats
and dryrun artifacts for free. :func:`analyze` turns a journal into typed
verdicts (RANK_STARVED, QUANT_SATURATED, EF_NOT_DRAINING,
SUBSPACE_THRASH) that ``launch/fleet_status`` renders per host and
``plan/solver.solve(health_report=...)`` feeds back into rank floors.

Like its siblings this module imports ONLY the stdlib at module scope —
``launch/fleet_status`` must stay importable on an operator box without
jax. The device-side emitters import jax lazily, inside the traced
functions that are already jax-bound.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import statistics
import threading
import time
from typing import Any, Dict, List, Optional

HEALTH_CODEC_V1 = "coap-health/v1"

# Host-side sampling cadence (steps between observe_state calls); refresh
# metrics follow the optimizer's own T_u schedule and need no knob.
DEFAULT_SAMPLE_EVERY = 25

VERDICT_RANK_STARVED = "RANK_STARVED"
VERDICT_QUANT_SATURATED = "QUANT_SATURATED"
VERDICT_EF_NOT_DRAINING = "EF_NOT_DRAINING"
VERDICT_SUBSPACE_THRASH = "SUBSPACE_THRASH"
KNOWN_VERDICTS = (
    VERDICT_RANK_STARVED,
    VERDICT_QUANT_SATURATED,
    VERDICT_EF_NOT_DRAINING,
    VERDICT_SUBSPACE_THRASH,
)

DEFAULT_THRESHOLDS: Dict[str, float] = {
    # Median captured energy below this after warmup -> the rank floor is
    # starving the subspace (GaLore's quality-tracks-energy observation).
    "energy_floor": 0.5,
    # Median energy at/above this with no other verdicts -> headroom: the
    # solver may relax the bucket's rank floor one pow2 step.
    "energy_headroom": 0.98,
    # Column-normalized cross-refresh overlap below this after warmup ->
    # the subspace is thrashing (every refresh lands somewhere new).
    "overlap_floor": 0.5,
    # Refreshes to skip before drift/energy judgments (init + settle).
    "warmup_refreshes": 2,
    # |q| == 127 rail fraction above this -> codec saturating (absmax
    # scaling puts ~1/256 of uniform mass on the rail; a spike means the
    # distribution collapsed onto it). Non-finite scales always fire.
    "sat_rate_max": 0.05,
    # EF rms last-third/first-third growth ratio above this -> the error
    # feedback is accumulating instead of draining ~1/T.
    "ef_growth_max": 3.0,
    # Minimum EF samples before the growth-ratio judgment.
    "ef_min_samples": 6,
}


def bucket_label(kind: str, shape, dtype) -> str:
    """The stable per-bucket health key: ``<kind>:<dims>x..:<dtype>`` —
    deliberately WITHOUT the rank, so a recorded journal still addresses
    the same bucket after the solver tightens/relaxes its rank floor."""
    dims = "x".join(str(int(s)) for s in shape)
    return f"{kind}:{dims}:{dtype}"


# ---------------------------------------------------------------------------
# Monitor: journal writer + registry mirror
# ---------------------------------------------------------------------------
class HealthMonitor:
    """Appends health rows to one jsonl journal (torn-write-tolerant, like
    ``trace.jsonl``) and mirrors every metric into the process registry as
    a ``health/<bucket>/<metric>`` gauge. ``path=None`` disables: the
    device-side emitters check :attr:`enabled` at trace time, so disabled
    runs compile bit-identical programs."""

    def __init__(self, path: Optional[str] = None,
                 host: Optional[str] = None,
                 sample_every: int = DEFAULT_SAMPLE_EVERY):
        self.path = path
        self.host = host or os.environ.get("REPRO_HOST_ID", "")
        self.sample_every = int(sample_every)
        self._lock = threading.Lock()
        self._f = None
        if path:
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            self._f = open(path, "a")

    @property
    def enabled(self) -> bool:
        return self._f is not None

    def record(self, step: int, bucket: str, event: str,
               metrics: Dict[str, float]) -> None:
        """One journal row + registry gauges. ``event`` is ``"refresh"``
        (device emit at a refresh boundary) or ``"sample"`` (host-side
        state observation)."""
        if self._f is None:
            return
        clean: Dict[str, float] = {}
        for k, v in metrics.items():
            try:
                clean[k] = float(v)
            except (TypeError, ValueError):
                continue
        row = {
            "ts": time.time(),
            "host": self.host,
            "step": int(step),
            "bucket": bucket,
            "event": event,
            "metrics": clean,
        }
        line = json.dumps(row)
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()
        # Gauges ride heartbeats + dryrun artifacts via the registry.
        from repro.obs.registry import get_registry

        reg = get_registry()
        for k, v in clean.items():
            reg.set_gauge(f"health/{bucket}/{k}", v)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


_MONITOR = HealthMonitor(os.environ.get("REPRO_HEALTH") or None)


def get_monitor() -> HealthMonitor:
    """THE process-wide health monitor (disabled unless configured)."""
    return _MONITOR


def configure(path: Optional[str], host: Optional[str] = None,
              sample_every: Optional[int] = None) -> HealthMonitor:
    """(Re)configure the process monitor — what a worker does at boot from
    ``ElasticConfig.health_path``. ``path=None`` disables. Idempotent on
    the same path (keeps appending). ``REPRO_HEALTH`` is the env
    override, mirroring ``REPRO_TRACE``."""
    global _MONITOR
    if (
        _MONITOR.path == path
        and (host is None or _MONITOR.host == host)
        and (sample_every is None or _MONITOR.sample_every == sample_every)
    ):
        return _MONITOR
    old = _MONITOR
    _MONITOR = HealthMonitor(
        path, host=host,
        sample_every=(sample_every if sample_every is not None
                      else old.sample_every),
    )
    old.close()
    return _MONITOR


def read_health(path: str) -> List[Dict[str, Any]]:
    """All well-formed rows of a health.jsonl (torn trailing lines from a
    killed writer are skipped, like ``read_trace``)."""
    out = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(row, dict) and "bucket" in row and "step" in row:
                    out.append(row)
    except FileNotFoundError:
        pass
    return out


# ---------------------------------------------------------------------------
# Device-side refresh emitters (called INSIDE the optimizer's jitted update)
# ---------------------------------------------------------------------------
def emit_refresh_matrix(label: str, gc, p_old, p_new, refreshed, count):
    """Refresh-boundary metrics for a stacked matrix bucket, from inside
    the jitted update. ``gc`` (B,m,n) is the canonical gradient the
    refresh just consumed, ``p_old``/``p_new`` (B,n,r), ``refreshed`` the
    (B,) bool mask. Everything runs under ``lax.cond(any(refreshed))`` so
    non-refresh steps execute nothing — zero extra G traffic — and ships
    through ``jax.debug.callback`` (no value flows back: numerics are
    untouched). No-op (checked at trace time) when the monitor is
    disabled, so untraced runs compile bit-identical programs."""
    mon = get_monitor()
    if not mon.enabled:
        return
    import jax
    import jax.numpy as jnp
    from jax import lax

    def send(count_, n_ref, energy, resid, overlap):
        mon.record(int(count_), label, "refresh", {
            "n_refreshed": n_ref,
            "energy": energy,
            "eqn6_residual": resid,
            "subspace_overlap": overlap,
        })

    def do():
        g32 = gc.astype(jnp.float32)
        pn = p_new.astype(jnp.float32)
        po = p_old.astype(jnp.float32)
        mask = refreshed.astype(jnp.float32)

        # Per-stacked-element reduction: everything but axis 0 (the
        # bucket axis the ``refreshed`` mask indexes). Leaves may carry
        # extra leading dims beyond (B, m, n) — e.g. layer-stacked
        # (B, L, m, n) buckets — so reductions are ellipsis-shaped.
        def bsum(x):
            return jnp.sum(x.reshape(x.shape[0], -1), axis=1)

        gp = jnp.einsum("...mn,...nr->...mr", g32, pn)
        g_sq = bsum(g32 * g32)
        gp_sq = bsum(gp * gp)
        energy = gp_sq / jnp.maximum(g_sq, 1e-30)
        # ‖G − G P Pᵀ‖² = ‖G‖² − 2‖GP‖² + tr((GP)ᵀ(GP)·PᵀP): the r×r
        # trace identity — never materializes the m×n reconstruction.
        ptp = jnp.einsum("...nr,...ns->...rs", pn, pn)
        quad = bsum(jnp.einsum("...mr,...ms,...rs->...", gp, gp, ptp))
        resid = jnp.maximum(
            1.0 - 2.0 * energy + quad / jnp.maximum(g_sq, 1e-30), 0.0
        )
        # Column-normalized overlap: Eqn-6 P is not orthonormal, so the
        # raw ‖P_oldᵀP_new‖²/r would conflate scale with drift.
        pon = po / jnp.maximum(
            jnp.linalg.norm(po, axis=-2, keepdims=True), 1e-30
        )
        pnn = pn / jnp.maximum(
            jnp.linalg.norm(pn, axis=-2, keepdims=True), 1e-30
        )
        ov = jnp.einsum("...nr,...ns->...rs", pon, pnn)
        n_mats = max(
            1, int(jnp.size(pn) // (pn.shape[0] * pn.shape[-2] * pn.shape[-1]))
        )
        overlap = bsum(ov * ov) / (pn.shape[-1] * n_mats)
        n_ref = jnp.sum(mask)
        denom = jnp.maximum(n_ref, 1.0)

        def masked_mean(x):
            return jnp.sum(x * mask) / denom

        jax.debug.callback(
            send, count, n_ref, masked_mean(energy), masked_mean(resid),
            masked_mean(overlap),
        )

    lax.cond(jnp.any(refreshed), do, lambda: None)


def emit_refresh_conv(label: str, g32, po_old, pi_old, p_o, p_i,
                      refreshed, count):
    """Refresh-boundary metrics for a stacked Tucker-2 conv bucket:
    captured core energy (via column-normalized factors, so it is a true
    fraction) and the per-mode factor overlap, averaged. Same
    cond + debug.callback structure as the matrix emitter."""
    mon = get_monitor()
    if not mon.enabled:
        return
    import jax
    import jax.numpy as jnp
    from jax import lax
    from repro.core.conv import project_core

    def send(count_, n_ref, energy, overlap):
        mon.record(int(count_), label, "refresh", {
            "n_refreshed": n_ref,
            "energy": energy,
            "subspace_overlap": overlap,
        })

    def _colnorm(p):
        return p / jnp.maximum(
            jnp.linalg.norm(p.astype(jnp.float32), axis=1, keepdims=True),
            1e-30,
        )

    def do():
        mask = refreshed.astype(jnp.float32)
        pon, pin = _colnorm(p_o), _colnorm(p_i)
        core = project_core(g32.astype(jnp.float32), pon, pin)
        axes = tuple(range(1, g32.ndim))
        g_sq = jnp.sum(jnp.square(g32.astype(jnp.float32)), axis=axes)
        c_sq = jnp.sum(jnp.square(core), axis=tuple(range(1, core.ndim)))
        energy = c_sq / jnp.maximum(g_sq, 1e-30)

        def mode_overlap(old, new):
            ov = jnp.einsum(
                "bnr,bns->brs", _colnorm(old), _colnorm(new)
            )
            return jnp.sum(ov * ov, axis=(1, 2)) / new.shape[-1]

        overlap = 0.5 * (
            mode_overlap(po_old, p_o) + mode_overlap(pi_old, p_i)
        )
        n_ref = jnp.sum(mask)
        denom = jnp.maximum(n_ref, 1.0)
        jax.debug.callback(
            send, count, n_ref,
            jnp.sum(energy * mask) / denom,
            jnp.sum(overlap * mask) / denom,
        )

    lax.cond(jnp.any(refreshed), do, lambda: None)


# ---------------------------------------------------------------------------
# Host-side sampled observation (state only — structurally zero G reads)
# ---------------------------------------------------------------------------
def _find_projected_states(node, out: list) -> None:
    """Collect every optimizer-state node carrying (count, leaves) —
    ProjectedAdamState / ProjectedAdafactorState inside a possibly nested
    chain tuple — without importing the jax-heavy core modules."""
    if hasattr(node, "leaves") and hasattr(node, "count"):
        out.append(node)
        return
    if isinstance(node, (tuple, list)):
        for child in node:
            _find_projected_states(child, out)


def observe_state(opt_state, step: int,
                  monitor: Optional[HealthMonitor] = None) -> int:
    """Sampled host-side health pass over an optimizer state: per-bucket
    int8 codec stats (rail/saturation rate, non-finite scale fraction,
    relative quant error) and the ``sync_codes`` EF-sidecar rms. Reads
    ONLY the optimizer state — never the gradient — so the hot step path
    keeps exactly zero extra G round-trips. Stacked-state layouts only
    (the deployment default); per-leaf states are skipped silently.
    Returns the number of rows recorded."""
    mon = monitor or get_monitor()
    if not mon.enabled:
        return 0
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops as kops

    states: list = []
    _find_projected_states(opt_state, states)
    n_rows = 0
    for st in states:
        leaves = st.leaves
        layout = getattr(leaves, "layout", None)
        buckets = getattr(leaves, "buckets", None)
        if layout is None or buckets is None:
            continue
        for info, leaf in zip(layout.buckets, buckets):
            label = bucket_label(info.kind, info.shape, info.dtype)
            mets: Dict[str, Any] = {}
            for name in ("m", "v"):
                q = getattr(leaf, name, None)
                scale = getattr(leaf, name + "_scale", None)
                if q is None or scale is None:
                    continue
                if jnp.dtype(q.dtype) != jnp.int8:
                    continue
                stats = kops.rowblock_code_stats(q, scale)
                for k, v in stats.items():
                    mets[f"{name}_{k}"] = v
            ef = getattr(leaf, "ef", None)
            if ef is not None:
                ef32 = ef.astype(jnp.float32)
                mets["ef_rms"] = jnp.sqrt(jnp.mean(jnp.square(ef32)))
            if not mets:
                continue
            # ONE transfer for the bucket's whole stat dict.
            fetched = jax.device_get(mets)
            mon.record(step, label, "sample",
                       {k: float(v) for k, v in fetched.items()})
            n_rows += 1
    return n_rows


# ---------------------------------------------------------------------------
# Analysis: journal rows -> typed verdicts
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class HealthReport:
    """The ``coap-health/v1`` artifact: per-bucket metric summaries and
    typed verdicts. Unknown verdict strings from a NEWER writer round-trip
    untouched (forward compat): consumers render them as-is and the
    solver ignores verdicts it does not recognize."""

    buckets: Dict[str, Dict[str, Any]]
    verdicts: List[str]
    thresholds: Dict[str, float]
    codec: str = HEALTH_CODEC_V1

    def ok(self) -> bool:
        return not self.verdicts

    def to_dict(self) -> Dict[str, Any]:
        return {
            "codec": self.codec,
            "buckets": self.buckets,
            "verdicts": list(self.verdicts),
            "thresholds": dict(self.thresholds),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "HealthReport":
        codec = d.get("codec", "")
        if not str(codec).startswith("coap-health/"):
            raise ValueError(
                f"not a coap-health artifact (codec {codec!r})"
            )
        return cls(
            buckets=dict(d.get("buckets") or {}),
            verdicts=list(d.get("verdicts") or []),
            thresholds=dict(d.get("thresholds") or {}),
            codec=str(codec),
        )

    def save(self, path: str) -> None:
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        tmp = f"{path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "HealthReport":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def _finite(values) -> List[float]:
    return [float(v) for v in values
            if isinstance(v, (int, float)) and math.isfinite(v)]


def analyze(rows: List[Dict[str, Any]],
            thresholds: Optional[Dict[str, float]] = None) -> HealthReport:
    """Pure pass: journal rows -> :class:`HealthReport`. Safe on empty,
    partial and unknown-schema rows (skips anything malformed) — exactly
    what ``fleet_status`` runs on an operator box."""
    th = dict(DEFAULT_THRESHOLDS)
    th.update(thresholds or {})
    by_bucket: Dict[str, List[Dict[str, Any]]] = {}
    for r in rows:
        if not isinstance(r, dict):
            continue
        label = r.get("bucket")
        if not isinstance(label, str) or not isinstance(
            r.get("metrics"), dict
        ):
            continue
        by_bucket.setdefault(label, []).append(r)

    buckets: Dict[str, Dict[str, Any]] = {}
    for label in sorted(by_bucket):
        rs = sorted(
            by_bucket[label],
            key=lambda r: (r.get("step", 0), r.get("ts", 0.0)),
        )
        refresh = [r["metrics"] for r in rs if r.get("event") == "refresh"]
        samples = [r["metrics"] for r in rs if r.get("event") == "sample"]
        warm = refresh[int(th["warmup_refreshes"]):]
        metrics: Dict[str, float] = {}
        verdicts: List[str] = []

        energies = _finite(m.get("energy") for m in (warm or refresh))
        if energies:
            med = statistics.median(energies)
            metrics["energy_median"] = med
            if med < th["energy_floor"]:
                verdicts.append(VERDICT_RANK_STARVED)

        overlaps = _finite(m.get("subspace_overlap") for m in warm)
        if len(overlaps) >= 2:
            ov = statistics.median(overlaps)
            metrics["overlap_median"] = ov
            if ov < th["overlap_floor"]:
                verdicts.append(VERDICT_SUBSPACE_THRASH)

        resids = _finite(m.get("eqn6_residual") for m in refresh)
        if resids:
            metrics["eqn6_residual_last"] = resids[-1]

        sat_rates, nonfinite, err_rels = [], [], []
        for m in samples:
            for k, v in m.items():
                if not isinstance(v, (int, float)) or not math.isfinite(v):
                    if k.endswith("scale_nonfinite"):
                        nonfinite.append(1.0)
                    continue
                if k.endswith("sat_rate"):
                    sat_rates.append(float(v))
                elif k.endswith("scale_nonfinite"):
                    nonfinite.append(float(v))
                elif k.endswith("err_rel"):
                    err_rels.append(float(v))
        if sat_rates:
            metrics["sat_rate_max"] = max(sat_rates)
        if nonfinite:
            metrics["scale_nonfinite_max"] = max(nonfinite)
        if err_rels:
            metrics["quant_err_rel_median"] = statistics.median(err_rels)
        if (nonfinite and max(nonfinite) > 0.0) or (
            sat_rates and max(sat_rates) > th["sat_rate_max"]
        ):
            verdicts.append(VERDICT_QUANT_SATURATED)

        efs = _finite(m.get("ef_rms") for m in samples
                      if "ef_rms" in m)
        if len(efs) >= int(th["ef_min_samples"]):
            k = max(1, len(efs) // 3)
            first = sum(efs[:k]) / k
            last = sum(efs[-k:]) / k
            ratio = last / first if first > 0 else (
                math.inf if last > 0 else 1.0
            )
            metrics["ef_growth_ratio"] = (
                ratio if math.isfinite(ratio) else 1e30
            )
            if ratio > th["ef_growth_max"]:
                verdicts.append(VERDICT_EF_NOT_DRAINING)

        buckets[label] = {
            "verdicts": verdicts,
            "metrics": metrics,
            "n_refresh": len(refresh),
            "n_sample": len(samples),
        }

    all_verdicts = sorted(
        {v for b in buckets.values() for v in b["verdicts"]}
    )
    return HealthReport(buckets=buckets, verdicts=all_verdicts,
                        thresholds=th)


def analyze_journal(path: str,
                    thresholds: Optional[Dict[str, float]] = None,
                    tail: int = 0) -> HealthReport:
    """:func:`analyze` over a journal file (``tail`` > 0 limits to the
    newest rows — what ``fleet_status`` uses for a live view)."""
    rows = read_health(path)
    if tail > 0:
        rows = rows[-tail:]
    return analyze(rows, thresholds=thresholds)
