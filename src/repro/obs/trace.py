"""Span tracer (stdlib-only): nested wall-clock spans → trace.jsonl →
Chrome/Perfetto ``trace_event`` JSON.

A span is one timed region with attributes::

    tracer = get_tracer()
    with tracer.span("loop/step", step=step) as sp:
        ...
        sp.set(refresh_groups=2)      # attrs discovered mid-span

Rows are appended to an append-only ``trace.jsonl`` on span EXIT (one
JSON object per line — the same torn-write-tolerant journal format as
``events.jsonl``), with nesting recovered from per-thread ``parent``/
``depth`` fields rather than file order, so interleaved threads and
worker restarts append safely to one file.

Disabled (no path configured) tracing costs one attribute load and a
truthiness check per ``span()`` call — ``span()`` returns a shared no-op
context manager, no allocation, no clock read. That is what the
``benchmarks/overhead.run_obs`` <3% hot-path gate certifies.

Export: :func:`export_perfetto` converts a trace.jsonl into the Chrome
``trace_event`` format (``{"traceEvents": [...]}``; ``ph: "X"`` complete
events with microsecond ``ts``/``dur``, ``ph: "i"`` instants) which
chrome://tracing and https://ui.perfetto.dev load directly.

Workers configure the module tracer once at boot (``configure(path)`` —
``launch/worker.py`` does this from ``ElasticConfig.trace_path``); the
``REPRO_TRACE`` environment variable is the no-code-change override.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


class _NullSpan:
    """Shared no-op span: the entire disabled-tracing code path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs) -> None:
        return None


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "attrs", "t0", "parent", "depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "_Span":
        stack = self.tracer._stack()
        self.parent = stack[-1].name if stack else None
        self.depth = len(stack)
        stack.append(self)
        self.t0 = time.time()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        dur = time.time() - self.t0
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        row = {
            "ph": "X",
            "name": self.name,
            "ts": self.t0,
            "dur": dur,
            "pid": self.tracer.pid,
            "tid": threading.get_ident(),
            "host": self.tracer.host,
            "depth": self.depth,
            "parent": self.parent,
        }
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        if self.attrs:
            row["attrs"] = self.attrs
        self.tracer._write(row)


class Tracer:
    """Appends span/instant rows to one jsonl file; thread-safe (a lock
    serializes writes, a ``threading.local`` stack tracks nesting per
    thread). With ``path=None`` the tracer is disabled and near-free."""

    def __init__(self, path: Optional[str] = None,
                 host: Optional[str] = None):
        self.path = path
        self.host = host or os.environ.get("REPRO_HOST_ID", "")
        self.pid = os.getpid()
        self._lock = threading.Lock()
        self._local = threading.local()
        self._f = None
        if path:
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            self._f = open(path, "a")

    @property
    def enabled(self) -> bool:
        return self._f is not None

    def _stack(self) -> List[_Span]:
        s = getattr(self._local, "stack", None)
        if s is None:
            s = self._local.stack = []
        return s

    def _write(self, row: Dict[str, Any]) -> None:
        if self._f is None:
            return
        line = json.dumps(row, default=str)
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()

    # -- the API -------------------------------------------------------------
    def span(self, name: str, **attrs):
        """A timed context manager; the row is written on exit."""
        if self._f is None:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """A point event (``ph: "i"``) — decisions, faults, commits."""
        if self._f is None:
            return
        row = {
            "ph": "i",
            "name": name,
            "ts": time.time(),
            "pid": self.pid,
            "tid": threading.get_ident(),
            "host": self.host,
        }
        if attrs:
            row["attrs"] = attrs
        self._write(row)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


_TRACER = Tracer(os.environ.get("REPRO_TRACE") or None)


def get_tracer() -> Tracer:
    """THE process-wide tracer (disabled unless configured)."""
    return _TRACER


def configure(path: Optional[str], host: Optional[str] = None) -> Tracer:
    """(Re)configure the process tracer — what a worker does at boot from
    ``ElasticConfig.trace_path``. ``path=None`` disables. Idempotent: a
    reconfigure to the same path keeps appending to it."""
    global _TRACER
    if _TRACER.path == path and (host is None or _TRACER.host == host):
        return _TRACER
    old = _TRACER
    _TRACER = Tracer(path, host=host)
    old.close()
    return _TRACER


# -- reading / export --------------------------------------------------------


def read_trace(path: str) -> List[Dict[str, Any]]:
    """All well-formed rows of a trace.jsonl (torn trailing lines — a
    killed worker mid-append — are skipped, like ``read_events``)."""
    out = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(row, dict) and "name" in row and "ts" in row:
                    out.append(row)
    except FileNotFoundError:
        pass
    return out


def trace_events(rows: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Chrome ``trace_event`` list from trace rows: ``ph "X"`` complete
    events (ts/dur in µs) and ``ph "i"`` instants. One process row per
    (host, pid) via ``process_name`` metadata."""
    events: List[Dict[str, Any]] = []
    seen_procs = set()
    for r in rows:
        pid = int(r.get("pid", 0))
        host = r.get("host") or ""
        if (host, pid) not in seen_procs:
            seen_procs.add((host, pid))
            events.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": f"{host or 'proc'}:{pid}"},
            })
        ev = {
            "name": r["name"],
            "ph": r.get("ph", "X"),
            "ts": float(r["ts"]) * 1e6,
            "pid": pid,
            "tid": int(r.get("tid", 0)),
            "cat": str(r["name"]).split("/")[0],
            "args": dict(r.get("attrs") or {}),
        }
        if ev["ph"] == "X":
            ev["dur"] = float(r.get("dur", 0.0)) * 1e6
        else:
            ev["s"] = "t"  # instant scope: thread
        events.append(ev)
    return events


def export_perfetto(trace_path: str, out_path: str) -> Dict[str, Any]:
    """trace.jsonl → a Perfetto/chrome://tracing-loadable JSON file.
    Returns the document (also written to ``out_path``)."""
    doc = {
        "traceEvents": trace_events(read_trace(trace_path)),
        "displayTimeUnit": "ms",
    }
    parent = os.path.dirname(out_path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = f"{out_path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, out_path)
    return doc
