"""Unified telemetry: span tracing, counter registry, cost calibration,
projection-health metrics.

``obs.trace``, ``obs.registry`` and ``obs.health`` are STDLIB-ONLY at
import by design — the operator CLI (``launch/fleet_status``), the fleet
protocol (``train/fleet.py``) and the kernel dispatch layer all import
them, and none of those should drag in jax (``obs.health`` imports jax
lazily inside its device-side emitters only). ``obs.calib`` (the
measured-cost feedback loop) is the one jax-aware module: it re-derives
the planned refresh schedule and fits roofline constants from recorded
spans.
"""
from repro.obs.registry import get_registry, merge_snapshots  # noqa: F401
from repro.obs.trace import configure, get_tracer  # noqa: F401
