"""Unified telemetry: span tracing, counter registry, cost calibration.

``obs.trace`` and ``obs.registry`` are STDLIB-ONLY by design — the
operator CLI (``launch/fleet_status``), the fleet protocol
(``train/fleet.py``) and the kernel dispatch layer all import them, and
none of those should drag in jax. ``obs.calib`` (the measured-cost
feedback loop) is the one jax-aware module: it re-derives the planned
refresh schedule and fits roofline constants from recorded spans.
"""
from repro.obs.registry import get_registry, merge_snapshots  # noqa: F401
from repro.obs.trace import configure, get_tracer  # noqa: F401
