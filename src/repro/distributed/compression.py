"""Cross-pod projected-gradient compression (beyond-paper; DESIGN.md §5).

The pod axis is pure data parallelism over the slowest links. The baseline
step all-reduces the full gradient G (m·n per matrix) across pods. But COAP
consumes G only two ways:

  1. every step:   G_proj = G P        (m·r — the moment/update input)
  2. every T_u:    the full G          (Eqn-6/Eqn-7 refresh input)

Projection is linear, so  mean_pods(G)·P == mean_pods(G·P)  exactly. We
therefore all-reduce the r-rank projection each step and the full gradient
only on refresh steps:

    cross-pod bytes/step = m·r + m·n/T_u      vs      m·n

Conv (Tucker-2) leaves compress the same way: the n-mode products are
linear, so the r_O·r_I·K1·K2 projected core is all-reduced each step and
the full O·I·K1·K2 gradient only on factor-refresh steps.

PARITY WITH THE CORE TRANSFORM. ``compressed_update`` supports every
configuration ``scale_by_projected_adam`` supports and runs the same
schedule machinery, so a pod-parallel run obeys the same plan as the
identical single-pod run:

  * **strategies** — coap / galore / flora refresh through the shared
    ``_refresh_p`` (matrix) and the conv strategy dispatch, including
    flora's per-leaf RNG keyed by the ORIGINAL flat leaf index;
  * **staggered refresh** — per-leaf phases come from the shared
    ``bucket_phases`` allocation (the same pure function of (layout, cfg)
    the core transform and the elastic supervisor use), so refresh cadence
    is identical to the single-pod staggered schedule;
  * **per-bucket overrides** — a plan's per-bucket quantize / T_u /
    stagger_groups ride through ``_bucket_cfg`` exactly as in the core
    transform (mixed-override buckets raise the same ValueError, naming
    the offending paths);
  * **quantized states** (``quantize=True``) — the dequant→reduce→requant
    schedule: int8 moment codes are dequantized in-pod, the r-rank
    projected gradient is reduced in fp32, the moment EMA runs in fp32 and
    the results are requantized through the SAME row-block (projected) /
    flat (conv, dense) codecs the single-pod path uses. The op sequence
    per leaf mirrors the unfused oracle (``kernels/ref``'s
    ``coap_fused_update_q8`` / ``quantized_adam_update``) exactly, so
    where the pod-mean is the identity (identical per-pod gradients) the
    emitted int8 codes are BIT-EXACT against the single-pod quantized step
    (``use_fused_kernel=False``); otherwise the only drift is the fp32
    pmean itself — no extra codec rounding, the moments pay exactly the
    same one requantization per step the single-pod schedule pays.

INT8 COLLECTIVE (``sync_codes=True``). The fp32 r-rank reduction is
replaced by an all-reduce of int8 CODES: each pod adds its error-feedback
accumulator to its local G_proj, the per-block absmax is agreed via a
(scales-only) ``pmax``, every pod emits codes under that shared scale, and
the codes are summed (a psum of int8 payloads — the wire carries ~1 byte
per element plus one fp32 scale per ``quant_block`` elements, vs 4 bytes
per element for fp32 sync). The mean is reconstructed as
``scale·Σq/npods``, paying exactly ONE extra blockwise rounding per step —
the same single-rounding rule ``stacked_state.migrate`` documents for
quantize flips. The rounding residue goes into a per-leaf fp32
error-feedback accumulator (``ProjLeaf.ef`` / ``ConvLeaf.ef``, allocated
by ``init_fn`` when ``cfg.sync_codes``; accounted as 'ef_sidecar' and
predicted by ``plan/bytes.py``), so the applied reductions telescope:
``Σ_t applied_t = Σ_t mean_t + ef_0 − ef_T`` — quantization error does not
accumulate in the moments. SIMULATION NOTE: real hardware keeps each pod's
own residual ``y_k − s·q_k`` locally (no extra traffic); to keep the
optimizer state replicated under this pure-DP shard_map (``out_specs
P()``) we store the pod-mean residual instead — the telescoping guarantee
is identical, and the residual mean is NOT part of the modeled wire format
(``benchmarks/overhead.run_sync`` counts codes + scales). The full-G
refresh-step all-reduce stays fp32 (rare; amortized by T_u). Dense leaves
(small) always sync fp32.

Implementation: ``shard_map`` manual over the 'pod' axis only (data/model
stay auto inside), computing per-pod gradients, reducing the compressed
tensors, and running the same leaf update the core transform uses.

Stacked-state aware: when the optimizer state is stored pre-stacked
(``stacked_state=True``; core/stacked_state.py), per-leaf moments are
addressed as bucket slices through the codec's ``leaf_view`` — inside jit
those slices fuse into their consumers, so the reduction schedule (r-rank
every step, full G on refresh steps) is unchanged — and the new leaf states
are re-encoded into the same stacked layout on the way out. The per-leaf
branch validates each state leaf against its path's spec (kind + stored
shapes), so a congruent-but-mismatched state tree raises instead of
silently pairing moments with the wrong leaves.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import conv as conv_mod
from repro.core import projector, recalibrate
from repro.core import stacked_state
from repro.core.coap_adam import (
    ConvLeaf,
    DenseLeaf,
    ProjLeaf,
    ProjectedAdamConfig,
    ProjectedAdamState,
    _bucket_cfg,
    _leaf_cfg,
    _load,
    _maybe_transplant,
    _refresh_p,
    _sched_preds,
    _store,
    _wants_transplant,
    bucket_phases,
)
from repro.core.projector import KIND_CONV, KIND_DENSE, KIND_PROJECT, path_str
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.optim import apply_updates
from repro.train.train_state import TrainState


def _allreduce_codes(x, ef, axis_name: str, block: int):
    """Int8-code all-reduce with error feedback (the sync_codes wire path).

    ``x`` is this pod's fp32 contribution, ``ef`` the replicated fp32
    error-feedback accumulator. Wire payload per step: ``numel(x)`` int8
    codes + ``ceil(numel/block)`` fp32 scales (the pmax of block absmaxes).
    Returns ``(reduced_mean, new_ef)`` where the mean carries exactly one
    blockwise rounding and ``new_ef`` is the pod-mean rounding residual
    (see the module docstring's simulation note).

    Telemetry contract (``obs/health.observe_state``): the EF sidecar this
    returns is stored on ``ProjLeaf.ef`` / ``ConvLeaf.ef`` and sampled
    HOST-SIDE at the health cadence as ``ef_rms`` — no in-collective
    instrumentation, no per-device callbacks under shard_map. A healthy
    loop keeps ``ef_rms`` bounded (the applied error telescopes, shrinking
    ~1/T over a window); a monotonically growing trajectory means the
    compensation is not being applied and fires ``EF_NOT_DRAINING``.
    """
    y = x + ef  # compensated contribution: EF applies once, in the mean
    flat = y.reshape(-1)
    n = flat.shape[0]
    nblocks = -(-n // block)
    pad = nblocks * block - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    b = flat.reshape(nblocks, block)
    # Shared per-block scale: agree on the global absmax first (a
    # scales-only exchange), so every pod's codes are commensurable and
    # the sum of codes dequantizes to the sum of quantized values exactly.
    absmax = lax.pmax(jnp.max(jnp.abs(b), axis=-1), axis_name)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(b * inv[:, None]), -127.0, 127.0)
    # The modeled wire: int8 codes. (Simulated as an f32 psum — integer
    # code sums are exact in f32 far beyond any real pod count.)
    qsum = lax.psum(q, axis_name)
    npods = lax.psum(jnp.ones((), jnp.float32), axis_name)

    def unpack(blocks):
        return blocks.reshape(-1)[:n].reshape(x.shape)

    red = unpack(scale[:, None] * (qsum / npods))
    deq_local = unpack(scale[:, None] * q)
    new_ef = lax.pmean(y - deq_local, axis_name)
    return red, new_ef


def _check_leaf_state(path: str, spec, leaf, lcfg: ProjectedAdamConfig, g):
    """Per-leaf structural validation (the per-leaf-branch counterpart of
    the stacked layout signature check): the state leaf's KIND and stored
    shapes must match what this path's spec implies, or moments would be
    silently paired with the wrong leaves (congruent-but-reordered state
    trees). Raises a loud ValueError naming the path."""
    want = {KIND_PROJECT: ProjLeaf, KIND_CONV: ConvLeaf}.get(
        spec.kind, DenseLeaf
    )
    if not isinstance(leaf, want):
        raise ValueError(
            f"compressed_update: state leaf at {path!r} is "
            f"{type(leaf).__name__}, expected {want.__name__} for spec kind "
            f"{spec.kind!r} — the state tree does not match the gradient "
            "tree (rules / model structure changed since init, or a "
            "reordered congruent tree was passed)"
        )

    def flat_codec_shape(numel: int):
        nblocks = -(-numel // lcfg.quant_block)
        return (nblocks, lcfg.quant_block)

    if spec.kind == KIND_PROJECT:
        # The row-block codec is shape-preserving: quantized or not, the
        # stored moment has the canonical moment shape.
        ok = tuple(leaf.m.shape) == tuple(
            projector.moment_shape(g.shape, spec)
        )
    elif spec.kind == KIND_CONV:
        csh = conv_mod.core_shape(g.shape, spec)
        core = 1
        for s in csh:
            core *= int(s)
        o, i = int(g.shape[0]), int(g.shape[1])
        want_m = flat_codec_shape(core) if lcfg.quantize else tuple(csh)
        ok = (
            tuple(leaf.p_o.shape) == (o, int(spec.rank_o))
            and tuple(leaf.p_i.shape) == (i, int(spec.rank_i))
            and tuple(leaf.m.shape) == want_m
        )
    else:
        nel = 1
        for s in g.shape:
            nel *= int(s)
        want_mu = flat_codec_shape(nel) if lcfg.quantize else tuple(g.shape)
        ok = tuple(leaf.mu.shape) == want_mu
    if not ok:
        raise ValueError(
            f"compressed_update: state leaf at {path!r} has stored shapes "
            "inconsistent with this leaf's spec — the state tree does not "
            "match the gradient tree (reordered congruent tree, or a "
            "quantize flip without stacked_state.migrate?)"
        )


def _check_ef(path: str, leaf) -> None:
    if leaf.ef is None:
        raise ValueError(
            f"compressed_update: sync_codes=True but the state leaf at "
            f"{path!r} has no error-feedback sidecar — the state was "
            "initialized by a config without sync_codes; re-initialize "
            "(or migrate) before enabling the int8 collective"
        )


def _update_proj_compressed(lcfg, leaf: ProjLeaf, g, spec, count, t, idx,
                            ph: int, axis_name: str):
    """One compressed step for one projected leaf: the single-pod unfused
    op sequence (``kref.coap_fused_update_q8`` when quantized) with the
    r-rank reduction spliced in between projection and the moment EMA."""
    gc_local = projector.to_canonical(g, spec).astype(jnp.float32)
    do_ref, _ = _sched_preds(count, ph, lcfg.t_update, lcfg.lam)
    p_old = leaf.p

    if lcfg.quantize:
        def m_loader():
            return kops.dequantize_rowblock(
                leaf.m[None], leaf.m_scale[None], block=lcfg.quant_block
            )
    else:
        def m_loader():
            return leaf.m[None].astype(jnp.float32)

    # Refresh needs the full averaged gradient (rare — every T_u steps for
    # this leaf's phase). Off refresh steps the branch is untaken and the
    # full-G all-reduce does not happen; the local value only feeds
    # _refresh_p's untaken branches.
    gc_full = lax.cond(
        do_ref, lambda: lax.pmean(gc_local, axis_name), lambda: gc_local
    )
    # B=1 lift onto the SHARED strategy/stagger refresh machinery (the
    # original flat idx keeps flora's per-leaf RNG stream unchanged; the
    # single phase (ph,) reproduces this leaf's staggered cadence).
    new_p, refreshed = _refresh_p(
        lcfg, spec, p_old[None], gc_full[None], m_loader, count,
        jnp.asarray([idx], jnp.int32), (ph,),
    )
    new_p = new_p[0]
    refreshed0 = refreshed[0]

    if lcfg.quantize:
        m_q, m_s = leaf.m, leaf.m_scale
        if _wants_transplant(lcfg):
            # Match the core quantized transplant bit-for-bit: the carried
            # M pays one int8 requant→dequant round-trip on refresh steps
            # (_update_proj_bucket.carry_q — "one added block-absmax
            # rounding per refresh").
            def transplanted():
                carried = projector.project(
                    projector.backproject(m_loader()[0], p_old), new_p
                )
                return kops.quantize_rowblock(
                    carried, block=lcfg.quant_block
                )

            m_q, m_s = lax.cond(
                refreshed0, transplanted, lambda: (m_q, m_s)
            )
        # The unfused oracle schedule, inlined so the reduction replaces
        # its local projection (kref is what use_fused_kernel=False runs).
        m32 = kref.dequantize_rowblock(m_q, m_s, lcfg.quant_block)
        v32 = kref.dequantize_rowblock(leaf.v, leaf.v_scale, lcfg.quant_block)
    else:
        m32 = leaf.m.astype(jnp.float32)
        v32 = leaf.v.astype(jnp.float32)
        m32 = _maybe_transplant(lcfg, m32, p_old, new_p, refreshed0)

    # Every-step path: reduce only the r-rank projection (linearity:
    # project(pmean(G)) == pmean(project(G)) — P is replicated).
    g_proj_local = projector.project(gc_local, new_p)
    if lcfg.sync_codes:
        g_proj, new_ef = _allreduce_codes(
            g_proj_local, leaf.ef, axis_name, lcfg.quant_block
        )
    else:
        g_proj = lax.pmean(g_proj_local, axis_name)
        new_ef = leaf.ef

    new_m = lcfg.b1 * m32 + (1.0 - lcfg.b1) * g_proj
    new_v = lcfg.b2 * v32 + (1.0 - lcfg.b2) * jnp.square(g_proj)
    tf = t.astype(jnp.float32)
    delta = (new_m / (1.0 - lcfg.b1**tf)) / (
        jnp.sqrt(new_v / (1.0 - lcfg.b2**tf)) + lcfg.eps
    )
    if lcfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
        delta = jnp.clip(delta, -kref.QUANT_DELTA_CLIP, kref.QUANT_DELTA_CLIP)
    update_c = projector.backproject(delta, new_p)
    update = projector.from_canonical(update_c, spec) * lcfg.update_scale

    if lcfg.quantize:
        nm, nms = kref.quantize_rowblock(new_m, lcfg.quant_block)
        nv, nvs = kref.quantize_rowblock(new_v, lcfg.quant_block)
    else:
        nm = new_m.astype(lcfg.state_dtype)
        nv = new_v.astype(lcfg.state_dtype)
        nms, nvs = leaf.m_scale, leaf.v_scale  # fp32 placeholders
    return update.astype(g.dtype), ProjLeaf(
        p=new_p, m=nm, v=nv, m_scale=nms, v_scale=nvs, ef=new_ef
    )


def _conv_refresh(lcfg, leaf: ConvLeaf, g_full32, m32, spec, count, ph, idx):
    """Strategy-aware Tucker-2 factor refresh for ONE leaf, mirroring
    ``conv.update_conv_bucket.refresh_slice`` (B=1): coap goes through the
    shared ``refresh_factors``, galore re-SVDs the canonical unfoldings,
    flora resamples with the same ``7919·idx + mode`` key folding."""
    g1 = conv_mod.mode1_canonical(g_full32)
    g2 = conv_mod.mode2_canonical(g_full32)
    if lcfg.strategy == "coap":
        _, do_recal = _sched_preds(count, ph, lcfg.t_update, lcfg.lam)
        return conv_mod.refresh_factors(
            lcfg, leaf.p_o, leaf.p_i, g1, g2, m32, do_recal
        )
    if lcfg.strategy == "galore":
        return (
            recalibrate.galore_svd(g1, spec.rank_o).astype(leaf.p_o.dtype),
            recalibrate.galore_svd(g2, spec.rank_i).astype(leaf.p_i.dtype),
        )

    # flora
    def resample(mode, canon_shape, rank, dtype):
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(lcfg.seed), 7919 * idx + mode),
            count,
        )
        return recalibrate.random_projection(key, canon_shape, rank, dtype)

    return (
        resample(1, g1.shape, spec.rank_o, leaf.p_o.dtype),
        resample(2, g2.shape, spec.rank_i, leaf.p_i.dtype),
    )


def _update_conv_compressed(lcfg, leaf: ConvLeaf, g, spec, count, t, idx,
                            ph: int, axis_name: str):
    """Tucker-2 leaves: only the r_O·r_I·K1·K2 core is all-reduced each
    step; the full gradient crosses pods on factor-refresh steps only."""
    g32_local = g.astype(jnp.float32)
    do_ref, _ = _sched_preds(count, ph, lcfg.t_update, lcfg.lam)
    csh = conv_mod.core_shape(g.shape, spec)
    m32 = _load(leaf.m, leaf.m_scale, tuple(csh), lcfg)
    v32 = _load(leaf.v, leaf.v_scale, tuple(csh), lcfg)

    def conv_refreshed():
        g_full = lax.pmean(g32_local, axis_name)
        return _conv_refresh(lcfg, leaf, g_full, m32, spec, count, ph, idx)

    p_o, p_i = lax.cond(
        do_ref, conv_refreshed, lambda: (leaf.p_o, leaf.p_i)
    )
    core_local = conv_mod.project_core(g32_local, p_o, p_i)
    if lcfg.sync_codes:
        g_core, new_ef = _allreduce_codes(
            core_local, leaf.ef, axis_name, lcfg.quant_block
        )
    else:
        g_core = lax.pmean(core_local, axis_name)
        new_ef = leaf.ef
    new_m = lcfg.b1 * m32 + (1.0 - lcfg.b1) * g_core
    new_v = lcfg.b2 * v32 + (1.0 - lcfg.b2) * jnp.square(g_core)
    tf = t.astype(jnp.float32)
    delta_core = (new_m / (1.0 - lcfg.b1**tf)) / (
        jnp.sqrt(new_v / (1.0 - lcfg.b2**tf)) + lcfg.eps
    )
    if lcfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
        delta_core = jnp.clip(
            delta_core, -kref.QUANT_DELTA_CLIP, kref.QUANT_DELTA_CLIP
        )
    update = conv_mod.restore_core(delta_core, p_o, p_i) * lcfg.update_scale
    sm, sms = _store(new_m, lcfg)
    sv, svs = _store(new_v, lcfg)
    return update.astype(g.dtype), ConvLeaf(
        p_o=p_o, p_i=p_i, m=sm, v=sv, m_scale=sms, v_scale=svs, ef=new_ef
    )


def _update_dense_compressed(lcfg, leaf: DenseLeaf, g, t, axis_name: str):
    """Dense leaves: classic full all-reduce + Adam (small tensors; always
    fp32 on the wire). Quantized states follow the dequant→reduce→requant
    schedule of ``kref.quantized_adam_update``."""
    g32 = lax.pmean(g.astype(jnp.float32), axis_name)
    mu = _load(leaf.mu, leaf.mu_scale, tuple(g.shape), lcfg)
    nu = _load(leaf.nu, leaf.nu_scale, tuple(g.shape), lcfg)
    new_mu = lcfg.b1 * mu + (1.0 - lcfg.b1) * g32
    new_nu = lcfg.b2 * nu + (1.0 - lcfg.b2) * jnp.square(g32)
    tf = t.astype(jnp.float32)
    upd = (new_mu / (1.0 - lcfg.b1**tf)) / (
        jnp.sqrt(new_nu / (1.0 - lcfg.b2**tf)) + lcfg.eps
    )
    if lcfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
        upd = jnp.clip(upd, -kref.QUANT_DELTA_CLIP, kref.QUANT_DELTA_CLIP)
    smu, smus = _store(new_mu, lcfg)
    snu, snus = _store(new_nu, lcfg)
    return upd.astype(g.dtype), DenseLeaf(
        mu=smu, nu=snu, mu_scale=smus, nu_scale=snus
    )


def compressed_update(cfg: ProjectedAdamConfig, grads, state: ProjectedAdamState,
                      axis_name: str = "pod"):
    """Per-pod grads -> (updates, new_state) with compressed cross-pod
    reduction. Must run inside shard_map manual over ``axis_name``.

    Semantics == all-reduce(grads) then core update (linearity; the full-G
    all-reduce still happens on refresh steps, under the same lax.cond).
    Supports the full core-transform configuration space — strategies,
    stagger, per-bucket plan overrides, quantized states and the
    ``sync_codes`` int8 collective (module docstring). Any structural
    mismatch between config, state and gradient tree raises a loud
    ValueError instead of silently drifting.
    """
    count = state.count
    t = count + 1
    flat_u, treedef = jax.tree_util.tree_flatten_with_path(grads)
    # THE bucket assignment (shared with the core transform, the
    # stacked-state codec and the elastic supervisor) — drives the bucket-
    # effective configs and the staggered phase allocation even in per-leaf
    # storage mode, so refresh cadence matches the single-pod run exactly.
    layout = stacked_state.layout_for_flat(cfg.rules.spec_for, flat_u)
    # Raises on mixed-override buckets, naming the offending paths. A
    # plan's per-bucket t_update / quantize / stagger_groups become the
    # bucket-effective config here — including overrides that differ from
    # the global knobs (the schedule below is per-leaf, not global).
    bucket_cfgs = [_bucket_cfg(cfg, info) for info in layout.buckets]
    phase_by_bucket = bucket_phases(cfg, layout)

    # Per-flat-index schedule/config tables.
    lcfg_by_idx = {}
    ph_by_idx = {}
    spec_by_idx = {}
    for bi, info in enumerate(layout.buckets):
        staggerable = info.kind in (
            stacked_state.BUCKET_PROJECT, stacked_state.BUCKET_CONV
        )
        for slot, i in enumerate(info.indices):
            lcfg_by_idx[i] = bucket_cfgs[bi]
            spec_by_idx[i] = info.spec
            ph_by_idx[i] = phase_by_bucket[bi][slot] if staggerable else 0
    for tinfo in layout.tail:
        # Residual tail (custom classify only): synchronized per-leaf
        # schedule, like the core transform's tail path.
        lcfg_by_idx[tinfo.index] = _leaf_cfg(cfg, tinfo.path)
        spec_by_idx[tinfo.index] = tinfo.spec
        ph_by_idx[tinfo.index] = 0

    stacked = isinstance(state.leaves, stacked_state.StackedLeaves)
    if stacked:
        # Same structural check the core transform does: a congruent-but-
        # reordered tree must raise, never silently pair moments with the
        # wrong leaves (layout paths/indices are part of the signature).
        if state.leaves.layout.signature() != layout.signature():
            raise ValueError(
                "stacked optimizer state does not match the gradient tree "
                "(optimizer rules / model structure changed since init?)"
            )
        flat_s = [
            stacked_state.leaf_view(state.leaves, i)
            for i in range(len(flat_u))
        ]
    else:
        flat_s = treedef.flatten_up_to(state.leaves)
        for idx, ((kp, g), leaf) in enumerate(zip(flat_u, flat_s)):
            _check_leaf_state(
                path_str(kp), spec_by_idx[idx], leaf, lcfg_by_idx[idx], g
            )
    if cfg.sync_codes:
        for idx, ((kp, _), leaf) in enumerate(zip(flat_u, flat_s)):
            if spec_by_idx[idx].kind in (KIND_PROJECT, KIND_CONV):
                _check_ef(path_str(kp), leaf)

    new_updates, new_leaves = [], []
    for idx, ((kp, g), leaf) in enumerate(zip(flat_u, flat_s)):
        spec = spec_by_idx[idx]
        lcfg = lcfg_by_idx[idx]
        ph = ph_by_idx[idx]
        if spec.kind == KIND_PROJECT:
            u, nl = _update_proj_compressed(
                lcfg, leaf, g, spec, count, t, idx, ph, axis_name
            )
        elif spec.kind == KIND_CONV:
            u, nl = _update_conv_compressed(
                lcfg, leaf, g, spec, count, t, idx, ph, axis_name
            )
        elif spec.kind == KIND_DENSE:
            u, nl = _update_dense_compressed(lcfg, leaf, g, t, axis_name)
        else:
            # Future-proofing: any new projection kind must get an explicit
            # compressed schedule — loud failure, never silent fp32 drift.
            raise ValueError(
                f"compressed_update: unsupported projection kind "
                f"{spec.kind!r} at {path_str(kp)!r} — add a compressed "
                "schedule for it in distributed/compression.py"
            )
        new_updates.append(u)
        new_leaves.append(nl)
    if stacked:
        leaves_out = stacked_state.encode(state.leaves.layout, new_leaves)
    else:
        leaves_out = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return (
        jax.tree_util.tree_unflatten(treedef, new_updates),
        ProjectedAdamState(count=count + 1, leaves=leaves_out),
    )


def make_compressed_train_step(model, cfg: ProjectedAdamConfig, mesh,
                               learning_rate: float):
    """COAP train step with compressed cross-pod gradient sync.

    shard_map is manual over 'pod' only; 'data'/'model' remain auto so the
    in-pod FSDP/TP sharding is still XLA-partitioned. The optimizer states
    and params are replicated across pods (pure DP) — specs P() over pod.
    """
    axis = "pod"

    def per_pod(params, opt_state, step, batch):
        def loss_fn(p):
            loss, _ = model.loss(p, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # NOTE: no pmean(grads) here — compressed_update reduces instead.
        inner = opt_state  # ProjectedAdamState
        updates, new_inner = compressed_update(cfg, grads, inner, axis)
        updates = jax.tree_util.tree_map(lambda u: -learning_rate * u, updates)
        params = apply_updates(params, updates)
        loss = lax.pmean(loss, axis)
        return params, new_inner, loss

    pspec = P()  # replicated over pod (manual axis)
    in_specs = (pspec, pspec, pspec, P(axis))
    out_specs = (pspec, pspec, pspec)
    mapped = compat.shard_map(
        per_pod, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False, axis_names={axis},
    )

    def step_fn(state: TrainState, batch):
        params, inner, loss = mapped(state.params, state.opt_state, state.step,
                                     batch)
        return (
            TrainState(step=state.step + 1, params=params, opt_state=inner),
            {"loss": loss},
        )

    return step_fn
