"""Cross-pod projected-gradient compression (beyond-paper; DESIGN.md §5).

The pod axis is pure data parallelism over the slowest links. The baseline
step all-reduces the full gradient G (m·n per matrix) across pods. But COAP
consumes G only two ways:

  1. every step:   G_proj = G P        (m·r — the moment/update input)
  2. every T_u:    the full G          (Eqn-6/Eqn-7 refresh input)

Projection is linear, so  mean_pods(G)·P == mean_pods(G·P)  exactly. We
therefore all-reduce the r-rank projection each step and the full gradient
only on refresh steps:

    cross-pod bytes/step = m·r + m·n/T_u      vs      m·n

Conv (Tucker-2) leaves compress the same way: the n-mode products are
linear, so the r_O·r_I·K1·K2 projected core is all-reduced each step and
the full O·I·K1·K2 gradient only on factor-refresh steps.

At paper ranks (n/r = 4–12, T_u = 40–200) that is a 3.8–11× cross-pod
traffic cut with bitwise-identical optimizer semantics (equivalence proven
in tests/test_distributed.py on a (2,2,2) host mesh).

Implementation: ``shard_map`` manual over the 'pod' axis only (data/model
stay auto inside), computing per-pod gradients, reducing the compressed
tensors, and running the same leaf update the core transform uses.

Stacked-state aware: when the optimizer state is stored pre-stacked
(``stacked_state=True``; core/stacked_state.py), per-leaf moments are
addressed as bucket slices through the codec's ``leaf_view`` — inside jit
those slices fuse into their consumers, so the reduction schedule (r-rank
every step, full G on refresh steps) is unchanged — and the new leaf states
are re-encoded into the same stacked layout on the way out.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import conv as conv_mod
from repro.core import correlation, projector, recalibrate
from repro.core import stacked_state
from repro.core.coap_adam import (
    ConvLeaf,
    DenseLeaf,
    ProjLeaf,
    ProjectedAdamConfig,
    ProjectedAdamState,
)
from repro.core.projector import KIND_CONV, KIND_PROJECT, path_str
from repro.optim import apply_updates
from repro.train.train_state import TrainState


def compressed_update(cfg: ProjectedAdamConfig, grads, state: ProjectedAdamState,
                      axis_name: str = "pod"):
    """Per-pod grads -> (updates, new_state) with compressed cross-pod
    reduction. Must run inside shard_map manual over ``axis_name``.

    Semantics == all-reduce(grads) then core update (linearity; the full-G
    all-reduce still happens on refresh steps, under the same lax.cond)."""
    if cfg.overrides is not None and any(
        ov.t_update is not None and ov.t_update != cfg.t_update
        for _, ov in cfg.overrides.entries
    ):
        # This path computes the refresh schedule from the GLOBAL
        # cfg.t_update below; silently ignoring a bucket pinned to a
        # DIFFERENT cadence would desync it from the single-pod planned
        # optimizer. Overrides that merely restate the global T_u (what
        # the v1 solver emits) are fine; stagger_groups is irrelevant
        # here — this path has always refreshed synchronized.
        raise NotImplementedError(
            "compressed_update does not support per-bucket t_update "
            "overrides that differ from the global schedule"
        )
    if cfg.any_quantized():
        # This path does fp32 moment arithmetic directly on leaf.m/leaf.v.
        # Under the shape-preserving row-block int8 codec those arrays are
        # quantization CODES — using them here would corrupt silently (the
        # old flat codec at least failed shape checks). Compressed sync for
        # quantized states needs a dequant->reduce->requant schedule; not
        # implemented.
        raise NotImplementedError(
            "compressed_update does not support quantize=True states"
        )
    count = state.count
    t = count + 1
    flat_u, treedef = jax.tree_util.tree_flatten_with_path(grads)
    stacked = isinstance(state.leaves, stacked_state.StackedLeaves)
    if stacked:
        # Same structural check the core transform does: a congruent-but-
        # reordered tree must raise, never silently pair moments with the
        # wrong leaves (layout paths/indices are part of the signature).
        layout = stacked_state.layout_for_flat(cfg.rules.spec_for, flat_u)
        if state.leaves.layout.signature() != layout.signature():
            raise ValueError(
                "stacked optimizer state does not match the gradient tree "
                "(optimizer rules / model structure changed since init?)"
            )
        flat_s = [
            stacked_state.leaf_view(state.leaves, i)
            for i in range(len(flat_u))
        ]
    else:
        flat_s = treedef.flatten_up_to(state.leaves)
    new_updates, new_leaves = [], []
    for idx, ((kp, g), leaf) in enumerate(zip(flat_u, flat_s)):
        spec = cfg.rules.spec_for(path_str(kp), g.shape)
        if spec.kind == KIND_PROJECT:
            gc_local = projector.to_canonical(g, spec).astype(jnp.float32)
            do_ref = (count % cfg.t_update) == 0
            do_recal = (count % (cfg.lam * cfg.t_update)) == 0

            # Refresh path: needs the full averaged gradient (rare).
            def refreshed():
                gc_full = lax.pmean(gc_local, axis_name)
                return lax.cond(
                    do_recal,
                    lambda: recalibrate.lowcost_svd(gc_full, leaf.p),
                    lambda: correlation.sgd_update(
                        leaf.p, gc_full, leaf.m, lr=cfg.eqn6_lr,
                        steps=cfg.eqn6_steps, normalize=cfg.eqn6_normalize,
                    ),
                )

            new_p = lax.cond(do_ref, refreshed, lambda: leaf.p)
            # Every-step path: reduce only the r-rank projection.
            g_proj = lax.pmean(projector.project(gc_local, new_p), axis_name)
            new_m = cfg.b1 * leaf.m + (1.0 - cfg.b1) * g_proj
            new_v = cfg.b2 * leaf.v + (1.0 - cfg.b2) * jnp.square(g_proj)
            tf = t.astype(jnp.float32)
            delta = (new_m / (1.0 - cfg.b1**tf)) / (
                jnp.sqrt(new_v / (1.0 - cfg.b2**tf)) + cfg.eps
            )
            upd_c = projector.backproject(delta, new_p)
            upd = projector.from_canonical(upd_c, spec) * cfg.update_scale
            new_updates.append(upd.astype(g.dtype))
            new_leaves.append(ProjLeaf(p=new_p, m=new_m, v=new_v,
                                       m_scale=leaf.m_scale,
                                       v_scale=leaf.v_scale))
        elif spec.kind == KIND_CONV:
            # Tucker-2 leaves: the n-mode products are linear, so only the
            # r_O x r_I x K1 x K2 core is all-reduced each step; the full
            # gradient crosses pods on factor-refresh steps only. Addressed
            # through leaf_view, this reads conv bucket slices directly
            # out of stacked storage.
            g32_local = g.astype(jnp.float32)
            do_ref = (count % cfg.t_update) == 0
            do_recal = (count % (cfg.lam * cfg.t_update)) == 0
            m = leaf.m  # fp32 (quantize rejected above)

            def conv_refreshed():
                g_full = lax.pmean(g32_local, axis_name)
                return conv_mod.refresh_factors(
                    cfg,
                    leaf.p_o,
                    leaf.p_i,
                    conv_mod.mode1_canonical(g_full),
                    conv_mod.mode2_canonical(g_full),
                    m,
                    do_recal,
                )

            p_o, p_i = lax.cond(
                do_ref, conv_refreshed, lambda: (leaf.p_o, leaf.p_i)
            )
            g_core = lax.pmean(
                conv_mod.project_core(g32_local, p_o, p_i), axis_name
            )
            new_m = cfg.b1 * m + (1.0 - cfg.b1) * g_core
            new_v = cfg.b2 * leaf.v + (1.0 - cfg.b2) * jnp.square(g_core)
            tf = t.astype(jnp.float32)
            delta = (new_m / (1.0 - cfg.b1**tf)) / (
                jnp.sqrt(new_v / (1.0 - cfg.b2**tf)) + cfg.eps
            )
            upd = conv_mod.restore_core(delta, p_o, p_i) * cfg.update_scale
            new_updates.append(upd.astype(g.dtype))
            new_leaves.append(ConvLeaf(p_o=p_o, p_i=p_i, m=new_m, v=new_v,
                                       m_scale=leaf.m_scale,
                                       v_scale=leaf.v_scale))
        else:
            # Dense leaves: classic full all-reduce + Adam.
            g32 = lax.pmean(g.astype(jnp.float32), axis_name)
            new_mu = cfg.b1 * leaf.mu + (1.0 - cfg.b1) * g32
            new_nu = cfg.b2 * leaf.nu + (1.0 - cfg.b2) * jnp.square(g32)
            tf = t.astype(jnp.float32)
            upd = (new_mu / (1.0 - cfg.b1**tf)) / (
                jnp.sqrt(new_nu / (1.0 - cfg.b2**tf)) + cfg.eps
            )
            new_updates.append(upd.astype(g.dtype))
            new_leaves.append(DenseLeaf(mu=new_mu, nu=new_nu,
                                        mu_scale=leaf.mu_scale,
                                        nu_scale=leaf.nu_scale))
    if stacked:
        leaves_out = stacked_state.encode(state.leaves.layout, new_leaves)
    else:
        leaves_out = jax.tree_util.tree_unflatten(treedef, new_leaves)
    return (
        jax.tree_util.tree_unflatten(treedef, new_updates),
        ProjectedAdamState(count=count + 1, leaves=leaves_out),
    )


def make_compressed_train_step(model, cfg: ProjectedAdamConfig, mesh,
                               learning_rate: float):
    """COAP train step with compressed cross-pod gradient sync.

    shard_map is manual over 'pod' only; 'data'/'model' remain auto so the
    in-pod FSDP/TP sharding is still XLA-partitioned. The optimizer states
    and params are replicated across pods (pure DP) — specs P() over pod.
    """
    axis = "pod"

    def per_pod(params, opt_state, step, batch):
        def loss_fn(p):
            loss, _ = model.loss(p, batch)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        # NOTE: no pmean(grads) here — compressed_update reduces instead.
        inner = opt_state  # ProjectedAdamState
        updates, new_inner = compressed_update(cfg, grads, inner, axis)
        updates = jax.tree_util.tree_map(lambda u: -learning_rate * u, updates)
        params = apply_updates(params, updates)
        loss = lax.pmean(loss, axis)
        return params, new_inner, loss

    pspec = P()  # replicated over pod (manual axis)
    in_specs = (pspec, pspec, pspec, P(axis))
    out_specs = (pspec, pspec, pspec)
    mapped = compat.shard_map(
        per_pod, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False, axis_names={axis},
    )

    def step_fn(state: TrainState, batch):
        params, inner, loss = mapped(state.params, state.opt_state, state.step,
                                     batch)
        return (
            TrainState(step=state.step + 1, params=params, opt_state=inner),
            {"loss": loss},
        )

    return step_fn
