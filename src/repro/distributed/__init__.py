"""Distribution layer: logical-axis sharding rules, cross-pod gradient
compression, collective helpers, and an optional pipeline stage."""
