"""GPipe-style pipeline parallelism over a mesh axis (optional mode).

For latency-bound cross-pod deployments the `pod` axis can run as a
pipeline instead of pure DP: layers are split into `n_stages` contiguous
groups, microbatches stream through stages, and activations hop stage→stage
with `jax.lax.ppermute`. Implemented with shard_map manual over the stage
axis; the classic GPipe schedule (fill, steady state, drain) is expressed
as a lax.fori_loop over ``n_micro + n_stages - 1`` ticks — every stage
computes on every tick (idle ticks process garbage that is masked out),
which is the standard SPMD formulation.

This module is self-contained (takes any per-stage apply function) and is
validated on an 8-host-device mesh in tests/test_pipeline.py: pipeline
output == sequential stack output, for 2 and 4 stages.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def split_stage_params(stacked_params: Any, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L/n_stages, ...)."""

    def split(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree_util.tree_map(split, stacked_params)


def pipeline_apply(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,  # pytree with leading (n_stages, ...) axis
    x: jnp.ndarray,  # (n_micro, micro_batch, ...) microbatched input
    *,
    mesh,
    axis: str = "pod",
) -> jnp.ndarray:
    """Runs x through n_stages sequential stages living on `axis`.

    stage_fn(params_for_stage, h) -> h  applies one stage's layer group.
    Returns (n_micro, micro_batch, ...) outputs (same layout as x).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro >= 1

    def per_stage(params_s, x_all):
        # params_s: this stage's params; shard_map leaves the manual axis as
        # a local size-1 leading dim — strip it.
        params_s = jax.tree_util.tree_map(lambda v: v[0], params_s)
        # x_all: full (n_micro, mb, ...) input, replicated; only stage 0
        # reads it.
        stage = jax.lax.axis_index(axis)
        mb_shape = x_all.shape[1:]
        n_ticks = n_micro + n_stages - 1

        def tick(t, carry):
            buf, outputs = carry
            # stage 0 ingests microbatch t (or garbage past the end)
            idx = jnp.minimum(t, n_micro - 1)
            fresh = jax.lax.dynamic_index_in_dim(x_all, idx, 0, False)
            h_in = jnp.where(stage == 0, fresh, buf)
            h_out = stage_fn(params_s, h_in)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            write = (stage == n_stages - 1) & (out_idx >= 0)
            safe_idx = jnp.clip(out_idx, 0, n_micro - 1)
            cur = jax.lax.dynamic_index_in_dim(outputs, safe_idx, 0, False)
            upd = jnp.where(write, h_out, cur)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, upd, safe_idx, 0
            )
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(h_out, axis, perm)
            return buf, outputs

        buf0 = jnp.zeros(mb_shape, x_all.dtype)
        outs0 = jnp.zeros((n_micro,) + mb_shape, x_all.dtype)
        _, outputs = jax.lax.fori_loop(0, n_ticks, tick, (buf0, outs0))
        # outputs live on the last stage; broadcast so out_specs can be P()
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            axis,
        )
        return outputs

    # Manual over the whole mesh (JAX requires specs to resolve every
    # axis); non-pipeline axes are replicated, every shard computes the
    # same schedule.
    in_specs = (P(axis), P())
    out_specs = P()
    return compat.shard_map(
        per_stage, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(stage_params, x)
