"""Logical-axis sharding: one rule table lays out every architecture.

Mesh axes: ``pod`` (pure DP across pods — slow inter-pod links; params
replicated, gradients all-reduced, optionally COAP-compressed, see
``distributed/compression.py``), ``data`` (FSDP: params/grads/optimizer
states sharded, all-gather on use), ``model`` (tensor parallel: heads / ffn
/ vocab).

Every ParamDef carries logical axis names; ``spec_for_axes`` maps them to
mesh axes, dropping any axis that does not divide evenly (safe fallback to
replication — e.g. the 8-expert dim on a 16-way axis stays local, DESIGN.md
§4). Activation/cache constraints are applied only when an ambient mesh
exists, so the same model code runs unsharded on CPU tests.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import ParamDef, is_param_def

# Logical axis -> preferred mesh axis (in priority order; first that fits).
PARAM_RULES: Dict[str, Tuple[str, ...]] = {
    "vocab": ("model",),
    "embed": ("data",),  # FSDP dim
    "ffn": ("model",),
    "heads": ("model",),
    "lora": ("model",),  # MLA latents: small; sharded if divisible
    "experts": (),  # 8 experts never divide the 16-way axes: keep local
    "moe_embed": (),  # expert d_model: replicated (see models/moe.py note)
    "layers": (),  # scan dim
}

# A second table used by the perf hillclimb (EXPERIMENTS.md §Perf) — fully
# model-parallel layout for tiny models where FSDP all-gathers dominate.
PARAM_RULES_TP_ONLY: Dict[str, Tuple[str, ...]] = {
    **PARAM_RULES,
    "embed": (),
}

# Decode-time layout: expert weights ARE the traffic at 1-token steps, so
# shard their d_model over 'data' (train replicates it to kill per-layer
# activation all-reduces — see models/moe.py; EXPERIMENTS.md §Perf). The
# serve engine loads checkpoints with this table; elastic restore reshards.
PARAM_RULES_SERVE: Dict[str, Tuple[str, ...]] = {
    **PARAM_RULES,
    "moe_embed": ("data",),
}


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 0


def current_mesh():
    """The ambient mesh from `with mesh:` (None on unsharded CPU tests)."""
    try:
        from jax._src import mesh as mesh_lib

        env = mesh_lib.thread_resources.env
        m = env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:  # pragma: no cover
            return m
    except Exception:
        pass
    return None


def spec_for_axes(axes: Sequence[Optional[str]], shape: Sequence[int], mesh,
                  rules: Dict[str, Tuple[str, ...]] = PARAM_RULES) -> P:
    """Logical axes tuple -> PartitionSpec, dropping non-dividing axes."""
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        chosen = None
        if ax is not None:
            for cand in rules.get(ax, ()):
                size = mesh_axis_size(mesh, cand)
                if size and dim % size == 0 and cand not in used:
                    chosen = cand
                    used.add(cand)
                    break
        out.append(chosen)
    return P(*out)


def param_specs(defs, mesh, rules=PARAM_RULES):
    """Def-tree -> PartitionSpec tree."""
    return jax.tree_util.tree_map(
        lambda d: spec_for_axes(d.axes, d.shape, mesh, rules),
        defs,
        is_leaf=is_param_def,
    )


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def replicated_specs(template) -> Any:
    """``P()`` for every array leaf of ``template`` — the elastic
    supervisor's default placement when restoring a checkpoint onto a
    freshly-built (possibly resized) data mesh: land replicated first,
    then let pjit reshard into the step function's layout. The template
    may be abstract (ShapeDtypeStructs from ``jax.eval_shape``)."""
    return jax.tree_util.tree_map(lambda _: P(), template)


# ---------------------------------------------------------------------------
# Activation / batch constraints
# ---------------------------------------------------------------------------
def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch shards over (pod+data when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _nonmanual_axes(mesh) -> set:
    """Axes usable in sharding constraints (drops shard_map-manual axes)."""
    from repro import compat

    manual = compat.manual_axes_in_scope()
    if manual:
        if not hasattr(jax, "shard_map"):
            # jax<=0.4: XLA's partitioner aborts on constraints inside a
            # partially-manual region (IsManualSubgroup check) — emit none.
            return set()
        return set(mesh.axis_names) - set(manual)
    try:
        abstract = jax.sharding.get_abstract_mesh()
        if abstract is not None and not abstract.empty:
            types = dict(zip(abstract.axis_names, abstract.axis_types))
            return {
                a for a in abstract.axis_names
                if "manual" not in str(types[a]).lower()
            }
    except Exception:
        pass
    return set(mesh.axis_names)


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint via logical names; no-op without a mesh.

    logical entries: 'batch' | 'seq_data' | 'model' | 'data' | None.
    Axes currently Manual (inside shard_map) are dropped from constraints.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    allowed = _nonmanual_axes(mesh)
    used = set()
    axes = []
    for dim, ax in zip(x.shape, logical):
        if ax == "batch":
            cand = tuple(a for a in batch_axes(mesh) if a in allowed)
            total = 1
            for c in cand:
                total *= mesh.shape[c]
            if cand and dim % total == 0 and not (set(cand) & used):
                axes.append(cand if len(cand) > 1 else cand[0])
                used.update(cand)
            else:
                axes.append(None)
        elif ax in ("seq_data", "data", "model"):
            name = "data" if ax == "seq_data" else ax
            size = mesh_axis_size(mesh, name)
            if size and dim % size == 0 and name not in used and name in allowed:
                axes.append(name)
                used.add(name)
            else:
                axes.append(None)
        else:
            axes.append(None)
    if not used and not hasattr(jax, "shard_map"):
        from repro import compat

        if compat.manual_axes_in_scope():
            # jax<=0.4 inside a shard_map body: even a fully-replicated
            # constraint aborts XLA's partitioner (IsManualSubgroup check).
            # Elsewhere the replicated constraint is kept — it pins layout.
            return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*axes))
    )


def batch_specs(batch_tree, mesh, seq_shard: bool = False):
    """Shardings for the input batch dict: batch dim over (pod, data) —
    or, when the batch doesn't divide (long_500k B=1), the sequence dim
    over 'data' (sequence parallelism)."""
    baxes = batch_axes(mesh)
    total = 1
    for a in baxes:
        total *= mesh.shape[a]

    def one(x):
        shape = x.shape
        if len(shape) == 0:
            return P()
        spec: list = [None] * len(shape)
        # positions for mrope have a leading (3,...) axis; batch is axis 1
        b_axis = 1 if (len(shape) >= 2 and shape[0] == 3) else 0
        if shape[b_axis] % total == 0 and total > 1 and not seq_shard:
            spec[b_axis] = baxes if len(baxes) > 1 else baxes[0]
        elif len(shape) > b_axis + 1 and "data" in mesh.axis_names:
            # sequence parallelism fallback
            s_axis = b_axis + 1
            if shape[s_axis] % mesh.shape["data"] == 0:
                spec[s_axis] = "data"
        return P(*spec)

    return jax.tree_util.tree_map(one, batch_tree)
