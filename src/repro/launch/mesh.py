"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets --xla_force_host_platform_device_count=512 before
any jax import; smoke tests see 1 device and never call these).

  single-pod: (16, 16)    = 256 chips,  axes (data, model)
  multi-pod:  (P, 16, 16) = P·256 chips, axes (pod, data, model); the pod
              axis is pure data parallelism over the slowest links — the
              compressed-sync wire model (``distributed/compression.py``,
              ``benchmarks/overhead.run_sync``) prices exactly this axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, pods: int = 2):
    """The (data, model) production mesh; ``multi_pod=True`` prepends a pod
    axis of size ``pods`` (cross-pod topology sweeps vary this)."""
    if pods < 1:
        raise ValueError(f"pods must be >= 1, got {pods}")
    shape = (pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} (launch/dryrun.py sets this automatically)"
        )
    return jax.make_mesh(
        shape, axes, devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for the 8-device subprocess tests."""
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def pod_mesh(pods: int = 2):
    """A pod-only mesh (pure cross-pod DP) for compressed-sync tests and
    benchmarks: ``(pods,)`` over axis 'pod'."""
    return jax.make_mesh(
        (pods,), ("pod",),
        axis_types=(jax.sharding.AxisType.Auto,),
    )
