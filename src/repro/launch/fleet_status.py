"""fleet_status: one operator view over a fleet's journals (stdlib-only).

    PYTHONPATH=src python -m repro.launch.fleet_status --dir <run_dir> \
        [--dir <run_dir2> ...] [--fleet-dir <fleet_dir>] \
        [--json] [--follow] [--interval 2] [--events 5]

Every elastic run directory already carries the full story as plain
files — ``heartbeat.json`` (liveness + step + phase + registry
counters), ``events.jsonl`` (both sides' supervision events),
``metrics.jsonl`` (loss/throughput rows), ``DONE.json``, the checkpoint
directories, ``health.jsonl`` (projection-health rows; ``obs/health``),
and ``worker_spec.json`` (which knows the heartbeat timeout). A fleet
directory (``train/fleet.py``) adds member liveness and the committed
``coap-plan/v1`` per replan epoch. This CLI tails them all into one
table: per-host phase/step/staleness, last loss, checkpoint progress,
projection-health verdicts, the current plan epoch + digest, and recent
events.

``--json`` emits the same view as one machine-readable document;
``--follow`` redraws every ``--interval`` seconds. Deliberately imports
NOTHING jax-adjacent: it must run on an operator box (or a dying host)
in milliseconds.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time
from typing import Any, Dict, List, Optional

_CKPT_RE = re.compile(r"^ckpt_(\d+)$")
DEFAULT_HEARTBEAT_TIMEOUT_S = 300.0


def _read_json(path: str) -> Optional[Dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def _tail_jsonl(path: str, n: int) -> List[Dict]:
    """Last ``n`` well-formed rows of a jsonl journal (torn trailing
    lines from a killed writer are skipped)."""
    rows: List[Dict] = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if isinstance(row, dict):
                    rows.append(row)
    except OSError:
        pass
    return rows[-n:] if n > 0 else rows


def _ckpt_steps(run_dir: str) -> List[int]:
    """Checkpoint steps by directory scan (same contract as
    ``train/checkpoint.steps`` — ``ckpt_<step>/manifest.json`` — without
    importing the jax-heavy checkpoint module)."""
    out = []
    try:
        for d in os.listdir(run_dir):
            m = _CKPT_RE.match(d)
            if m and os.path.exists(
                os.path.join(run_dir, d, "manifest.json")
            ):
                out.append(int(m.group(1)))
    except OSError:
        pass
    return sorted(out)


def host_view(
    run_dir: str, n_events: int = 5, now: Optional[float] = None
) -> Dict[str, Any]:
    """Everything the journals say about ONE run directory."""
    now = time.time() if now is None else now
    spec = _read_json(os.path.join(run_dir, "worker_spec.json")) or {}
    ecfg = spec.get("elastic") or {}
    host = ecfg.get("host_id") or os.path.basename(
        os.path.abspath(run_dir)
    )
    timeout = float(
        ecfg.get("heartbeat_timeout_s") or DEFAULT_HEARTBEAT_TIMEOUT_S
    )

    hb_path = ecfg.get("heartbeat_path") or os.path.join(
        run_dir, "heartbeat.json"
    )
    hb = _read_json(hb_path)
    if hb is None:
        status, staleness = "missing", None
    else:
        staleness = now - float(hb.get("time", 0.0))
        status = "alive" if staleness < timeout else "stale"

    done = _read_json(os.path.join(run_dir, "DONE.json"))
    if done:
        status = "done"

    events_path = ecfg.get("events_path") or os.path.join(
        run_dir, "events.jsonl"
    )
    events = [
        {"time": r.get("time"), "host": r.get("host"),
         "event": r.get("event")}
        for r in _tail_jsonl(events_path, n_events)
        if "event" in r
    ]

    metrics_path = ecfg.get("metrics_path") or os.path.join(
        run_dir, "metrics.jsonl"
    )
    last_metrics = (_tail_jsonl(metrics_path, 1) or [None])[-1]

    # Projection-health verdicts from the run's health journal
    # (``obs/health`` is stdlib-only at import, so this stays operator-box
    # safe). Analyze the recent tail only: verdicts are about the CURRENT
    # numerics, and the tail keeps the CLI O(1) in journal length.
    health_path = ecfg.get("health_path") or os.path.join(
        run_dir, "health.jsonl"
    )
    health: Optional[Dict[str, Any]] = None
    health_rows = _tail_jsonl(health_path, 400)
    if health_rows:
        from repro.obs.health import analyze

        rep = analyze(health_rows)
        health = {
            "ok": rep.ok(),
            "verdicts": sorted(
                {v for b in rep.buckets.values() for v in b["verdicts"]}
            ),
            "n_buckets": len(rep.buckets),
        }

    ckpts = _ckpt_steps(run_dir)
    hb = hb or {}
    return {
        "host": host,
        "dir": run_dir,
        "status": status,  # alive | stale | missing | done
        "phase": hb.get("phase"),
        "step": (int(done["step"]) if done and "step" in done
                 else hb.get("step")),
        "staleness_s": staleness,
        "heartbeat_timeout_s": timeout,
        "straggler_flagged": hb.get("straggler_flagged"),
        "counters": (hb.get("counters")
                     if isinstance(hb.get("counters"), dict) else None),
        "gauges": (hb.get("gauges")
                   if isinstance(hb.get("gauges"), dict) else None),
        "health": health,
        "total_steps": ecfg.get("total_steps"),
        "last_metrics": last_metrics,
        "ckpt_latest": ckpts[-1] if ckpts else None,
        "ckpt_count": len(ckpts),
        "done": done,
        "recent_events": events,
    }


def fleet_view(fleet_dir: str, now: Optional[float] = None,
               member_timeout_s: float = 30.0) -> Dict[str, Any]:
    """The consensus layer's view: member liveness + the most recently
    committed plan epoch and its content digest."""
    now = time.time() if now is None else now
    members = []
    mdir = os.path.join(fleet_dir, "members")
    try:
        for fname in sorted(os.listdir(mdir)):
            if not fname.endswith(".json"):
                continue
            rec = _read_json(os.path.join(mdir, fname))
            if not rec:
                continue
            age = now - float(rec.get("time", 0.0))
            members.append({
                "host": rec.get("host"),
                "age_s": age,
                "alive": age < member_timeout_s,
            })
    except OSError:
        pass

    epochs = []
    edir = os.path.join(fleet_dir, "epochs")
    try:
        for name in os.listdir(edir):
            commit = os.path.join(edir, name, "plan.json")
            if not os.path.exists(commit):
                continue
            rec = _read_json(commit) or {}
            epochs.append({
                "epoch": name,
                "committed_by": rec.get("host"),
                "plan_digest": rec.get("digest"),
                "mtime": os.path.getmtime(commit),
            })
    except OSError:
        pass
    epochs.sort(key=lambda e: e["mtime"])
    current = epochs[-1] if epochs else None
    return {
        "fleet_dir": fleet_dir,
        "members": members,
        "n_alive": sum(1 for m in members if m["alive"]),
        "epochs": [e["epoch"] for e in epochs],
        "current_epoch": current,
    }


def collect(run_dirs: List[str], fleet_dir: Optional[str],
            n_events: int = 5) -> Dict[str, Any]:
    now = time.time()
    doc: Dict[str, Any] = {
        "time": now,
        "hosts": [host_view(d, n_events=n_events, now=now)
                  for d in run_dirs],
    }
    if fleet_dir:
        doc["fleet"] = fleet_view(fleet_dir, now=now)
    return doc


# -- rendering ---------------------------------------------------------------


def _fmt_age(s: Optional[float]) -> str:
    if s is None:
        return "-"
    if s < 120:
        return f"{s:.1f}s"
    if s < 7200:
        return f"{s/60:.1f}m"
    return f"{s/3600:.1f}h"


def _fmt_event(e: Dict) -> str:
    ev = e.get("event")
    body = " ".join(str(x) for x in ev) if isinstance(ev, list) else str(ev)
    return f"{e.get('host', '?')}: {body}"


def render(doc: Dict[str, Any]) -> str:
    lines = [
        "| host | status | phase | step | ckpt | stale | straggler "
        "| loss | health |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for h in doc["hosts"]:
        m = h.get("last_metrics") or {}
        loss = m.get("loss")
        loss_s = f"{loss:.4f}" if isinstance(loss, (int, float)) else "-"
        hl = h.get("health")
        if hl is None:
            health_s = "-"
        elif hl.get("ok"):
            health_s = "ok"
        else:
            health_s = ",".join(hl.get("verdicts") or []) or "ok"
        total = h.get("total_steps")
        step = h.get("step")
        if step is not None and total:
            step_s = f"{step}/{total}"
        else:
            step_s = str(step) if step is not None else "-"
        ckpt = h.get("ckpt_latest")
        strag = h.get("straggler_flagged")
        lines.append(
            f"| {h['host']} | {h['status']} | {h.get('phase') or '-'} | "
            f"{step_s} | {ckpt if ckpt is not None else '-'} | "
            f"{_fmt_age(h.get('staleness_s'))} | "
            f"{strag if strag is not None else '-'} | {loss_s} | "
            f"{health_s} |"
        )
    fleet = doc.get("fleet")
    if fleet:
        cur = fleet.get("current_epoch")
        lines.append("")
        lines.append(
            f"fleet: {fleet['n_alive']}/{len(fleet['members'])} members "
            f"alive; {len(fleet['epochs'])} committed epoch(s)"
        )
        if cur:
            lines.append(
                f"current plan epoch {cur['epoch']} "
                f"(digest {str(cur['plan_digest'])[:12]}..., "
                f"committed by {cur['committed_by']})"
            )
    recent = [
        (e.get("time") or 0.0, e)
        for h in doc["hosts"] for e in h.get("recent_events", [])
    ]
    if recent:
        lines.append("")
        lines.append("recent events:")
        for _, e in sorted(recent, key=lambda te: te[0])[-8:]:
            lines.append(f"  {_fmt_event(e)}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="one fleet view over elastic run + fleet directories"
    )
    ap.add_argument("--dir", action="append", default=[],
                    help="elastic run directory (repeatable)")
    ap.add_argument("--fleet-dir", default=None,
                    help="train/fleet.py consensus directory")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output")
    ap.add_argument("--follow", action="store_true",
                    help="redraw every --interval seconds")
    ap.add_argument("--interval", type=float, default=2.0)
    ap.add_argument("--events", type=int, default=5,
                    help="recent events per host")
    args = ap.parse_args(argv)
    if not args.dir and not args.fleet_dir:
        ap.error("give at least one --dir or --fleet-dir")

    while True:
        doc = collect(args.dir, args.fleet_dir, n_events=args.events)
        if args.as_json:
            out = json.dumps(doc, indent=1, default=str)
        else:
            out = render(doc)
        if args.follow:
            sys.stdout.write("\x1b[2J\x1b[H")
        print(out, flush=True)
        if not args.follow:
            return 0
        time.sleep(args.interval)


if __name__ == "__main__":
    raise SystemExit(main())
