"""Out-of-process elastic worker: ONE attempt of the replan → migrate →
resume loop, run as its own OS process so the supervisor can really
``SIGKILL`` it (``train/elastic.ProcessSupervisor`` is the parent).

  PYTHONPATH=src python -m repro.launch.worker --spec <ckpt_dir>/worker_spec.json

The spec file carries the model/data recipe plus the serialized
``ElasticConfig`` — everything the worker needs lives in the checkpoint
directory, the one piece of shared state a preemptible fleet already has.
The attempt index arrives via ``REPRO_WORKER_ATTEMPT`` (set by the
supervisor at spawn).

Exit protocol (the supervisor never *trusts* exit codes for liveness —
death is declared on heartbeat evidence alone — but cooperative exits
carry meaning):

  * ``0``  — run complete; ``DONE.json`` written atomically next to the
    spec with the final step and loss.
  * ``75`` (``EXIT_DRAINED``, EX_TEMPFAIL) — a preemption notice was
    honored: checkpoint saved at the current step, ack written, leaving
    before the deadline. The supervisor relaunches immediately without
    charging the crash budget.
  * anything else — crash; the supervisor's crash budget + backoff apply.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", required=True,
                    help="worker_spec.json written by ProcessSupervisor")
    args = ap.parse_args(argv)

    with open(args.spec) as f:
        spec = json.load(f)
    attempt = int(os.environ.get("REPRO_WORKER_ATTEMPT", "0"))

    # Import after arg parsing so --help stays instant.
    import contextlib

    from repro.configs import get_config, get_smoke
    from repro.core.api import OptimizerConfig
    from repro.data.synthetic import SyntheticLM
    from repro.models.model import build_model
    from repro.train.elastic import (
        EXIT_DRAINED,
        ElasticSupervisor,
        elastic_config_from_dict,
    )
    from repro.obs.registry import get_registry
    from repro.obs.trace import configure as trace_configure
    from repro.obs.trace import get_tracer
    from repro.train.fault_tolerance import DrainPreemption, Heartbeat

    ecfg = elastic_config_from_dict(spec["elastic"])
    if ecfg.trace_path:
        trace_configure(ecfg.trace_path, host=ecfg.host_id)
    tracer = get_tracer()
    reg = get_registry()
    reg.set_phase("boot")

    # Liveness = process-liveness for the ENTIRE worker lifetime: the
    # refresher must outlive run_attempt (which runs its own) because the
    # model build before it and the final-loss compile + DONE write after
    # it are long non-stepping phases too — a stale-kill there would
    # declare a healthy worker dead mid-completion.
    hb_guard = contextlib.nullcontext()
    if ecfg.heartbeat_path and ecfg.heartbeat_interval_s > 0:
        hb_guard = Heartbeat(
            ecfg.heartbeat_path, timeout=ecfg.heartbeat_timeout_s
        ).auto(ecfg.heartbeat_interval_s)

    arch = spec.get("arch", "tinyllama-1.1b")
    cfg = get_smoke(arch) if spec.get("smoke", True) else get_config(arch)
    with tracer.span("worker/build", attempt=attempt, arch=arch):
        model = build_model(cfg)
    data = SyntheticLM(
        vocab=cfg.vocab_size,
        order=int(spec.get("data_order", 2)),
        noise=float(spec.get("data_noise", 0.1)),
    )
    batch = int(spec.get("batch", 8))
    seq = int(spec.get("seq", 64))

    sup = ElasticSupervisor(
        model,
        lambda step, host: data.batch(step, batch, seq, host),
        ecfg,
        ocfg=OptimizerConfig(
            name=spec.get("optimizer", "coap-adamw"),
            learning_rate=float(spec.get("lr", 3e-3)),
        ),
        # Injected faults that belong IN the worker (torn writes,
        # straggler slowdowns) could be plumbed here; process-level kills
        # and notices are the parent's job.
        fault_injector=None,
    )
    with hb_guard:
        try:
            state = sup.run_attempt(attempt)
        except DrainPreemption:
            return EXIT_DRAINED

        reg.set_phase("final_eval")
        with tracer.span("worker/final_eval", attempt=attempt):
            final_loss, _ = model.loss(
                state.params, data.batch(ecfg.total_steps + 1, batch, seq, 0)
            )
        done_path = os.path.join(ecfg.ckpt_dir, "DONE.json")
        tmp = f"{done_path}.{os.getpid()}.tmp"
        with open(tmp, "w") as f:
            json.dump(
                {"step": int(state.step), "loss": float(final_loss),
                 "attempt": attempt}, f,
            )
        os.replace(tmp, done_path)
    return 0


if __name__ == "__main__":
    sys.exit(main())
