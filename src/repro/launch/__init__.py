"""Launchers: production meshes, the multi-pod dry-run, roofline analysis,
and the train/serve drivers."""
