"""Roofline analysis over the dry-run artifacts (assignment §Roofline).

For every (arch × shape) on the single-pod 16x16 mesh:

    compute term    = HLO_FLOPs_per_device / 197 TFLOP/s      (v5e bf16)
    memory term     = HLO_bytes_per_device / 819 GB/s         (HBM)
    collective term = ring-adjusted collective bytes / 50 GB/s (ICI link)

COAP's Eqn-6/7 refresh lives under lax.cond; its cost is amortized by
1/T_u into the steady-state terms (reported both ways). MODEL_FLOPS uses
6·N·D (train, dense), 6·N_active·D (MoE), 2·N·D (prefill), 2·N·B (decode).

  PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16] [--json out]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / ICI link

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")


def model_flops_per_device(rec: Dict) -> float:
    n_act = rec["n_active_params"]
    nd = rec["n_devices"]
    if rec["kind"] == "train":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 6.0 * n_act * tokens / nd
    if rec["kind"] == "prefill":
        tokens = rec["seq_len"] * rec["global_batch"]
        return 2.0 * n_act * tokens / nd
    return 2.0 * n_act * rec["global_batch"] / nd  # decode: 1 token/seq


def terms(rec: Dict, amortize: bool = True) -> Dict:
    t_u = rec.get("t_update", 40)
    amort = (1.0 / t_u) if amortize else 1.0
    flops = rec["flops_per_device"] + amort * rec.get("flops_cond_per_device", 0.0)
    bytes_ = rec["bytes_per_device"] + amort * rec.get("bytes_cond_per_device", 0.0)
    coll = rec["collective_bytes"]
    coll_b = coll["steady"] + amort * coll.get("conditional", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_n = coll_b / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])
    mf = model_flops_per_device(rec)
    bound = max(t_c, t_m, t_n)
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "collective_s": t_n,
        "dominant": dom[0],
        "step_bound_s": bound,
        "model_flops_per_device": mf,
        "useful_flops_ratio": mf / max(flops, 1.0),
        # fraction of roofline: useful work per second at the bound vs peak
        "roofline_fraction": (mf / max(bound, 1e-12)) / PEAK_FLOPS,
    }


_SUGGEST = {
    "compute": ("compute-bound: raise MXU utilization (bigger per-device "
                "tiles, fewer remat recomputes, fused COAP update)"),
    "memory": ("HBM-bound: fuse attention (flash/chunked, avoid score "
               "materialization), int8 optimizer states, better remat policy"),
    "collective": ("ICI-bound: reshard to cut all-gathers (TP-only layout "
                   "for small models), compress cross-pod grads (G@P), "
                   "overlap collectives with compute"),
}


def load(mesh: str = "16x16", tag: str = "") -> List[Dict]:
    rows = []
    suffix = f"__{mesh}{('__' + tag) if tag else ''}.json"
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, f"*{suffix}"))):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        if len(parts) != (3 if not tag else 4):
            continue
        with open(path) as f:
            rec = json.load(f)
        rows.append(rec)
    return rows


def build_table(mesh: str = "16x16", tag: str = "") -> List[Dict]:
    out = []
    for rec in load(mesh, tag):
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "status": rec["status"]}
        if rec["status"] == "ok":
            row.update(terms(rec))
            row["suggestion"] = _SUGGEST[row["dominant"]]
            row["mem_temp_gb"] = rec["memory"]["temp_bytes"] / 1e9
            row["grad_accum"] = rec.get("grad_accum", "-")
        else:
            row["reason"] = rec.get("reason", rec.get("error", ""))[:100]
        out.append(row)
    return out


def markdown(rows: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "6ND/HLO | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.1%} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", default="artifacts/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.mesh, args.tag)
    print(markdown(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        most_coll = max(ok, key=lambda r: r["collective_s"] /
                        max(r["step_bound_s"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline_fraction']:.2%})")
        print(f"most collective-bound: {most_coll['arch']}/{most_coll['shape']} "
              f"(coll {most_coll['collective_s']:.3g}s of bound "
              f"{most_coll['step_bound_s']:.3g}s)")
    if args.json:
        os.makedirs(os.path.dirname(args.json), exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
