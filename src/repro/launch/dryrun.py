import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first lines: jax locks the device count on first init.

"""Multi-pod dry-run: AOT-lower + compile every (architecture × input shape ×
mesh) cell against the production meshes, proving the distribution config is
coherent — and extracting the roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--optimizer coap-adamw] [--all]

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json and
are consumed by launch/roofline.py and EXPERIMENTS.md.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs, supports_shape
from repro.configs.registry import ASSIGNED
from repro.core.api import OptimizerConfig, make_optimizer
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.train.step import make_train_step
from repro.train.train_state import TrainState, abstract_train_state

ARTIFACT_DIR = os.path.join("artifacts", "dryrun")

# Paper-faithful optimizer settings for the dry-run train cells (Table 5 /
# appendix Table 1: rank 512, T_u 40, λ 5 for ~1B; rank 1024 T_u 100 for 7B+).
def default_opt(cfg) -> OptimizerConfig:
    big = cfg.n_params() > 3e9
    return OptimizerConfig(
        name="coap-adamw",
        learning_rate=1e-2,
        rank=1024 if big else 512,
        t_update=100 if big else 40,
        lam=1 if big else 5,
        grad_clip=1.0,
    )


def generic_state_specs(tree, mesh):
    """Optimizer-state shardings (ZeRO-ish): largest dim over 'data',
    next over 'model' when divisible; small/1-D leaves replicated."""

    def one(x):
        if not hasattr(x, "shape") or len(x.shape) < 2:
            return P()
        spec = [None] * len(x.shape)
        order = sorted(range(len(x.shape)), key=lambda i: -x.shape[i])
        axes = ["data", "model"] if "data" in mesh.axis_names else ["model"]
        for dim_idx in order:
            if not axes:
                break
            ax = axes[0]
            if (
                x.shape[dim_idx] % mesh.shape[ax] == 0
                and x.shape[dim_idx] >= 2 * mesh.shape[ax]
            ):
                spec[dim_idx] = ax
                axes.pop(0)
        return P(*spec)

    return jax.tree_util.tree_map(one, tree)


def _named(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               optimizer: str = "coap-adamw", rules=shd.PARAM_RULES,
               extra_opt: Optional[dict] = None,
               arch_overrides: Optional[dict] = None,
               grad_accum_override: Optional[int] = None,
               plan=None):
    """Returns (step_fn, in_shardings, abstract_args, mesh, meta)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if arch_overrides:
        cfg = _dc.replace(cfg, **arch_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "optimizer": optimizer, "kind": shape.kind}

    batch_abs = input_specs(cfg, shape)
    batch_spec = shd.batch_specs(batch_abs, mesh,
                                 seq_shard=shape.global_batch == 1)

    if shape.kind == "train":
        ocfg = default_opt(cfg)
        ocfg.name = optimizer
        for k, v in (extra_opt or {}).items():
            setattr(ocfg, k, v)
        if plan is not None:
            # Budget-planned cell: the coap-plan/v1 artifact owns rules,
            # layout and per-bucket knobs; run-level knobs stay on ocfg.
            ocfg.plan = plan
            meta["plan_codec"] = plan.codec
            meta["plan_budget_bytes"] = plan.budget_bytes
        tx = make_optimizer(ocfg)
        state_abs = abstract_train_state(model, tx)
        pspecs = model.param_specs(mesh, rules)
        ospecs = generic_state_specs(state_abs.opt_state, mesh)
        state_spec = TrainState(step=P(), params=pspecs, opt_state=ospecs)
        # microbatch accumulation: big models can't hold a 1M-token
        # activation working set; production runs accumulate. Recorded in
        # the artifact so the roofline is per *full* step.
        n = cfg.n_params()
        grad_accum = 16 if n > 5e10 else (4 if n > 4e9 else 1)
        if grad_accum_override:
            grad_accum = grad_accum_override
        meta["grad_accum"] = grad_accum
        step = make_train_step(model, tx, grad_accum=grad_accum)
        in_shardings = (_named(mesh, state_spec), _named(mesh, batch_spec))
        args = (state_abs, batch_abs)
        if plan is not None:
            # Describe the PLANNED knobs, not default_opt's: t_update feeds
            # the roofline's refresh amortization, rank the artifact reader.
            meta["rank"] = sorted({
                b.spec.rank for b in plan.buckets if b.kind == "project"
            })
            meta["t_update"] = plan.globals_.t_update
        else:
            meta["rank"] = ocfg.rank
            meta["t_update"] = ocfg.t_update
        return step, in_shardings, args, mesh, meta

    pspecs = model.param_specs(mesh, rules)
    params_abs = model.abstract_params()
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, _, _ = model.logits(params, batch)
            return logits[:, -1:]  # serving returns last-token logits

        in_shardings = (_named(mesh, pspecs), _named(mesh, batch_spec))
        return prefill_step, in_shardings, (params_abs, batch_abs), mesh, meta

    # decode: one token against a seq_len-deep cache.
    # Serving layout: decode is weight-read-bound, so expert d_model shards
    # over 'data' (PARAM_RULES_SERVE) unlike the train layout.
    if rules is shd.PARAM_RULES:
        rules = shd.PARAM_RULES_SERVE
        pspecs = model.param_specs(mesh, rules)
    b = shape.global_batch
    cache_abs = model.cache_shapes(b, shape.seq_len)
    cspecs = model.cache_specs(mesh, b)

    def serve_step(params, caches, batch):
        return model.decode_step(params, caches, batch)

    in_shardings = (
        _named(mesh, pspecs), _named(mesh, cspecs), _named(mesh, batch_spec)
    )
    return serve_step, in_shardings, (params_abs, cache_abs, batch_abs), mesh, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             optimizer: str = "coap-adamw", tag: str = "",
             rules=shd.PARAM_RULES, extra_opt: Optional[dict] = None,
             save: bool = True, arch_overrides: Optional[dict] = None,
             grad_accum_override: Optional[int] = None, plan=None,
             health_journal: Optional[str] = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    out_name = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": why}
        _save(out_name, rec, save)
        return rec

    t0 = time.time()
    plan_rec = None
    if plan is not None and shape.kind == "train":
        # Exactness gate BEFORE any compile: the plan's predicted bytes
        # must equal accounting.abstract_state_bytes of the optimizer the
        # plan actually constructs (eval_shape — no allocation). A
        # mismatch fails the cell; a drifted byte model must never launch.
        from repro import plan as plan_mod

        try:
            vrep = plan_mod.verify(
                plan, build_model(cfg).abstract_params(),
                learning_rate=default_opt(cfg).learning_rate,
            )
        except plan_mod.PlanMismatchError as e:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "status": "error",
                   "error": f"PlanMismatchError: {e}"}
            _save(out_name, rec, save)
            return rec
        plan_rec = {
            "predicted_state_bytes": vrep["predicted_total"],
            "accounted_state_bytes": vrep["accounted_total"],
            "match": vrep["match"],
            "eqn6_fallback_buckets_predicted": vrep["eqn6_fallback_buckets"],
        }

    step, in_shardings, args, mesh, meta = build_cell(
        arch, shape_name, multi_pod, optimizer, rules, extra_opt,
        arch_overrides, grad_accum_override, plan,
    )
    if arch_overrides:
        meta["arch_overrides"] = {k: str(v) for k, v in arch_overrides.items()}
    try:
        from repro.kernels import ops as kops

        kops.reset_eqn6_fallbacks()
        with mesh:
            jitted = jax.jit(step, in_shardings=in_shardings)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        analysis = hlo_analysis.analyze(hlo, n_devices=len(mesh.devices.flat))
        rec = dict(meta)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "n_devices": int(len(mesh.devices.flat)),
            # call-graph cost model (scan bodies x trip count; see
            # hlo_analysis.py) — xla_* fields keep XLA's single-pass
            # aggregate for reference.
            "flops_per_device": analysis["flops"],
            "flops_cond_per_device": analysis["flops_cond"],
            "bytes_per_device": analysis["hbm_bytes"],
            "bytes_cond_per_device": analysis["hbm_bytes_cond"],
            "collective_bytes": {
                "steady": analysis["collective_bytes"],
                "conditional": analysis["collective_bytes_cond"],
                "by_op": analysis["collective_by_op"],
                "by_op_cond": analysis["collective_by_op_cond"],
            },
            "xla_flops": cost.get("flops", 0.0),
            "xla_bytes": cost.get("bytes accessed", 0.0),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            "hlo_lines": hlo.count("\n"),
            "n_params": cfg.n_params(),
            "n_active_params": cfg.n_active_params(),
            "seq_len": shape.seq_len,
            "global_batch": shape.global_batch,
            # Counted fused-Eqn-6 fallback telemetry (per traced (m, n, r),
            # kernels/ops): plans that land a bucket on the slow unfused
            # refresh are visible here, not just as a one-shot warning.
            "eqn6_fallbacks": _live_eqn6_fallbacks(),
            # Process-wide obs registry snapshot (counters + gauges) —
            # anything any subsystem counted while building this cell.
            "registry": _registry_snapshot(),
        })
        if plan_rec is not None:
            rec["plan"] = plan_rec
        if health_journal:
            # Embed the analyzed verdicts of a prior run's health journal
            # so the dryrun artifact carries BOTH the predicted cost of
            # this cell and the observed numerics of the run it models.
            from repro.obs.health import analyze_journal

            rec["health"] = analyze_journal(health_journal).to_dict()
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        rec = dict(meta)
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    _save(out_name, rec, save)
    return rec


def _live_eqn6_fallbacks() -> dict:
    # THE telemetry formatter (shared with repro.plan.validate) — one
    # definition of the '(m, n, r)' artifact key shape.
    from repro.plan.validate import live_eqn6_fallbacks

    return live_eqn6_fallbacks()


def _registry_snapshot() -> dict:
    from repro.obs.registry import get_registry

    return get_registry().snapshot()


def _save(name: str, rec: dict, save: bool):
    if not save:
        return
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


OPTIMIZED_OVERRIDES = {
    # Beyond-paper performance defaults (EXPERIMENTS.md §Perf): flash-kernel
    # attention, shard_map local-EP MoE dispatch, absorbed MLA decode,
    # pure-bf16 elementwise.
    "attn_impl": "flash",
    "bf16_elementwise": True,
}


def optimized_overrides(arch: str) -> dict:
    cfg = get_config(arch)
    out = dict(OPTIMIZED_OVERRIDES)
    if cfg.n_experts:
        out["moe_impl"] = "local_ep"
    if cfg.mla:
        out["mla_absorbed_decode"] = True
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimizer", default="coap-adamw")
    ap.add_argument("--plan", default="",
                    help="coap-plan/v1 artifact: drive the train cells from "
                         "the planned knobs and cross-check predicted vs "
                         "accounted state bytes before compiling")
    ap.add_argument("--health", default="",
                    help="health.jsonl journal from a prior run: embed its "
                         "analyzed coap-health/v1 verdicts in each cell "
                         "artifact")
    ap.add_argument("--tag", default="")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf beyond-paper overrides")
    ap.add_argument("--all", action="store_true",
                    help="every assigned arch x shape on the chosen mesh(es)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    if args.optimized and not args.tag:
        args.tag = "opt"
    plan = None
    if args.plan:
        from repro.plan.artifact import load_plan

        plan = load_plan(args.plan)
        if not args.tag:
            args.tag = "plan"
        if plan.arch and not args.arch:
            args.arch = plan.arch

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "2x16x16" if mp else "16x16"
                out = f"{arch}__{shape}__{mesh_name}" + (
                    f"__{args.tag}" if args.tag else "")
                path = os.path.join(ARTIFACT_DIR, out + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[cached] {out}: {rec['status']}")
                        results.append(rec)
                        continue
                t0 = time.time()
                overrides = optimized_overrides(arch) if args.optimized else None
                if plan is not None and plan.arch and plan.arch != arch:
                    print(f"[skip] {out}: plan is for {plan.arch}")
                    continue
                rec = run_cell(arch, shape, mp, args.optimizer, args.tag,
                               arch_overrides=overrides, plan=plan,
                               health_journal=args.health or None)
                dt = time.time() - t0
                status = rec["status"]
                extra = rec.get("reason", rec.get("error", ""))[:90]
                print(f"[{dt:6.1f}s] {out}: {status} {extra}", flush=True)
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors ==")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
