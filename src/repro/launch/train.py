"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --optimizer coap-adamw --steps 200 --smoke            # CPU-size run
  ... --watch ckpt_dir    # supervisor mode: restart wedged/dead jobs

On a real pod every host runs this same script (SPMD); here the --smoke flag
selects the reduced config so the full loop (data pipeline, checkpointing,
straggler watchdog, heartbeats, metrics) is exercised end-to-end on CPU.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.configs import get_config, get_smoke
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.optim import warmup_cosine_schedule
from repro.train.fault_tolerance import Heartbeat, run_with_restart
from repro.train.loop import TrainLoop, TrainLoopConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--optimizer", default="coap-adamw")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--t-update", type=int, default=40)
    ap.add_argument("--lam", type=int, default=5)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--metrics", default="artifacts/train_metrics.jsonl")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--watch", default="", help="supervise a heartbeat file")
    args = ap.parse_args()

    if args.watch:
        hb = Heartbeat(args.watch, timeout=120.0)
        while True:
            print("alive" if hb.is_alive() else "DEAD — operator should restart")
            time.sleep(30)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    lr = warmup_cosine_schedule(args.lr, max(10, args.steps // 20), args.steps)
    tx = make_optimizer(OptimizerConfig(
        name=args.optimizer, learning_rate=lr, rank=args.rank,
        t_update=args.t_update, lam=args.lam,
        min_dim=16 if args.smoke else 128, weight_decay=0.0,
    ))
    data = SyntheticLM(vocab=cfg.vocab_size, order=2, noise=0.1)
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, metrics_path=args.metrics,
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat.json"),
        grad_accum=args.grad_accum, log_every=10,
    )

    def attempt(i):
        if i:
            print(f"[restart {i}] resuming from newest checkpoint")
        loop = TrainLoop(
            model, tx,
            lambda step, host: data.batch(step, args.batch, args.seq, host),
            loop_cfg,
        )
        return loop.run()

    state = run_with_restart(attempt, max_restarts=args.max_restarts,
                             on_restart=lambda i, e: print(f"crash: {e}"))
    print(f"done at step {int(state.step)}; "
          f"ce_floor={data.ce_floor():.4f}")


if __name__ == "__main__":
    main()
