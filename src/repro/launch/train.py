"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --optimizer coap-adamw --steps 200 --smoke            # CPU-size run

  ... --watch --devices 8 --hbm-per-device 40GB \
      --shrink-to 4 --shrink-at 100                # elastic supervisor

On a real pod every host runs this same script (SPMD); here the --smoke flag
selects the reduced config so the full loop (data pipeline, checkpointing,
straggler watchdog, heartbeats, metrics) is exercised end-to-end on CPU.

``--watch`` runs the preemption-native elastic supervisor
(``train/elastic.py``): each attempt replans against the current topology
(``plan.solve_for_topology``), restores the newest checkpoint that passes
its crc32 integrity checks, migrates the optimizer state into the new
plan's layout (``stacked_state.migrate``) if the plan changed, and resumes.
Restart policy is a sliding crash budget (``--max-crashes`` per
``--crash-window`` seconds) plus exponential backoff with seeded jitter.
``--inject-kills`` / ``--inject-torn`` / ``--inject-slow`` drive the seeded
fault injector (``train/faults.py``) through the REAL supervise → kill →
replan → relaunch path, so the failure handling is exercised, not assumed.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import get_config, get_smoke
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.optim import warmup_cosine_schedule
from repro.train.fault_tolerance import run_with_restart
from repro.train.loop import TrainLoop, TrainLoopConfig


def _watch(args, cfg, model, data):
    """Elastic supervisor mode (see train/elastic.py). With ``--process``
    the worker is a SPAWNED process (``launch/worker.py``) the supervisor
    can really SIGKILL, supervised purely through the heartbeat file."""
    from repro.launch.plan import parse_budget
    from repro.train.elastic import (
        ElasticConfig,
        ElasticSupervisor,
        ProcessSupervisor,
        Topology,
    )
    from repro.train.faults import FaultInjector, FaultSchedule

    hbm = parse_budget(args.hbm_per_device)
    if hbm is None:
        raise SystemExit("--watch needs an explicit --hbm-per-device budget")
    topology = [Topology(args.devices, hbm)]
    if args.shrink_to:
        topology.append(
            Topology(args.shrink_to, hbm, from_step=args.shrink_at)
        )
    injector = None
    if (args.inject_kills or args.inject_torn or args.inject_slow
            or args.inject_notices):
        sched = FaultSchedule.generate(
            seed=args.fault_seed, total_steps=args.steps,
            n_kills=args.inject_kills, n_torn=args.inject_torn,
            n_slow=args.inject_slow, n_notices=args.inject_notices,
            notice_deadline_s=args.notice_deadline,
        )
        print(f"[watch] fault schedule: {sched}")
        injector = FaultInjector(sched, seed=args.fault_seed)

    ecfg = ElasticConfig(
        ckpt_dir=args.ckpt_dir,
        total_steps=args.steps,
        topology=tuple(topology),
        solve_kw=dict(min_dim=16 if args.smoke else 128,
                      t_update=args.t_update, lam=args.lam),
        ckpt_every=args.ckpt_every,
        log_every=10,
        metrics_path=args.metrics,
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat.json"),
        grad_accum=args.grad_accum,
        max_crashes=args.max_crashes,
        crash_window_s=args.crash_window,
        backoff_base=args.backoff_base,
        backoff_cap=args.backoff_cap,
        seed=args.fault_seed,
        heartbeat_interval_s=args.heartbeat_interval,
        heartbeat_timeout_s=args.heartbeat_timeout,
        resume_horizon_steps=args.resume_horizon,
        fleet_dir=args.fleet_dir or None,
        host_id=args.host_id,
    )

    if args.process:
        spec = dict(
            arch=args.arch, smoke=bool(args.smoke),
            optimizer=args.optimizer, lr=args.lr,
            batch=args.batch, seq=args.seq,
        )
        psup = ProcessSupervisor(spec, ecfg, fault_injector=injector)
        done = psup.run()
        for ev in psup.events:
            print(f"[watch] {ev}")
        print(f"done at step {done.get('step')}; "
              f"loss={done.get('loss'):.4f}; "
              f"ce_floor={data.ce_floor():.4f}")
        return

    sup = ElasticSupervisor(
        model,
        lambda step, host: data.batch(step, args.batch, args.seq, host),
        ecfg,
        ocfg=OptimizerConfig(name=args.optimizer, learning_rate=args.lr),
        fault_injector=injector,
    )
    state = sup.run()
    for ev in sup.events:
        print(f"[watch] {ev}")
    if sup.last_resume:
        print(f"[watch] last resume: {json.dumps(sup.last_resume)}")
    print(f"done at step {int(state.step)}; ce_floor={data.ce_floor():.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--optimizer", default="coap-adamw")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--t-update", type=int, default=40)
    ap.add_argument("--lam", type=int, default=5)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--metrics", default="artifacts/train_metrics.jsonl")
    ap.add_argument("--max-restarts", type=int, default=3)
    # -- elastic supervisor mode -------------------------------------------
    ap.add_argument("--watch", action="store_true",
                    help="elastic supervisor: replan/migrate/resume on crash")
    ap.add_argument("--devices", type=int, default=1,
                    help="[watch] initial device count")
    ap.add_argument("--hbm-per-device", default="auto",
                    help="[watch] per-device HBM budget, e.g. 40GB / 512MiB")
    ap.add_argument("--shrink-to", type=int, default=0,
                    help="[watch] device count after --shrink-at (0 = never)")
    ap.add_argument("--shrink-at", type=int, default=0,
                    help="[watch] step at which the topology shrinks")
    ap.add_argument("--inject-kills", type=int, default=0,
                    help="[watch] seeded injected preemptions")
    ap.add_argument("--inject-torn", type=int, default=0,
                    help="[watch] seeded torn checkpoint writes")
    ap.add_argument("--inject-slow", type=int, default=0,
                    help="[watch] seeded straggler steps")
    ap.add_argument("--inject-notices", type=int, default=0,
                    help="[watch] seeded preemption NOTICES (drain before "
                         "the kill; requires --process or notice polling)")
    ap.add_argument("--notice-deadline", type=float, default=5.0,
                    help="[watch] seconds of warning a notice gives")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--process", action="store_true",
                    help="[watch] out-of-process workers: spawn "
                         "launch/worker.py per attempt, supervise via the "
                         "heartbeat file, SIGKILL for real")
    ap.add_argument("--heartbeat-interval", type=float, default=0.0,
                    help="[watch] worker-side heartbeat refresher period "
                         "(0 = beat only at step boundaries)")
    ap.add_argument("--heartbeat-timeout", type=float, default=300.0,
                    help="[watch] heartbeat age after which the worker "
                         "reads as stale")
    ap.add_argument("--resume-horizon", type=int, default=0,
                    help="[watch] >0: resume-latency-aware replans, "
                         "amortizing migrate+recompile over this many steps")
    ap.add_argument("--fleet-dir", default="",
                    help="[watch] shared dir for multi-supervisor plan "
                         "consensus (train/fleet.py)")
    ap.add_argument("--host-id", default="host-0",
                    help="[watch] this supervisor's fleet member id")
    ap.add_argument("--max-crashes", type=int, default=10,
                    help="[watch] crash budget: N crashes per window")
    ap.add_argument("--crash-window", type=float, default=600.0,
                    help="[watch] crash-budget window, seconds")
    ap.add_argument("--backoff-base", type=float, default=1.0,
                    help="[watch] restart backoff base, seconds (0 = none)")
    ap.add_argument("--backoff-cap", type=float, default=30.0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    data = SyntheticLM(vocab=cfg.vocab_size, order=2, noise=0.1)

    if args.watch:
        _watch(args, cfg, model, data)
        return

    lr = warmup_cosine_schedule(args.lr, max(10, args.steps // 20), args.steps)
    tx = make_optimizer(OptimizerConfig(
        name=args.optimizer, learning_rate=lr, rank=args.rank,
        t_update=args.t_update, lam=args.lam,
        min_dim=16 if args.smoke else 128, weight_decay=0.0,
    ))
    loop_cfg = TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, metrics_path=args.metrics,
        heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat.json"),
        grad_accum=args.grad_accum, log_every=10,
    )

    def attempt(i):
        if i:
            print(f"[restart {i}] resuming from newest checkpoint")
        loop = TrainLoop(
            model, tx,
            lambda step, host: data.batch(step, args.batch, args.seq, host),
            loop_cfg,
        )
        return loop.run()

    state = run_with_restart(attempt, max_restarts=args.max_restarts,
                             on_restart=lambda i, e: print(f"crash: {e}"))
    print(f"done at step {int(state.step)}; "
          f"ce_floor={data.ce_floor():.4f}")


if __name__ == "__main__":
    main()
