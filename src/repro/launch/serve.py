"""Serving launcher: batched generation with any --arch (smoke size on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b --smoke \
      --prompts "hello world" "the quick brown fox"
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke
from repro.data.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompts", nargs="*",
                    default=["hello world", "the quick brown fox"])
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tok = ByteTokenizer()
    engine = ServeEngine(model, params, ServeConfig(
        max_new_tokens=args.max_new_tokens, temperature=args.temperature,
    ))
    prompts = [[t % cfg.vocab_size for t in tok.encode(p)] for p in args.prompts]
    t0 = time.time()
    outs = engine.generate(prompts)
    dt = time.time() - t0
    new_tokens = sum(len(o) - len(p) for o, p in zip(outs, prompts))
    for p, o in zip(args.prompts, outs):
        print(f"prompt={p!r} -> {o[-args.max_new_tokens:]}")
    print(f"{new_tokens} tokens in {dt:.2f}s "
          f"({new_tokens/dt:.1f} tok/s, untrained weights)")


if __name__ == "__main__":
    main()
