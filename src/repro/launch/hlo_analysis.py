"""HLO cost model for the roofline: call-graph-aware FLOPs / HBM bytes /
collective bytes from the compiled SPMD module text.

Why not ``compiled.cost_analysis()``: XLA's aggregate counts while-loop
bodies ONCE, but all our stacks are scan-over-layers — an 80-layer model
would be under-counted 80x. This walker multiplies each while body by its
``known_trip_count`` (emitted by XLA in backend_config) and attributes cost
through fusion/call/conditional edges from ENTRY.

Three quantities per device (the HLO is already the per-device module):
  * flops            — 2·result·contraction for every dot (+conv estimate);
                       elementwise ops ignored (dots dominate transformers).
  * hbm_bytes        — operand+result bytes of top-level fusions/dots/copies/
                       collectives (fusion boundaries ≈ HBM materialization).
  * collective_bytes — ring-adjusted per-op communicated volume:
        all-reduce 2(k-1)/k · b;  all-gather (k-1)/k · b(gathered);
        reduce-scatter (k-1) · b(shard);  all-to-all (k-1)/k · b;
        collective-permute 1 · b.

Each quantity is split into ``steady`` (always executed) and ``cond``
(inside `conditional` branches — COAP's Eqn-6/7 refresh path), so the
steady-state roofline can amortize refresh cost by 1/T_u.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%([\w.\-]+)\s+\((.*)\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_COLL_FACTORS = {
    "all-reduce": lambda k: 2.0 * (k - 1) / k,
    "all-gather": lambda k: (k - 1) / k,
    "reduce-scatter": lambda k: float(k - 1),
    "all-to-all": lambda k: (k - 1) / k,
    "collective-permute": lambda k: 1.0,
}
# ops whose operands/results approximate HBM traffic post-fusion
# Deliberately excludes view-ish ops (reshape/broadcast/slice/transpose/
# iota/ds/dus/reduce): on TPU these fuse into consumers; counting them on the
# CPU-backend HLO (where they appear unfused) would inflate the memory term
# severalfold. Fusion call sites carry the real operand/result traffic.
_TRAFFIC_OPS = (
    "fusion", "dot", "convolution", "copy", "gather", "scatter", "sort",
    "custom-call", "cholesky", "triangular-solve",
) + _COLL_OPS
_FREE_OPS = ("get-tuple-element", "bitcast", "tuple", "parameter", "constant",
             "after-all", "partition-id", "replica-id")

# Kernel-boundary accounting: ops inside a jax.named_scope carrying this tag
# correspond to a validated Pallas kernel (kernels/flash_attention.py). Their
# FLOPs are real, but intermediate tensors live in VMEM on TPU — so only
# dataflow ENTERING the region from outside counts as HBM traffic (the
# kernel's q/k/v reads); region outputs are counted by their consumers.
REGION_TAG = "PALLAS_FLASH_REGION"


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shapes(type_str: str) -> List[Tuple[str, int, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            dim_list = [int(d) for d in dims.split(",") if d]
            out.append((dtype, _elems(dims), dim_list))
    return out


def _type_bytes(type_str: str) -> int:
    return sum(_DTYPE_BYTES[d] * n for d, n, _ in _first_shapes(type_str))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def coll_total(self) -> float:
        return sum(self.coll.values())

    def __add__(self, o: "Cost") -> "Cost":
        coll = dict(self.coll)
        for k, v in o.coll.items():
            coll[k] = coll.get(k, 0.0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes, coll)

    def __mul__(self, s: float) -> "Cost":
        return Cost(self.flops * s, self.bytes * s,
                    {k: v * s for k, v in self.coll.items()})


@dataclasses.dataclass
class Edge:
    callee: str
    multiplier: float
    conditional: bool
    fusion: bool = False  # fusion/to_apply internals: flops real, bytes not


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.defs: Dict[str, str] = {}  # %op name -> result type string
        self.region_defs: set = set()  # names defined inside a kernel region
        self.local = Cost()
        self.local_cond = Cost()  # nothing at local level; kept for symmetry
        self.edges: List[Edge] = []


def _op_kind(rhs: str) -> Optional[str]:
    m = re.match(r"(?:\(?[\w\[\],{}\s\-]*\)?\s)?.*?([\w\-]+)\(", rhs)
    # robust: find first "name(" that is a known op
    for op in _COLL_OPS:
        if re.search(rf"\b{op}(?:-start|-done)?\(", rhs):
            return op
    m2 = re.search(r"\b([a-z][\w\-]*)\(", rhs)
    return m2.group(1) if m2 else None


def parse(hlo: str) -> Tuple[Dict[str, _Computation], str, int]:
    comps: Dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for raw in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(raw)
        if hdr and "{" in raw:
            cur = _Computation(hdr.group(1))
            comps[cur.name] = cur
            if raw.lstrip().startswith("ENTRY"):
                entry = cur.name
            # parameters: name: type pairs
            for pname, ptype in re.findall(r"([\w.\-]+):\s*([\w\[\],]+)",
                                           hdr.group(2)):
                cur.defs[pname] = ptype
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(raw)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        result_type = rhs.split(" ", 1)[0] if " " in rhs else rhs
        # tuple results keep full "(a, b)" prefix up to the op name
        cur.defs[name] = rhs.split("=", 1)[0] if False else result_type
        _accumulate(cur, name, rhs, raw)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c].defs)) if comps else ""
    n_dev = 1
    return comps, entry, n_dev


def _operands(rhs: str) -> List[str]:
    m = re.search(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)", rhs[rhs.find("("):])
    if not m:
        return []
    return re.findall(r"%([\w.\-]+)", m.group(1))


def _accumulate(comp: _Computation, name: str, rhs: str, raw: str):
    in_region = REGION_TAG in raw
    if in_region:
        comp.region_defs.add(name)
    op = _op_kind(rhs)
    if op is None or op in _FREE_OPS:
        return
    result_type = rhs[: rhs.find(op + "(")] if (op + "(") in rhs else rhs
    # tuple result: everything before the op name
    res_bytes = _type_bytes(result_type)

    # ---- call edges
    if op == "while":
        body = re.search(r"body=%?([\w.\-]+)", raw)
        cond = re.search(r"condition=%?([\w.\-]+)", raw)
        trip = _TRIP_RE.search(raw)
        n = int(trip.group(1)) if trip else 1
        if body:
            comp.edges.append(Edge(body.group(1), float(max(n, 1)), False))
        if cond:
            comp.edges.append(Edge(cond.group(1), float(max(n, 1)) + 1, False))
        return
    if op == "conditional":
        names = re.findall(
            r"(?:branch_computations=\{([^}]*)\}|"
            r"(?:true|false)_computation=%?([\w.\-]+))", raw)
        for grp, single in names:
            if grp:
                for nme in grp.split(","):
                    comp.edges.append(Edge(nme.strip().lstrip("%"), 1.0, True))
            if single:
                comp.edges.append(Edge(single, 1.0, True))
        return
    for callee in re.findall(r"(?:calls|to_apply)=%?([\w.\-]+)", raw):
        # fusion internals: count flops (a fused dot is still a dot) but not
        # bytes (VMEM-resident) — the fusion call site carries the traffic.
        comp.edges.append(Edge(callee, 1.0, False, fusion=True))

    # ---- flops
    if op == "dot":
        ops_ = _operands(rhs)
        contract = 1
        lhs_type = comp.defs.get(ops_[0], "") if ops_ else ""
        lhs_shapes = _first_shapes(lhs_type)
        cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", raw)
        if lhs_shapes and cdims:
            dims = lhs_shapes[0][2]
            for i in cdims.group(1).split(","):
                if i and int(i) < len(dims):
                    contract *= dims[int(i)]
        res_elems = sum(n for _, n, _ in _first_shapes(result_type))
        comp.local.flops += 2.0 * res_elems * max(contract, 1)
    elif op == "convolution":
        res_elems = sum(n for _, n, _ in _first_shapes(result_type))
        win = re.search(r"window=\{size=([\dx]+)", raw)
        wprod = 1
        if win:
            for d in win.group(1).split("x"):
                wprod *= int(d)
        ops_ = _operands(rhs)
        in_ch = 1
        if len(ops_) >= 2:
            ksh = _first_shapes(comp.defs.get(ops_[1], ""))
            if ksh:
                in_ch = max(ksh[0][2][-2] if len(ksh[0][2]) >= 2 else 1, 1)
        comp.local.flops += 2.0 * res_elems * wprod * in_ch

    # ---- bytes (HBM traffic approximation at fusion boundaries)
    if op in _TRAFFIC_OPS:
        if in_region:
            # kernel-boundary: only region-external operands are HBM reads
            opn_bytes = sum(
                _type_bytes(comp.defs.get(o, ""))
                for o in _operands(rhs) if o not in comp.region_defs
            )
            comp.local.bytes += opn_bytes
        else:
            opn_bytes = 0
            for o in _operands(rhs):
                opn_bytes += _type_bytes(comp.defs.get(o, ""))
            comp.local.bytes += res_bytes + opn_bytes

    # ---- collectives
    if op in _COLL_OPS:
        k = _group_size(raw, 0)
        comm = _COLL_FACTORS[op](max(k, 2)) * res_bytes
        comp.local.coll[op] = comp.local.coll.get(op, 0.0) + comm


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


def analyze(hlo: str, n_devices: int = 1) -> Dict:
    """Full-module per-device cost. Returns dict with steady/cond splits."""
    comps, entry, _ = parse(hlo)
    memo: Dict[Tuple[str, bool], Tuple[Cost, Cost]] = {}

    def walk(name: str) -> Tuple[Cost, Cost]:
        """Returns (steady, cond) subtree costs."""
        if name not in comps:
            return Cost(), Cost()
        if name in memo:
            return memo[name]
        memo[name] = (Cost(), Cost())  # cycle guard
        comp = comps[name]
        steady = Cost() + comp.local
        cond = Cost()
        for e in comp.edges:
            s, c = walk(e.callee)
            if e.fusion:
                s = Cost(flops=s.flops)
                c = Cost(flops=c.flops)
            if e.conditional:
                cond = cond + (s + c) * e.multiplier
            else:
                steady = steady + s * e.multiplier
                cond = cond + c * e.multiplier
        memo[name] = (steady, cond)
        return memo[name]

    steady, cond = walk(entry)
    return {
        "flops": steady.flops,
        "flops_cond": cond.flops,
        "hbm_bytes": steady.bytes,
        "hbm_bytes_cond": cond.bytes,
        "collective_bytes": steady.coll_total(),
        "collective_bytes_cond": cond.coll_total(),
        "collective_by_op": steady.coll,
        "collective_by_op_cond": cond.coll,
    }


# Back-compat shim used by dryrun.py's earlier artifacts
def collective_bytes(hlo: str, n_devices: int) -> Dict[str, float]:
    a = analyze(hlo, n_devices)
    return {
        "total": a["collective_bytes"] + a["collective_bytes_cond"],
        "steady": a["collective_bytes"],
        "by_op": a["collective_by_op"],
        "conditional": a["collective_bytes_cond"],
    }
