"""Plan CLI: solve an architecture's COAP knobs under an HBM budget.

    PYTHONPATH=src python -m repro.launch.plan --arch llama-1b --budget 40GB
        [--quantize auto|force|off] [--compression 4.0] [--t-update N]
        [--out artifacts/plan/<arch>.json] [--verify] [--all]

Prints the chosen plan as a table (one row per congruence bucket: rank,
storage codec, refresh cadence, predicted state bytes, AdamW baseline,
fused-Eqn-6 feasibility), writes the ``coap-plan/v1`` artifact, and with
``--verify`` cross-checks the predicted bytes against
``accounting.abstract_state_bytes`` of the actually-constructed optimizer —
the same exactness gate ``launch/dryrun --plan`` runs before training.
``--all`` plans (and verifies) every registry architecture — the CI plan
smoke (`scripts/ci.sh`).
"""
from __future__ import annotations

import argparse
import os
import re
import sys

ARTIFACT_DIR = os.path.join("artifacts", "plan")

_UNITS = {
    "": 1, "B": 1,
    "KB": 10**3, "MB": 10**6, "GB": 10**9, "TB": 10**12,
    "KIB": 2**10, "MIB": 2**20, "GIB": 2**30, "TIB": 2**40,
}


def parse_budget(text: str):
    """'40GB' / '40 GiB' / '1.5e10' -> bytes (decimal GB = 1e9); 'auto' ->
    None (unconstrained: fp32 plan, budget recorded as the resident total —
    what the --all registry smoke uses, since one fixed byte count cannot
    fit both whisper-medium and grok-314b)."""
    if str(text).strip().lower() == "auto":
        return None
    m = re.fullmatch(
        r"\s*([0-9.eE+]+)\s*([A-Za-z]*)\s*", str(text)
    )
    if not m or m.group(2).upper() not in _UNITS:
        raise ValueError(
            f"cannot parse budget {text!r} (try '40GB', '512MiB', bytes)"
        )
    return int(float(m.group(1)) * _UNITS[m.group(2).upper()])


def _fmt_bytes(b: float) -> str:
    if b >= 1e9:
        return f"{b/1e9:8.2f} GB"
    return f"{b/1e6:8.1f} MB"


def render_table(plan) -> str:
    rows = [
        "| bucket | shape | leaves | rank | store | T_u | groups | "
        "state | adamw | eqn6 |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for b in plan.buckets:
        if b.kind == "conv":
            rank = f"({b.spec.rank_o},{b.spec.rank_i})"
        elif b.kind == "project":
            rank = str(b.spec.rank)
        else:
            rank = "dense"
        fused = {True: "fused", False: "FALLBACK", None: "-"}[b.eqn6_fused]
        rows.append(
            f"| {b.kind} | {'x'.join(map(str, b.shape))} | {b.count} | "
            f"{rank} | {'int8' if b.quantize else plan.globals_.state_dtype} "
            f"| {b.t_update} | {b.stagger_groups} | "
            f"{_fmt_bytes(b.predicted_bytes_total).strip()} | "
            f"{_fmt_bytes(b.baseline_adamw_bytes).strip()} | {fused} |"
        )
    p = plan.predicted
    rows.append("")
    rows.append(
        f"optimizer state {_fmt_bytes(p['state_bytes_total']).strip()} "
        f"(AdamW {_fmt_bytes(p['baseline']['state_bytes_total']).strip()}): "
        f"-{p['reduction_vs_adamw']:.1%} moment-state (paper denominator), "
        f"-{p['reduction_vs_adamw_total']:.1%} total"
    )
    rows.append(
        f"budget {_fmt_bytes(plan.budget_bytes).strip()}: params "
        f"{_fmt_bytes(p['params_bytes']).strip()} + grads "
        f"{_fmt_bytes(p['grads_bytes']).strip()} + state = "
        f"{_fmt_bytes(p['hbm_total_bytes']).strip()} resident "
        f"({p['n_quantized_buckets']} bucket(s) on int8)"
    )
    rows.append(
        f"predicted optimizer step cost: {plan.cost['step_seconds']*1e3:.2f}"
        " ms (roofline, calibrated)"
    )
    fb = [b for b in plan.buckets if b.eqn6_fused is False]
    if fb:
        rows.append(
            f"NOTE: {len(fb)} bucket(s) exceed the fused Eqn-6 VMEM budget "
            "and will refresh on the unfused path (ROADMAP: n-split kernel)"
        )
    return "\n".join(rows)


def plan_one(arch: str, budget: int, args, tolerate_infeasible: bool) -> bool:
    """Plan (and optionally verify) one arch; returns success."""
    from repro import plan as plan_mod
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.plan.artifact import save_plan

    cfg = get_config(arch)
    params = build_model(cfg).abstract_params()  # built ONCE, reused below
    calib = None
    if getattr(args, "calib", None):
        from repro.plan.cost import Calibration

        calib = Calibration.load(calib_path=args.calib)
    try:
        plan = plan_mod.solve(
            params, budget,
            arch=arch,
            big_model=cfg.n_params() > 3e9,
            rank_compression=args.compression,
            quantize=args.quantize,
            t_update=args.t_update,
            stagger_groups=args.stagger_groups,
            calib=calib,
        )
    except plan_mod.PlanInfeasibleError as e:
        # Under --all a fixed budget legitimately cannot fit every arch
        # (grok-314B outgrows any laptop budget): report and keep going.
        # For an explicit single arch, infeasibility is the failure the
        # caller asked the planner to detect — exit nonzero.
        print(f"== plan: {arch}: INFEASIBLE — {e}")
        return tolerate_infeasible
    shown = "auto" if budget is None else _fmt_bytes(budget).strip()
    print(f"== plan: {arch} under {shown} ==")
    print(render_table(plan))
    out = args.out
    if not out:
        if budget is None:
            tag = "auto"
        elif budget % 10**9 == 0:
            tag = f"{budget//10**9}GB"
        else:
            tag = str(budget)
        out = os.path.join(ARTIFACT_DIR, f"{arch}__{tag}.json")
    save_plan(plan, out)
    print(f"wrote {out}")
    if not args.verify:
        return True
    rep = plan_mod.verify(plan, params)
    print(
        f"verify: predicted {rep['predicted_total']} == accounted "
        f"{rep['accounted_total']} bytes "
        f"({'EXACT MATCH' if rep['match'] else 'MISMATCH'})"
    )
    return rep["match"]


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Budget-driven COAP memory planner (coap-plan/v1)"
    )
    ap.add_argument("--arch", default="llama-1b")
    ap.add_argument("--budget", default="40GB",
                    help="HBM budget for params+grads+optimizer state")
    ap.add_argument("--quantize", default="auto",
                    choices=["auto", "force", "off"])
    ap.add_argument("--compression", type=float, default=4.0,
                    help="quality floor c: rank >= min(m,n)/c (paper: 4)")
    ap.add_argument("--t-update", type=int, default=None,
                    help="override the scale-recipe T_u")
    ap.add_argument("--stagger-groups", type=int, default=8)
    ap.add_argument("--calib", default="",
                    help="coap-calib/v1 artifact (obs.calib) — ranks "
                         "candidates by measured seconds instead of the "
                         "analytic roofline constants")
    ap.add_argument("--out", default="")
    ap.add_argument("--verify", action="store_true",
                    help="cross-check predicted bytes against the real "
                         "optimizer (accounting.abstract_state_bytes)")
    ap.add_argument("--all", action="store_true",
                    help="plan every registry architecture")
    args = ap.parse_args(argv)
    budget = parse_budget(args.budget)

    if args.all:
        from repro.configs.registry import list_archs

        archs = list_archs()
        if args.out:
            print("--all plans every arch: ignoring --out, using per-arch "
                  f"names under {ARTIFACT_DIR}/")
            args.out = ""
    else:
        archs = [args.arch]
    ok = True
    for arch in archs:
        ok = plan_one(arch, budget, args, tolerate_infeasible=args.all) and ok
        print()
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
