"""Version-compat shims for jax API drift.

``jax.shard_map`` only exists from jax 0.6; on 0.4.x the equivalent lives at
``jax.experimental.shard_map.shard_map`` with a slightly different keyword
surface (``check_rep`` instead of ``check_vma``; the manual axis set is
expressed through its complement ``auto`` instead of ``axis_names``). All
shard_map call sites in this repo go through :func:`shard_map` below, which
accepts the modern keyword form and translates as needed.
"""
from __future__ import annotations

from typing import Any, FrozenSet, List, Optional, Set

import jax

# Stack of manual-axis sets for shard_map bodies currently being traced.
# jax<=0.4 has no public way to ask "which mesh axes are Manual here?" (the
# abstract-mesh axis_types API landed later), so the shim records it at trace
# time; ``manual_axes_in_scope`` is consulted by sharding constraints to drop
# manual axes. Trace-time only — single-threaded per trace, plain list is fine.
_MANUAL_STACK: List[FrozenSet[str]] = []


def manual_axes_in_scope() -> FrozenSet[str]:
    """Mesh axes that are shard_map-manual at the current trace point."""
    out: Set[str] = set()
    for axes in _MANUAL_STACK:
        out |= axes
    return frozenset(out)


def shard_map(
    f,
    *,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    check_vma: bool = True,
    axis_names: Optional[Set[str]] = None,
):
    """``jax.shard_map`` with fallback to the jax<=0.4 experimental API.

    ``axis_names`` — the mesh axes the body is *manual* over (all axes when
    None), matching the modern API; translated to the experimental API's
    ``auto`` complement set.
    """
    manual = frozenset(axis_names) if axis_names is not None else frozenset(
        mesh.axis_names
    )

    if hasattr(jax, "shard_map"):
        def body(*args, **kw):
            _MANUAL_STACK.append(manual)
            try:
                return f(*args, **kw)
            finally:
                _MANUAL_STACK.pop()

        kwargs = dict(
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(body, **kwargs)

    # jax<=0.4 fallback. The experimental ``auto=`` partial-manual mode
    # aborts XLA's SPMD partitioner (IsManualSubgroup check) on these
    # replicated-in/replicated-out bodies, so go FULL manual instead: axes
    # that would have been auto carry only replicated operands here, so the
    # body computes the same values on every shard along them — identical
    # numerics, just without XLA re-partitioning the interior. All mesh axes
    # are recorded as manual so inner sharding constraints are dropped.
    from jax.experimental.shard_map import shard_map as _shard_map

    full_manual = frozenset(mesh.axis_names)

    def body04(*args, **kw):
        _MANUAL_STACK.append(full_manual)
        try:
            return f(*args, **kw)
        finally:
            _MANUAL_STACK.pop()

    return _shard_map(
        body04, mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
