"""Preemption-native elastic training: the replan → migrate → resume loop.

COAP's value proposition — big-model training on less memory — lands on
preemptible/spot capacity in practice, where the run that matters is the
one that survives kills, topology churn and budget changes. This module
composes the repo's ingredients into that run:

  1. **replan** — on every (re)start the supervisor reads the CURRENT
     topology (device count × HBM per device) and re-runs the analytic
     planner (``plan.solver.solve_for_topology``; pod-total budget =
     ``n_devices × hbm_per_device``, FSDP/ZeRO-style). Shrinking 8→4
     devices halves the pool and the solver's quantize knapsack flips
     buckets to int8 exactly where needed — a NEW ``coap-plan/v1``.
  2. **migrate** — the newest valid checkpoint is restored into the plan
     that WROTE it (the plan artifact rides in the checkpoint manifest's
     ``meta``, atomically with the arrays) and its optimizer state is
     transformed to the new plan's layout by ``stacked_state.migrate``
     (rank truncate / Eqn-7-style expand, quantize requant/dequant,
     re-bucket) — byte-exact against ``accounting.abstract_state_bytes``
     of the target optimizer. Checkpoints that fail their crc32 integrity
     check (torn writes) are skipped, falling back newest→oldest.
  3. **resume** — training continues mid-epoch. ``ProjectedAdamState
     .count`` is preserved through migration and the staggered refresh /
     Eqn-7 recalibration cadence is a pure function of ``(step, layout)``
     (``coap_adam.bucket_phases`` + ``_sched_preds``), so the schedule
     re-derives deterministically — two resumes from the same checkpoint
     follow bit-identical phases (:func:`stagger_signature` pins this).

Restart policy comes from ``fault_tolerance.run_with_restart``: sliding
crash-budget window + exponential backoff with seeded jitter. Failure
modes are exercised end-to-end by ``train/faults.py`` injection (seeded
kills, torn checkpoint writes, heartbeat silence, stragglers) — driven
from the CLI via ``python -m repro.launch.train --watch``.

Topology changes take effect at attempt boundaries: a preemption/scale
event kills the worker (for real, or via an injected kill), and the next
attempt replans against the new topology. That matches how clusters
actually deliver topology change — as the death of the old allocation.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import random
import subprocess
import sys
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import stacked_state
from repro.core.api import OptimizerConfig, make_optimizer
from repro.core.coap_adam import ProjectedAdamState, bucket_phases
from repro.obs import calib as obs_calib
from repro.obs.registry import get_registry
from repro.obs.health import configure as health_configure
from repro.obs.trace import configure as trace_configure
from repro.obs.trace import get_tracer
from repro.plan import apply as plan_apply
from repro.plan.artifact import Plan
from repro.plan.solver import solve_for_topology
from repro.train import checkpoint as ckpt
from repro.train import fleet as fleet_mod
from repro.train.fault_tolerance import (
    CrashBudget,
    DrainPreemption,
    Heartbeat,
    SupervisionPolicy,
    backoff_delay,
    decide_supervision,
    run_with_restart,
)
from repro.train.loop import TrainLoop, TrainLoopConfig
from repro.train.train_state import TrainState

# Exit code a worker process uses to say "I drained cleanly on a
# preemption notice" (vs 0 = run complete, anything else = crash).
# 75 = EX_TEMPFAIL: try again, nothing is wrong.
EXIT_DRAINED = 75


@dataclasses.dataclass(frozen=True)
class Topology:
    """One cluster configuration, effective from training step
    ``from_step`` onward (a schedule entry for tests/simulation; in
    production there is typically one entry, replaced when the allocation
    actually changes)."""

    n_devices: int
    hbm_per_device: int  # bytes
    from_step: int = 0


def topology_at(topologies: Sequence[Topology], step: int) -> Topology:
    """The topology in effect at ``step``: the last entry whose
    ``from_step`` is <= step (entries need not be sorted)."""
    best = None
    for t in topologies:
        if t.from_step <= step and (best is None or t.from_step >= best.from_step):
            best = t
    if best is None:
        raise ValueError(
            f"no topology covers step {step} (need an entry with "
            "from_step <= step; give the initial topology from_step=0)"
        )
    return best


@dataclasses.dataclass
class ElasticConfig:
    ckpt_dir: str
    total_steps: int
    topology: Tuple[Topology, ...]
    # Planner knobs forwarded to solve_for_topology (rank_compression,
    # min_dim, t_update, lam, stagger_groups, quantize, ...).
    solve_kw: Dict[str, Any] = dataclasses.field(default_factory=dict)
    ckpt_every: int = 10
    ckpt_keep: int = 3
    log_every: int = 100
    metrics_path: Optional[str] = None
    heartbeat_path: Optional[str] = None
    grad_accum: int = 1
    # Restart policy (fault_tolerance): sliding crash budget + backoff.
    max_crashes: int = 10
    crash_window_s: float = 600.0
    backoff_base: float = 0.0  # seconds; 0 disables sleeping (tests)
    backoff_cap: float = 30.0
    backoff_jitter: float = 0.1
    seed: int = 0
    # Optional mesh: direct (non-migrating) restores are device_put
    # replicated onto it (distributed.sharding.replicated_specs).
    mesh: Any = None
    # Preemption-notice file the worker polls every step (see
    # TrainLoopConfig.notice_path): present -> checkpoint now, ack, exit
    # as a drain. The supervisor (in- or out-of-process) owns the file's
    # lifecycle and clears it before every attempt.
    notice_path: Optional[str] = None
    # >0: each attempt runs a HeartbeatRefresher daemon beating every
    # this-many seconds, so liveness = process-liveness (restore/compile
    # phases don't read as stale) and a SIGKILL shows up within
    # Heartbeat.timeout. 0 (default): per-step beats only.
    heartbeat_interval_s: float = 0.0
    heartbeat_timeout_s: float = 300.0
    # Wall-clock floor per training step (TrainLoopConfig.min_step_s).
    min_step_s: float = 0.0
    # Events journal (JSON lines): every supervisor event is also
    # appended here, which is how an out-of-process worker's events reach
    # its supervisor and the tests.
    events_path: Optional[str] = None
    # >0: replans are resume-latency-aware — the solver sees the plan the
    # newest checkpoint was written under and amortizes per-bucket
    # migrate+recompile cost over this many remaining steps
    # (plan/solver.solve: prev_plan / resume_horizon_steps).
    resume_horizon_steps: int = 0
    # Multi-supervisor plan consensus (train/fleet.py): when fleet_dir is
    # set, every replan goes through PlanConsensus.plan_for_epoch — one
    # elected host solves, peers adopt the committed coap-plan/v1.
    fleet_dir: Optional[str] = None
    host_id: str = "host-0"
    # Span-trace journal (obs/trace.py): when set, every attempt records
    # restore/migrate/compile/step/checkpoint spans here (exportable to
    # Perfetto via obs.trace.export_perfetto, fittable into a
    # coap-calib/v1 artifact via obs.calib.build_from_trace). Serialized
    # with the rest of the config, so spawned workers trace too.
    trace_path: Optional[str] = None
    # Projection-health journal (obs/health.py): when set, every attempt
    # configures the process monitor here — refresh-boundary numerics
    # (captured energy / Eqn-6 residual / subspace overlap) from inside
    # the optimizer plus sampled int8-codec and EF-sidecar stats every
    # ``health_every`` steps. Serialized with the config so spawned
    # workers journal too; fleet_status reads it for the health column.
    health_path: Optional[str] = None
    health_every: int = 25


def elastic_config_to_dict(cfg: ElasticConfig) -> Dict[str, Any]:
    """JSON-serializable form of an ElasticConfig (the worker-spec wire
    format). The mesh is not serializable and must be None."""
    if cfg.mesh is not None:
        raise ValueError("elastic_config_to_dict: mesh must be None "
                         "(worker processes build their own)")
    d = dataclasses.asdict(cfg)
    d.pop("mesh")
    return d


def elastic_config_from_dict(d: Dict[str, Any]) -> ElasticConfig:
    d = dict(d)
    d["topology"] = tuple(
        t if isinstance(t, Topology) else Topology(**t)
        for t in d.get("topology", ())
    )
    return ElasticConfig(**d)


def _map_projected_states(opt_state, fn: Callable[[ProjectedAdamState], Any]):
    """Apply ``fn`` to every ProjectedAdamState inside a (possibly nested
    chain) optimizer state, leaving everything else untouched."""
    return jax.tree_util.tree_map(
        lambda n: fn(n) if isinstance(n, ProjectedAdamState) else n,
        opt_state,
        is_leaf=lambda n: isinstance(n, ProjectedAdamState),
    )


def find_projected_state(opt_state) -> Optional[ProjectedAdamState]:
    """The (first) ProjectedAdamState inside an optimizer state tree."""
    found = []

    def grab(n):
        found.append(n)
        return n

    _map_projected_states(opt_state, grab)
    return found[0] if found else None


def migrate_opt_state(
    opt_state,
    src_plan: Plan,
    dst_plan: Plan,
    params,
    ocfg: OptimizerConfig,
):
    """Optimizer-state tree under ``src_plan`` -> the same tree under
    ``dst_plan`` via ``stacked_state.migrate``. ``count`` is preserved —
    the resumed schedule continues from the same step. ``params`` may be
    abstract (shapes only)."""
    dst_layout = stacked_state.layout_for_tree(
        plan_apply.planned_rules(dst_plan).spec_for, params
    )
    qmap = plan_apply.quantize_by_path(dst_plan)
    g = dst_plan.globals_

    def mig(s: ProjectedAdamState) -> ProjectedAdamState:
        if not isinstance(s.leaves, stacked_state.StackedLeaves):
            raise ValueError(
                "plan migration operates on stacked-bucket/v2 state; this "
                "state is per-leaf (plans set stacked_state=True — was the "
                "checkpoint written by an unplanned run?)"
            )
        leaves = stacked_state.migrate(
            s.leaves,
            dst_layout,
            quantize_for=lambda p: qmap[p],
            quant_block=g.quant_block,
            src_quant_block=src_plan.globals_.quant_block,
            state_dtype=jnp.dtype(g.state_dtype).type,
            seed=ocfg.seed,
        )
        return ProjectedAdamState(count=s.count, leaves=leaves)

    return _map_projected_states(opt_state, mig)


def stagger_signature(plan: Plan, params, ocfg: OptimizerConfig):
    """The staggered refresh phases the planned optimizer will follow — a
    pure function of ``(layout, plan)`` via ``coap_adam.bucket_phases``,
    so it is identical across restarts, resumes and hosts. The kill/
    resume tests compare this signature across two resumes from the same
    checkpoint (bit-identical schedules, acceptance criterion 3)."""
    cfg = plan_apply.planned_config(plan, ocfg)
    layout = stacked_state.layout_for_tree(cfg.rules.spec_for, params)
    phases = bucket_phases(cfg, layout)
    return tuple(sorted((bi, tuple(ph)) for bi, ph in phases.items()))


class ElasticSupervisor:
    """supervise → (kill) → replan → migrate → relaunch.

    Each worker *attempt* plans against the current topology, restores
    the newest checkpoint that passes integrity checks (migrating its
    optimizer state if the plan changed), and runs ``TrainLoop`` to
    completion. Crashes — real or injected — return control here; the
    sliding crash budget and exponential backoff decide whether/when the
    next attempt launches. ``events`` records what happened (resumes,
    migrations, torn checkpoints skipped) for tests and operators;
    ``last_resume`` holds the latest resume-latency breakdown
    (restore vs migrate vs compile — ``benchmarks/overhead.run_elastic``
    reports the same split).
    """

    def __init__(
        self,
        model,
        batch_fn: Callable[[int, int], Dict],
        cfg: ElasticConfig,
        ocfg: Optional[OptimizerConfig] = None,
        fault_injector=None,
        init_key=None,
        sleep_fn: Callable[[float], None] = time.sleep,
    ):
        self.model = model
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.ocfg = ocfg if ocfg is not None else OptimizerConfig()
        self.fault_injector = fault_injector
        self.sleep_fn = sleep_fn
        self._init_key = init_key if init_key is not None else jax.random.key(0)
        self._abstract_params = jax.eval_shape(
            lambda: self.model.init(self._init_key)
        )
        self._plans: Dict[Tuple, Plan] = {}
        self.events: list = []
        self.last_resume: Optional[Dict[str, Any]] = None
        self.heartbeat = (
            Heartbeat(cfg.heartbeat_path) if cfg.heartbeat_path else None
        )
        self.consensus = None
        if cfg.fleet_dir:
            self.consensus = fleet_mod.PlanConsensus(
                fleet_mod.FleetConfig(
                    fleet_dir=cfg.fleet_dir, host_id=cfg.host_id
                )
            )

    # -- events -------------------------------------------------------------
    def _emit(self, event: tuple) -> None:
        """Record an event in memory and (when events_path is set) in the
        shared JSON-lines journal — the channel a worker process uses to
        report resumes/migrations back across the process boundary."""
        self.events.append(event)
        get_registry().inc(f"events/{event[0]}")
        path = self.cfg.events_path
        if path:
            parent = os.path.dirname(path)
            if parent:
                os.makedirs(parent, exist_ok=True)
            with open(path, "a") as f:
                f.write(json.dumps(
                    {"time": time.time(), "host": self.cfg.host_id,
                     "event": list(event)},
                    default=str) + "\n")

    # -- planning -----------------------------------------------------------
    def _prev_plan(self) -> Optional[Plan]:
        """The plan the newest decodable checkpoint was written under —
        what an in-flight replan should be measured against."""
        for step in reversed(ckpt.steps(self.cfg.ckpt_dir)):
            try:
                meta = ckpt.read_meta(self.cfg.ckpt_dir, step) or {}
                if "plan" in meta:
                    return Plan.from_dict(meta["plan"])
            except Exception:  # noqa: BLE001 — unreadable meta: keep walking
                continue
        return None

    def plan_for(self, topo: Topology) -> Plan:
        """The (cached, deterministic) plan for a topology.

        With ``resume_horizon_steps`` set, the solve is resume-latency-
        aware against the newest checkpoint's plan. With ``fleet_dir``
        set, the solve goes through fleet consensus: one elected host
        solves and publishes, everyone (this host included) trains under
        the committed artifact.
        """
        cfg = self.cfg
        kw = dict(cfg.solve_kw)
        prev_digest = None
        if cfg.resume_horizon_steps > 0:
            prev = self._prev_plan()
            if prev is not None:
                kw["prev_plan"] = prev
                kw["resume_horizon_steps"] = cfg.resume_horizon_steps
                prev_digest = fleet_mod.plan_digest(prev.to_dict())
        key = (topo.n_devices, topo.hbm_per_device, prev_digest)
        if key not in self._plans:
            solve = lambda: solve_for_topology(  # noqa: E731
                self._abstract_params,
                topo.n_devices,
                topo.hbm_per_device,
                **kw,
            )
            if self.consensus is not None:
                epoch = (
                    f"{topo.from_step}:"
                    f"{topo.n_devices}x{topo.hbm_per_device}"
                )
                plan_dict, role = self.consensus.plan_for_epoch(
                    epoch, lambda: solve().to_dict()
                )
                self._emit((f"plan_{role}", epoch))
                self._plans[key] = Plan.from_dict(plan_dict)
            else:
                self._plans[key] = solve()
        return self._plans[key]

    def current_topology(self) -> Topology:
        progress = ckpt.latest_step(self.cfg.ckpt_dir) or 0
        return topology_at(self.cfg.topology, progress)

    def _tx_for(self, plan: Plan):
        return make_optimizer(dataclasses.replace(self.ocfg, plan=plan))

    def _template(self, tx):
        return jax.eval_shape(
            lambda: TrainState.create(self.model.init(self._init_key), tx)
        )

    # -- restore ------------------------------------------------------------
    def restore_into_plan(self, dst_plan: Plan, tx):
        """Newest→oldest walk over the checkpoint directory: restore the
        first checkpoint that passes its crc32 integrity checks, migrating
        its optimizer state into ``dst_plan``'s layout when the plan that
        wrote it differs. Returns ``(state | None, step | None, timings)``
        with the restore/migrate wall-time split."""
        timings = {"restore_s": 0.0, "migrate_s": 0.0}
        cfg = self.cfg
        tracer = get_tracer()
        reg = get_registry()
        reg.set_phase("restore")
        for step in reversed(ckpt.steps(cfg.ckpt_dir)):
            try:
                try:
                    meta = ckpt.read_meta(cfg.ckpt_dir, step) or {}
                except (OSError, ValueError) as e:
                    # Unreadable manifest: same treatment as a torn
                    # checkpoint — skip to the next older one.
                    self._emit(("bad_plan_meta", step, str(e)))
                    continue
                src_plan = None
                if "plan" in meta:
                    try:
                        src_plan = Plan.from_dict(meta["plan"])
                    except (KeyError, TypeError, ValueError) as e:
                        # Undecodable or unknown-version plan artifact
                        # (PlanVersionError is a ValueError): the arrays
                        # may be fine, but without the plan that wrote
                        # them we cannot rebuild their layout — treat
                        # like a torn checkpoint and fall back.
                        self._emit(("bad_plan_meta", step, str(e)))
                        continue
                same = (
                    src_plan is not None
                    and src_plan.to_dict() == dst_plan.to_dict()
                )
                t0 = time.perf_counter()
                if same or src_plan is None:
                    # Identical plan (or legacy checkpoint without one):
                    # direct restore into the target template — the codec-
                    # aware manifest handles stacked/per-leaf differences.
                    with tracer.span("elastic/restore", step=step):
                        template = self._template(tx)
                        mesh = cfg.mesh
                        spec_tree = None
                        if mesh is not None:
                            from repro.distributed.sharding import (
                                replicated_specs,
                            )

                            spec_tree = replicated_specs(template)
                        state = ckpt.restore(
                            cfg.ckpt_dir, template, step=step,
                            mesh=mesh, spec_tree=spec_tree,
                        )
                    timings["restore_s"] = time.perf_counter() - t0
                else:
                    # Replan happened: restore under the SOURCE plan's
                    # exact layout, then migrate to the target.
                    with tracer.span("elastic/restore", step=step,
                                     replanned=True):
                        src_tx = self._tx_for(src_plan)
                        state = ckpt.restore(
                            cfg.ckpt_dir, self._template(src_tx), step=step
                        )
                    timings["restore_s"] = time.perf_counter() - t0
                    t1 = time.perf_counter()
                    reg.set_phase("migrate")
                    with tracer.span("elastic/migrate", step=step):
                        opt = migrate_opt_state(
                            state.opt_state, src_plan, dst_plan,
                            self._abstract_params, self.ocfg,
                        )
                        opt = jax.tree_util.tree_map(jnp.asarray, opt)
                        state = state._replace(opt_state=opt)
                    timings["migrate_s"] = time.perf_counter() - t1
                    self._emit(("migrate", step))
                return state, step, timings
            except ckpt.TornCheckpointError as e:
                # Torn/corrupt checkpoint: fall back to the next older one.
                self._emit(("torn_checkpoint", step, str(e)))
                continue
        return None, None, timings

    # -- attempts -----------------------------------------------------------
    def run_attempt(self, attempt: int) -> TrainState:
        """ONE worker attempt: replan for the current topology, restore/
        migrate the newest good checkpoint, train to completion (or until
        a fault / preemption notice ends the attempt). This is exactly
        what an out-of-process worker executes (``launch/worker.py``);
        :meth:`run` drives it in-process under the restart policy."""
        cfg = self.cfg
        if cfg.trace_path:
            trace_configure(cfg.trace_path, host=cfg.host_id)
        if cfg.health_path:
            health_configure(cfg.health_path, host=cfg.host_id)
        tracer = get_tracer()
        reg = get_registry()
        # A notice acted on by the PREVIOUS attempt is consumed here; a
        # live notice always arrives after the attempt is underway.
        if cfg.notice_path and os.path.exists(cfg.notice_path):
            os.remove(cfg.notice_path)
        refresher = contextlib.nullcontext()
        if cfg.heartbeat_path and cfg.heartbeat_interval_s > 0:
            refresher = Heartbeat(
                cfg.heartbeat_path, timeout=cfg.heartbeat_timeout_s
            ).auto(cfg.heartbeat_interval_s)
        with refresher, tracer.span("elastic/attempt", attempt=attempt):
            reg.set_phase("replan")
            topo = self.current_topology()
            with tracer.span("elastic/replan", attempt=attempt,
                             n_devices=topo.n_devices):
                plan = self.plan_for(topo)
                tx = self._tx_for(plan)
            state, step, timings = self.restore_into_plan(plan, tx)
            self.last_resume = {
                "attempt": attempt,
                "resume_step": step,
                "n_devices": topo.n_devices,
                "hbm_per_device": topo.hbm_per_device,
                **timings,
            }
            self._emit(("resume", attempt, step, topo.n_devices))
            tracer.instant(
                "elastic/resume", attempt=attempt, step=step,
                n_devices=topo.n_devices, **timings,
            )
            refresh_schedule = None
            if tracer.enabled:
                # Step-span refresh attribution (and the calibration fit
                # keyed on it) only matters when a trace is recorded.
                refresh_schedule = obs_calib.planned_refresh_schedule(
                    plan, self._abstract_params, self.ocfg
                )
            loop_cfg = TrainLoopConfig(
                total_steps=cfg.total_steps,
                ckpt_dir=cfg.ckpt_dir,
                ckpt_every=cfg.ckpt_every,
                ckpt_keep=cfg.ckpt_keep,
                log_every=cfg.log_every,
                metrics_path=cfg.metrics_path,
                heartbeat_path=cfg.heartbeat_path,
                grad_accum=cfg.grad_accum,
                fault_injector=self.fault_injector,
                # The plan rides in every checkpoint manifest, atomically —
                # the NEXT resume reads it back to rebuild this exact layout.
                ckpt_meta={"plan": plan.to_dict()},
                notice_path=cfg.notice_path,
                min_step_s=cfg.min_step_s,
                refresh_schedule=refresh_schedule,
                health_every=cfg.health_every,
            )
            loop = TrainLoop(
                self.model, tx, self.batch_fn, loop_cfg,
                init_key=self._init_key, initial_state=state,
            )
            try:
                return loop.run()
            except DrainPreemption as e:
                self._emit(("drain", attempt, e.step))
                raise

    # Internal alias kept for callers of the pre-process-model name.
    _attempt = run_attempt

    def run(self) -> TrainState:
        """Supervise to completion (or until the crash budget exhausts —
        then the last exception propagates). Drains (preemption notices
        the worker honored) relaunch immediately without charging the
        crash budget."""
        cfg = self.cfg
        return run_with_restart(
            self.run_attempt,
            on_restart=lambda i, e: self._emit(
                ("crash", i, type(e).__name__, str(e))
            ),
            crash_budget=CrashBudget(
                max_crashes=cfg.max_crashes,
                window_seconds=cfg.crash_window_s,
            ),
            backoff_base=cfg.backoff_base,
            backoff_cap=cfg.backoff_cap,
            backoff_jitter=cfg.backoff_jitter,
            sleep_fn=self.sleep_fn,
            seed=cfg.seed,
            drain_types=(DrainPreemption,),
        )


# ---------------------------------------------------------------------------
# Process-isolated supervision: the exec worker model.
# ---------------------------------------------------------------------------


def _read_json_file(path: str) -> Optional[Dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return None


def read_events(path: str) -> list:
    """The events journal (JSON lines) as a list of event tuples — the
    cross-process view of ``ElasticSupervisor.events``."""
    out = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(tuple(json.loads(line)["event"]))
                except (json.JSONDecodeError, KeyError, TypeError):
                    continue
    except FileNotFoundError:
        pass
    return out


@dataclasses.dataclass
class ProcessSupervisorConfig:
    """Knobs of the out-of-process watch loop (the in-process restart
    policy — crash budget, backoff — still comes from ElasticConfig)."""

    poll_interval_s: float = 0.1
    policy: SupervisionPolicy = SupervisionPolicy()
    # Deadline attached to supervisor-initiated drains (straggler beats):
    # the worker has this long to checkpoint before the backing SIGKILL.
    drain_deadline_s: float = 10.0
    # Test hook: replaces the `python -m repro.launch.worker --spec ...`
    # command line (the file protocol stays the same).
    worker_cmd: Optional[Sequence[str]] = None
    spawn_env: Optional[Dict[str, str]] = None


class ProcessSupervisor:
    """The exec worker model: every attempt is a SPAWNED PROCESS the
    supervisor can really ``SIGKILL``, supervised purely through files —

      * the **heartbeat** file is the only liveness signal: ``"missing"``
        past the start grace or ``"stale"`` past the stale grace (see
        :func:`fault_tolerance.decide_supervision`) ⇒ SIGKILL + relaunch.
        The supervisor never interprets the worker's exit status as a
        death signal — a real preemption gives it no such courtesy;
      * the **notice** file delivers preemption warnings (injected via
        ``FaultSchedule.notice_at`` or issued by the supervisor itself on
        straggler evidence); a worker that acks and exits ``EXIT_DRAINED``
        before the deadline is relaunched immediately, crash-budget
        untouched, and resumes with zero lost steps;
      * ``DONE.json`` is the completion marker (final step + loss);
      * ``events.jsonl`` journals both sides' events.

    The worker command is ``python -m repro.launch.worker --spec
    worker_spec.json`` — the spec (model/data recipe + the serialized
    ElasticConfig) is written into the checkpoint directory, which is the
    one piece of shared state a preemptible fleet already has.
    """

    def __init__(
        self,
        spec: Dict[str, Any],
        cfg: ElasticConfig,
        pcfg: Optional[ProcessSupervisorConfig] = None,
        fault_injector=None,
    ):
        self.spec = dict(spec)
        self.cfg = cfg
        self.pcfg = pcfg if pcfg is not None else ProcessSupervisorConfig()
        self.fault_injector = fault_injector
        self.events: list = []
        d = cfg.ckpt_dir
        os.makedirs(d, exist_ok=True)
        if not cfg.heartbeat_path:
            cfg.heartbeat_path = os.path.join(d, "heartbeat.json")
        if not cfg.notice_path:
            cfg.notice_path = os.path.join(d, "notice.json")
        if not cfg.events_path:
            cfg.events_path = os.path.join(d, "events.jsonl")
        self.done_path = os.path.join(d, "DONE.json")
        self.spec_path = os.path.join(d, "worker_spec.json")
        self.heartbeat = Heartbeat(
            cfg.heartbeat_path, timeout=cfg.heartbeat_timeout_s
        )

    # -- plumbing -----------------------------------------------------------
    def _emit(self, event: tuple) -> None:
        self.events.append(event)
        get_registry().inc(f"supervisor/{event[0]}")
        get_tracer().instant(f"supervisor/{event[0]}")
        path = self.cfg.events_path
        if path:
            with open(path, "a") as f:
                f.write(json.dumps(
                    {"time": time.time(), "host": "supervisor",
                     "event": list(event)},
                    default=str) + "\n")

    def _write_notice(self, deadline: float) -> None:
        path = self.cfg.notice_path
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"deadline": deadline}, f)
        os.replace(tmp, path)

    def _clear_attempt_files(self) -> None:
        """Consume the previous attempt's liveness/notice state so the
        fresh worker boots into 'missing'-under-grace, not 'stale'."""
        for p in (self.cfg.heartbeat_path, self.cfg.notice_path,
                  self.cfg.notice_path + ".ack", self.done_path):
            if p and os.path.exists(p):
                os.remove(p)

    def _spawn(self, attempt: int) -> subprocess.Popen:
        pcfg = self.pcfg
        if pcfg.worker_cmd:
            cmd = list(pcfg.worker_cmd)
        else:
            cmd = [sys.executable, "-m", "repro.launch.worker",
                   "--spec", self.spec_path]
        env = dict(os.environ)
        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        pp = env.get("PYTHONPATH", "")
        if src_root not in pp.split(os.pathsep):
            env["PYTHONPATH"] = (
                src_root + (os.pathsep + pp if pp else "")
            )
        env["REPRO_WORKER_ATTEMPT"] = str(attempt)
        if pcfg.spawn_env:
            env.update(pcfg.spawn_env)
        return subprocess.Popen(cmd, env=env)

    def _reap(self, proc: subprocess.Popen) -> Optional[int]:
        try:
            return proc.wait(timeout=30)
        except subprocess.TimeoutExpired:
            proc.kill()
            return proc.wait()

    def _ack(self) -> Dict:
        return _read_json_file(self.cfg.notice_path + ".ack") or {}

    # -- the watch loop -----------------------------------------------------
    def _watch(self, proc: subprocess.Popen, attempt: int):
        """Poll until this attempt resolves. Returns ``(outcome, info)``
        with outcome ``'done' | 'drained' | 'crash'``. Death is declared
        ONLY on heartbeat evidence (decide_supervision); exit codes are
        read solely for the cooperative done/drain protocol."""
        cfg, pcfg = self.cfg, self.pcfg
        hb = self.heartbeat
        spawn_t = time.time()
        kill_deadline = None
        drain_sent = False
        inj = self.fault_injector
        while True:
            if os.path.exists(self.done_path):
                self._reap(proc)
                return "done", (_read_json_file(self.done_path) or {})
            rc = proc.poll()
            if rc == EXIT_DRAINED:
                return "drained", self._ack()

            now = time.time()
            payload = hb.read() or {}
            step = int(payload.get("step", -1) or -1)

            # Injected process-level faults keyed on OBSERVED progress
            # (the supervisor only knows what the heartbeat tells it).
            if inj is not None and step >= 0 and rc is None:
                if kill_deadline is None and hasattr(inj, "due_notice"):
                    d = inj.due_notice(step)
                    if d is not None:
                        self._write_notice(now + d)
                        kill_deadline = now + d
                        self._emit(("notice", attempt, step, d))
                if hasattr(inj, "due_kill") and inj.due_kill(step):
                    self._emit(("sigkill", attempt, step))
                    proc.kill()
            if kill_deadline is not None and now >= kill_deadline:
                if proc.poll() is None:
                    self._emit(("deadline_kill", attempt, step))
                    proc.kill()
                kill_deadline = None

            status = hb.status()
            stale_for = 0.0
            if status == "stale" and payload:
                stale_for = (
                    now - float(payload.get("time", now))
                    - cfg.heartbeat_timeout_s
                )
            decision = decide_supervision(
                status,
                missing_for_s=now - spawn_t,
                stale_for_s=stale_for,
                straggler_flagged=int(
                    payload.get("straggler_flagged", 0) or 0
                ),
                policy=pcfg.policy,
            )
            if decision == "kill":
                # Reactive kill on heartbeat evidence — distinct from the
                # planned drain path in the counter taxonomy.
                get_registry().inc("supervisor/reactive_kill")
                proc.kill()
                rc = self._reap(proc)
                # The heartbeat verdict may have raced a clean handoff.
                if os.path.exists(self.done_path):
                    return "done", (_read_json_file(self.done_path) or {})
                if rc == EXIT_DRAINED:
                    return "drained", self._ack()
                return "crash", {"heartbeat": status, "step": step}
            if decision == "drain" and not drain_sent:
                drain_sent = True
                self._write_notice(now + pcfg.drain_deadline_s)
                kill_deadline = now + pcfg.drain_deadline_s
                self._emit(("drain_notice", attempt, step))
            time.sleep(pcfg.poll_interval_s)

    def run(self) -> Dict:
        """Supervise spawned workers to completion; returns the DONE
        payload (final step + loss). Crashes are governed by the same
        sliding crash budget + jittered backoff as the in-process path;
        drains relaunch immediately."""
        cfg = self.cfg
        spec = dict(self.spec)
        spec["elastic"] = elastic_config_to_dict(cfg)
        tmp = self.spec_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(spec, f, indent=1)
        os.replace(tmp, self.spec_path)

        budget = CrashBudget(
            max_crashes=cfg.max_crashes, window_seconds=cfg.crash_window_s
        )
        rng = random.Random(cfg.seed)
        attempt = 0
        crashes = 0
        while True:
            self._clear_attempt_files()
            proc = self._spawn(attempt)
            self._emit(("spawn", attempt, proc.pid))
            outcome, info = self._watch(proc, attempt)
            self._emit((outcome, attempt, info))
            if outcome == "done":
                return info
            attempt += 1
            if outcome == "crash":
                crashes += 1
                budget.record()
                if budget.exhausted():
                    raise RuntimeError(
                        f"worker crash budget exhausted ({crashes} crashes "
                        f"within {cfg.crash_window_s}s): {info}"
                    )
                delay = backoff_delay(
                    crashes, cfg.backoff_base, cfg.backoff_cap,
                    cfg.backoff_jitter, rng,
                )
                if delay > 0:
                    time.sleep(delay)
            # 'drained' relaunches immediately: planned handoff.
