"""Preemption-native elastic training: the replan → migrate → resume loop.

COAP's value proposition — big-model training on less memory — lands on
preemptible/spot capacity in practice, where the run that matters is the
one that survives kills, topology churn and budget changes. This module
composes the repo's ingredients into that run:

  1. **replan** — on every (re)start the supervisor reads the CURRENT
     topology (device count × HBM per device) and re-runs the analytic
     planner (``plan.solver.solve_for_topology``; pod-total budget =
     ``n_devices × hbm_per_device``, FSDP/ZeRO-style). Shrinking 8→4
     devices halves the pool and the solver's quantize knapsack flips
     buckets to int8 exactly where needed — a NEW ``coap-plan/v1``.
  2. **migrate** — the newest valid checkpoint is restored into the plan
     that WROTE it (the plan artifact rides in the checkpoint manifest's
     ``meta``, atomically with the arrays) and its optimizer state is
     transformed to the new plan's layout by ``stacked_state.migrate``
     (rank truncate / Eqn-7-style expand, quantize requant/dequant,
     re-bucket) — byte-exact against ``accounting.abstract_state_bytes``
     of the target optimizer. Checkpoints that fail their crc32 integrity
     check (torn writes) are skipped, falling back newest→oldest.
  3. **resume** — training continues mid-epoch. ``ProjectedAdamState
     .count`` is preserved through migration and the staggered refresh /
     Eqn-7 recalibration cadence is a pure function of ``(step, layout)``
     (``coap_adam.bucket_phases`` + ``_sched_preds``), so the schedule
     re-derives deterministically — two resumes from the same checkpoint
     follow bit-identical phases (:func:`stagger_signature` pins this).

Restart policy comes from ``fault_tolerance.run_with_restart``: sliding
crash-budget window + exponential backoff with seeded jitter. Failure
modes are exercised end-to-end by ``train/faults.py`` injection (seeded
kills, torn checkpoint writes, heartbeat silence, stragglers) — driven
from the CLI via ``python -m repro.launch.train --watch``.

Topology changes take effect at attempt boundaries: a preemption/scale
event kills the worker (for real, or via an injected kill), and the next
attempt replans against the new topology. That matches how clusters
actually deliver topology change — as the death of the old allocation.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import stacked_state
from repro.core.api import OptimizerConfig, make_optimizer
from repro.core.coap_adam import ProjectedAdamState, bucket_phases
from repro.plan import apply as plan_apply
from repro.plan.artifact import Plan
from repro.plan.solver import solve_for_topology
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (
    CrashBudget,
    Heartbeat,
    run_with_restart,
)
from repro.train.loop import TrainLoop, TrainLoopConfig
from repro.train.train_state import TrainState


@dataclasses.dataclass(frozen=True)
class Topology:
    """One cluster configuration, effective from training step
    ``from_step`` onward (a schedule entry for tests/simulation; in
    production there is typically one entry, replaced when the allocation
    actually changes)."""

    n_devices: int
    hbm_per_device: int  # bytes
    from_step: int = 0


def topology_at(topologies: Sequence[Topology], step: int) -> Topology:
    """The topology in effect at ``step``: the last entry whose
    ``from_step`` is <= step (entries need not be sorted)."""
    best = None
    for t in topologies:
        if t.from_step <= step and (best is None or t.from_step >= best.from_step):
            best = t
    if best is None:
        raise ValueError(
            f"no topology covers step {step} (need an entry with "
            "from_step <= step; give the initial topology from_step=0)"
        )
    return best


@dataclasses.dataclass
class ElasticConfig:
    ckpt_dir: str
    total_steps: int
    topology: Tuple[Topology, ...]
    # Planner knobs forwarded to solve_for_topology (rank_compression,
    # min_dim, t_update, lam, stagger_groups, quantize, ...).
    solve_kw: Dict[str, Any] = dataclasses.field(default_factory=dict)
    ckpt_every: int = 10
    ckpt_keep: int = 3
    log_every: int = 100
    metrics_path: Optional[str] = None
    heartbeat_path: Optional[str] = None
    grad_accum: int = 1
    # Restart policy (fault_tolerance): sliding crash budget + backoff.
    max_crashes: int = 10
    crash_window_s: float = 600.0
    backoff_base: float = 0.0  # seconds; 0 disables sleeping (tests)
    backoff_cap: float = 30.0
    backoff_jitter: float = 0.1
    seed: int = 0
    # Optional mesh: direct (non-migrating) restores are device_put
    # replicated onto it (distributed.sharding.replicated_specs).
    mesh: Any = None


def _map_projected_states(opt_state, fn: Callable[[ProjectedAdamState], Any]):
    """Apply ``fn`` to every ProjectedAdamState inside a (possibly nested
    chain) optimizer state, leaving everything else untouched."""
    return jax.tree_util.tree_map(
        lambda n: fn(n) if isinstance(n, ProjectedAdamState) else n,
        opt_state,
        is_leaf=lambda n: isinstance(n, ProjectedAdamState),
    )


def find_projected_state(opt_state) -> Optional[ProjectedAdamState]:
    """The (first) ProjectedAdamState inside an optimizer state tree."""
    found = []

    def grab(n):
        found.append(n)
        return n

    _map_projected_states(opt_state, grab)
    return found[0] if found else None


def migrate_opt_state(
    opt_state,
    src_plan: Plan,
    dst_plan: Plan,
    params,
    ocfg: OptimizerConfig,
):
    """Optimizer-state tree under ``src_plan`` -> the same tree under
    ``dst_plan`` via ``stacked_state.migrate``. ``count`` is preserved —
    the resumed schedule continues from the same step. ``params`` may be
    abstract (shapes only)."""
    dst_layout = stacked_state.layout_for_tree(
        plan_apply.planned_rules(dst_plan).spec_for, params
    )
    qmap = plan_apply.quantize_by_path(dst_plan)
    g = dst_plan.globals_

    def mig(s: ProjectedAdamState) -> ProjectedAdamState:
        if not isinstance(s.leaves, stacked_state.StackedLeaves):
            raise ValueError(
                "plan migration operates on stacked-bucket/v2 state; this "
                "state is per-leaf (plans set stacked_state=True — was the "
                "checkpoint written by an unplanned run?)"
            )
        leaves = stacked_state.migrate(
            s.leaves,
            dst_layout,
            quantize_for=lambda p: qmap[p],
            quant_block=g.quant_block,
            src_quant_block=src_plan.globals_.quant_block,
            state_dtype=jnp.dtype(g.state_dtype).type,
            seed=ocfg.seed,
        )
        return ProjectedAdamState(count=s.count, leaves=leaves)

    return _map_projected_states(opt_state, mig)


def stagger_signature(plan: Plan, params, ocfg: OptimizerConfig):
    """The staggered refresh phases the planned optimizer will follow — a
    pure function of ``(layout, plan)`` via ``coap_adam.bucket_phases``,
    so it is identical across restarts, resumes and hosts. The kill/
    resume tests compare this signature across two resumes from the same
    checkpoint (bit-identical schedules, acceptance criterion 3)."""
    cfg = plan_apply.planned_config(plan, ocfg)
    layout = stacked_state.layout_for_tree(cfg.rules.spec_for, params)
    phases = bucket_phases(cfg, layout)
    return tuple(sorted((bi, tuple(ph)) for bi, ph in phases.items()))


class ElasticSupervisor:
    """supervise → (kill) → replan → migrate → relaunch.

    Each worker *attempt* plans against the current topology, restores
    the newest checkpoint that passes integrity checks (migrating its
    optimizer state if the plan changed), and runs ``TrainLoop`` to
    completion. Crashes — real or injected — return control here; the
    sliding crash budget and exponential backoff decide whether/when the
    next attempt launches. ``events`` records what happened (resumes,
    migrations, torn checkpoints skipped) for tests and operators;
    ``last_resume`` holds the latest resume-latency breakdown
    (restore vs migrate vs compile — ``benchmarks/overhead.run_elastic``
    reports the same split).
    """

    def __init__(
        self,
        model,
        batch_fn: Callable[[int, int], Dict],
        cfg: ElasticConfig,
        ocfg: Optional[OptimizerConfig] = None,
        fault_injector=None,
        init_key=None,
        sleep_fn: Callable[[float], None] = time.sleep,
    ):
        self.model = model
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.ocfg = ocfg if ocfg is not None else OptimizerConfig()
        self.fault_injector = fault_injector
        self.sleep_fn = sleep_fn
        self._init_key = init_key if init_key is not None else jax.random.key(0)
        self._abstract_params = jax.eval_shape(
            lambda: self.model.init(self._init_key)
        )
        self._plans: Dict[Tuple[int, int], Plan] = {}
        self.events: list = []
        self.last_resume: Optional[Dict[str, Any]] = None
        self.heartbeat = (
            Heartbeat(cfg.heartbeat_path) if cfg.heartbeat_path else None
        )

    # -- planning -----------------------------------------------------------
    def plan_for(self, topo: Topology) -> Plan:
        """The (cached, deterministic) plan for a topology."""
        key = (topo.n_devices, topo.hbm_per_device)
        if key not in self._plans:
            self._plans[key] = solve_for_topology(
                self._abstract_params,
                topo.n_devices,
                topo.hbm_per_device,
                **self.cfg.solve_kw,
            )
        return self._plans[key]

    def current_topology(self) -> Topology:
        progress = ckpt.latest_step(self.cfg.ckpt_dir) or 0
        return topology_at(self.cfg.topology, progress)

    def _tx_for(self, plan: Plan):
        return make_optimizer(dataclasses.replace(self.ocfg, plan=plan))

    def _template(self, tx):
        return jax.eval_shape(
            lambda: TrainState.create(self.model.init(self._init_key), tx)
        )

    # -- restore ------------------------------------------------------------
    def restore_into_plan(self, dst_plan: Plan, tx):
        """Newest→oldest walk over the checkpoint directory: restore the
        first checkpoint that passes its crc32 integrity checks, migrating
        its optimizer state into ``dst_plan``'s layout when the plan that
        wrote it differs. Returns ``(state | None, step | None, timings)``
        with the restore/migrate wall-time split."""
        timings = {"restore_s": 0.0, "migrate_s": 0.0}
        cfg = self.cfg
        for step in reversed(ckpt.steps(cfg.ckpt_dir)):
            try:
                meta = ckpt.read_meta(cfg.ckpt_dir, step) or {}
                src_plan = (
                    Plan.from_dict(meta["plan"]) if "plan" in meta else None
                )
                same = (
                    src_plan is not None
                    and src_plan.to_dict() == dst_plan.to_dict()
                )
                t0 = time.perf_counter()
                if same or src_plan is None:
                    # Identical plan (or legacy checkpoint without one):
                    # direct restore into the target template — the codec-
                    # aware manifest handles stacked/per-leaf differences.
                    template = self._template(tx)
                    mesh = cfg.mesh
                    spec_tree = None
                    if mesh is not None:
                        from repro.distributed.sharding import replicated_specs

                        spec_tree = replicated_specs(template)
                    state = ckpt.restore(
                        cfg.ckpt_dir, template, step=step,
                        mesh=mesh, spec_tree=spec_tree,
                    )
                    timings["restore_s"] = time.perf_counter() - t0
                else:
                    # Replan happened: restore under the SOURCE plan's
                    # exact layout, then migrate to the target.
                    src_tx = self._tx_for(src_plan)
                    state = ckpt.restore(
                        cfg.ckpt_dir, self._template(src_tx), step=step
                    )
                    timings["restore_s"] = time.perf_counter() - t0
                    t1 = time.perf_counter()
                    opt = migrate_opt_state(
                        state.opt_state, src_plan, dst_plan,
                        self._abstract_params, self.ocfg,
                    )
                    opt = jax.tree_util.tree_map(jnp.asarray, opt)
                    state = state._replace(opt_state=opt)
                    timings["migrate_s"] = time.perf_counter() - t1
                    self.events.append(("migrate", step))
                return state, step, timings
            except ckpt.TornCheckpointError as e:
                # Torn/corrupt checkpoint: fall back to the next older one.
                self.events.append(("torn_checkpoint", step, str(e)))
                continue
        return None, None, timings

    # -- attempts -----------------------------------------------------------
    def _attempt(self, attempt: int) -> TrainState:
        cfg = self.cfg
        topo = self.current_topology()
        plan = self.plan_for(topo)
        tx = self._tx_for(plan)
        state, step, timings = self.restore_into_plan(plan, tx)
        self.last_resume = {
            "attempt": attempt,
            "resume_step": step,
            "n_devices": topo.n_devices,
            "hbm_per_device": topo.hbm_per_device,
            **timings,
        }
        self.events.append(
            ("resume", attempt, step, topo.n_devices)
        )
        loop_cfg = TrainLoopConfig(
            total_steps=cfg.total_steps,
            ckpt_dir=cfg.ckpt_dir,
            ckpt_every=cfg.ckpt_every,
            ckpt_keep=cfg.ckpt_keep,
            log_every=cfg.log_every,
            metrics_path=cfg.metrics_path,
            heartbeat_path=cfg.heartbeat_path,
            grad_accum=cfg.grad_accum,
            fault_injector=self.fault_injector,
            # The plan rides in every checkpoint manifest, atomically —
            # the NEXT resume reads it back to rebuild this exact layout.
            ckpt_meta={"plan": plan.to_dict()},
        )
        loop = TrainLoop(
            self.model, tx, self.batch_fn, loop_cfg,
            init_key=self._init_key, initial_state=state,
        )
        return loop.run()

    def run(self) -> TrainState:
        """Supervise to completion (or until the crash budget exhausts —
        then the last exception propagates)."""
        cfg = self.cfg
        return run_with_restart(
            self._attempt,
            on_restart=lambda i, e: self.events.append(
                ("crash", i, type(e).__name__, str(e))
            ),
            crash_budget=CrashBudget(
                max_crashes=cfg.max_crashes,
                window_seconds=cfg.crash_window_s,
            ),
            backoff_base=cfg.backoff_base,
            backoff_cap=cfg.backoff_cap,
            backoff_jitter=cfg.backoff_jitter,
            sleep_fn=self.sleep_fn,
            seed=cfg.seed,
        )
