"""TrainState pytree + constructors."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    params: Any
    opt_state: Any

    @classmethod
    def create(cls, params, tx):
        return cls(
            step=jnp.zeros([], jnp.int32),
            params=params,
            opt_state=tx.init(params),
        )


def abstract_train_state(model, tx):
    """ShapeDtypeStruct TrainState — the dry-run's zero-allocation stand-in."""
    params = model.abstract_params()
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=params,
        opt_state=jax.eval_shape(tx.init, params),
    )
