"""Training substrate: step factory, fault-tolerant loop, checkpointing."""
from repro.train.train_state import TrainState
from repro.train.step import make_train_step
from repro.train.loop import TrainLoop, TrainLoopConfig
from repro.train import checkpoint, metrics

__all__ = ["TrainState", "make_train_step", "TrainLoop", "TrainLoopConfig",
           "checkpoint", "metrics"]
