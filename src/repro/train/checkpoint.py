"""Checkpointing: atomic, step-numbered, elastic reshard-on-restore,
stacked-state codec aware.

Layout:  <dir>/ckpt_<step>/   manifest.json + <leaf_index>.npy per array
Writes go to ``ckpt_<step>.tmp`` and are renamed only after every file is
flushed — a crash mid-write can never corrupt the newest valid checkpoint,
and an async save only ever exposes a complete ``ckpt_<step>`` directory
(``wait_pending`` joins outstanding writers). bfloat16 arrays are stored as
uint16 views (numpy has no native bf16) with the logical dtype recorded in
the manifest.

MANIFEST FORMAT (``"version": 2``; version-1 manifests — no ``version`` /
``stacked`` keys — restore unchanged):

  * ``leaves``  — one entry per ordinary array: ``{path, file, dtype,
    shape}``. ``path`` is the array's LOGICAL per-leaf tree path. Every
    array row also records a ``crc32`` of its stored bytes (optional on
    read: older manifests restore unchanged); a mismatch or unreadable
    file raises :class:`TornCheckpointError` naming the offending path.
  * ``meta``    — optional JSON dict stored atomically with the arrays
    (the elastic supervisor records the ``coap-plan/v1`` artifact that
    produced the optimizer state here; see ``train/elastic.py``).
  * ``stacked`` — one entry per pre-stacked bucket array
    (``core/stacked_state.StackedLeaves`` fields): ``{path, file, dtype,
    shape, codec, axis, slots}`` where ``codec`` is
    ``stacked_state.STACKED_CODEC`` ("stacked-bucket/v2": axis-0 slices are
    bit-exact per-leaf arrays; conv/Tucker-2 leaves bucket like everything
    else), ``axis`` is the bucket axis (0) and ``slots[j]`` is the logical
    per-leaf path of slice ``j``.

Because stacked entries name their slices by the SAME logical paths a
per-leaf state would use, the two storage modes are mutually restorable: a
checkpoint saved in stacked mode restores into a per-leaf template (each
leaf loads as a slice of its bucket file) and vice versa (each bucket
assembles by stacking its slot arrays); matching stacked layouts take the
whole-file fast path. The reader accepts every codec in
``stacked_state.DECODABLE_CODECS``: "stacked-bucket/v1" entries (written
before conv bucketing; conv states were plain per-leaf 'leaves' entries)
carry the identical per-entry slice semantics, so a v1 checkpoint restores
under v2 code — conv buckets assemble slot-by-slot from its per-leaf
entries — and a v2 checkpoint restores into a v1-layout template by
slicing the conv bucket files. Unknown codec versions fail loudly.

Restore takes a *template* pytree (abstract TrainState) and, optionally, a
mesh + sharding tree: leaves are device_put directly to their shards, so a
checkpoint written on one mesh restores onto any other (elastic scaling —
tested 4→8 devices, per-leaf and stacked, in tests/test_distributed.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stacked_state

_MANIFEST = "manifest.json"
_FORMAT_VERSION = 2

# Outstanding async writer threads (pruned on inspection).
_PENDING: list = []


class TornCheckpointError(ValueError):
    """A checkpoint array failed its integrity check (truncated file or
    checksum mismatch) — the checkpoint is torn/corrupt. The message names
    the offending file so an operator (or the elastic supervisor, which
    falls back to the next-older checkpoint) can act on it."""


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _store_array(arr: np.ndarray):
    """-> (storable array, logical dtype string). bf16 goes as uint16."""
    logical = str(arr.dtype)
    if arr.dtype == jnp.bfloat16:
        arr = arr.view(np.uint16)
    return arr, logical


def _load_logical(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    return arr.view(jnp.bfloat16) if logical_dtype == "bfloat16" else arr


def wait_pending() -> None:
    """Join all outstanding async checkpoint writers (tests / shutdown)."""
    while _PENDING:
        t = _PENDING.pop()
        t.join()


def save(directory: str, step: int, state: Any, keep: int = 3,
         async_: bool = False, meta: Optional[dict] = None) -> str:
    """Write ckpt_<step>; returns its final path.

    ``async_=True`` snapshots the state to host synchronously, then writes
    in a daemon thread; the step directory appears (atomic rename) only
    after every file and the manifest are flushed, so a reader can never
    observe a torn checkpoint.

    Every array row records a ``crc32`` of its stored bytes (optional on
    read — v2 manifests written before this field restore unchanged) so a
    checkpoint corrupted AFTER the atomic rename (partial copy, disk
    fault, injected torn write) fails loudly at restore instead of
    resuming from garbage. ``meta`` is an optional JSON-serializable dict
    stored atomically with the manifest — the elastic supervisor keeps the
    ``coap-plan/v1`` artifact that produced the state here, so a resume
    can rebuild the exact source layout before migrating.
    """
    host_state = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                        state)

    def _write():
        final = os.path.join(directory, f"ckpt_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        entries = stacked_state.manifest_entries(host_state)
        manifest = {"step": step, "version": _FORMAT_VERSION,
                    "leaves": [], "stacked": []}
        if meta is not None:
            manifest["meta"] = meta
        for i, entry in enumerate(entries):
            arr, logical_dtype = _store_array(np.asarray(entry.value))
            fname = f"{i:06d}.npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            row = {"path": entry.path, "file": fname,
                   "dtype": logical_dtype, "shape": list(arr.shape),
                   "crc32": _crc32(arr)}
            if entry.kind == "stacked":
                row["codec"] = stacked_state.STACKED_CODEC
                row["axis"] = 0
                row["slots"] = list(entry.slots)
                manifest["stacked"].append(row)
            else:
                manifest["leaves"].append(row)
        mpath = os.path.join(tmp, _MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        _gc(directory, keep)
        return final

    if async_:
        _PENDING[:] = [t for t in _PENDING if t.is_alive()]
        t = threading.Thread(target=_write, daemon=True)
        _PENDING.append(t)
        t.start()
        return os.path.join(directory, f"ckpt_{step:08d}")
    return _write()


def _gc(directory: str, keep: int):
    ckpts = sorted(
        d for d in os.listdir(directory)
        if d.startswith("ckpt_") and not d.endswith(".tmp")
    )
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def steps(directory: str) -> List[int]:
    """All checkpoint steps with a manifest, ascending. The elastic
    supervisor walks this newest→oldest to find the latest checkpoint
    that passes its integrity checks (torn ones raise on restore)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("ckpt_") and not d.endswith(".tmp"):
            p = os.path.join(directory, d, _MANIFEST)
            if os.path.exists(p):
                out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    all_steps = steps(directory)
    return all_steps[-1] if all_steps else None


def read_meta(directory: str, step: Optional[int] = None) -> Optional[dict]:
    """The ``meta`` dict saved with ckpt_<step> (None if absent)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None
    mpath = os.path.join(directory, f"ckpt_{step:08d}", _MANIFEST)
    with open(mpath) as f:
        return json.load(f).get("meta")


class _CkptIndex:
    """Logical-path -> array resolver over a v1/v2 checkpoint directory."""

    def __init__(self, cdir: str, manifest: dict):
        self.cdir = cdir
        self.direct = {e["path"]: e for e in manifest["leaves"]}
        self.stacked = {}
        self.slots = {}  # logical path -> (stacked entry, slot index)
        for se in manifest.get("stacked", []):
            if se.get("codec") not in stacked_state.DECODABLE_CODECS:
                raise ValueError(
                    f"unknown stacked-state codec {se.get('codec')!r} in "
                    f"{cdir} — this build reads "
                    f"{sorted(stacked_state.DECODABLE_CODECS)}"
                )
            self.stacked[se["path"]] = se
            for j, sp in enumerate(se["slots"]):
                self.slots[sp] = (se, j)
        self._files = {}

    def _file(self, entry) -> np.ndarray:
        fname = entry["file"]
        if fname not in self._files:
            fpath = os.path.join(self.cdir, fname)
            try:
                arr = np.load(fpath)
            # A garbled .npy header escapes through numpy's header parser
            # as parser-specific exceptions (SyntaxError, tokenize
            # .TokenError, ...), not just ValueError/OSError — any load
            # failure here means the file is torn.
            except Exception as e:
                raise TornCheckpointError(
                    f"checkpoint array {fpath} (leaf {entry['path']!r}) is "
                    f"unreadable — torn/partial write: {e}"
                ) from e
            want = entry.get("crc32")
            if want is not None and _crc32(arr) != want:
                raise TornCheckpointError(
                    f"checkpoint array {fpath} (leaf {entry['path']!r}) "
                    f"fails its crc32 check — torn/corrupt write; restore "
                    "from an older checkpoint"
                )
            self._files[fname] = _load_logical(arr, entry["dtype"])
        return self._files[fname]

    def resolve(self, path: str) -> np.ndarray:
        """An array by its logical per-leaf path, from either storage mode."""
        if path in self.direct:
            return self._file(self.direct[path])
        if path in self.slots:
            entry, slot = self.slots[path]
            return self._file(entry)[slot]
        raise ValueError(
            f"checkpoint {self.cdir} has no leaf {path!r} — the run "
            "configuration (optimizer/model structure) differs from the "
            "one that wrote this checkpoint; use a fresh --ckpt-dir or "
            "restore with the original config"
        )

    def resolve_stacked(self, path: str, slots) -> np.ndarray:
        """A bucket array: whole-file fast path when the checkpoint was
        written with the identical layout, else assembled slot-by-slot
        (this is the cross-mode / re-bucketed restore path)."""
        entry = self.stacked.get(path)
        if entry is not None and tuple(entry["slots"]) == tuple(slots):
            return self._file(entry)
        return np.stack([self.resolve(sp) for sp in slots])


def restore(directory: str, template: Any, step: Optional[int] = None,
            mesh=None, spec_tree: Any = None) -> Any:
    """Load into the structure of ``template``. With mesh+spec_tree, every
    leaf is placed sharded (elastic: any mesh works). The template may use
    per-leaf or stacked state storage independently of what the checkpoint
    was written with (see module docstring)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    cdir = os.path.join(directory, f"ckpt_{step:08d}")
    with open(os.path.join(cdir, _MANIFEST)) as f:
        manifest = json.load(f)
    index = _CkptIndex(cdir, manifest)

    entries = stacked_state.manifest_entries(template)
    treedef = jax.tree_util.tree_structure(template)
    spec_flat = None
    if spec_tree is not None:
        spec_flat, _ = jax.tree_util.tree_flatten(
            spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )

    leaves = []
    for i, entry in enumerate(entries):
        if entry.kind == "stacked":
            arr = index.resolve_stacked(entry.path, entry.slots)
        else:
            arr = index.resolve(entry.path)
        if mesh is not None and spec_flat is not None:
            sharding = jax.sharding.NamedSharding(mesh, spec_flat[i])
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
