"""Checkpointing: atomic, step-numbered, elastic reshard-on-restore.

Layout:  <dir>/ckpt_<step>/   manifest.json + <leaf_index>.npy per leaf
Writes go to ``ckpt_<step>.tmp`` and are renamed only after every file is
flushed — a crash mid-write can never corrupt the newest valid checkpoint.
bfloat16 leaves are stored as uint16 views (numpy has no native bf16) with
the logical dtype recorded in the manifest.

Restore takes a *template* pytree (abstract TrainState) and, optionally, a
mesh + sharding tree: leaves are device_put directly to their shards, so a
checkpoint written on one mesh restores onto any other (elastic scaling —
tested 4→8 devices in tests/test_distributed.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    from repro.core.projector import path_str

    return [(path_str(kp), leaf) for kp, leaf in flat], treedef


def save(directory: str, step: int, state: Any, keep: int = 3,
         async_: bool = False) -> str:
    """Write ckpt_<step>; returns its final path."""
    host_state = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)),
                                        state)

    def _write():
        final = os.path.join(directory, f"ckpt_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        flat, _ = _leaf_paths(host_state)
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(flat):
            arr = np.asarray(leaf)
            logical_dtype = str(arr.dtype)
            if arr.dtype == jnp.bfloat16:
                arr = arr.view(np.uint16)
            fname = f"{i:06d}.npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append(
                {"path": path, "file": fname, "dtype": logical_dtype,
                 "shape": list(arr.shape)}
            )
        mpath = os.path.join(tmp, _MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        _gc(directory, keep)
        return final

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return os.path.join(directory, f"ckpt_{step:08d}")
    return _write()


def _gc(directory: str, keep: int):
    ckpts = sorted(
        d for d in os.listdir(directory)
        if d.startswith("ckpt_") and not d.endswith(".tmp")
    )
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for d in os.listdir(directory):
        if d.startswith("ckpt_") and not d.endswith(".tmp"):
            p = os.path.join(directory, d, _MANIFEST)
            if os.path.exists(p):
                best = max(best or -1, int(d.split("_")[1]))
    return best


def restore(directory: str, template: Any, step: Optional[int] = None,
            mesh=None, spec_tree: Any = None) -> Any:
    """Load into the structure of ``template``. With mesh+spec_tree, every
    leaf is placed sharded (elastic: any mesh works)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    cdir = os.path.join(directory, f"ckpt_{step:08d}")
    with open(os.path.join(cdir, _MANIFEST)) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = _leaf_paths(template)
    spec_flat = None
    if spec_tree is not None:
        spec_list, _ = jax.tree_util.tree_flatten(
            spec_tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        spec_flat = spec_list

    leaves = []
    for i, (path, tmpl_leaf) in enumerate(flat):
        if path not in by_path:
            raise ValueError(
                f"checkpoint {cdir} has no leaf {path!r} — the run "
                "configuration (optimizer/model structure) differs from the "
                "one that wrote this checkpoint; use a fresh --ckpt-dir or "
                "restore with the original config"
            )
        entry = by_path[path]
        arr = np.load(os.path.join(cdir, entry["file"]))
        if entry["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if mesh is not None and spec_flat is not None:
            sharding = jax.sharding.NamedSharding(mesh, spec_flat[i])
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
