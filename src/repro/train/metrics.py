"""Training metrics: CEU (paper Fig 3), PPL, throughput, jsonl logging."""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def cumulative_effective_update(updates) -> jnp.ndarray:
    """CEU increment (paper Fig 3): Σ‖ΔW‖₁ over the applied update tree."""
    return sum(
        jnp.sum(jnp.abs(u.astype(jnp.float32)))
        for u in jax.tree_util.tree_leaves(updates)
    )


class MetricsLogger:
    """Append-only jsonl metrics with wall-clock + tokens/s derivation."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a") if path else None
        self.history = []
        self._last_time = None
        self._last_step = None
        self._last_counters: Dict[str, float] = {}

    def log(self, step: int, metrics: Dict[str, Any], tokens: int = 0):
        now = time.time()
        row = {"step": step}
        # ONE transfer for the whole row: per-key float(v) would issue a
        # blocking device sync per metric, serializing the host against
        # the device once per key every log step.
        fetched = jax.device_get(metrics)
        for k, v in fetched.items():
            try:
                row[k] = float(v)
            except (TypeError, ValueError):
                row[k] = str(v)
        # Registry counter DELTAS since the previous row (``delta/<name>``,
        # nonzero only). The registry is host-resident state, so this adds
        # zero device syncs — the ONE device_get above stays the row's only
        # transfer (contract regression-tested in test_obs).
        from repro.obs.registry import get_registry

        counters = get_registry().snapshot()["counters"]
        for name, val in counters.items():
            d = float(val) - self._last_counters.get(name, 0.0)
            if d:
                row[f"delta/{name}"] = int(d) if d.is_integer() else d
        self._last_counters = {k: float(v) for k, v in counters.items()}
        if self._last_time is not None and tokens and step > self._last_step:
            dt = now - self._last_time
            row["tokens_per_s"] = tokens * (step - self._last_step) / max(dt, 1e-9)
            row["step_time_s"] = dt / (step - self._last_step)
        self._last_time, self._last_step = now, step
        self.history.append(row)
        if self._f:
            self._f.write(json.dumps(row) + "\n")
            self._f.flush()
        return row

    def close(self):
        if self._f:
            self._f.close()
            self._f = None

    # Context-manager close so worker processes (which run many logger
    # lifetimes per process across restarts) never leak file handles.
    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def ppl(ce: float) -> float:
    return float(math.exp(min(ce, 30.0)))
