"""The fault-tolerant training loop (used by examples/ and launch/train.py).

Features: checkpoint/resume (atomic, elastic), heartbeat files, straggler
detection, CEU/PPL metrics, restart-exact data replay. Single-host here;
on a pod each host runs the same loop (SPMD) with its data shard.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.obs import health
from repro.obs.registry import get_registry
from repro.obs.trace import get_tracer
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (
    DrainPreemption,
    Heartbeat,
    StragglerDetector,
)
from repro.train.metrics import MetricsLogger
from repro.train.step import make_train_step
from repro.train.train_state import TrainState


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3
    log_every: int = 10
    metrics_path: Optional[str] = None
    heartbeat_path: Optional[str] = None
    grad_accum: int = 1
    crash_at_step: Optional[int] = None  # fault-injection for tests
    # Seeded fault injection (train/faults.FaultInjector): kills, torn
    # checkpoint writes, heartbeat silence, slow steps. Owned by the
    # supervisor so one-shot faults survive across worker attempts.
    fault_injector: Optional[Any] = None
    # JSON dict saved with every checkpoint manifest (the elastic
    # supervisor stores the coap-plan/v1 artifact here).
    ckpt_meta: Optional[Dict] = None
    # Preemption-notice channel: a JSON file ({"deadline": unix_time})
    # whose appearance means "this allocation dies soon". The loop checks
    # it at the top of every step and DRAINS: checkpoint at the current
    # step, acknowledge (notice_path + ".ack"), raise DrainPreemption.
    # The supervisor owns the file's lifecycle (writes it, clears it
    # before relaunch).
    notice_path: Optional[str] = None
    # Wall-clock floor per step (seconds). Real fleets pace steps for
    # power/thermal smoothing; here it also makes process-supervision
    # races (notice vs kill vs heartbeat) testable on CPU where smoke
    # steps would otherwise finish in microseconds.
    min_step_s: float = 0.0
    # Optional refresh-group attribution for step spans: a callable
    # ``step -> [ {bucket, phase, size, frac, kind}, ... ]`` (see
    # ``obs.calib.planned_refresh_schedule``). The elastic supervisor
    # passes the planned schedule so a trace shows WHICH stagger groups
    # refreshed on each step — what the calibration fit keys on.
    refresh_schedule: Optional[Callable[[int], Any]] = None
    # Sampled projection-health cadence (``obs/health.observe_state``):
    # every N steps the loop reads the RESIDENT optimizer state (int8
    # codec stats, EF-sidecar norms) — never the gradient, so off-cadence
    # steps pay nothing and no step ever re-reads G. Refresh-boundary
    # metrics (energy/residual/overlap) are emitted from inside the
    # optimizer's own refresh branch, not from here. 0 disables.
    health_every: int = 25


class TrainLoop:
    def __init__(self, model, tx, batch_fn: Callable[[int, int], Dict],
                 cfg: TrainLoopConfig, init_key=None, initial_state=None):
        self.model = model
        self.tx = tx
        self.batch_fn = batch_fn
        self.cfg = cfg
        self.logger = MetricsLogger(cfg.metrics_path)
        self.straggler = StragglerDetector()
        self.heartbeat = (
            Heartbeat(cfg.heartbeat_path) if cfg.heartbeat_path else None
        )
        self._step_fn = jax.jit(make_train_step(model, tx,
                                                grad_accum=cfg.grad_accum))
        self._init_key = init_key if init_key is not None else jax.random.key(0)
        # A supervisor that already restored (and possibly migrated) the
        # state passes it here; init_or_restore then skips its own restore.
        self._initial_state = initial_state

    # -- state ---------------------------------------------------------------
    def init_or_restore(self) -> TrainState:
        cfg = self.cfg
        if self._initial_state is not None:
            return self._initial_state
        if cfg.ckpt_dir and ckpt.latest_step(cfg.ckpt_dir) is not None:
            template = jax.eval_shape(
                lambda: TrainState.create(
                    self.model.init(self._init_key), self.tx
                )
            )
            state = ckpt.restore(cfg.ckpt_dir, template)
            return state
        params = self.model.init(self._init_key)
        return TrainState.create(params, self.tx)

    # -- drain ---------------------------------------------------------------
    def _notice_deadline(self, step: int) -> Optional[float]:
        """An active preemption notice's absolute deadline, or None. File
        channel first (process mode), then the in-process injector."""
        cfg = self.cfg
        if cfg.notice_path and os.path.exists(cfg.notice_path):
            try:
                with open(cfg.notice_path) as f:
                    return float(json.load(f).get("deadline", 0.0))
            except (json.JSONDecodeError, ValueError, OSError):
                return 0.0  # unreadable notice still means "leave now"
        inj = cfg.fault_injector
        if inj is not None and hasattr(inj, "due_notice"):
            d = inj.due_notice(step)
            if d is not None:
                return time.time() + d
        return None

    def _drain(self, state: TrainState, step: int, deadline: float):
        """Checkpoint at exactly ``step`` (every completed step survives),
        acknowledge the notice, and hand control back as a planned
        preemption. The next attempt resumes from ``step``: zero lost."""
        cfg = self.cfg
        get_registry().inc("loop/drain")
        if cfg.ckpt_dir:
            with get_tracer().span("loop/checkpoint", step=step,
                                   reason="drain"):
                ckpt.save(cfg.ckpt_dir, step, state, keep=cfg.ckpt_keep,
                          meta=cfg.ckpt_meta)
        if cfg.notice_path:
            ack = cfg.notice_path + ".ack"
            tmp = ack + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"step": step, "time": time.time()}, f)
            os.replace(tmp, ack)
        raise DrainPreemption(step, deadline)

    # -- main ----------------------------------------------------------------
    def run(self) -> TrainState:
        # The logger is closed in the finally: worker processes run one
        # loop per attempt, and leaked jsonl handles accumulate across
        # restarts otherwise. ``logger.history`` stays readable after.
        try:
            return self._run()
        finally:
            self.logger.close()

    def _run(self) -> TrainState:
        cfg = self.cfg
        tracer = get_tracer()
        reg = get_registry()
        reg.set_phase("train")
        state = self.init_or_restore()
        start = int(state.step)
        ceu_total = 0.0
        inj = cfg.fault_injector
        for step in range(start, cfg.total_steps):
            deadline = self._notice_deadline(step)
            if deadline is not None:
                self._drain(state, step, deadline)
            if cfg.crash_at_step is not None and step == cfg.crash_at_step:
                raise RuntimeError(f"induced crash at step {step}")
            if inj is not None:
                inj.maybe_kill(step)
            batch = self.batch_fn(step, 0)
            # Refresh-group attribution is computed host-side BEFORE the
            # step (a pure function of (plan, step)) so the span carries
            # exactly what the jitted update is about to do.
            span_attrs = {"step": step}
            if cfg.refresh_schedule is not None:
                ev = cfg.refresh_schedule(step)
                if ev:
                    span_attrs["refresh"] = ev
            if step == start:
                # First execution of this loop instance traces + compiles.
                span_attrs["compile"] = True
            t0 = time.time()
            with tracer.span("loop/step", **span_attrs):
                state, metrics = self._step_fn(state, batch)
                jax.block_until_ready(state.params)
            dt = time.time() - t0
            if cfg.min_step_s > 0 and dt < cfg.min_step_s:
                time.sleep(cfg.min_step_s - dt)
            if inj is not None:
                dt += inj.slow_delay(step)
            slow = self.straggler.observe(dt)
            if slow:
                reg.inc("loop/straggler_step")
            ceu_total += float(metrics["ceu"])
            if (
                cfg.health_every
                and health.get_monitor().enabled
                and step % cfg.health_every == 0
            ):
                health.observe_state(state.opt_state, step)
            if self.heartbeat and not (
                inj is not None and inj.heartbeat_silent(step)
            ):
                snap = reg.snapshot()
                self.heartbeat.beat(
                    step,
                    extra={
                        "straggler_flagged": self.straggler.flagged,
                        "phase": reg.gauge("phase", "train"),
                        # The registry snapshot rides every beat: the
                        # supervisor (and fleet_status) reads a worker's
                        # counters AND health gauges with no extra channel.
                        "counters": snap["counters"],
                        "gauges": snap["gauges"],
                    },
                )
            if step % cfg.log_every == 0 or step == cfg.total_steps - 1:
                row = dict(metrics)
                row["ceu_total"] = ceu_total
                row["straggler"] = int(slow)
                ntok = 0
                b = batch.get("tokens", batch.get("embeds"))
                if b is not None:
                    ntok = b.shape[0] * b.shape[1]
                self.logger.log(step, row, tokens=ntok)
            if (
                cfg.ckpt_dir
                and cfg.ckpt_every
                and (step + 1) % cfg.ckpt_every == 0
            ):
                with tracer.span("loop/checkpoint", step=step + 1):
                    ckpt.save(cfg.ckpt_dir, step + 1, state,
                              keep=cfg.ckpt_keep, meta=cfg.ckpt_meta)
                reg.inc("ckpt/save")
                if inj is not None:
                    inj.after_save(cfg.ckpt_dir, step + 1)
        if cfg.ckpt_dir:
            with tracer.span("loop/checkpoint", step=int(state.step),
                             reason="final"):
                ckpt.save(cfg.ckpt_dir, int(state.step), state,
                          keep=cfg.ckpt_keep, meta=cfg.ckpt_meta)
            reg.inc("ckpt/save")
            if inj is not None:
                inj.after_save(cfg.ckpt_dir, int(state.step))
        return state
