"""Fault-tolerance utilities: heartbeats, straggler detection, auto-restart.

On a real multi-host pod each host runs these locally; an external
supervisor (launch/train.py --watch) kills and relaunches wedged jobs, and
the checkpoint/restore + restart-exact data pipeline guarantee bitwise
resume. In this container the same machinery is exercised single-host by
tests/test_train_loop.py (induced crashes, induced stragglers).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Optional


@dataclasses.dataclass
class Heartbeat:
    """Writes {step, time} to a file; a supervisor declares the host dead
    after ``timeout`` seconds of silence."""

    path: str
    timeout: float = 300.0

    def beat(self, step: int):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.path)

    def is_alive(self) -> bool:
        try:
            with open(self.path) as f:
                last = json.load(f)["time"]
            return (time.time() - last) < self.timeout
        except (FileNotFoundError, json.JSONDecodeError, KeyError):
            return False


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time watchdog: flags steps slower than mean + z·std.

    At 1000+ nodes stragglers show up as whole-step slowdowns (synchronous
    SPMD): detection is what's actionable per-host — the supervisor decides
    whether to drain/replace the slow host. We log and count here.
    """

    z_threshold: float = 4.0
    decay: float = 0.95
    warmup: int = 10

    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the statistics
            if self.n == 1:
                self.mean = dt
            self.mean = self.decay * self.mean + (1 - self.decay) * dt
            self.var = self.decay * self.var + (1 - self.decay) * (dt - self.mean) ** 2
            return False
        std = max(self.var**0.5, 1e-6, 0.01 * self.mean)
        is_straggler = dt > self.mean + self.z_threshold * std
        if is_straggler:
            self.flagged += 1
        else:
            self.mean = self.decay * self.mean + (1 - self.decay) * dt
            self.var = self.decay * self.var + (1 - self.decay) * (dt - self.mean) ** 2
        return is_straggler


def run_with_restart(
    make_and_run: Callable[[int], None],
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, Exception], None]] = None,
):
    """Crash-restart driver: calls make_and_run(attempt); on exception,
    retries (the callee restores from the newest checkpoint)."""
    attempt = 0
    while True:
        try:
            return make_and_run(attempt)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure restarts
            attempt += 1
            if attempt > max_restarts:
                raise
            if on_restart:
                on_restart(attempt, e)
