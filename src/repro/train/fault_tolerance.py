"""Fault-tolerance primitives for the preemption-native supervisor.

These are the building blocks ``train/elastic.py`` composes into a real
replan→migrate→resume control loop (driven by ``launch/train.py --watch``):

  * :class:`Heartbeat` — liveness file each worker beats every step; the
    supervisor distinguishes ``"missing"`` (never started / cleaned up)
    from ``"stale"`` (started, then went silent — died or wedged) via
    :meth:`Heartbeat.status`.
  * :class:`StragglerDetector` — EWMA step-time watchdog; flags steps
    slower than mean + z·std so the supervisor can drain/replace the host.
  * :class:`CrashBudget` — sliding-window restart policy (at most N
    crashes per M seconds), replacing a lifetime counter: a week-long run
    on spot capacity legitimately restarts many times, but a tight burst
    of crashes means the job itself is broken.
  * :func:`run_with_restart` — the restart driver: exponential backoff
    with deterministic jitter between attempts, governed by either a
    lifetime ``max_restarts`` cap (legacy) or a :class:`CrashBudget`;
    :class:`DrainPreemption` exceptions restart immediately without
    charging the budget (a drain is a planned handoff, not a crash).
  * :class:`SupervisionPolicy` / :func:`decide_supervision` — the
    process supervisor's escalation ladder ("missing" → start grace →
    kill; "stale" → stale grace → kill; straggler beats → drain) as a
    pure, unit-testable decision function.

On a real multi-host pod each host runs these locally; the supervisor
kills and relaunches wedged jobs, and checkpoint/restore + restart-exact
data replay guarantee bitwise resume. Single-host, the same machinery is
exercised by tests/test_train_loop.py and tests/test_elastic.py under
seeded fault injection (``train/faults.py``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple


class DrainPreemption(Exception):
    """A worker attempt stopped *cleanly* on a preemption notice: it
    checkpointed at ``step`` and exited before the kill deadline. The
    restart driver treats this as a planned handoff, not a crash — no
    crash-budget charge, no backoff — and the next attempt resumes from
    exactly ``step`` (zero lost steps)."""

    def __init__(self, step: int, deadline: Optional[float] = None):
        super().__init__(f"drained at step {step}")
        self.step = int(step)
        self.deadline = deadline


@dataclasses.dataclass
class Heartbeat:
    """Writes {step, time} to a file; a supervisor declares the host dead
    after ``timeout`` seconds of silence.

    :meth:`status` separates the two dead-looking cases the supervisor
    must treat differently: ``"missing"`` (no heartbeat file — the worker
    never started, or its directory was cleaned) vs ``"stale"`` (the file
    exists but is older than ``timeout`` — the worker started and then
    died or wedged). ``is_alive`` remains the simple boolean view.
    """

    path: str
    timeout: float = 300.0

    def _write(self, payload: Dict) -> None:
        """Atomic publish (write-temp + ``os.replace``) with a PER-WRITER
        temp name: the training loop's ``beat`` and the refresher
        daemon's ``touch`` run on different threads (and supervisor/
        worker on different processes) against the same path — a shared
        ``.tmp`` would let one writer replace a file the other is still
        mid-``json.dump`` into, publishing a torn heartbeat that reads
        as "missing" and gets a live worker killed."""
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        tmp = f"{self.path}.{os.getpid()}.{threading.get_ident()}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    def beat(self, step: int, extra: Optional[Dict] = None):
        payload = {"step": step, "time": time.time()}
        if extra:
            payload.update(extra)
        self._write(payload)

    def read(self) -> Optional[Dict]:
        """The full last-beat payload (step, time, any extras), or None."""
        try:
            with open(self.path) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return None

    def touch(self) -> None:
        """Refresh the beat *time* without claiming step progress: re-write
        the last payload with a fresh timestamp. Used by the worker's
        auto-beat thread so liveness is process-liveness (a SIGKILL stops
        the refresher instantly) while ``step`` still tracks real
        progress from the training loop's own beats.

        The obs registry's ``phase`` gauge rides every touch: during long
        non-stepping phases (restore, migrate, jit compile) the refresher
        is the only writer, and operators (``launch/fleet_status``) want
        to see WHICH phase the silent worker is in."""
        from repro.obs.registry import get_registry

        payload = self.read() or {"step": 0}
        payload["time"] = time.time()
        phase = get_registry().gauge("phase", None)
        if phase is not None:
            payload["phase"] = phase
        self._write(payload)

    def auto(self, interval: float) -> "HeartbeatRefresher":
        """A daemon-thread refresher calling :meth:`touch` every
        ``interval`` seconds. Use as a context manager around a worker
        attempt so long non-stepping phases (restore, migrate, jit
        compile) do not read as ``"stale"`` to the supervisor."""
        return HeartbeatRefresher(self, interval)

    def status(self) -> str:
        """'alive' | 'stale' | 'missing'."""
        try:
            with open(self.path) as f:
                last = json.load(f)["time"]
        except FileNotFoundError:
            return "missing"
        except (json.JSONDecodeError, KeyError):
            # A torn write can only be the .tmp file (os.replace is atomic),
            # so unreadable content means something external clobbered the
            # path — treat as never-properly-started.
            return "missing"
        return "alive" if (time.time() - last) < self.timeout else "stale"

    def last_step(self) -> Optional[int]:
        """The last step the worker reported, or None if unreadable."""
        try:
            with open(self.path) as f:
                return int(json.load(f)["step"])
        except (FileNotFoundError, json.JSONDecodeError, KeyError, ValueError):
            return None

    def is_alive(self) -> bool:
        return self.status() == "alive"


class HeartbeatRefresher:
    """Context manager: beats a :class:`Heartbeat` from a daemon thread.

    Liveness then means "the process is alive", decoupled from step
    cadence — exactly what a process supervisor should key kills on. A
    SIGKILL takes the thread with the process, so the file goes stale
    within ``timeout`` regardless of what the worker was doing.
    """

    def __init__(self, heartbeat: Heartbeat, interval: float):
        self.heartbeat = heartbeat
        self.interval = max(float(interval), 0.01)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def __enter__(self) -> "HeartbeatRefresher":
        self.heartbeat.touch()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.heartbeat.touch()
            except OSError:
                pass  # transient fs trouble; next tick retries

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time watchdog: flags steps slower than mean + z·std.

    At 1000+ nodes stragglers show up as whole-step slowdowns (synchronous
    SPMD): detection is what's actionable per-host — the supervisor decides
    whether to drain/replace the slow host. We log and count here.

    Warmup seeding: the first observation sets ``mean = dt`` exactly (var
    0) and is NOT additionally folded through the EWMA — seeding and then
    decaying in the same call would re-weight the first sample and bias
    the early statistics. Subsequent warmup samples update the EWMA
    normally; flagging starts after ``warmup`` observations.
    """

    z_threshold: float = 4.0
    decay: float = 0.95
    warmup: int = 10

    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            # Clean seed: the first sample IS the statistics.
            self.mean = dt
            self.var = 0.0
            return False
        if self.n <= self.warmup:
            self.mean = self.decay * self.mean + (1 - self.decay) * dt
            self.var = self.decay * self.var + (1 - self.decay) * (dt - self.mean) ** 2
            return False
        std = max(self.var**0.5, 1e-6, 0.01 * self.mean)
        is_straggler = dt > self.mean + self.z_threshold * std
        if is_straggler:
            self.flagged += 1
        else:
            self.mean = self.decay * self.mean + (1 - self.decay) * dt
            self.var = self.decay * self.var + (1 - self.decay) * (dt - self.mean) ** 2
        return is_straggler


@dataclasses.dataclass
class CrashBudget:
    """Sliding-window restart policy: at most ``max_crashes`` within any
    ``window_seconds`` window. Unlike a lifetime counter, a long healthy
    run can absorb unbounded occasional preemptions — only a *burst* of
    failures (crash-looping) exhausts the budget.
    """

    max_crashes: int = 5
    window_seconds: float = 600.0
    time_fn: Callable[[], float] = time.time
    _times: List[float] = dataclasses.field(default_factory=list)

    def record(self) -> None:
        now = self.time_fn()
        self._times.append(now)
        self._prune(now)

    def exhausted(self) -> bool:
        self._prune(self.time_fn())
        return len(self._times) > self.max_crashes

    def in_window(self) -> int:
        self._prune(self.time_fn())
        return len(self._times)

    def _prune(self, now: float) -> None:
        cutoff = now - self.window_seconds
        self._times[:] = [t for t in self._times if t >= cutoff]


@dataclasses.dataclass(frozen=True)
class SupervisionPolicy:
    """How a process supervisor escalates on heartbeat evidence.

    * ``"missing"`` heartbeat — the worker never produced a beat this
      attempt. Within ``start_grace_s`` of the spawn that is normal
      (interpreter boot, restore, first-step compile all happen before
      the first beat unless the worker runs a :class:`HeartbeatRefresher`);
      past it, the worker is presumed dead-on-arrival → kill + restart.
    * ``"stale"`` heartbeat — the worker beat and then went silent.
      A short ``stale_grace_s`` absorbs fs jitter; past it → kill +
      restart. (Kill is issued even though the process is probably
      already dead — SIGKILL on a corpse is a no-op and guarantees the
      slot is really free before relaunch.)
    * straggler drain — when the worker's own beats report
      ``straggler_flagged >= straggler_drain_after`` flagged slow steps,
      the supervisor *drains* (notice + checkpoint + clean handoff)
      rather than killing: the host is sick, not the job. ``0`` disables.
    """

    start_grace_s: float = 180.0
    stale_grace_s: float = 2.0
    straggler_drain_after: int = 0


def decide_supervision(
    status: str,
    *,
    missing_for_s: float = 0.0,
    stale_for_s: float = 0.0,
    straggler_flagged: int = 0,
    policy: SupervisionPolicy = SupervisionPolicy(),
) -> str:
    """The supervisor's per-poll decision, as a pure function so the
    escalation ladder is unit-testable without processes:
    ``'ok' | 'wait' | 'kill' | 'drain'``.

    ``missing_for_s`` is seconds since the attempt spawned (only
    meaningful for ``"missing"``); ``stale_for_s`` is seconds past the
    heartbeat timeout (only meaningful for ``"stale"``).
    """
    if status == "alive":
        if (
            policy.straggler_drain_after > 0
            and straggler_flagged >= policy.straggler_drain_after
        ):
            return "drain"
        return "ok"
    if status == "missing":
        return "kill" if missing_for_s > policy.start_grace_s else "wait"
    if status == "stale":
        return "kill" if stale_for_s > policy.stale_grace_s else "wait"
    raise ValueError(f"unknown heartbeat status {status!r}")


def backoff_delay(
    attempt: int,
    base: float,
    cap: float,
    jitter: float,
    rng: Optional[random.Random] = None,
) -> float:
    """Delay before restart ``attempt`` (1-based): ``min(cap, base·2^(a-1))``
    plus up to ``jitter`` fractional seeded noise (so a fleet of restarting
    workers does not thundering-herd the checkpoint store)."""
    if base <= 0:
        return 0.0
    d = min(cap, base * (2.0 ** (attempt - 1)))
    if jitter > 0 and rng is not None:
        d *= 1.0 + jitter * rng.random()
    return d


def run_with_restart(
    make_and_run: Callable[[int], None],
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, Exception], None]] = None,
    *,
    crash_budget: Optional[CrashBudget] = None,
    backoff_base: float = 0.0,
    backoff_cap: float = 30.0,
    backoff_jitter: float = 0.1,
    sleep_fn: Callable[[float], None] = time.sleep,
    seed: int = 0,
    drain_types: Tuple[type, ...] = (),
    on_drain: Optional[Callable[[int, Exception], None]] = None,
):
    """Crash-restart driver: calls make_and_run(attempt); on exception,
    retries (the callee restores from the newest checkpoint).

    Restart policy: with ``crash_budget`` set, restarts are allowed as long
    as the sliding window is not exhausted (``max_restarts`` is ignored —
    long-lived runs restart indefinitely, crash loops stop fast); without
    it, the legacy lifetime ``max_restarts`` cap applies. Between attempts
    the driver sleeps ``backoff_delay`` (exponential with seeded jitter;
    ``backoff_base=0`` disables sleeping — the default, and what unit
    tests use). ``sleep_fn`` is injectable for tests/supervisors.

    Exceptions matching ``drain_types`` (e.g. :class:`DrainPreemption`)
    are planned handoffs, not crashes: the next attempt launches
    immediately — no crash-budget charge, no backoff — after ``on_drain``
    is notified. A drained worker checkpointed at its exact stop step, so
    the relaunch resumes with zero lost steps.
    """
    rng = random.Random(seed)
    attempt = 0
    while True:
        try:
            return make_and_run(attempt)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure restarts
            attempt += 1
            if drain_types and isinstance(e, drain_types):
                if on_drain:
                    on_drain(attempt, e)
                continue
            if crash_budget is not None:
                crash_budget.record()
                if crash_budget.exhausted():
                    raise
            elif attempt > max_restarts:
                raise
            if on_restart:
                on_restart(attempt, e)
            delay = backoff_delay(
                attempt, backoff_base, backoff_cap, backoff_jitter, rng
            )
            if delay > 0:
                sleep_fn(delay)
