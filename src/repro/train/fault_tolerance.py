"""Fault-tolerance primitives for the preemption-native supervisor.

These are the building blocks ``train/elastic.py`` composes into a real
replan→migrate→resume control loop (driven by ``launch/train.py --watch``):

  * :class:`Heartbeat` — liveness file each worker beats every step; the
    supervisor distinguishes ``"missing"`` (never started / cleaned up)
    from ``"stale"`` (started, then went silent — died or wedged) via
    :meth:`Heartbeat.status`.
  * :class:`StragglerDetector` — EWMA step-time watchdog; flags steps
    slower than mean + z·std so the supervisor can drain/replace the host.
  * :class:`CrashBudget` — sliding-window restart policy (at most N
    crashes per M seconds), replacing a lifetime counter: a week-long run
    on spot capacity legitimately restarts many times, but a tight burst
    of crashes means the job itself is broken.
  * :func:`run_with_restart` — the restart driver: exponential backoff
    with deterministic jitter between attempts, governed by either a
    lifetime ``max_restarts`` cap (legacy) or a :class:`CrashBudget`.

On a real multi-host pod each host runs these locally; the supervisor
kills and relaunches wedged jobs, and checkpoint/restore + restart-exact
data replay guarantee bitwise resume. Single-host, the same machinery is
exercised by tests/test_train_loop.py and tests/test_elastic.py under
seeded fault injection (``train/faults.py``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import random
import time
from typing import Callable, List, Optional


@dataclasses.dataclass
class Heartbeat:
    """Writes {step, time} to a file; a supervisor declares the host dead
    after ``timeout`` seconds of silence.

    :meth:`status` separates the two dead-looking cases the supervisor
    must treat differently: ``"missing"`` (no heartbeat file — the worker
    never started, or its directory was cleaned) vs ``"stale"`` (the file
    exists but is older than ``timeout`` — the worker started and then
    died or wedged). ``is_alive`` remains the simple boolean view.
    """

    path: str
    timeout: float = 300.0

    def beat(self, step: int):
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, self.path)

    def status(self) -> str:
        """'alive' | 'stale' | 'missing'."""
        try:
            with open(self.path) as f:
                last = json.load(f)["time"]
        except FileNotFoundError:
            return "missing"
        except (json.JSONDecodeError, KeyError):
            # A torn write can only be the .tmp file (os.replace is atomic),
            # so unreadable content means something external clobbered the
            # path — treat as never-properly-started.
            return "missing"
        return "alive" if (time.time() - last) < self.timeout else "stale"

    def last_step(self) -> Optional[int]:
        """The last step the worker reported, or None if unreadable."""
        try:
            with open(self.path) as f:
                return int(json.load(f)["step"])
        except (FileNotFoundError, json.JSONDecodeError, KeyError, ValueError):
            return None

    def is_alive(self) -> bool:
        return self.status() == "alive"


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time watchdog: flags steps slower than mean + z·std.

    At 1000+ nodes stragglers show up as whole-step slowdowns (synchronous
    SPMD): detection is what's actionable per-host — the supervisor decides
    whether to drain/replace the slow host. We log and count here.

    Warmup seeding: the first observation sets ``mean = dt`` exactly (var
    0) and is NOT additionally folded through the EWMA — seeding and then
    decaying in the same call would re-weight the first sample and bias
    the early statistics. Subsequent warmup samples update the EWMA
    normally; flagging starts after ``warmup`` observations.
    """

    z_threshold: float = 4.0
    decay: float = 0.95
    warmup: int = 10

    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.n += 1
        if self.n == 1:
            # Clean seed: the first sample IS the statistics.
            self.mean = dt
            self.var = 0.0
            return False
        if self.n <= self.warmup:
            self.mean = self.decay * self.mean + (1 - self.decay) * dt
            self.var = self.decay * self.var + (1 - self.decay) * (dt - self.mean) ** 2
            return False
        std = max(self.var**0.5, 1e-6, 0.01 * self.mean)
        is_straggler = dt > self.mean + self.z_threshold * std
        if is_straggler:
            self.flagged += 1
        else:
            self.mean = self.decay * self.mean + (1 - self.decay) * dt
            self.var = self.decay * self.var + (1 - self.decay) * (dt - self.mean) ** 2
        return is_straggler


@dataclasses.dataclass
class CrashBudget:
    """Sliding-window restart policy: at most ``max_crashes`` within any
    ``window_seconds`` window. Unlike a lifetime counter, a long healthy
    run can absorb unbounded occasional preemptions — only a *burst* of
    failures (crash-looping) exhausts the budget.
    """

    max_crashes: int = 5
    window_seconds: float = 600.0
    time_fn: Callable[[], float] = time.time
    _times: List[float] = dataclasses.field(default_factory=list)

    def record(self) -> None:
        now = self.time_fn()
        self._times.append(now)
        self._prune(now)

    def exhausted(self) -> bool:
        self._prune(self.time_fn())
        return len(self._times) > self.max_crashes

    def in_window(self) -> int:
        self._prune(self.time_fn())
        return len(self._times)

    def _prune(self, now: float) -> None:
        cutoff = now - self.window_seconds
        self._times[:] = [t for t in self._times if t >= cutoff]


def backoff_delay(
    attempt: int,
    base: float,
    cap: float,
    jitter: float,
    rng: Optional[random.Random] = None,
) -> float:
    """Delay before restart ``attempt`` (1-based): ``min(cap, base·2^(a-1))``
    plus up to ``jitter`` fractional seeded noise (so a fleet of restarting
    workers does not thundering-herd the checkpoint store)."""
    if base <= 0:
        return 0.0
    d = min(cap, base * (2.0 ** (attempt - 1)))
    if jitter > 0 and rng is not None:
        d *= 1.0 + jitter * rng.random()
    return d


def run_with_restart(
    make_and_run: Callable[[int], None],
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, Exception], None]] = None,
    *,
    crash_budget: Optional[CrashBudget] = None,
    backoff_base: float = 0.0,
    backoff_cap: float = 30.0,
    backoff_jitter: float = 0.1,
    sleep_fn: Callable[[float], None] = time.sleep,
    seed: int = 0,
):
    """Crash-restart driver: calls make_and_run(attempt); on exception,
    retries (the callee restores from the newest checkpoint).

    Restart policy: with ``crash_budget`` set, restarts are allowed as long
    as the sliding window is not exhausted (``max_restarts`` is ignored —
    long-lived runs restart indefinitely, crash loops stop fast); without
    it, the legacy lifetime ``max_restarts`` cap applies. Between attempts
    the driver sleeps ``backoff_delay`` (exponential with seeded jitter;
    ``backoff_base=0`` disables sleeping — the default, and what unit
    tests use). ``sleep_fn`` is injectable for tests/supervisors.
    """
    rng = random.Random(seed)
    attempt = 0
    while True:
        try:
            return make_and_run(attempt)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure restarts
            attempt += 1
            if crash_budget is not None:
                crash_budget.record()
                if crash_budget.exhausted():
                    raise
            elif attempt > max_restarts:
                raise
            if on_restart:
                on_restart(attempt, e)
            delay = backoff_delay(
                attempt, backoff_base, backoff_cap, backoff_jitter, rng
            )
            if delay > 0:
                sleep_fn(delay)
