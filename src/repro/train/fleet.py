"""Fleet coordination: multi-supervisor consensus on the plan artifact.

When a topology change hits a multi-host fleet, every host's supervisor
wants to replan — but the fleet must train under ONE ``coap-plan/v1``
artifact (stagger phases, bucket codecs and checkpoint layout are all
derived from it; two hosts on different plans corrupt the run). This
module is the agreement protocol, built on the same shared filesystem
the checkpoint store already requires (the manifest ``meta`` channel is
the durable end state: the adopted plan rides in every checkpoint the
fleet writes from then on).

Protocol, per replan *epoch* (an epoch names one topology change, e.g.
``"120:4x276688"`` = from step 120, 4 devices × that many bytes):

  1. **liveness** — every supervisor heartbeats a member file under
     ``<fleet_dir>/members/``; the elected *leader* is the minimum alive
     ``host_id`` (deterministic, no ballots needed).
  2. **propose** — the leader runs ``solve_for_topology`` and *stages*
     its proposal under ``<fleet_dir>/epochs/<epoch>/props/<host>.json``
     (content-addressed: the proposal records the sha256 digest of its
     canonical plan JSON). Peers wait for a commit; if the leader dies
     before committing, the wait times out and the peer solves + commits
     itself — liveness is preserved without extra rounds.
  3. **commit** — first-wins atomic publication of
     ``<epoch>/plan.json`` (hardlink of a fully-written temp file, so a
     committed plan is never torn). The VALUE committed is not "my
     proposal" but the winner of a deterministic tie-break over all
     currently staged proposals — min by ``(digest, host_id)`` — so two
     hosts racing to commit different proposals (e.g. divergent local
     calibration files) converge on the SAME artifact no matter which
     one's ``link()`` lands first: either the winner's own commit lands,
     or the loser commits the winner's proposal for it.
  4. **adopt** — everyone (including losers of the race) reads the
     committed artifact back and trains under it. ``plan_for_epoch``
     returns the role (``"published"`` vs ``"adopted"``) for telemetry.

Everything is plain JSON files + POSIX atomic primitives (``os.replace``
for stage/liveness, ``os.link`` O_EXCL-style for commit) — the same
trust model as the checkpoint store, no extra services.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.registry import get_registry
from repro.obs.trace import get_tracer


def plan_digest(plan_dict: Dict) -> str:
    """Content address of a plan: sha256 over canonical (sorted-key,
    separator-normalized) JSON. Hosts that solved identical plans produce
    identical digests regardless of dict ordering."""
    blob = json.dumps(plan_dict, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _atomic_write_json(path: str, payload: Dict) -> None:
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)


def _read_json(path: str) -> Optional[Dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return None


def _slug(s: str) -> str:
    return "".join(c if (c.isalnum() or c in "-_.") else "_" for c in s)


@dataclasses.dataclass
class FleetConfig:
    fleet_dir: str
    host_id: str
    # A member whose liveness file is older than this is not counted for
    # leader election (its lease lapsed — likely preempted).
    member_timeout_s: float = 30.0
    # How long a peer waits for the leader's commit before solving and
    # committing itself (leader-death fallback).
    adopt_timeout_s: float = 60.0
    poll_interval_s: float = 0.05


class PlanConsensus:
    """One host's handle on the fleet agreement protocol (see module
    docstring). All methods are safe to call concurrently from multiple
    hosts sharing ``fleet_dir``."""

    def __init__(
        self,
        cfg: FleetConfig,
        time_fn: Callable[[], float] = time.time,
        sleep_fn: Callable[[float], None] = time.sleep,
    ):
        self.cfg = cfg
        self.host = cfg.host_id
        self.time_fn = time_fn
        self.sleep_fn = sleep_fn
        self._members = os.path.join(cfg.fleet_dir, "members")
        os.makedirs(self._members, exist_ok=True)

    # -- liveness / election -------------------------------------------------
    def beat(self) -> None:
        _atomic_write_json(
            os.path.join(self._members, _slug(self.host) + ".json"),
            {"host": self.host, "time": self.time_fn()},
        )

    def alive_hosts(self) -> List[str]:
        cutoff = self.time_fn() - self.cfg.member_timeout_s
        out = []
        for fname in os.listdir(self._members):
            if not fname.endswith(".json"):
                continue
            rec = _read_json(os.path.join(self._members, fname))
            if rec and float(rec.get("time", 0.0)) >= cutoff:
                out.append(str(rec["host"]))
        return sorted(out)

    def leader(self) -> str:
        """Deterministic election: the minimum alive host_id. With no
        alive peers visible (fresh dir, clock skew) every host considers
        itself leader — the commit tie-break keeps that safe."""
        alive = self.alive_hosts()
        return alive[0] if alive else self.host

    # -- proposals -----------------------------------------------------------
    def _edir(self, epoch: str) -> str:
        d = os.path.join(self.cfg.fleet_dir, "epochs", _slug(epoch))
        os.makedirs(os.path.join(d, "props"), exist_ok=True)
        return d

    def stage(self, epoch: str, plan_dict: Dict) -> str:
        """Stage this host's proposal for ``epoch``; returns its digest."""
        digest = plan_digest(plan_dict)
        with get_tracer().span("fleet/propose", epoch=epoch,
                               digest=digest[:12]):
            _atomic_write_json(
                os.path.join(self._edir(epoch), "props",
                             _slug(self.host) + ".json"),
                {"host": self.host, "digest": digest, "plan": plan_dict},
            )
        get_registry().inc("fleet/proposed")
        return digest

    def staged(self, epoch: str) -> List[Dict]:
        pdir = os.path.join(self._edir(epoch), "props")
        out = []
        for fname in sorted(os.listdir(pdir)):
            if not fname.endswith(".json"):
                continue
            rec = _read_json(os.path.join(pdir, fname))
            if rec and "plan" in rec and "digest" in rec:
                out.append(rec)
        return out

    def committed(self, epoch: str) -> Optional[Dict]:
        """The committed record ({host, digest, plan}) for ``epoch``, or
        None. Commits are hardlinked from fully-written temp files, so a
        visible commit is never torn."""
        return _read_json(os.path.join(self._edir(epoch), "plan.json"))

    def commit(self, epoch: str) -> Dict:
        """Publish a plan for ``epoch``: deterministic tie-break over the
        currently staged proposals — min by ``(digest, host_id)`` — then
        first-wins atomic create. Requires at least one staged proposal
        (stage your own first). Returns the record that actually won."""
        props = self.staged(epoch)
        if not props:
            raise ValueError(
                f"commit({epoch!r}): no staged proposals — stage one first"
            )
        winner = min(props, key=lambda p: (p["digest"], p["host"]))
        path = os.path.join(self._edir(epoch), "plan.json")
        tmp = f"{path}.{_slug(self.host)}.{os.getpid()}.tmp"
        with get_tracer().span("fleet/commit", epoch=epoch,
                               digest=winner["digest"][:12]):
            with open(tmp, "w") as f:
                json.dump(winner, f)
            try:
                os.link(tmp, path)  # atomic first-wins; complete content
                get_registry().inc("fleet/commit_won")
            except FileExistsError:
                # someone else landed first — adopt theirs below
                get_registry().inc("fleet/commit_lost")
            finally:
                os.unlink(tmp)
            out = self.committed(epoch)
        assert out is not None  # link succeeded or a commit already existed
        return out

    # -- the one-call protocol ----------------------------------------------
    def plan_for_epoch(
        self, epoch: str, solve_fn: Callable[[], Dict]
    ) -> Tuple[Dict, str]:
        """Agree on the plan for ``epoch``: returns ``(plan_dict, role)``
        with role ``"published"`` (this host's proposal won) or
        ``"adopted"`` (another host's artifact adopted). ``solve_fn`` is
        only invoked when this host actually needs to solve (it is the
        leader, or the leader's commit never arrived)."""
        self.beat()
        c = self.committed(epoch)
        if c is not None:
            get_registry().inc("fleet/adopted")
            return c["plan"], "adopted"
        if self.leader() != self.host:
            deadline = self.time_fn() + self.cfg.adopt_timeout_s
            with get_tracer().span("fleet/adopt_wait", epoch=epoch):
                while self.time_fn() < deadline:
                    c = self.committed(epoch)
                    if c is not None:
                        get_registry().inc("fleet/adopted")
                        return c["plan"], "adopted"
                    self.beat()
                    if self.leader() == self.host:
                        break  # leader's lease lapsed — take over
                    self.sleep_fn(self.cfg.poll_interval_s)
        with get_tracer().span("fleet/solve", epoch=epoch):
            self.stage(epoch, solve_fn())
        c = self.commit(epoch)
        role = "published" if c["host"] == self.host else "adopted"
        get_registry().inc(f"fleet/{role}")
        return c["plan"], role
