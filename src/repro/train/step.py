"""Train-step factory: loss → grads → optimizer, with microbatch gradient
accumulation and optional cross-pod projected-gradient compression."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.optim import apply_updates
from repro.train.train_state import TrainState


def make_train_step(
    model,
    tx,
    grad_accum: int = 1,
    donate: bool = True,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    grad_accum > 1 splits the per-device batch into microbatches and
    accumulates gradients through a lax.scan (bounds activation memory; the
    standard remat+accum combination for the train_4k cells).
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            if x.ndim >= 2 and x.shape[0] == 3:  # mrope positions (3,B,T)
                return jnp.moveaxis(
                    x.reshape(3, grad_accum, -1, *x.shape[2:]), 1, 0
                )
            return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return (acc, loss_sum + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (gsum, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros([], jnp.float32)), micro
        )
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
        loss = loss_sum / grad_accum
        return loss, {"ce": loss}, grads

    def step(state: TrainState, batch) -> tuple:
        loss, metrics, grads = compute_grads(state.params, batch)
        updates, opt_state = tx.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)
            )
        )
        # CEU (paper Fig 3): Σ‖ΔW‖₁ of the applied update
        ceu = sum(
            jnp.sum(jnp.abs(u.astype(jnp.float32)))
            for u in jax.tree_util.tree_leaves(updates)
        )
        out_metrics = {"loss": loss, "grad_norm": gnorm, "ceu": ceu}
        for k, v in metrics.items():
            out_metrics.setdefault(k, v)
        return (
            TrainState(step=state.step + 1, params=params, opt_state=opt_state),
            out_metrics,
        )

    return step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch) -> Dict[str, jnp.ndarray]:
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, "ppl": jnp.exp(metrics["ce"]), **metrics}

    return eval_step
