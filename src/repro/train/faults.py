"""Deterministic, seeded fault injection for the elastic control loop.

The supervisor in ``train/elastic.py`` is only trustworthy if its failure
paths are *exercised*, not just written. This module injects the four
failure modes preemptible training actually sees, reproducibly:

  * **kill-at-step** — the worker process dies mid-run (preemption);
  * **torn checkpoint writes** — a finalized checkpoint is corrupted after
    the fact (partial copy / disk fault) so the crc32 integrity check in
    ``train/checkpoint.py`` must catch it and the supervisor must fall
    back to an older checkpoint;
  * **heartbeat silence** — the worker stops beating for a window while
    still stepping (network partition / wedged filesystem), so the
    supervisor sees ``"stale"`` without a crash;
  * **slow-step stragglers** — injected step-time outliers the
    :class:`~repro.train.fault_tolerance.StragglerDetector` must flag;
  * **preemption notices** — advance warning with a deadline (the cloud
    "your VM goes away in N seconds" signal): the worker must drain
    (checkpoint + clean exit) before the backing kill lands, so the
    resume loses zero steps instead of rolling back to the last
    periodic checkpoint.

A :class:`FaultSchedule` is pure data (steps and windows, optionally
generated from a seed); a :class:`FaultInjector` executes it statefully:
each one-shot fault fires AT MOST ONCE per injector lifetime, so a killed
run that resumes from a checkpoint *before* the kill step does not die
again at the same step (the injector object lives in the supervisor,
outside the worker attempts — exactly where a real preemption lives).
"""
from __future__ import annotations

import dataclasses
import os
import random
from typing import Optional, Sequence, Tuple


class InjectedFault(RuntimeError):
    """Base class for injected failures (so tests can catch precisely)."""


class InjectedKill(InjectedFault):
    """The worker was 'preempted' at a scheduled step."""


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A deterministic fault plan, keyed by global step.

    ``kill_at`` / ``torn_write_at`` are one-shot step sets;
    ``heartbeat_silence`` is a tuple of ``[start, end)`` step windows;
    ``slow_steps`` maps steps to injected extra seconds.
    """

    kill_at: Tuple[int, ...] = ()
    torn_write_at: Tuple[int, ...] = ()
    heartbeat_silence: Tuple[Tuple[int, int], ...] = ()
    slow_steps: Tuple[Tuple[int, float], ...] = ()
    # Preemption notices: (step, deadline_seconds). At ``step`` the
    # worker/supervisor learns the kill lands ``deadline_seconds`` later —
    # long enough to checkpoint + drain cleanly (zero lost steps), unlike
    # ``kill_at`` which lands with no warning (reactive path: roll back to
    # the last periodic checkpoint, losing at most ``ckpt_every`` steps).
    notice_at: Tuple[Tuple[int, float], ...] = ()

    @classmethod
    def generate(
        cls,
        seed: int,
        total_steps: int,
        n_kills: int = 1,
        n_torn: int = 0,
        n_slow: int = 0,
        slow_seconds: float = 1.0,
        min_step: int = 1,
        n_notices: int = 0,
        notice_deadline_s: float = 5.0,
    ) -> "FaultSchedule":
        """A seeded random schedule over ``[min_step, total_steps)`` —
        same seed, same faults, on every machine."""
        rng = random.Random(seed)
        span = range(min_step, max(min_step + 1, total_steps))
        pick = lambda n: tuple(sorted(rng.sample(span, min(n, len(span)))))
        return cls(
            kill_at=pick(n_kills),
            torn_write_at=pick(n_torn),
            slow_steps=tuple((s, slow_seconds) for s in pick(n_slow)),
            notice_at=tuple((s, notice_deadline_s) for s in pick(n_notices)),
        )


class FaultInjector:
    """Stateful executor of a :class:`FaultSchedule`.

    Lives in the SUPERVISOR (one per run, shared across worker attempts):
    one-shot faults are remembered in ``fired`` so a resumed attempt does
    not replay them. The hooks are called from ``TrainLoop.run``:

      * :meth:`maybe_kill` — raise :class:`InjectedKill` at a kill step;
      * :meth:`heartbeat_silent` — suppress the heartbeat this step;
      * :meth:`slow_delay` — extra seconds to add to the observed step
        time (added to the measured dt, not slept — keeps tests fast
        while exercising the detector on the true code path);
      * :meth:`after_save` — corrupt the just-written checkpoint (flip
        bytes in one array file, seeded choice) to simulate a torn write.
    """

    def __init__(self, schedule: FaultSchedule, seed: int = 0):
        self.schedule = schedule
        self.seed = seed
        self.fired = set()
        self.kills = 0
        self.torn = 0
        self.notices = 0

    def _once(self, kind: str, step: int) -> bool:
        key = (kind, int(step))
        if key in self.fired:
            return False
        self.fired.add(key)
        return True

    def maybe_kill(self, step: int) -> None:
        if step in self.schedule.kill_at and self._once("kill", step):
            self.kills += 1
            raise InjectedKill(f"injected preemption at step {step}")

    def due_kill(self, step: int) -> bool:
        """Non-raising variant for the PROCESS supervisor, which observes
        worker progress through the heartbeat file and may skip step
        values: any not-yet-fired kill scheduled at or before ``step`` is
        due. The supervisor delivers it as a real ``SIGKILL``."""
        for s in self.schedule.kill_at:
            if s <= step and self._once("kill", s):
                self.kills += 1
                return True
        return False

    def due_notice(self, step: int) -> Optional[float]:
        """The deadline (seconds from now) of a preemption notice due at
        or before ``step``, one-shot — or None. In-process, ``TrainLoop``
        drains on it immediately; the process supervisor writes the
        notice file and schedules the backing SIGKILL at the deadline."""
        for s, deadline in self.schedule.notice_at:
            if s <= step and self._once("notice", s):
                self.notices += 1
                return float(deadline)
        return None

    def heartbeat_silent(self, step: int) -> bool:
        return any(a <= step < b for a, b in self.schedule.heartbeat_silence)

    def slow_delay(self, step: int) -> float:
        for s, extra in self.schedule.slow_steps:
            if s == step:
                return float(extra)
        return 0.0

    def after_save(self, ckpt_dir: Optional[str], step: int) -> None:
        """Tear the checkpoint just saved at ``step`` (if scheduled):
        truncate-and-garble one of its array files in place. The manifest
        stays intact — exactly the corruption crc32 exists to catch."""
        if ckpt_dir is None or step not in self.schedule.torn_write_at:
            return
        if not self._once("torn", step):
            return
        cdir = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
        victims = sorted(
            f for f in os.listdir(cdir) if f.endswith(".npy")
        )
        if not victims:
            return
        rng = random.Random(self.seed * 1_000_003 + step)
        victim = os.path.join(cdir, rng.choice(victims))
        size = os.path.getsize(victim)
        with open(victim, "r+b") as f:
            # Garble the payload (keep the npy header readable so both
            # the unreadable-file AND checksum-mismatch paths get
            # exercised across seeds), then truncate the tail.
            f.seek(size // 2)
            f.write(bytes(rng.randrange(256) for _ in range(min(64, size // 4 or 1))))
            f.truncate(max(size // 2 + 64, size * 3 // 4))
        self.torn += 1
