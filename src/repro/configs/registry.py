"""Architecture registry: --arch <id> resolution for launch/ and tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

_MODULES: Dict[str, str] = {
    "grok-1-314b": "repro.configs.grok_1_314b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "glm4-9b": "repro.configs.glm4_9b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1p1b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "internlm2-1.8b": "repro.configs.internlm2_1p8b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "whisper-medium": "repro.configs.whisper_medium",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "llama-1b": "repro.configs.llama_1b",
}

ASSIGNED: List[str] = [k for k in _MODULES if k != "llama-1b"]


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(name: str):
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke(name: str):
    return importlib.import_module(_MODULES[name]).SMOKE
