"""Mamba2-2.7B [arXiv:2405.21060; unverified]: 64L d2560 attention-free SSD,
d_state 128, head_dim 64, expand 2, vocab 50280."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab_size=50280, ssm_state=128, ssm_head_dim=64,
    ssm_expand=2, ssm_conv=4, ssm_chunk=256,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, vocab_size=256, ssm_state=16,
    ssm_head_dim=16, ssm_chunk=8, remat=False,
)
