"""Assigned architecture configs (exact public numbers) + smoke variants."""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, input_specs, supports_shape
from repro.configs.registry import get_config, get_smoke, list_archs

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "input_specs",
           "supports_shape", "get_config", "get_smoke", "list_archs"]
