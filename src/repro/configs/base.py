"""Architecture + shape schema for the assigned configs.

Every architecture file in this package exports ``CONFIG`` (exact public
numbers) and ``SMOKE`` (a reduced same-family config for CPU tests). The
four assigned input shapes are global; ``input_specs`` builds the
ShapeDtypeStruct stand-ins the dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    # attention features
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    logit_softcap: Optional[float] = None
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl
    qkv_bias: bool = False
    # MLA (minicpm3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    attn_every: int = 0  # hybrid: shared attn block after every k ssm layers
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # e.g. 1500 mel frames
    # frontend stubs (audio/vlm): inputs are precomputed embeddings
    embed_inputs: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16
    remat: bool = True
    remat_policy: str = "dots_no_batch"
    attn_impl: str = "naive"  # "chunked"/"flash" (see §Perf)
    bf16_elementwise: bool = False  # pure-bf16 norms/activations (§Perf)
    mla_absorbed_decode: bool = False  # latent-space MLA decode (§Perf)
    moe_impl: str = "auto"  # "local_ep" = shard_map local dispatch (§Perf)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS roofline)."""
        d, f, l, v = self.d_model, self.d_ff, self.n_layers, self.vocab_size
        hd = self.resolved_head_dim
        if self.mla:
            attn = (
                self.d_model * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                + self.d_model * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
        else:
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.n_experts:
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
        elif self.family in ("ssm",):
            ffn = 0
        else:
            ffn = 3 * d * f
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            d_inner = self.ssm_expand * d
            nh = d_inner // self.ssm_head_dim
            ssm = (
                d * (2 * d_inner + 2 * self.ssm_groups * self.ssm_state + nh)
                + d_inner * d
            )
        if self.family == "ssm":
            per_layer = ssm
        elif self.family == "hybrid":
            per_layer = ssm
        else:
            per_layer = attn + ffn
        total = l * per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.family == "hybrid" and self.attn_every:
            total += attn + 3 * d * f  # one shared transformer block
        if self.encoder_layers:
            total += self.encoder_layers * (2 * attn + 2 * d * f)
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.n_params()
        d, f, l = self.d_model, self.d_ff, self.n_layers
        inactive = l * (self.n_experts - self.top_k) * 3 * d * f
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supports_shape(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment skip rules (documented in DESIGN.md §7)."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, "O(1)-state decode"
        if cfg.sliding_window and cfg.sliding_window < shape.seq_len:
            return True, "SWA rolling cache (sub-quadratic)"
        return False, (
            "pure full attention: 524k dense KV decode is quadratic-history; "
            "assignment says skip"
        )
    if cfg.encoder_layers and shape.name == "prefill_32k":
        return True, "decoder prefill vs encoder stub"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeConfig, batch_override=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    No device allocation — this is what dryrun.py lowers against, and what
    smoke tests materialize (at reduced sizes) with jnp.zeros.
    """
    b = batch_override or shape.global_batch
    t = shape.seq_len
    specs = {}
    if shape.kind in ("train", "prefill"):
        if cfg.embed_inputs:
            specs["embeds"] = jax.ShapeDtypeStruct((b, t, cfg.d_model), cfg.dtype)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
        if cfg.mrope_sections:
            specs["positions"] = jax.ShapeDtypeStruct((3, b, t), jnp.int32)
        else:
            specs["positions"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    else:  # decode: one new token against a seq_len-deep cache
        if cfg.embed_inputs:
            specs["embeds"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.dtype)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        if cfg.mrope_sections:
            specs["positions"] = jax.ShapeDtypeStruct((3, b, 1), jnp.int32)
        else:
            specs["positions"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    if cfg.encoder_layers:
        enc_t = cfg.encoder_seq or 1500
        specs["enc_embeds"] = jax.ShapeDtypeStruct((b, enc_t, cfg.d_model), cfg.dtype)
    return specs
