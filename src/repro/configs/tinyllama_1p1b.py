"""TinyLlama-1.1B [arXiv:2401.02385; hf]: 22L d2048 32H GQA(kv=4)
d_ff 5632, vocab 32000 (llama2 arch, small)."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=5632, vocab_size=32000, head_dim=64,
    rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, remat=False,
)
