"""LLaMA-1B — the paper's own Table-5 pretraining model (GaLore recipe:
24L d2048 32H MHA d_ff 5461, vocab 32000; rank 512, T_u 40, λ 5)."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-1b", family="dense", n_layers=24, d_model=2048, n_heads=32,
    n_kv_heads=32, d_ff=5461, vocab_size=32000, head_dim=64,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16, remat=False,
)
