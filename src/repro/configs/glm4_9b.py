"""GLM4-9B [hf:THUDM/glm-4-9b]: 40L d4096 32H GQA(kv=2) d_ff 13696,
vocab 151552, RoPE."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096, n_heads=32,
    n_kv_heads=2, d_ff=13696, vocab_size=151552, head_dim=128, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, remat=False,
)
