"""Whisper-medium [arXiv:2212.04356; unverified]: enc-dec, 24+24L d1024 16H
d_ff 4096, vocab 51865. Conv mel frontend is a STUB — input_specs provides
precomputed frame embeddings (B, 1500, d). Decoder uses RoPE in this impl
(orig uses learned positions; mechanical simplification, DESIGN.md §8)."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=51865, head_dim=64,
    encoder_layers=24, encoder_seq=1500,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16, encoder_layers=2, encoder_seq=16, remat=False,
)
