"""grok-1 314B MoE [hf:xai-org/grok-1; unverified]: 64L d6144 48H GQA(kv=8)
d_ff 32768, 8 experts top-2, vocab 131072. Attn logit softcap 30 per the
public config."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=32768, vocab_size=131072, head_dim=128, n_experts=8,
    top_k=2, logit_softcap=30.0, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, n_experts=4, capacity_factor=4.0, remat=False,
)
