"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: 62L d2560 40H d_ff 6400
vocab 73448 with Multi-head Latent Attention (q_lora 768, kv_lora 256,
qk_nope 64, qk_rope 32, v_head 64). MLA compresses the *weights/cache*;
COAP compresses the *optimizer* — orthogonal (DESIGN.md §7)."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense", n_layers=62, d_model=2560, n_heads=40,
    n_kv_heads=40, d_ff=6400, vocab_size=73448, mla=True, q_lora_rank=768,
    kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8,
    qk_rope_dim=8, v_head_dim=8, remat=False,
)
