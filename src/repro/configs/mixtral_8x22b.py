"""Mixtral-8x22B [arXiv:2401.04088; hf]: 56L d6144 48H GQA(kv=8) d_ff 16384,
8 experts top-2, sliding-window attention (4096), vocab 32768."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144, n_heads=48,
    n_kv_heads=8, d_ff=16384, vocab_size=32768, head_dim=128, n_experts=8,
    top_k=2, sliding_window=4096, rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, n_experts=4, capacity_factor=4.0, sliding_window=8, remat=False,
)
