"""Qwen2-VL-72B [arXiv:2409.12191; hf]: 80L d8192 64H GQA(kv=8) d_ff 29568,
vocab 152064, M-RoPE (sections 16/24/24 over head_dim 128), qkv bias.
Vision frontend is a STUB — input_specs provides precomputed patch
embeddings plus (3, B, T) multimodal position ids."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm", n_layers=80, d_model=8192, n_heads=64,
    n_kv_heads=8, d_ff=29568, vocab_size=152064, head_dim=128,
    mrope_sections=(16, 24, 24), qkv_bias=True, rope_theta=1e6,
    embed_inputs=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, mrope_sections=(2, 3, 3), remat=False,
)
