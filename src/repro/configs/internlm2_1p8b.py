"""InternLM2-1.8B [arXiv:2403.17297; hf]: 24L d2048 16H GQA(kv=8)
d_ff 8192, vocab 92544."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b", family="dense", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92544, head_dim=128,
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, remat=False,
)
