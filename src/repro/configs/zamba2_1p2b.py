"""Zamba2-1.2B [arXiv:2411.15242]: 38 Mamba2 layers (d2048, ssm_state 64)
with ONE shared attention+MLP block applied every 6 layers (6 applications),
GQA kv=32, d_ff 8192 in the shared block, vocab 32000."""
import dataclasses
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, attn_every=6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16, ssm_state=16, ssm_head_dim=16, attn_every=2,
    ssm_chunk=8, remat=False,
)
