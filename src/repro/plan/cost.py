"""Per-step cost model for the planner — calibrated, roofline-shaped.

A bucket's per-step cost is ``max(bytes/HBM_BW, flops/PEAK_FLOPS)`` (the
``launch/roofline`` terms and hardware constants), summed over buckets:

  * hot path — gradient in + update out, optimizer state read+written at
    its STORED width (int8 states stream 1/4 the fp32 bytes + sidecar),
    P read; with per-leaf (non-stacked) storage the state traffic is
    multiplied by the measured stack/scatter copy factor from
    ``BENCH_state.json`` (analytic 6S vs 2S = 3x);
  * refresh — amortized by the schedule: Eqn-6 at rate ``1/T_u − 1/(λT_u)``
    streams G once per SGD step when the fused kernel fits VMEM
    (``kernels.eqn6.plan_bm`` — the kernel's OWN trace-time guard, so the
    planner predicts exactly what the dispatch will decide), and the
    measured unfused multiplier from ``BENCH_refresh.json`` (11 G-sized
    streams) when it does not; Eqn-7 recalibration at ``1/(λT_u)`` streams
    G twice (``BENCH_refresh`` / ``BENCH_conv`` accounting: two sweeps per
    mode for conv).

Calibration ratios are read from the ``BENCH_*.json`` files at the repo
root when present and fall back to their shipped values otherwise — the
plan artifact records which sources were live.

The roofline constants themselves are calibratable too: a measured
``coap-calib/v1`` artifact (``obs/calib.py`` fits HBM bandwidth and peak
FLOPS from recorded per-step span durations) overrides the analytic
``launch/roofline`` constants when present — explicit path, then the
``REPRO_COAP_CALIB`` environment variable, then
``artifacts/calib/coap-calib.json`` at the repo root. Without an
artifact the analytic constants apply and plans are bit-identical to the
uncalibrated solve.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Optional, Tuple

from repro.core.projector import KIND_CONV, KIND_PROJECT, ProjSpec
from repro.kernels import eqn6 as eqn6_mod
from repro.launch.roofline import HBM_BW, PEAK_FLOPS
from repro.plan import bytes as pbytes

_BENCH_DEFAULTS = {
    # BENCH_refresh.json: eqn6_g_stream_ratio_min — G-sized streams of the
    # unfused Eqn-6 chain per fused-kernel stream.
    "eqn6_unfused_g_streams": 11.0,
    # BENCH_state.json: analytic per-leaf/stacked state-traffic ratio
    # (6S stack+kernel+scatter vs 2S in-place).
    "state_copy_factor": 3.0,
    # BENCH_overhead.json: fused q8 bytes win over the 8-dispatch schedule
    # (conservative, incl. P re-stream) — the penalty an unfused q8 path
    # would pay.
    "q8_unfused_ratio": 1.75,
    # BENCH_conv.json: per-step launches per conv leaf vs per bucket
    # (recorded for the report; launch overhead itself is not modeled).
    "conv_launch_ratio": 9.0,
    # BENCH_elastic.json: cold resume-latency split (restore / migrate /
    # recompile seconds) and the bucket count of the measured scenario —
    # what a replanned attempt pays per CHANGED bucket before its first
    # step. Feeds the solver's resume-latency-aware mode
    # (``solve(prev_plan=..., resume_horizon_steps=...)``).
    "resume_restore_s": 0.0971,
    "resume_migrate_s": 1.8712,
    "resume_recompile_s": 16.3881,
    "resume_n_buckets": 8.0,
    # coap-calib/v1 (artifacts/calib/coap-calib.json, built by
    # obs/calib.py from recorded step spans): fitted roofline constants —
    # the planner ranks candidates by MEASURED seconds when these are
    # live, analytic chip constants otherwise.
    "hbm_bw": HBM_BW,
    "peak_flops": PEAK_FLOPS,
}

# Versioned schema of the measured-calibration artifact (obs/calib.py
# writes it, Calibration.load consumes it).
CALIB_CODEC = "coap-calib/v1"


@dataclasses.dataclass(frozen=True)
class Calibration:
    eqn6_unfused_g_streams: float = _BENCH_DEFAULTS["eqn6_unfused_g_streams"]
    state_copy_factor: float = _BENCH_DEFAULTS["state_copy_factor"]
    q8_unfused_ratio: float = _BENCH_DEFAULTS["q8_unfused_ratio"]
    conv_launch_ratio: float = _BENCH_DEFAULTS["conv_launch_ratio"]
    resume_restore_s: float = _BENCH_DEFAULTS["resume_restore_s"]
    resume_migrate_s: float = _BENCH_DEFAULTS["resume_migrate_s"]
    resume_recompile_s: float = _BENCH_DEFAULTS["resume_recompile_s"]
    resume_n_buckets: float = _BENCH_DEFAULTS["resume_n_buckets"]
    hbm_bw: float = _BENCH_DEFAULTS["hbm_bw"]
    peak_flops: float = _BENCH_DEFAULTS["peak_flops"]
    sources: Tuple[Tuple[str, str], ...] = ()  # (ratio, file) actually loaded

    def resume_penalty_s_per_bucket(self) -> float:
        """Seconds of resume latency attributable to ONE bucket whose
        layout changed: its share of migrate + recompile (restore is paid
        regardless of plan churn, so it is excluded)."""
        return (self.resume_migrate_s + self.resume_recompile_s) / max(
            1.0, self.resume_n_buckets
        )

    @classmethod
    def load(
        cls,
        root: Optional[str] = None,
        calib_path: Optional[str] = None,
    ) -> "Calibration":
        if root is None:
            root = os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.dirname(os.path.abspath(__file__)))))
        vals = dict(_BENCH_DEFAULTS)
        sources = []

        def pull(fname, extract):
            path = os.path.join(root, fname)
            try:
                with open(path) as f:
                    data = json.load(f)
                for key, value in extract(data).items():
                    if value and value > 0:
                        vals[key] = float(value)
                        sources.append((key, fname))
            except (OSError, ValueError, KeyError, TypeError,
                    AttributeError, ZeroDivisionError):
                pass  # malformed/partial bench file -> shipped default

        pull("BENCH_refresh.json", lambda d: {
            "eqn6_unfused_g_streams": d.get("eqn6_g_stream_ratio_min")})
        pull("BENCH_state.json", lambda d: {
            "state_copy_factor":
                d.get("analytic", {}).get("int8", {}).get("ratio")})
        pull("BENCH_overhead.json", lambda d: {
            "q8_unfused_ratio": d.get("ratio_min_conservative")})
        pull("BENCH_conv.json", lambda d: {
            "conv_launch_ratio": (
                d.get("conv_refresh", {}).get("launches_per_step_per_leaf", 0)
                / max(1, d.get("conv_refresh", {})
                      .get("launches_per_step_bucketed", 1)))})
        pull("BENCH_elastic.json", lambda d: {
            "resume_restore_s": d.get("restore_s"),
            "resume_migrate_s": d.get("migrate_s"),
            "resume_recompile_s": d.get("recompile_s"),
            "resume_n_buckets": d.get("scenario", {}).get("n_buckets")})

        # Measured roofline constants (coap-calib/v1): the solver ranks
        # candidates by FITTED seconds when an artifact is present;
        # absent / malformed / version-mismatched artifacts leave the
        # analytic constants — and every existing plan — bit-identical.
        cpath = (
            calib_path
            or os.environ.get("REPRO_COAP_CALIB")
            or os.path.join(root, "artifacts", "calib", "coap-calib.json")
        )
        try:
            with open(cpath) as f:
                data = json.load(f)
            if isinstance(data, dict) and data.get("codec") == CALIB_CODEC:
                for key in ("hbm_bw", "peak_flops"):
                    v = float(data.get(key) or 0.0)
                    if v > 0:
                        vals[key] = v
                        sources.append((key, os.path.basename(cpath)))
        except (OSError, ValueError, TypeError):
            pass  # no/unreadable artifact -> analytic constants
        return cls(sources=tuple(sources), **vals)


def eqn6_fused_ok(m: int, n: int, r: int, g_itemsize: int = 4,
                  vmem_budget: Optional[int] = None) -> bool:
    """Will the fused Eqn-6 kernel fit VMEM at this (m, n, r)? Asks the
    kernel's own trace-time planner, so plan-time prediction and dispatch
    behavior cannot drift."""
    return eqn6_mod.plan_bm(
        m, n, r, g_itemsize=g_itemsize, budget=vmem_budget
    ) is not None


def _roofline_seconds(
    bytes_: float,
    flops: float,
    hbm_bw: float = HBM_BW,
    peak_flops: float = PEAK_FLOPS,
) -> float:
    return max(bytes_ / hbm_bw, flops / peak_flops)


def bucket_step_cost(
    kind: str,
    shape,
    spec: ProjSpec,
    count: int,
    *,
    quantize: bool,
    t_update: int,
    lam: int,
    eqn6_steps: int = 1,
    stacked_state: bool = True,
    state_itemsize: int = 4,
    grad_itemsize: int = 4,
    calib: Calibration,
    vmem_budget: Optional[int] = None,
) -> Dict[str, float]:
    """Predicted amortized per-step cost of one bucket (``count`` leaves).

    Returns ``{seconds, bytes_per_step, flops_per_step, eqn6_fused}``
    plus the hot/event split (``hot_bytes``, ``hot_flops``,
    ``eqn6_event_bytes``, ``eqn6_event_flops``, ``recal_event_bytes``,
    ``recal_event_flops`` — per-EVENT totals across the bucket, what
    ``obs/calib.py`` attributes to individual refresh-group spans when
    fitting the roofline constants from a trace). ``eqn6_fused`` is None
    for buckets with no Eqn-6 refresh (dense, or non-coap paths).
    ``seconds`` uses ``calib.hbm_bw``/``calib.peak_flops`` — the fitted
    constants when a coap-calib/v1 artifact is live.
    """
    state = pbytes.leaf_state_bytes(shape, spec, quantize, state_itemsize)
    state_total = sum(state.values())
    moments = state_total - state.get(pbytes.CAT_PROJECTION, 0)
    numel = pbytes._numel(shape)
    g_bytes = numel * grad_itemsize

    copy_f = 1.0 if stacked_state else calib.state_copy_factor
    # hot path: G in + update out + moments read/written at stored width
    # (+ sidecar) + P read.
    hot_bytes = 2.0 * g_bytes + copy_f * (
        2.0 * moments + state.get(pbytes.CAT_PROJECTION, 0)
    )
    eqn6_fused = None
    if kind == KIND_PROJECT:
        lead, m, n = pbytes._canonical_mn(shape, spec)
        r = int(spec.rank)
        hot_flops = 4.0 * lead * m * n * r + 8.0 * lead * m * r
        eqn6_fused = eqn6_fused_ok(m, n, r, grad_itemsize, vmem_budget)
        g_mult = 1.0 if eqn6_fused else calib.eqn6_unfused_g_streams
        eqn6_bytes = g_bytes * eqn6_steps * g_mult
        eqn6_flops = 6.0 * lead * m * n * r * eqn6_steps
        recal_bytes = 2.0 * g_bytes
        recal_flops = 2.0 * lead * m * n * r + 4.0 * lead * m * r * r
    elif kind == KIND_CONV:
        o, i = int(shape[0]), int(shape[1])
        k = pbytes._numel(shape[2:])
        ro, ri = int(spec.rank_o), int(spec.rank_i)
        # project_core + restore_core: two einsum pairs over the core chain.
        pair = 2.0 * o * i * k * ri + 2.0 * o * ri * k * ro
        hot_flops = 2.0 * pair + 8.0 * ro * ri * k
        fused1 = eqn6_fused_ok(i * k, o, ro, grad_itemsize, vmem_budget)
        fused2 = eqn6_fused_ok(o * k, i, ri, grad_itemsize, vmem_budget)
        eqn6_fused = fused1 and fused2
        g_mult = 1.0 if eqn6_fused else calib.eqn6_unfused_g_streams
        # one canonical-unfolding sweep per mode (BENCH_conv accounting)
        eqn6_bytes = 2.0 * g_bytes * eqn6_steps * g_mult
        eqn6_flops = (2.0 * i * k * o * ro + 2.0 * o * k * i * ri) * eqn6_steps
        recal_bytes = 4.0 * g_bytes  # two sweeps per mode
        recal_flops = 2.0 * eqn6_flops
    else:  # dense Adam
        hot_flops = 8.0 * numel
        eqn6_bytes = eqn6_flops = recal_bytes = recal_flops = 0.0

    t_u = max(1, int(t_update))
    lam_tu = max(1, int(lam)) * t_u
    eqn6_rate = max(0.0, 1.0 / t_u - 1.0 / lam_tu)
    recal_rate = 1.0 / lam_tu
    bytes_step = hot_bytes + eqn6_rate * eqn6_bytes + recal_rate * recal_bytes
    flops_step = hot_flops + eqn6_rate * eqn6_flops + recal_rate * recal_flops
    bytes_step *= count
    flops_step *= count
    return {
        "seconds": _roofline_seconds(
            bytes_step, flops_step, calib.hbm_bw, calib.peak_flops
        ),
        "bytes_per_step": bytes_step,
        "flops_per_step": flops_step,
        "eqn6_fused": eqn6_fused,
        "hot_bytes": hot_bytes * count,
        "hot_flops": hot_flops * count,
        "eqn6_event_bytes": eqn6_bytes * count,
        "eqn6_event_flops": eqn6_flops * count,
        "recal_event_bytes": recal_bytes * count,
        "recal_event_flops": recal_flops * count,
    }
