"""Budget-driven memory planner (``coap-plan/v1``).

The planner closes the loop the paper leaves to the user: instead of
hand-picking rank / ``T_u`` / ``quantize`` per config (the GaLore failure
mode — pay for a too-high rank in SVD cost or a too-low one in quality),
``repro.plan`` takes an architecture plus an HBM budget and emits a
versioned plan artifact assigning per-bucket knobs, chosen by a solver that
minimizes predicted step cost subject to the budget.

The subsystem has four layers:

  * :mod:`repro.plan.bytes` — the EXACT optimizer-state byte model, built
    directly on ``stacked_state.build_layout`` and the storage-codec rules
    of ``core/coap_adam`` so predictions match
    ``accounting.abstract_state_bytes`` byte-for-byte by construction;
  * :mod:`repro.plan.cost` — the per-step cost model, calibrated from the
    measured ``BENCH_overhead/refresh/state/conv`` ratios and the
    ``launch/roofline`` hardware terms; it also predicts per-bucket fused
    Eqn-6 feasibility via the kernel's own ``plan_bm`` VMEM guard;
  * :mod:`repro.plan.solver` — rank floor (the paper's compression ratio
    ``c``), candidate enumeration, and the greedy per-bucket quantize
    knapsack that engages int8 storage only when fp32 cannot fit;
  * :mod:`repro.plan.artifact` / :mod:`repro.plan.apply` /
    :mod:`repro.plan.validate` — the ``coap-plan/v1`` JSON codec (unknown
    versions fail loudly), consumption into the optimizer
    (``OptimizerConfig.plan`` -> ``PlannedRules`` + per-bucket
    ``PlanOverrides``), and the exactness cross-check against the real
    constructed optimizer.

Entry points: ``python -m repro.launch.plan --arch llama-1b --budget 40GB``
(also ``make plan``), ``launch/dryrun.py --plan``, and
:func:`plan_for_arch` / :func:`repro.plan.solver.solve` from code.
"""
from __future__ import annotations

from repro.plan.artifact import (  # noqa: F401
    PLAN_CODEC,
    BucketPlan,
    Plan,
    PlanVersionError,
    load_plan,
    save_plan,
)
from repro.plan.solver import (  # noqa: F401
    PlanInfeasibleError,
    solve,
    solve_for_topology,
)
from repro.plan.validate import PlanMismatchError, verify  # noqa: F401


def plan_for_arch(arch: str, budget_bytes: int, **kw):
    """Plan a registry architecture: builds the abstract param tree (no
    allocation) and solves under the budget. Returns a :class:`Plan`."""
    from repro.configs import get_config
    from repro.models.model import build_model

    cfg = get_config(arch)
    params = build_model(cfg).abstract_params()
    kw.setdefault("big_model", cfg.n_params() > 3e9)
    return solve(params, budget_bytes, arch=arch, **kw)
