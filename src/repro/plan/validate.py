"""Exactness cross-check: planner-predicted bytes vs the real optimizer.

``verify(plan, params)`` constructs the optimizer the plan configures
(through ``core.api.make_optimizer`` — the same path training uses), runs
``accounting.abstract_state_bytes`` over it (eval_shape: no allocation,
works at grok-314B scale), and compares against the plan's predicted
by-category bytes. The match must be EXACT — a single byte of drift means
the byte model and the storage codec have diverged and the plan's budget
math can no longer be trusted.

Also surfaces the fused-Eqn-6 feasibility telemetry: the per-bucket
fallback prediction recorded in the plan, and (when the caller traced a
step) the live fallback counters from ``kernels.ops``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.plan.artifact import Plan


class PlanMismatchError(AssertionError):
    """Predicted bytes do not match the constructed optimizer's state."""


def optimizer_config(plan: Plan, learning_rate: float = 1e-3, **kw):
    """The ``OptimizerConfig`` that consumes this plan (float lr by
    default — a schedule adds one count scalar the plan does not model)."""
    from repro.core.api import OptimizerConfig

    return OptimizerConfig(
        name=plan.optimizer, learning_rate=learning_rate, plan=plan, **kw
    )


def verify(
    plan: Plan,
    params: Any,
    learning_rate: float = 1e-3,
    raise_on_mismatch: bool = True,
) -> Dict[str, Any]:
    """Build the planned optimizer and check predicted == accounted bytes.

    ``params`` may be concrete arrays or ShapeDtypeStructs. Returns a
    report dict; raises :class:`PlanMismatchError` on any byte drift unless
    ``raise_on_mismatch=False``.
    """
    from repro.core.accounting import abstract_state_bytes
    from repro.core.api import make_optimizer

    shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), params
    )
    tx = make_optimizer(optimizer_config(plan, learning_rate))
    rep = abstract_state_bytes(tx, shapes)

    want = dict(plan.predicted["by_category"])
    if callable(learning_rate):  # schedule: one extra count scalar
        want["other"] = want.get("other", 0) + 4
    got = {k: int(v) for k, v in rep.by_category.items()}
    match = got == want and rep.total_bytes == sum(want.values())

    report = {
        "match": match,
        "predicted_by_category": want,
        "accounted_by_category": got,
        "predicted_total": sum(want.values()),
        "accounted_total": int(rep.total_bytes),
        "eqn6_fallback_buckets": [
            {"shape": list(b.shape), "rank": b.spec.rank, "count": b.count}
            for b in plan.buckets
            if b.eqn6_fused is False
        ],
    }
    if not match and raise_on_mismatch:
        diffs = {
            k: (want.get(k, 0), got.get(k, 0))
            for k in sorted(set(want) | set(got))
            if want.get(k, 0) != got.get(k, 0)
        }
        raise PlanMismatchError(
            "planner-predicted bytes do not match "
            "accounting.abstract_state_bytes of the constructed optimizer: "
            f"per-category (predicted, accounted) diffs = {diffs}"
        )
    return report


def live_eqn6_fallbacks() -> Dict[str, int]:
    """The per-shape fused-Eqn-6 fallback counters accumulated since the
    last reset (``kernels.ops`` telemetry) — keyed '(m, n, r)' for JSON."""
    from repro.kernels import ops as kops

    return {
        f"({m}, {n}, {r})": c
        for (m, n, r), c in sorted(kops.eqn6_fallback_counts().items())
    }
