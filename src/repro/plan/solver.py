"""The budget solver: per-bucket knobs under an HBM budget.

Budget semantics: the budget covers the resident TRAINING STATE — params +
gradients (fixed terms, parameter dtype) + optimizer state (the planner's
controlled term). Activation working set is out of scope (it is a
batch/remat decision, not an optimizer-state one).

Knob selection:

  * **rank** — quality-floored cost minimization. The paper's matched-PPL
    recipe is a compression ratio ``c`` (rank = min(m, n)/c; Tucker-2
    splits √c per mode), so ranks below ``min(m,n)/c`` are inadmissible;
    among admissible candidates (the floor and power-of-two steps above
    it) the solver keeps the predicted-cheapest, which under the roofline
    model is the floor — higher ranks only buy quality the floor already
    guarantees. Leaves the base policy excludes (embeddings, norms,
    sub-``min_dim``) stay dense.
  * **quantize** — quality-lexicographic: fp32 states are preferred
    whenever they fit the budget (int8 is quality-neutral per the paper
    but not free); when fp32 does not fit, buckets flip to the int8 codec
    GREEDILY by bytes saved until the plan fits — so intermediate budgets
    yield genuinely mixed per-bucket plans. ``quantize='force'``/``'off'``
    override. Still over budget with everything int8 -> loud
    :class:`PlanInfeasibleError` (never a silently-broken plan).
  * **T_u / λ / stagger_groups** — the paper's scale recipe (T_u 40, λ 5
    up to ~3B; T_u 100, λ 1 above), ``stagger_groups`` capped at the
    bucket's leaf count; recorded per bucket (the optimizer honors
    per-bucket values — ``coap_adam.PlanOverrides``).
  * **stacked_state** — on whenever the measured stack/scatter copy factor
    (``BENCH_state.json``) says pre-stacked storage is cheaper (it always
    is; the knob exists so a calibration could turn it off).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.projector import (
    KIND_CONV,
    KIND_PROJECT,
    ProjSpec,
    ProjectionRules,
    path_str,
)
from repro.core import stacked_state
from repro.kernels import ref as kref
from repro.plan import bytes as pbytes
from repro.plan import cost as pcost
from repro.plan.artifact import (
    PLAN_CODEC_V1,
    BucketPlan,
    Plan,
    PlanGlobals,
)


class PlanInfeasibleError(ValueError):
    """No admissible knob assignment fits the budget."""


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def _rank_candidates(floor: int, cap: int) -> List[int]:
    """The quality-admissible rank ladder: the floor, then power-of-two
    steps up to (excl.) the dense cap."""
    out = [floor]
    p = _next_pow2(floor + 1)
    while p < cap:
        out.append(p)
        p *= 2
    return out


def _flatten(tree) -> Tuple[List[str], List[Tuple[int, ...]], List[str]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = [path_str(kp) for kp, _ in flat]
    shapes = [tuple(int(s) for s in leaf.shape) for _, leaf in flat]
    dtypes = [jnp.dtype(leaf.dtype).name for _, leaf in flat]
    return paths, shapes, dtypes


def solve(
    params,
    budget_bytes: Optional[int],
    *,
    arch: Optional[str] = None,
    optimizer: str = "coap-adamw",
    rank_compression: float = 4.0,
    min_dim: int = 128,
    quantize: str = "auto",  # 'auto' | 'force' | 'off'
    t_update: Optional[int] = None,
    lam: Optional[int] = None,
    stagger_groups: int = 8,
    state_dtype: str = "float32",
    quant_block: int = kref.QUANT_BLOCK,
    seed: int = 0,
    eqn6_steps: int = 1,
    eqn6_lr: float = 0.1,
    big_model: Optional[bool] = None,
    calib: Optional[pcost.Calibration] = None,
    vmem_budget: Optional[int] = None,
    prev_plan: Optional[Plan] = None,
    resume_horizon_steps: int = 0,
    sync_codes: bool = False,
    health_report=None,
) -> Plan:
    """Plan ``params`` (a concrete or abstract pytree) under
    ``budget_bytes`` (``None`` = unconstrained: keep the quality-preferred
    fp32 codec everywhere and record the resulting resident total as the
    budget). Returns a validated-schema :class:`Plan`.

    Resume-latency-aware mode (both knobs set): the elastic supervisor is
    replanning an IN-FLIGHT run that previously trained under
    ``prev_plan`` and expects to run ~``resume_horizon_steps`` more steps.
    Every bucket whose layout departs from ``prev_plan`` costs real
    wall-clock at resume (its share of the measured migrate + recompile
    split, ``BENCH_elastic.json`` via :class:`cost.Calibration`), so that
    one-time cost is amortized over the horizon and charged per step:
    rank candidates matching the previous spec win ties, and the quantize
    knapsack flips previously-int8 buckets first (their flip is free —
    the state is already in the int8 codec) before churning fp32 buckets.
    A long horizon amortizes the penalty to ~nothing (re-layout freely);
    a short one makes the solver conservative. With ``prev_plan=None`` or
    ``resume_horizon_steps=0`` the output is bit-identical to the
    history-free solve.

    Health-aware mode (``health_report``: an ``obs/health.HealthReport``
    or its ``to_dict()`` form from a prior run of THIS model): observed
    numerics adjust the per-bucket rank FLOOR before the candidate ladder
    is built. A bucket whose journal fired ``RANK_STARVED`` (captured
    energy below the floor) or ``SUBSPACE_THRASH`` (refreshes not
    converging) gets its floor tightened one power-of-two step up — the
    compression recipe was too aggressive for this tensor's spectrum. A
    verdict-free bucket whose median captured energy sits above the
    headroom threshold gets its floor relaxed one power-of-two step down
    (free memory; quality margin says the rank was overprovisioned).
    Buckets the journal never saw keep the recipe floor. Every adjustment
    is recorded in ``cost['health_adjustments']``. With
    ``health_report=None`` the output is bit-identical to the
    health-blind solve."""
    if quantize not in ("auto", "force", "off"):
        raise ValueError("quantize must be 'auto', 'force' or 'off'")
    calib = calib or pcost.Calibration.load()
    paths, shapes, dtypes = _flatten(params)
    state_itemsize = jnp.dtype(state_dtype).itemsize

    prev_spec: Dict[str, ProjSpec] = {}
    prev_q: Dict[str, bool] = {}
    resume_pen_s = 0.0  # amortized seconds/step per departing bucket
    if prev_plan is not None and resume_horizon_steps > 0:
        for b in prev_plan.buckets:
            for p in b.paths:
                prev_spec[p] = b.spec
                prev_q[p] = bool(b.quantize)
        resume_pen_s = calib.resume_penalty_s_per_bucket() / max(
            1, int(resume_horizon_steps)
        )

    n_params = sum(pbytes._numel(s) for s in shapes)
    if big_model is None:
        big_model = n_params > 3e9
    # Paper scale recipe (Table 5 / appendix): rank via c, T_u 40 λ 5 for
    # ~1B; T_u 100 λ 1 for 7B+ (same defaults launch/dryrun uses).
    t_u = int(t_update) if t_update is not None else (100 if big_model else 40)
    lam_ = int(lam) if lam is not None else (1 if big_model else 5)

    base_rules = ProjectionRules(rank_ratio=rank_compression, min_dim=min_dim)
    stacked = calib.state_copy_factor > 1.0

    # ---- rank selection per leaf (identical across congruent leaves) ----
    dtype_of = dict(zip(paths, dtypes))

    # Health feedback (see docstring): per-bucket-label floor shifts,
    # +1 = tighten one pow2 step, -1 = relax one step. Everything here is
    # gated on health_report so the health-blind solve stays bit-identical.
    floor_shift: Dict[str, int] = {}
    health_adjustments: Optional[Dict[str, Dict]] = None
    if health_report is not None:
        from repro.obs import health as _health

        rep = (
            _health.HealthReport.from_dict(health_report)
            if isinstance(health_report, dict)
            else health_report
        )
        headroom = float(
            rep.thresholds.get(
                "energy_headroom",
                _health.DEFAULT_THRESHOLDS["energy_headroom"],
            )
        )
        tighten_on = {
            _health.VERDICT_RANK_STARVED,
            _health.VERDICT_SUBSPACE_THRASH,
        }
        for label, b in rep.buckets.items():
            verdicts = set(b.get("verdicts") or [])
            if verdicts & tighten_on:
                floor_shift[label] = 1
            elif not verdicts:
                em = (b.get("metrics") or {}).get("energy_median")
                if em is not None and float(em) >= headroom:
                    floor_shift[label] = -1
        health_adjustments = {}

        def _health_label(kind: str, shape, path: str) -> str:
            return _health.bucket_label(kind, shape, dtype_of[path])

        def _record_adjust(label, action, old_spec, new_spec):
            if old_spec.kind == KIND_CONV:
                frm = {"rank_o": old_spec.rank_o, "rank_i": old_spec.rank_i}
                to = {"rank_o": new_spec.rank_o, "rank_i": new_spec.rank_i}
            else:
                frm = {"rank": old_spec.rank}
                to = {"rank": new_spec.rank}
            health_adjustments[label] = {
                "bucket": label,
                "action": action,
                "from": frm,
                "to": to,
            }

    def cost_of(kind: str, shape, spec: ProjSpec, q: bool,
                g_itemsize: int = 4) -> Dict[str, float]:
        return pcost.bucket_step_cost(
            kind, shape, spec, 1, quantize=q, t_update=t_u, lam=lam_,
            eqn6_steps=eqn6_steps, stacked_state=stacked,
            state_itemsize=state_itemsize, grad_itemsize=g_itemsize,
            calib=calib, vmem_budget=vmem_budget,
        )

    def choose_spec(path: str, shape) -> ProjSpec:
        base = base_rules.spec_for(path, shape)
        if base.kind == KIND_PROJECT:
            mn = min(shape[-2], shape[-1])
            if floor_shift:
                label = _health_label(base.kind, shape, path)
                shift = floor_shift.get(label, 0)
                if shift > 0:
                    new = base._replace(
                        rank=min(mn, _next_pow2(base.rank + 1))
                    )
                elif shift < 0:
                    new = base._replace(rank=max(1, base.rank // 2))
                else:
                    new = base
                if new.rank != base.rank:
                    _record_adjust(
                        label,
                        "tighten" if shift > 0 else "relax",
                        base, new,
                    )
                    base = new
            cands = [
                base._replace(rank=r)
                for r in _rank_candidates(base.rank, mn)
            ]
        elif base.kind == KIND_CONV:
            o, i = int(shape[0]), int(shape[1])
            if floor_shift:
                # Tighten-only for Tucker-2: the relax signal (energy
                # headroom) is a per-mode question the scalar captured
                # energy cannot attribute, so only starvation/thrash acts.
                label = _health_label(base.kind, shape, path)
                if floor_shift.get(label, 0) > 0:
                    new = base._replace(
                        rank_o=min(o, _next_pow2(base.rank_o + 1)),
                        rank_i=min(i, _next_pow2(base.rank_i + 1)),
                    )
                    if (new.rank_o, new.rank_i) != (base.rank_o,
                                                    base.rank_i):
                        _record_adjust(label, "tighten", base, new)
                        base = new
            pairs = {(base.rank_o, base.rank_i)}
            ro, ri = base.rank_o, base.rank_i
            while _next_pow2(ro + 1) < o and _next_pow2(ri + 1) < i:
                ro, ri = _next_pow2(ro + 1), _next_pow2(ri + 1)
                pairs.add((ro, ri))
            cands = [
                base._replace(rank_o=ro, rank_i=ri)
                for ro, ri in sorted(pairs)
            ]
        else:
            return base
        return min(
            cands,
            key=lambda sp: (
                cost_of(
                    base.kind, shape, sp, False,
                    jnp.dtype(dtype_of[path]).itemsize,
                )["seconds"]
                # Departing from the in-flight plan's spec costs resume
                # latency (migrate + recompile), amortized per step.
                + (resume_pen_s
                   if prev_spec.get(path, sp) != sp else 0.0)
            ),
        )

    chosen = {p: choose_spec(p, s) for p, s in zip(paths, shapes)}
    layout = stacked_state.build_layout(
        lambda p, s: chosen[p], paths, shapes, dtypes
    )
    if layout.tail:  # classify_default never tails; guard custom futures
        raise ValueError(
            "planner requires the default bucket classification "
            "(no per-leaf tail); got tail leaves "
            f"{[t.path for t in layout.tail]}"
        )

    # ---- budget: fixed terms + fp32 state, then the quantize knapsack ----
    itemsizes = [jnp.dtype(d).itemsize for d in dtypes]
    params_b, grads_b = pbytes.params_grads_bytes(shapes, itemsizes)
    fixed = params_b + grads_b

    def bucket_bytes(info, q: bool) -> Dict[str, int]:
        one = pbytes.leaf_state_bytes(
            shapes[info.indices[0]], info.spec, q, state_itemsize,
            quant_block, sync_codes,
        )
        return {k: v * len(info.indices) for k, v in one.items()}

    fp32_b = [sum(bucket_bytes(i, False).values()) for i in layout.buckets]
    q8_b = [sum(bucket_bytes(i, True).values()) for i in layout.buckets]

    quantized = [quantize == "force"] * len(layout.buckets)
    if quantize == "auto" and budget_bytes is not None:
        total = fixed + sum(fp32_b) + 4  # + step counter
        if total > budget_bytes:
            # Flip order: biggest saving first. In resume-aware mode a
            # flip that CHURNS the in-flight codec (the bucket was fp32
            # under prev_plan) additionally pays the amortized resume
            # penalty, expressed in roofline-equivalent bytes — so
            # buckets already stored int8 flip first.
            churn_b = resume_pen_s * calib.hbm_bw

            def flip_key(i: int) -> float:
                saving = q8_b[i] - fp32_b[i]
                if not churn_b:
                    return saving
                was_q8 = prev_q.get(layout.buckets[i].paths[0], False)
                return saving + (0.0 if was_q8 else churn_b)

            order = sorted(range(len(layout.buckets)), key=flip_key)
            for i in order:
                if total <= budget_bytes:
                    break
                if q8_b[i] < fp32_b[i]:
                    quantized[i] = True
                    total += q8_b[i] - fp32_b[i]
    state_total = 4 + sum(
        (q8_b[i] if q else fp32_b[i]) for i, q in enumerate(quantized)
    )
    hbm_total = fixed + state_total
    if budget_bytes is None:
        budget_bytes = hbm_total
    if hbm_total > budget_bytes:
        raise PlanInfeasibleError(
            f"budget {budget_bytes/1e9:.2f} GB cannot hold params+grads "
            f"({fixed/1e9:.2f} GB) plus the smallest admissible optimizer "
            f"state ({state_total/1e9:.2f} GB) at rank compression "
            f"c={rank_compression}; raise the budget or relax c"
        )

    # ---- assemble the artifact ----
    # THE byte roll-up: layout_state_report (also what the parity property
    # test exercises) — per-bucket tables + the by-category total incl.
    # the step counter, one definition for solver and verifier alike.
    quantize_by_path = {
        p: quantized[i]
        for i, info in enumerate(layout.buckets)
        for p in info.paths
    }
    by_cat, per_bucket = pbytes.layout_state_report(
        layout, shapes, lambda p: quantize_by_path[p], state_itemsize,
        quant_block, sync_codes,
    )
    bucket_plans: List[BucketPlan] = []
    step_seconds = 0.0
    for i, info in enumerate(layout.buckets):
        q = quantized[i]
        bb = per_bucket[i]
        # Gradients materialize in the LEAF's dtype — the fused-Eqn-6
        # feasibility check must see the same itemsize the real dispatch
        # will (bf16 streaming halves the tile footprint), or the plan's
        # FALLBACK column drifts from the live kernel decision.
        c = pcost.bucket_step_cost(
            info.kind, shapes[info.indices[0]], info.spec, len(info.indices),
            quantize=q, t_update=t_u, lam=lam_, eqn6_steps=eqn6_steps,
            stacked_state=stacked, state_itemsize=state_itemsize,
            grad_itemsize=jnp.dtype(info.dtype).itemsize,
            calib=calib, vmem_budget=vmem_budget,
        )
        step_seconds += c["seconds"]
        base_b = 2 * pbytes._numel(shapes[info.indices[0]]) * 4 * len(
            info.indices
        )
        bucket_plans.append(
            BucketPlan(
                kind=info.kind,
                shape=info.shape,
                dtype=info.dtype,
                paths=info.paths,
                spec=info.spec,
                quantize=q,
                t_update=t_u,
                stagger_groups=min(stagger_groups, len(info.indices)),
                predicted_bytes=bb,
                baseline_adamw_bytes=base_b,
                predicted_step_cost_s=c["seconds"],
                eqn6_fused=c["eqn6_fused"],
            )
        )

    baseline = pbytes.adamw_baseline_report(shapes, 4)
    base_total = sum(baseline.values())
    state_sum = sum(by_cat.values())
    groups = _grouped(by_cat)
    bgroups = _grouped(baseline)
    # Paper denominator: moment state (+ int8 sidecar) — P excluded from
    # BOTH sides (the paper's 'Optimizer Mem.' counts moments).
    red_moments = 1.0 - (
        (groups["moment_state"] + groups["quant_sidecar"])
        / max(1, bgroups["moment_state"])
    )
    red_total = 1.0 - state_sum / max(1, base_total)

    predicted = {
        "by_category": {k: int(v) for k, v in sorted(by_cat.items())},
        "state_bytes_total": int(state_sum),
        "baseline": {
            "by_category": {k: int(v) for k, v in sorted(baseline.items())},
            "state_bytes_total": int(base_total),
        },
        "reduction_vs_adamw": red_moments,
        "reduction_vs_adamw_total": red_total,
        "params_bytes": int(params_b),
        "grads_bytes": int(grads_b),
        "hbm_total_bytes": int(fixed + state_sum),
        "n_quantized_buckets": int(sum(quantized)),
    }
    cost = {
        "step_seconds": step_seconds,
        "calibration": {
            "eqn6_unfused_g_streams": calib.eqn6_unfused_g_streams,
            "state_copy_factor": calib.state_copy_factor,
            "q8_unfused_ratio": calib.q8_unfused_ratio,
            "conv_launch_ratio": calib.conv_launch_ratio,
            "resume_restore_s": calib.resume_restore_s,
            "resume_migrate_s": calib.resume_migrate_s,
            "resume_recompile_s": calib.resume_recompile_s,
            "resume_n_buckets": calib.resume_n_buckets,
            "hbm_bw": calib.hbm_bw,
            "peak_flops": calib.peak_flops,
        },
        "calibration_sources": [list(s) for s in calib.sources],
    }
    if resume_pen_s > 0.0:
        cost["resume_aware"] = {
            "resume_horizon_steps": int(resume_horizon_steps),
            "penalty_s_per_step_per_bucket": resume_pen_s,
        }
    if health_adjustments is not None:
        # Present whenever a report was passed (possibly empty): the
        # artifact says "health was consulted" even when nothing moved.
        cost["health_adjustments"] = [
            health_adjustments[k] for k in sorted(health_adjustments)
        ]
    return Plan(
        codec=PLAN_CODEC_V1,
        arch=arch,
        optimizer=optimizer,
        budget_bytes=int(budget_bytes),
        globals_=PlanGlobals(
            t_update=t_u,
            lam=lam_,
            stagger_groups=stagger_groups,
            stacked_state=stacked,
            state_dtype=state_dtype,
            quant_block=quant_block,
            seed=seed,
            eqn6_steps=eqn6_steps,
            eqn6_lr=eqn6_lr,
            rank_compression=rank_compression,
            min_dim=min_dim,
            sync_codes=sync_codes,
        ),
        buckets=bucket_plans,
        predicted=predicted,
        cost=cost,
    )


def solve_for_topology(
    params,
    n_devices: int,
    hbm_per_device: int,
    **kw,
) -> Plan:
    """Replanning entry for the elastic supervisor (``train/elastic.py``).

    The budget is the POD-TOTAL pool ``n_devices × hbm_per_device``:
    params, gradients and optimizer state are all sharded across the data
    axis (FSDP/ZeRO-style — the deployment COAP targets on preemptible
    capacity), so losing half the devices halves the pool and the solver's
    quantize knapsack re-engages int8 storage exactly where needed. A
    shrink below what even the fully-quantized minimum needs raises
    :class:`PlanInfeasibleError` — the supervisor surfaces that instead of
    silently training a different model.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    return solve(params, int(n_devices) * int(hbm_per_device), **kw)


def _grouped(by_cat: Dict[str, int]) -> Dict[str, int]:
    from repro.core.accounting import group_categories

    return group_categories(by_cat)
