"""The ``coap-plan/v1`` artifact: a versioned, portable plan codec.

A plan is the contract between the solver and every consumer — the
optimizer factory (``core/api.make_optimizer`` via ``OptimizerConfig.plan``),
the dry-run byte cross-check (``launch/dryrun --plan``) and the CLI table
(``launch/plan.py``). Like ``stacked-bucket/v2``, the codec string names the
schema; readers reject anything outside :data:`DECODABLE_PLAN_CODECS`
loudly instead of mis-applying knobs.

Schema (v1):

  * ``optimizer`` — the planned family (v1: ``coap-adamw``);
  * ``globals`` — tree-wide knobs (``t_update``, ``lam``,
    ``stagger_groups``, ``stacked_state``, ``state_dtype``, ``quant_block``,
    ``seed``, ``eqn6_steps``, ``eqn6_lr``, the rank-compression quality
    floor ``rank_compression`` and ``min_dim``);
  * ``buckets`` — one entry per congruence bucket of the planned layout
    (``stacked_state.build_layout`` under the planned rules): member
    ``paths``, the pinned ``ProjSpec``, and the per-bucket knobs
    ``quantize`` / ``t_update`` / ``stagger_groups``, plus the predicted
    byte/cost/fused-Eqn-6 columns;
  * ``predicted`` — by-category state bytes (must match
    ``accounting.abstract_state_bytes`` of the constructed optimizer
    EXACTLY — ``repro.plan.validate`` enforces it), the AdamW baseline,
    both reduction ratios (the paper's moments-only denominator and the
    everything-included one), and the budget decomposition;
  * ``cost`` — predicted optimizer step seconds + the calibration ratios
    (and which ``BENCH_*.json`` files supplied them).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Tuple

from repro.core.projector import ProjSpec

PLAN_CODEC_V1 = "coap-plan/v1"
PLAN_CODEC = PLAN_CODEC_V1
DECODABLE_PLAN_CODECS = frozenset({PLAN_CODEC_V1})


class PlanVersionError(ValueError):
    """Unknown/incompatible plan codec — fail loudly, never guess knobs."""


@dataclasses.dataclass(frozen=True)
class PlanGlobals:
    t_update: int = 40
    lam: int = 5
    stagger_groups: int = 8
    stacked_state: bool = True
    state_dtype: str = "float32"
    quant_block: int = 256
    seed: int = 0
    eqn6_steps: int = 1
    eqn6_lr: float = 0.1
    rank_compression: float = 4.0  # quality floor: r >= min(m,n)/c
    min_dim: int = 128
    # Cross-pod int8 collective (distributed/compression.py): when True the
    # constructed optimizer allocates the error-feedback sidecar and the
    # predicted bytes include it ('ef_sidecar'). Defaults False — absent
    # from older artifacts, which decode unchanged.
    sync_codes: bool = False


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    kind: str  # project | conv | dense
    shape: Tuple[int, ...]
    dtype: str
    paths: Tuple[str, ...]
    spec: ProjSpec
    quantize: bool
    t_update: int
    stagger_groups: int
    predicted_bytes: Dict[str, int]
    baseline_adamw_bytes: int
    predicted_step_cost_s: float
    eqn6_fused: Optional[bool]

    @property
    def count(self) -> int:
        return len(self.paths)

    @property
    def predicted_bytes_total(self) -> int:
        return sum(self.predicted_bytes.values())


@dataclasses.dataclass
class Plan:
    arch: Optional[str]
    optimizer: str
    budget_bytes: int
    globals_: PlanGlobals
    buckets: List[BucketPlan]
    predicted: Dict[str, Any]
    cost: Dict[str, Any]
    codec: str = PLAN_CODEC_V1

    def to_dict(self) -> Dict[str, Any]:
        return {
            "codec": self.codec,
            "arch": self.arch,
            "optimizer": self.optimizer,
            "budget_bytes": int(self.budget_bytes),
            "globals": dataclasses.asdict(self.globals_),
            "buckets": [
                {
                    "kind": b.kind,
                    "shape": list(b.shape),
                    "dtype": b.dtype,
                    "count": b.count,
                    "paths": list(b.paths),
                    "spec": b.spec._asdict(),
                    "quantize": b.quantize,
                    "t_update": b.t_update,
                    "stagger_groups": b.stagger_groups,
                    "predicted_bytes": {
                        k: int(v) for k, v in b.predicted_bytes.items()
                    },
                    "predicted_bytes_total": int(b.predicted_bytes_total),
                    "baseline_adamw_bytes": int(b.baseline_adamw_bytes),
                    "predicted_step_cost_s": b.predicted_step_cost_s,
                    "eqn6_fused": b.eqn6_fused,
                }
                for b in self.buckets
            ],
            "predicted": self.predicted,
            "cost": self.cost,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Plan":
        codec = d.get("codec")
        if codec not in DECODABLE_PLAN_CODECS:
            raise PlanVersionError(
                f"unknown plan codec {codec!r}: this build reads "
                f"{sorted(DECODABLE_PLAN_CODECS)} — refusing to guess what "
                "a newer/older schema means"
            )
        buckets = [
            BucketPlan(
                kind=b["kind"],
                shape=tuple(int(s) for s in b["shape"]),
                dtype=b["dtype"],
                paths=tuple(b["paths"]),
                spec=ProjSpec(**b["spec"]),
                quantize=bool(b["quantize"]),
                t_update=int(b["t_update"]),
                stagger_groups=int(b["stagger_groups"]),
                predicted_bytes={
                    k: int(v) for k, v in b["predicted_bytes"].items()
                },
                baseline_adamw_bytes=int(b["baseline_adamw_bytes"]),
                predicted_step_cost_s=float(b["predicted_step_cost_s"]),
                eqn6_fused=b.get("eqn6_fused"),
            )
            for b in d["buckets"]
        ]
        return cls(
            codec=codec,
            arch=d.get("arch"),
            optimizer=d["optimizer"],
            budget_bytes=int(d["budget_bytes"]),
            globals_=PlanGlobals(**d["globals"]),
            buckets=buckets,
            predicted=d["predicted"],
            cost=d["cost"],
        )


def save_plan(plan: Plan, path: str) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(plan.to_dict(), f, indent=1, sort_keys=True)
    return path


def load_plan(path: str) -> Plan:
    with open(path) as f:
        return Plan.from_dict(json.load(f))


def resolve(plan_or_path) -> Plan:
    """Accept a Plan, a dict, or a JSON path — everything a config field or
    CLI flag might carry."""
    if isinstance(plan_or_path, Plan):
        return plan_or_path
    if isinstance(plan_or_path, dict):
        return Plan.from_dict(plan_or_path)
    if isinstance(plan_or_path, (str, os.PathLike)):
        return load_plan(os.fspath(plan_or_path))
    raise TypeError(
        f"cannot resolve a plan from {type(plan_or_path).__name__}"
    )
