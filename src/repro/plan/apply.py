"""Plan consumption: ``coap-plan/v1`` -> a configured optimizer.

The plan's per-bucket decisions map onto two existing mechanisms:

  * ranks/kinds pin the per-path :class:`ProjSpec` via
    ``projector.PlannedRules`` (override rules layered over the base
    policy), so ``build_layout`` reproduces the planner's buckets exactly;
  * ``quantize`` / ``t_update`` / ``stagger_groups`` ride per-path in
    ``coap_adam.PlanOverrides`` (the optimizer enforces bucket uniformity).

``core/api.make_optimizer`` routes here when ``OptimizerConfig.plan`` is
set; this module deliberately does NOT import ``core.api`` (no cycle).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from typing import Dict

from repro.core.coap_adam import (
    LeafOverrides,
    PlanOverrides,
    ProjectedAdamConfig,
    projected_adamw_from_config,
)
from repro.core.projector import PlannedRules, ProjSpec
from repro.optim.transform import GradientTransformation
from repro.plan.artifact import Plan, resolve  # noqa: F401  (re-export)

_SUPPORTED_OPTIMIZERS = ("coap-adamw",)


def planned_rules(plan: Plan, min_dim: Optional[int] = None) -> PlannedRules:
    overrides: Tuple[Tuple[str, ProjSpec], ...] = tuple(
        (path, b.spec) for b in plan.buckets for path in b.paths
    )
    return PlannedRules(
        rank_ratio=plan.globals_.rank_compression,
        min_dim=plan.globals_.min_dim if min_dim is None else min_dim,
        spec_overrides=overrides,
    )


def plan_overrides(plan: Plan) -> PlanOverrides:
    return PlanOverrides(
        entries=tuple(
            (
                path,
                LeafOverrides(
                    quantize=b.quantize,
                    t_update=b.t_update,
                    stagger_groups=b.stagger_groups,
                ),
            )
            for b in plan.buckets
            for path in b.paths
        )
    )


def quantize_by_path(plan: Plan) -> Dict[str, bool]:
    """path -> does the plan store this leaf's moments int8 (the
    ``quantize_for`` callable of ``stacked_state.migrate``, as a dict)."""
    return {
        path: bool(b.quantize) for b in plan.buckets for path in b.paths
    }


def planned_config(plan: Plan, ocfg) -> ProjectedAdamConfig:
    """The exact :class:`ProjectedAdamConfig` the planned transform runs
    with — exposed so schedule consumers (``coap_adam.bucket_phases`` via
    the elastic supervisor) derive cadence/phases from the same config the
    optimizer uses, not a reconstruction."""
    g = plan.globals_
    return ProjectedAdamConfig(
        rules=planned_rules(plan),
        strategy="coap",
        b1=ocfg.b1,
        b2=ocfg.b2,
        eps=ocfg.eps,
        t_update=g.t_update,
        lam=g.lam,
        eqn6_lr=g.eqn6_lr,
        eqn6_steps=g.eqn6_steps,
        seed=ocfg.seed,
        update_scale=ocfg.update_scale,
        moment_transplant=ocfg.moment_transplant,
        quantize=False,  # per-bucket via overrides, never globally
        quant_block=g.quant_block,
        state_dtype=jnp.dtype(g.state_dtype).type,
        stagger=True,
        stagger_groups=g.stagger_groups,
        stacked_state=g.stacked_state,
        sync_codes=g.sync_codes,
        overrides=plan_overrides(plan),
    )


def transform(plan: Plan, ocfg) -> GradientTransformation:
    """The planned ``scale_by_projected_adam`` chain member (no grad clip /
    lr — ``make_optimizer`` owns those). ``ocfg`` is the
    ``core.api.OptimizerConfig`` carrying the run-level knobs the plan does
    not own (lr, betas, weight decay)."""
    if plan.optimizer not in _SUPPORTED_OPTIMIZERS:
        raise ValueError(
            f"plan optimizer {plan.optimizer!r} not supported by this build "
            f"(supported: {_SUPPORTED_OPTIMIZERS})"
        )
    if ocfg.name not in ("coap-adamw", plan.optimizer):
        raise ValueError(
            f"OptimizerConfig.name={ocfg.name!r} conflicts with the plan's "
            f"optimizer {plan.optimizer!r}"
        )
    # Run-level knobs stay on the OptimizerConfig (api.py contract): seed
    # drives init RNG, update_scale / moment_transplant are
    # training-dynamics choices the plan does not own.
    # plan.globals_.seed records what the solver assumed (the
    # OptimizerConfig default) for artifact reproducibility.
    return projected_adamw_from_config(
        planned_config(plan, ocfg),
        ocfg.learning_rate,
        weight_decay=ocfg.weight_decay,
    )
