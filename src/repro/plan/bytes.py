"""Exact optimizer-state byte model — the planner's memory ground truth.

Every formula here restates a storage rule of ``core/coap_adam`` /
``core/conv`` in closed form:

  * ``ProjLeaf``  — P from ``projector.init_p`` (state dtype), moments from
    ``moment_shape`` stored via ``_init_stored_proj`` (state dtype, or the
    shape-preserving row-block int8 codec + per-row-block fp32 scales);
  * ``ConvLeaf``  — Tucker-2 factors from ``conv.init_factors`` (always
    fp32), core moments from ``conv.core_shape`` stored via ``_init_stored``
    (state dtype, or the flat ``(nblocks, block)`` int8 codec);
  * ``DenseLeaf`` — full-shape moments via ``_init_stored``;
  * unquantized leaves carry two ``(1,)`` fp32 scale placeholders
    ("counted for honesty", accounting.py).

Categories match ``accounting._CATEGORY_FIELDS`` verbatim, so a predicted
report compares against ``accounting.abstract_state_bytes`` per category —
and ``tests/test_plan.py`` property-checks the equality EXACTLY on
randomized trees. Stacking is byte-neutral (a bucket stacks B equal-shape
arrays), so one model covers ``stacked_state`` True and False.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from repro.core.projector import KIND_CONV, KIND_PROJECT, ProjSpec
from repro.core.stacked_state import StackedLayout
from repro.kernels import ref as kref

# accounting.CATEGORY_GROUPS is the authoritative grouping; imported there.
CAT_PROJECTION = "projection"
CAT_MOMENTS = "moments"
CAT_DENSE_MOMENTS = "dense_moments"
CAT_SCALES = "quant_scales"
CAT_EF = "ef_sidecar"  # sync_codes error-feedback accumulator (fp32)
CAT_OTHER = "other"


def _numel(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _canonical_mn(shape, spec: ProjSpec) -> Tuple[int, int, int]:
    """(lead numel, canonical m, canonical n) of a projected leaf."""
    lead = _numel(shape[:-2])
    m, n = int(shape[-2]), int(shape[-1])
    if spec.transpose:
        m, n = n, m
    return lead, m, n


def _merge(into: Dict[str, int], add: Dict[str, int], times: int = 1) -> None:
    for k, v in add.items():
        into[k] = into.get(k, 0) + v * times


def proj_leaf_bytes(
    shape, spec: ProjSpec, quantize: bool, state_itemsize: int = 4,
    block: int = kref.QUANT_BLOCK, sync_codes: bool = False,
) -> Dict[str, int]:
    """One ``ProjLeaf``: P ``lead+(n, r)``; moments ``lead+(m, r)``.

    ``sync_codes`` adds the cross-pod error-feedback accumulator (fp32,
    moment shape; ``ProjLeaf.ef``) — absent (zero bytes, not a placeholder)
    when the int8 collective is off."""
    lead, m, n = _canonical_mn(shape, spec)
    r = int(spec.rank)
    out = {CAT_PROJECTION: lead * n * r * state_itemsize}
    if quantize:
        nblk = kref.rowblock_nblocks(r, block)
        out[CAT_MOMENTS] = 2 * lead * m * r  # int8, shape-preserving
        out[CAT_SCALES] = 2 * lead * m * nblk * 4
    else:
        out[CAT_MOMENTS] = 2 * lead * m * r * state_itemsize
        out[CAT_SCALES] = 2 * 4  # (1,) fp32 placeholders
    if sync_codes:
        out[CAT_EF] = lead * m * r * 4
    return out


def conv_leaf_bytes(
    shape, spec: ProjSpec, quantize: bool, state_itemsize: int = 4,
    block: int = kref.QUANT_BLOCK, sync_codes: bool = False,
) -> Dict[str, int]:
    """One ``ConvLeaf``: factors ``(O, r_O)``/``(I, r_I)`` fp32; core
    moments ``(r_O, r_I, K1, K2)`` under the flat int8 codec when
    quantized. ``sync_codes`` adds the fp32 core-shaped error-feedback
    accumulator (``ConvLeaf.ef``)."""
    o, i = int(shape[0]), int(shape[1])
    core = int(spec.rank_o) * int(spec.rank_i) * _numel(shape[2:])
    out = {CAT_PROJECTION: (o * spec.rank_o + i * spec.rank_i) * 4}
    if quantize:
        nblocks = -(-core // block)
        out[CAT_MOMENTS] = 2 * nblocks * block  # int8 codes, zero-padded
        out[CAT_SCALES] = 2 * nblocks * 4
    else:
        out[CAT_MOMENTS] = 2 * core * state_itemsize
        out[CAT_SCALES] = 2 * 4
    if sync_codes:
        out[CAT_EF] = core * 4
    return out


def dense_leaf_bytes(
    shape, quantize: bool, state_itemsize: int = 4,
    block: int = kref.QUANT_BLOCK,
) -> Dict[str, int]:
    """One ``DenseLeaf``: full-shape Adam moments."""
    nel = _numel(shape)
    if quantize:
        nblocks = -(-nel // block)
        return {CAT_DENSE_MOMENTS: 2 * nblocks * block,
                CAT_SCALES: 2 * nblocks * 4}
    return {CAT_DENSE_MOMENTS: 2 * nel * state_itemsize, CAT_SCALES: 2 * 4}


def leaf_state_bytes(
    shape, spec: ProjSpec, quantize: bool, state_itemsize: int = 4,
    block: int = kref.QUANT_BLOCK, sync_codes: bool = False,
) -> Dict[str, int]:
    if spec.kind == KIND_PROJECT:
        return proj_leaf_bytes(
            shape, spec, quantize, state_itemsize, block, sync_codes
        )
    if spec.kind == KIND_CONV:
        return conv_leaf_bytes(
            shape, spec, quantize, state_itemsize, block, sync_codes
        )
    # Dense leaves sync full fp32 gradients (small); no EF sidecar.
    return dense_leaf_bytes(shape, quantize, state_itemsize, block)


def layout_state_report(
    layout: StackedLayout,
    shapes: List[Tuple[int, ...]],
    quantize_for: Callable[[str], bool],
    state_itemsize: int = 4,
    block: int = kref.QUANT_BLOCK,
    sync_codes: bool = False,
) -> Tuple[Dict[str, int], List[Dict[str, int]]]:
    """Predicted ``scale_by_projected_adam`` state bytes for a layout.

    ``shapes[i]`` is the i-th flat leaf's shape; ``quantize_for(path)``
    resolves the per-leaf storage codec (a plan's per-bucket knob);
    ``sync_codes`` adds the int8-collective error-feedback sidecar on every
    projected/conv leaf (a tree-wide knob, matching the config). Returns
    ``(by_category_total, per_bucket)`` where ``per_bucket`` aligns with
    ``layout.buckets`` followed by ``layout.tail``. The total includes the
    transform's own step counter (4 bytes, 'other') — chain-level scalars
    (e.g. a schedule count) are the caller's to add.
    """
    total: Dict[str, int] = {}
    per_bucket: List[Dict[str, int]] = []
    for info in layout.buckets:
        q = quantize_for(info.paths[0])
        one = leaf_state_bytes(
            shapes[info.indices[0]], info.spec, q, state_itemsize, block,
            sync_codes,
        )
        mine: Dict[str, int] = {}
        _merge(mine, one, times=len(info.indices))
        per_bucket.append(mine)
        _merge(total, mine)
    for t in layout.tail:
        one = leaf_state_bytes(
            shapes[t.index], t.spec, quantize_for(t.path), state_itemsize,
            block, sync_codes,
        )
        per_bucket.append(dict(one))
        _merge(total, one)
    _merge(total, {CAT_OTHER: 4})  # ProjectedAdamState.count (int32)
    return total, per_bucket


def adamw_baseline_report(
    shapes: List[Tuple[int, ...]], moment_itemsize: int = 4
) -> Dict[str, int]:
    """The dense-AdamW denominator: two full moments per param leaf (api
    passes ``mu_dtype=state_dtype``) plus the step counter."""
    nel = sum(_numel(s) for s in shapes)
    return {CAT_DENSE_MOMENTS: 2 * nel * moment_itemsize, CAT_OTHER: 4}


def params_grads_bytes(shapes, itemsizes) -> Tuple[int, int]:
    """(params, grads) resident bytes — the budget's fixed terms. Gradients
    are materialized in the parameter dtype."""
    b = sum(_numel(s) * int(i) for s, i in zip(shapes, itemsizes))
    return b, b
