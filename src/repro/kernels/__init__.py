"""Pallas TPU kernels for COAP's compute hot-spots.

Kernels (each <name>.py has the pallas_call + BlockSpec; ops.py holds the
jit'd dispatching wrappers; ref.py the pure-jnp oracles):
  * coap_update.py — fused G@P projection + Adam moment EMA + ΔW epilogue.
  * quant8.py      — block-wise absmax int8 quant/dequant + fused 8-bit step.
  * rmsnorm.py     — fused RMSNorm for the serving path.
"""
