"""Jit'd public wrappers for the Pallas kernels with backend dispatch.

On TPU the Pallas implementations run natively; elsewhere (this CPU
container) we execute the ``ref.py`` oracle, or the Pallas body under
``interpret=True`` when ``REPRO_PALLAS=interpret`` is set (used by the kernel
test suite). The numerics are identical by construction (tests enforce it).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref

_MODE_ENV = "REPRO_PALLAS"


def _mode() -> str:
    forced = os.environ.get(_MODE_ENV, "")
    if forced:
        return forced  # 'pallas' | 'interpret' | 'ref'
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _interpret_flag():
    return _mode() == "interpret"


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps"))
def coap_fused_update(g, p, m, v, count, b1=0.9, b2=0.999, eps=1e-8):
    """Fused G@P + Adam moment EMA + bias-corrected ΔW_proj. See kernel
    ``coap_update.py`` for the TPU implementation and tiling rationale."""
    if _mode() == "ref":
        return ref.coap_fused_update(g, p, m, v, count, b1=b1, b2=b2, eps=eps)
    from repro.kernels import coap_update

    return coap_update.coap_fused_update_pallas(
        g, p, m, v, count, b1=b1, b2=b2, eps=eps, interpret=_interpret_flag()
    )


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps"))
def coap_fused_update_bp(g, p, m, v, count, b1=0.9, b2=0.999, eps=1e-8):
    """Back-projection-fused step: returns (m', v', ΔW) with ΔW = Δ_proj Pᵀ
    produced as a second MXU stage of the same kernel — Δ_proj never hits
    HBM. See ``coap_update.coap_fused_update_bp_pallas``."""
    if _mode() == "ref":
        return ref.coap_fused_update_bp(g, p, m, v, count, b1=b1, b2=b2, eps=eps)
    from repro.kernels import coap_update

    return coap_update.coap_fused_update_bp_pallas(
        g, p, m, v, count, b1=b1, b2=b2, eps=eps, interpret=_interpret_flag()
    )


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "block"))
def coap_fused_update_q8(
    g, p, m_q, m_scale, v_q, v_scale, count,
    b1=0.9, b2=0.999, eps=1e-8, block=ref.QUANT_BLOCK,
):
    """Single-pass 8-bit COAP step (project + dequant + Adam + requant +
    back-project in one kernel; row-block codec). See ``quant8``."""
    if _mode() == "ref":
        return ref.coap_fused_update_q8(
            g, p, m_q, m_scale, v_q, v_scale, count,
            b1=b1, b2=b2, eps=eps, block=block,
        )
    from repro.kernels import quant8

    return quant8.coap_fused_update_q8_pallas(
        g, p, m_q, m_scale, v_q, v_scale, count,
        b1=b1, b2=b2, eps=eps, block=block, interpret=_interpret_flag(),
    )


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_rowblock(x, block=ref.QUANT_BLOCK):
    """Row-block int8 codec (projected-state layout). jnp-implemented in all
    modes: it runs only at init / refresh-transplant time, never in the
    per-step hot loop (the fused q8 kernel requantizes in-VMEM)."""
    return ref.quantize_rowblock(x, block)


@functools.partial(jax.jit, static_argnames=("block", "dtype"))
def dequantize_rowblock(q, scale, block=ref.QUANT_BLOCK, dtype=jnp.float32):
    """Inverse of :func:`quantize_rowblock` (refresh-path only; see above)."""
    return ref.dequantize_rowblock(q, scale, block, dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def rowblock_code_stats(q, scale, block=ref.QUANT_BLOCK):
    """Codec-health stats (sat/rail rate, non-finite scales, relative
    quant error) of a row-block-coded state tensor — the sampled
    ``obs/health.observe_state`` surface. jnp in all modes: it reads only
    resident int8 state at the health cadence, never the hot loop."""
    return ref.rowblock_code_stats(q, scale, block)


@functools.partial(jax.jit, static_argnames=("block",))
def quantize_blockwise(x, block=ref.QUANT_BLOCK):
    if _mode() == "ref":
        return ref.quantize_blockwise(x, block)
    from repro.kernels import quant8

    return quant8.quantize_blockwise_pallas(x, block, interpret=_interpret_flag())


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "block"))
def dequantize_blockwise(q, scale, shape, dtype=jnp.float32, block=ref.QUANT_BLOCK):
    if _mode() == "ref":
        return ref.dequantize_blockwise(q, scale, shape, dtype)
    from repro.kernels import quant8

    return quant8.dequantize_blockwise_pallas(
        q, scale, shape, dtype, block, interpret=_interpret_flag()
    )


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "block"))
def quantized_adam_update(
    g_proj, m_q, m_scale, v_q, v_scale, count, b1=0.9, b2=0.999, eps=1e-8,
    block=ref.QUANT_BLOCK,
):
    if _mode() == "ref":
        return ref.quantized_adam_update(
            g_proj, m_q, m_scale, v_q, v_scale, count, b1, b2, eps, block
        )
    from repro.kernels import quant8

    return quant8.quantized_adam_update_pallas(
        g_proj, m_q, m_scale, v_q, v_scale, count, b1, b2, eps, block,
        interpret=_interpret_flag(),
    )


@functools.partial(jax.jit, static_argnames=("lr", "steps", "normalize"))
def _eqn6_ref(p, g, m_proj, lr, steps, normalize):
    return ref.eqn6_sgd_update(
        p, g, m_proj, lr=lr, steps=steps, normalize=normalize
    )[0]


# Fused-Eqn-6 fallback telemetry: plans that land a bucket on the slow
# unfused path must be VISIBLE (launch/dryrun and launch/plan surface
# these counts), not buried in one warning per trace. Counters key on the
# 2-D dispatch shape (m, n, r) and increment once per TRACE that fell
# back; the RuntimeWarning is deduplicated per unique (n, r, budget) —
# the footprint that decides the fallback is bm-independent in (n, r), so
# repeated traces of the same layer shape add no information.
_EQN6_FALLBACK_COUNTS = {}
_EQN6_WARNED = set()


def eqn6_fallback_counts() -> dict:
    """{(m, n, r): traces-that-fell-back} since the last reset."""
    return dict(_EQN6_FALLBACK_COUNTS)


def reset_eqn6_fallbacks() -> None:
    """Clear fallback counters AND the warning dedup set (test isolation /
    per-dryrun-cell accounting)."""
    _EQN6_FALLBACK_COUNTS.clear()
    _EQN6_WARNED.clear()


def _record_eqn6_fallback(g, p, budget: int, err) -> None:
    import warnings

    from repro.obs.registry import get_registry

    m_dim, n_dim = int(g.shape[-2]), int(g.shape[-1])
    r = int(p.shape[-1])
    key = (m_dim, n_dim, r)
    _EQN6_FALLBACK_COUNTS[key] = _EQN6_FALLBACK_COUNTS.get(key, 0) + 1
    # Mirror into the process-wide registry so fallbacks ride heartbeats
    # and dryrun artifacts; reset_eqn6_fallbacks deliberately does NOT
    # clear it — the registry is lifetime-of-process telemetry.
    get_registry().inc(f"eqn6/fallback/{m_dim}x{n_dim}x{r}")
    warn_key = (n_dim, r, int(budget))
    if warn_key not in _EQN6_WARNED:
        _EQN6_WARNED.add(warn_key)
        warnings.warn(f"{err}", RuntimeWarning)


def eqn6_sgd_update(p, g, m_proj, lr=0.1, steps=1, normalize=False):
    """Fused Eqn-6 projection refresh: ``steps`` SGD iterations on the
    paper's Eqn-6 objective with loss+grad computed in ONE tiled sweep over
    G per step (see ``eqn6.py``). Accepts bf16 ``g``/``m_proj`` (upcast
    per-tile in VMEM). ``normalize=True`` fuses the scale-invariant
    variant's ‖G‖ pre-pass as a first grid phase. Returns the new P only
    (in ``p``'s dtype).

    VMEM guard: when the kernel's trace-time footprint estimate cannot fit
    at any row-tile size (wide layers; ``eqn6.plan_bm``), the dispatch
    falls back to the unfused jnp oracle — identical numerics, no
    uncompilable kernel."""
    if _mode() == "ref":
        return _eqn6_ref(p, g, m_proj, lr, steps, normalize)
    from repro.kernels import eqn6

    budget = eqn6._vmem_budget()
    try:
        # Resolve the env budget HERE, outside the jit cache: the budget is
        # a static argument of the kernel wrapper, so passing it concretely
        # makes a changed REPRO_EQN6_VMEM_BUDGET a cache miss instead of a
        # silently-ignored env read inside an already-cached trace.
        return eqn6.eqn6_sgd_update_pallas(
            p, g, m_proj, lr=lr, steps=steps, normalize=normalize,
            interpret=_interpret_flag(), vmem_budget=budget,
        )[0]
    except eqn6.Eqn6VmemError as e:
        _record_eqn6_fallback(g, p, budget, e)
        return _eqn6_ref(p, g, m_proj, lr, steps, normalize)


def rmsnorm(x, scale, eps=1e-6):
    if _mode() == "ref":
        return ref.rmsnorm(x, scale, eps)
    from repro.kernels import rmsnorm as _rk

    return _rk.rmsnorm_pallas(x, scale, eps, interpret=_interpret_flag())
