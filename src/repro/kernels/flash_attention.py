"""Flash attention as a Pallas TPU kernel (fwd + custom-VJP bwd).

THE model-side hot-spot kernel: the dry-run showed every train/prefill cell
HBM-bound on attention-score traffic (naive: ~8 full (T,S)-sized tensor
passes per layer; pure-JAX chunking does NOT help training because scan
autodiff stores every tile as a residual — measured in EXPERIMENTS.md
§Perf). The kernel keeps the running-softmax state in VMEM, so per layer
the only HBM traffic is q, k, v, o (+ the (T,) lse statistics): the classic
FlashAttention schedule adapted to the MXU/VMEM hierarchy.

Layout: q (BH, T, hd), k/v (BKH, S, hd) — batch×heads flattened into the
leading grid axis; GQA maps q-head → kv-head in the BlockSpec index map.
Grid (bh, nq, nk), kv innermost ('arbitrary') with VMEM scratch
accumulators; the epilogue at the last kv block writes o and lse.

Backward: two Pallas kernels sharing the recompute-from-(q,k,v,lse) trick —
  * dkv pass: grid (bkh, nk, nq): accumulates dk, dv over query blocks.
  * dq  pass: grid (bh,  nq, nk): accumulates dq over kv blocks.
``delta = rowsum(do ⊙ o)`` is precomputed (cheap elementwise jnp).

Supports causal masking, sliding windows and logit softcap (grok).
Validated against the naive jnp oracle in tests/test_flash_attention.py
(interpret mode, values + grads).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    _HAS_PLTPU = False

NEG_INF = -1e30
DEFAULT_QB = 512
DEFAULT_KB = 512


def _mask(q0, k0, qb, kb, s_real, causal, window):
    q_pos = q0 + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = k0 + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    keep = k_pos < s_real
    if causal:
        keep &= k_pos <= q_pos
    if window is not None:
        keep &= k_pos > q_pos - window
    return keep


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, causal, window, softcap, nk, kb, s_real):
    qi, ki = pl.program_id(1), pl.program_id(2)
    qb = q_ref.shape[1]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (qb, hd)
    k = k_ref[0].astype(jnp.float32)  # (kb, hd)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (qb, kb)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    keep = _mask(qi * qb, ki * kb, qb, kb, s_real, causal, window)
    logits = jnp.where(keep, logits, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _epilogue():
        l_safe = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l_safe).astype(o_ref.dtype)
        lse_ref[0] = (m_scr[...] + jnp.log(l_safe))[:, 0]


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale, causal, window, softcap, nq, qb, s_real, group):
    ki, qi = pl.program_id(1), pl.program_id(2)
    kb = k_ref.shape[1]

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0].astype(jnp.float32)  # (qb, hd)
    k = k_ref[0].astype(jnp.float32)  # (kb, hd)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)  # (qb, hd)
    lse = lse_ref[0]  # (qb,)
    delta = delta_ref[0]  # (qb,)

    raw = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (qb, kb)
    if softcap is not None:
        capped = softcap * jnp.tanh(raw / softcap)
        dcap = 1.0 - (capped / softcap) ** 2  # d capped / d raw
    else:
        capped, dcap = raw, None
    keep = _mask(qi * qb, ki * kb, qb, kb, s_real, causal, window)
    logits = jnp.where(keep, capped, NEG_INF)
    p = jnp.exp(logits - lse[:, None])  # (qb, kb) softmax probs
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (qb, kb)
    ds = p * (dp - delta[:, None])
    if dcap is not None:
        ds = ds * dcap
    ds = jnp.where(keep, ds, 0.0) * scale
    dk_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(qi == nq - 1)
    def _epilogue():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, scale, causal, window, softcap, nk, kb, s_real):
    qi, ki = pl.program_id(1), pl.program_id(2)
    qb = q_ref.shape[1]

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]
    delta = delta_ref[0]

    raw = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        capped = softcap * jnp.tanh(raw / softcap)
        dcap = 1.0 - (capped / softcap) ** 2
    else:
        capped, dcap = raw, None
    keep = _mask(qi * qb, ki * kb, qb, kb, s_real, causal, window)
    logits = jnp.where(keep, capped, NEG_INF)
    p = jnp.exp(logits - lse[:, None])
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    ds = p * (dp - delta[:, None])
    if dcap is not None:
        ds = ds * dcap
    ds = jnp.where(keep, ds, 0.0) * scale
    dq_scr[...] += jax.lax.dot_general(
        ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ki == nk - 1)
    def _epilogue():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _pad_seq(x, blk):
    pad = (-x.shape[1]) % blk
    if pad:
        return jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


def _pallas_kwargs(interpret, semantics):
    kw = dict(interpret=interpret)
    if _HAS_PLTPU and not interpret:
        try:
            kw["compiler_params"] = pltpu.CompilerParams(
                dimension_semantics=semantics)
        except Exception:  # pragma: no cover
            pass
    return kw


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9)
)
def flash_attention(q, k, v, scale, causal=True, window=None, softcap=None,
                    qb=DEFAULT_QB, kb=DEFAULT_KB, interpret=False):
    """q (BH, T, hd); k/v (BKH, S, hd) with BH = BKH*group. Returns o."""
    o, _ = _fwd(q, k, v, scale, causal, window, softcap, qb, kb, interpret)
    return o


def _fwd(q, k, v, scale, causal, window, softcap, qb, kb, interpret):
    bh, t, hd = q.shape
    bkh, s, _ = k.shape
    group = bh // bkh
    qb_e, kb_e = min(qb, t), min(kb, s)
    qp, kp, vp = _pad_seq(q, qb_e), _pad_seq(k, kb_e), _pad_seq(v, kb_e)
    tp, sp = qp.shape[1], kp.shape[1]
    nq, nk = tp // qb_e, sp // kb_e
    grid = (bh, nq, nk)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, nk=nk, kb=kb_e, s_real=s,
    )
    kwargs = dict(
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb_e, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb_e, hd), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, kb_e, hd), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qb_e, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, qb_e), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, tp, hd), q.dtype),
            jax.ShapeDtypeStruct((bh, tp), jnp.float32),
        ],
        **_pallas_kwargs(interpret, ("parallel", "parallel", "arbitrary")),
    )
    if _HAS_PLTPU:
        kwargs["scratch_shapes"] = [
            pltpu.VMEM((qb_e, 1), jnp.float32),
            pltpu.VMEM((qb_e, 1), jnp.float32),
            pltpu.VMEM((qb_e, hd), jnp.float32),
        ]
    o, lse = pl.pallas_call(kernel, **kwargs)(qp, kp, vp)
    return o[:, :t], (q, k, v, o[:, :t], lse[:, :t])


def _fwd_rule(q, k, v, scale, causal, window, softcap, qb, kb, interpret):
    o, res = _fwd(q, k, v, scale, causal, window, softcap, qb, kb, interpret)
    return o, res


def _bwd_rule(scale, causal, window, softcap, qb, kb, interpret, res, do):
    q, k, v, o, lse = res
    bh, t, hd = q.shape
    bkh, s, _ = k.shape
    group = bh // bkh
    qb_e, kb_e = min(qb, t), min(kb, s)
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    qp, dop = _pad_seq(q, qb_e), _pad_seq(do, qb_e)
    kp, vp = _pad_seq(k, kb_e), _pad_seq(v, kb_e)
    pad_t = qp.shape[1] - t
    lse_p = jnp.pad(lse, ((0, 0), (0, pad_t)), constant_values=0.0)
    delta_p = jnp.pad(delta, ((0, 0), (0, pad_t)))
    tp, sp = qp.shape[1], kp.shape[1]
    nq, nk = tp // qb_e, sp // kb_e

    # --- dk / dv: grid over kv blocks, accumulate over q blocks ---
    dkv_kernel = functools.partial(
        _dkv_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, nq=nq, qb=qb_e, s_real=s, group=group,
    )
    # grid (bh, nk, nq): one (kv-head-replicated) pass per q-head; dk/dv
    # outputs are per q-head and summed over the group afterwards.
    kwargs = dict(
        grid=(bh, nk, nq),
        in_specs=[
            pl.BlockSpec((1, qb_e, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, kb_e, hd), lambda b, j, i, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, kb_e, hd), lambda b, j, i, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, qb_e, hd), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, qb_e), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, qb_e), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, kb_e, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, kb_e, hd), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sp, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, sp, hd), jnp.float32),
        ],
        **_pallas_kwargs(interpret, ("parallel", "parallel", "arbitrary")),
    )
    if _HAS_PLTPU:
        kwargs["scratch_shapes"] = [
            pltpu.VMEM((kb_e, hd), jnp.float32),
            pltpu.VMEM((kb_e, hd), jnp.float32),
        ]
    dk_per_qh, dv_per_qh = pl.pallas_call(dkv_kernel, **kwargs)(
        qp, kp, vp, dop, lse_p, delta_p
    )
    dk = dk_per_qh.reshape(bkh, group, sp, hd).sum(axis=1)[:, :s]
    dv = dv_per_qh.reshape(bkh, group, sp, hd).sum(axis=1)[:, :s]

    # --- dq: grid over q blocks, accumulate over kv blocks ---
    dq_kernel = functools.partial(
        _dq_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, nk=nk, kb=kb_e, s_real=s,
    )
    kwargs = dict(
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qb_e, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb_e, hd), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, kb_e, hd), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, qb_e, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, qb_e), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, qb_e), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, qb_e, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tp, hd), q.dtype),
        **_pallas_kwargs(interpret, ("parallel", "parallel", "arbitrary")),
    )
    if _HAS_PLTPU:
        kwargs["scratch_shapes"] = [pltpu.VMEM((qb_e, hd), jnp.float32)]
    dq = pl.pallas_call(dq_kernel, **kwargs)(
        qp, kp, vp, dop, lse_p, delta_p
    )[:, :t]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd_rule, _bwd_rule)


def attend_flash(q, k, v, *, scale, causal=True, window=None, softcap=None,
                 interpret=False, qb=DEFAULT_QB, kb=DEFAULT_KB):
    """Model-layout adapter: q (B,T,H,hd), k/v (B,S,K,hd) -> (B,T,H,hd)."""
    b, t, h, hd = q.shape
    s, kh = k.shape[1], k.shape[2]
    group = h // kh
    # (B,T,H,hd) -> (B*H, T, hd) with q-heads of one kv-head adjacent
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    o = flash_attention(qf, kf, vf, scale, causal, window, softcap, qb, kb,
                        interpret)
    return o.reshape(b, h, t, hd).transpose(0, 2, 1, 3)
