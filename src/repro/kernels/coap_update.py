"""Fused COAP-Adam update kernel (the paper's per-step hot loop, TPU-native).

Computes, in ONE pass over HBM:

    G_proj = G @ P            (MXU matmul, fp32 accumulation in VMEM scratch)
    M'     = β₁M + (1−β₁)G_proj
    V'     = β₂V + (1−β₂)G_proj²          (VPU epilogue on the resident tile)
    ΔW_p   = (M'/c₁) / (sqrt(V'/c₂) + ε)

Why fuse: the unfused schedule writes G_proj (m·r) to HBM, then re-reads
G_proj+M+V and writes M'+V'+ΔW — ≈ mn + 7mr words of traffic. The fused
kernel reads G once, streams P per n-block, and touches M/V exactly once:
≈ mn + (m/bm)·nr + 5mr. For LLaMA-1B shapes (m=5461, n=2048, r=512,
bm=512) that is a ~1.9× HBM-traffic reduction on the optimizer step
(measured against cost_analysis in EXPERIMENTS.md §Perf).

Tiling: grid (m/bm, n/bn), n innermost ('arbitrary') for the reduction;
blocks bm=512, bn=512 keep the working set
(G 1MB + P 1MB + acc bm·r ≤ 2MB + M/V/out tiles 3·bm·r) under 16MB VMEM for
r ≤ 1024, with all MXU dims 128-aligned. The wrapper pads ragged shapes and
vmaps over leading (layer/expert) stack axes.

bf16 gradient streaming: G blocks are DMA'd in the caller's dtype and
upcast to fp32 in VMEM (the ``astype`` inside the body), so bf16 training
halves the kernel's dominant HBM read (the m·n gradient) with fp32 MXU
accumulation — the optimizer never materializes an fp32 copy of G
(``coap_adam._update_proj_bucket`` passes the canonical gradient through
uncast; only the unfused jnp fallbacks cast eagerly).

``coap_fused_update_bp_pallas`` additionally fuses the back-projection
``ΔW = Δ_proj Pᵀ`` as a second MXU stage in the SAME kernel: the inner grid
dimension runs 2·(n/bn) steps — phase 1 (k < kn) accumulates G@P exactly as
above; the epilogue at k = kn−1 computes Δ_proj into the accumulator
scratch; phase 2 (k ≥ kn) re-streams P per n-block and writes the (bm, bn)
tiles of Δ_proj·Pᵀ. Δ_proj never exists in HBM, and the index maps pin G to
its last block through phase 2 so G is fetched exactly once. Extra traffic
vs the non-BP kernel is one more P sweep per m-row plus the mn output —
strictly less than the unfused schedule's write+read of Δ_proj (2mr) plus
its separate backproject pass (mn + (m/bm)·nr + mn).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only compiler params; absent/renamed on some builds
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    _HAS_PLTPU = False

DEFAULT_BM = 512
DEFAULT_BN = 512


def _kernel(corr_ref, g_ref, p_ref, m_ref, v_ref,
            new_m_ref, new_v_ref, delta_ref, acc_ref,
            *, b1: float, b2: float, eps: float, n_steps: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: accumulate this n-block's contribution to G @ P.
    acc_ref[...] += jnp.dot(
        g_ref[...].astype(jnp.float32),
        p_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_steps - 1)
    def _epilogue():
        g_proj = acc_ref[...]
        m = m_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        new_m = b1 * m + (1.0 - b1) * g_proj
        new_v = b2 * v + (1.0 - b2) * g_proj * g_proj
        c1 = corr_ref[0]
        c2 = corr_ref[1]
        delta = (new_m / c1) / (jnp.sqrt(new_v / c2) + eps)
        new_m_ref[...] = new_m
        new_v_ref[...] = new_v
        delta_ref[...] = delta


def _kernel_bp(corr_ref, g_ref, p_ref, m_ref, v_ref,
               new_m_ref, new_v_ref, dw_ref, acc_ref,
               *, b1: float, b2: float, eps: float, kn: int):
    """Two-phase body: phase 1 accumulates G@P; the k==kn-1 epilogue runs the
    Adam update and parks Δ_proj in the accumulator scratch; phase 2 emits
    the back-projected (bm, bn) tiles of ΔW = Δ_proj Pᵀ."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < kn)
    def _accumulate():
        acc_ref[...] += jnp.dot(
            g_ref[...].astype(jnp.float32),
            p_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == kn - 1)
    def _epilogue():
        g_proj = acc_ref[...]
        m = m_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        new_m = b1 * m + (1.0 - b1) * g_proj
        new_v = b2 * v + (1.0 - b2) * g_proj * g_proj
        c1 = corr_ref[0]
        c2 = corr_ref[1]
        delta = (new_m / c1) / (jnp.sqrt(new_v / c2) + eps)
        new_m_ref[...] = new_m
        new_v_ref[...] = new_v
        acc_ref[...] = delta  # scratch reuse: phase 2 consumes Δ_proj

    @pl.when(k >= kn)
    def _backproject():
        # (bm, r) @ (bn, r)ᵀ on the MXU, contracting r.
        dw_ref[...] = jax.lax.dot_general(
            acc_ref[...], p_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# Shared two-phase grid pieces (also used by quant8's fused int8 kernel so
# the two fused variants stay in lockstep):
def pin_g_index(kn):
    """G streams through phase 1, then stays pinned on its last block
    (index unchanged -> no phase-2 refetch)."""
    return lambda i, k: (i, jnp.where(k < kn, k, kn - 1))


def park_out_index(kn):
    """ΔW tiles park on block 0 through phase 1 (no copy-out until the
    index advances), then advance one tile per phase-2 step."""
    return lambda i, k: (i, jnp.maximum(k - kn, 0))


def two_phase_compiler_params():
    """dimension_semantics for (parallel rows, arbitrary two-phase inner
    dim), tolerant of the CompilerParams/TPUCompilerParams rename."""
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )
    except Exception:  # older naming
        return pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        )


@functools.partial(
    jax.jit, static_argnames=("b1", "b2", "eps", "interpret", "bm", "bn")
)
def coap_fused_update_pallas(
    g, p, m, v, count, b1=0.9, b2=0.999, eps=1e-8,
    interpret: bool = False, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
):
    """Public entry. g (...,m,n), p (...,n,r), m/v (...,m,r) -> (m', v', Δ)."""
    if g.ndim > 2:  # stacked weights: vmap over the leading axes
        fn = functools.partial(
            coap_fused_update_pallas, b1=b1, b2=b2, eps=eps,
            interpret=interpret, bm=bm, bn=bn,
        )
        for _ in range(g.ndim - 2):
            fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, None))
        return fn(g, p, m, v, count)

    m_dim, n_dim = g.shape
    r = p.shape[-1]
    t = count.astype(jnp.float32)
    corr = jnp.stack([1.0 - b1**t, 1.0 - b2**t])

    bm_eff = min(bm, max(8, m_dim))
    bn_eff = min(bn, max(128, n_dim))
    g_p = _pad_to(_pad_to(g, bm_eff, 0), bn_eff, 1)
    p_p = _pad_to(p, bn_eff, 0)
    m_p = _pad_to(m.astype(jnp.float32), bm_eff, 0)
    v_p = _pad_to(v.astype(jnp.float32), bm_eff, 0)
    mp, np_ = g_p.shape
    grid = (mp // bm_eff, np_ // bn_eff)

    kernel = functools.partial(
        _kernel, b1=b1, b2=b2, eps=eps, n_steps=grid[1]
    )
    out_shape = [
        jax.ShapeDtypeStruct((mp, r), jnp.float32),
        jax.ShapeDtypeStruct((mp, r), jnp.float32),
        jax.ShapeDtypeStruct((mp, r), jnp.float32),
    ]
    in_specs = [
        pl.BlockSpec((2,), lambda i, k: (0,)),  # corr coefficients
        pl.BlockSpec((bm_eff, bn_eff), lambda i, k: (i, k)),  # G
        pl.BlockSpec((bn_eff, r), lambda i, k: (k, 0)),  # P
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),  # M
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),  # V
    ]
    out_specs = [
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),
    ]
    kwargs = dict(
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    if _HAS_PLTPU:
        kwargs["scratch_shapes"] = [pltpu.VMEM((bm_eff, r), jnp.float32)]
        if not interpret:
            try:
                kwargs["compiler_params"] = pltpu.CompilerParams(
                    dimension_semantics=("parallel", "arbitrary")
                )
            except Exception:  # older naming
                kwargs["compiler_params"] = pltpu.TPUCompilerParams(
                    dimension_semantics=("parallel", "arbitrary")
                )
    else:  # pragma: no cover
        raise RuntimeError("Pallas TPU backend unavailable; use ops ref path")

    new_m, new_v, delta = pl.pallas_call(kernel, **kwargs)(
        corr, g_p, p_p, m_p, v_p
    )
    return new_m[:m_dim], new_v[:m_dim], delta[:m_dim]


@functools.partial(
    jax.jit, static_argnames=("b1", "b2", "eps", "interpret", "bm", "bn")
)
def coap_fused_update_bp_pallas(
    g, p, m, v, count, b1=0.9, b2=0.999, eps=1e-8,
    interpret: bool = False, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
):
    """Back-projection-fused variant: g (...,m,n), p (...,n,r), m/v (...,m,r)
    -> (m', v', ΔW (...,m,n)). Δ_proj stays in VMEM scratch."""
    if g.ndim > 2:  # stacked weights: vmap over the leading axes
        fn = functools.partial(
            coap_fused_update_bp_pallas, b1=b1, b2=b2, eps=eps,
            interpret=interpret, bm=bm, bn=bn,
        )
        for _ in range(g.ndim - 2):
            fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, None))
        return fn(g, p, m, v, count)

    m_dim, n_dim = g.shape
    r = p.shape[-1]
    t = count.astype(jnp.float32)
    corr = jnp.stack([1.0 - b1**t, 1.0 - b2**t])

    bm_eff = min(bm, max(8, m_dim))
    bn_eff = min(bn, max(128, n_dim))
    g_p = _pad_to(_pad_to(g, bm_eff, 0), bn_eff, 1)
    p_p = _pad_to(p, bn_eff, 0)
    m_p = _pad_to(m.astype(jnp.float32), bm_eff, 0)
    v_p = _pad_to(v.astype(jnp.float32), bm_eff, 0)
    mp, np_ = g_p.shape
    kn = np_ // bn_eff
    grid = (mp // bm_eff, 2 * kn)

    kernel = functools.partial(_kernel_bp, b1=b1, b2=b2, eps=eps, kn=kn)
    out_shape = [
        jax.ShapeDtypeStruct((mp, r), jnp.float32),
        jax.ShapeDtypeStruct((mp, r), jnp.float32),
        jax.ShapeDtypeStruct((mp, np_), jnp.float32),
    ]
    in_specs = [
        pl.BlockSpec((2,), lambda i, k: (0,)),  # corr coefficients
        pl.BlockSpec((bm_eff, bn_eff), pin_g_index(kn)),  # G
        pl.BlockSpec((bn_eff, r), lambda i, k: (k % kn, 0)),  # P (both phases)
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),  # M
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),  # V
    ]
    out_specs = [
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),
        pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0)),
        pl.BlockSpec((bm_eff, bn_eff), park_out_index(kn)),  # ΔW
    ]
    kwargs = dict(
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    if _HAS_PLTPU:
        kwargs["scratch_shapes"] = [pltpu.VMEM((bm_eff, r), jnp.float32)]
        if not interpret:
            kwargs["compiler_params"] = two_phase_compiler_params()
    else:  # pragma: no cover
        raise RuntimeError("Pallas TPU backend unavailable; use ops ref path")

    new_m, new_v, dw = pl.pallas_call(kernel, **kwargs)(
        corr, g_p, p_p, m_p, v_p
    )
    return new_m[:m_dim], new_v[:m_dim], dw[:m_dim, :n_dim]
