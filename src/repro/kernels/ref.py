"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *canonical semantics*: kernels must match them bit-for-bit in
fp32 (tests sweep shapes/dtypes with ``interpret=True``). They are also the
CPU execution path — ``ops.py`` dispatches to these off-TPU, so the whole
framework runs (slowly but exactly) in this container.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

QUANT_BLOCK = 256  # VPU lane width (128) x 2; absmax granularity for int8 states.
# Linear absmax int8 can quantize tiny second-moment entries to 0 while the
# first moment stays nonzero -> m/(sqrt(0)+eps) explodes (observed divergence
# in examples/finetune_compare.py). Dynamic-tree codebooks avoid this by
# construction; our TPU-friendly linear codec instead clips the bias-corrected
# update elementwise (normal Adam updates are |d| <~ 3, so 5 is inert).
QUANT_DELTA_CLIP = 5.0


# ---------------------------------------------------------------------------
# Fused COAP-Adam update (kernel: coap_update.py)
# ---------------------------------------------------------------------------
def coap_fused_update(
    g: jnp.ndarray,  # (m, n) canonical gradient tile
    p: jnp.ndarray,  # (n, r) projection
    m: jnp.ndarray,  # (m, r) first moment (fp32)
    v: jnp.ndarray,  # (m, r) second moment (fp32)
    count: jnp.ndarray,  # scalar int32, 1-based step for bias correction
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One projected-Adam step: G@P on the MXU + moment EMA + bias-corrected
    ΔW_proj epilogue. Returns (new_m, new_v, delta_w_proj) — all (m, r) fp32.
    Broadcasts over leading (layer/expert) stack axes.
    """
    g_proj = jnp.einsum(
        "...mn,...nr->...mr", g.astype(jnp.float32), p.astype(jnp.float32)
    )
    new_m = b1 * m + (1.0 - b1) * g_proj
    new_v = b2 * v + (1.0 - b2) * jnp.square(g_proj)
    t = count.astype(jnp.float32)
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t
    delta = (new_m / c1) / (jnp.sqrt(new_v / c2) + eps)
    return new_m, new_v, delta


# ---------------------------------------------------------------------------
# Block-wise absmax int8 quantization (kernel: quant8.py)
# ---------------------------------------------------------------------------
def _flat_padded(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def quantize_blockwise(
    x: jnp.ndarray, block: int = QUANT_BLOCK
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (any shape) -> (q int8 [nblocks, block], scale f32 [nblocks])."""
    flat, _ = _flat_padded(x.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(blocks * inv[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_blockwise(
    q: jnp.ndarray, scale: jnp.ndarray, shape: Tuple[int, ...], dtype=jnp.float32
) -> jnp.ndarray:
    """(q [nblocks, block], scale [nblocks]) -> original-shape array."""
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape).astype(dtype)


def quantized_adam_update(
    g_proj: jnp.ndarray,  # (m, r) fresh projected gradient
    m_q: jnp.ndarray,
    m_scale: jnp.ndarray,
    v_q: jnp.ndarray,
    v_scale: jnp.ndarray,
    count: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    block: int = QUANT_BLOCK,
):
    """Fused dequant -> Adam moment update -> requant (8-bit COAP step).

    Returns (new_m_q, new_m_scale, new_v_q, new_v_scale, delta_w_proj).
    """
    shape = g_proj.shape
    m = dequantize_blockwise(m_q, m_scale, shape)
    v = dequantize_blockwise(v_q, v_scale, shape)
    g32 = g_proj.astype(jnp.float32)
    new_m = b1 * m + (1.0 - b1) * g32
    new_v = b2 * v + (1.0 - b2) * jnp.square(g32)
    t = count.astype(jnp.float32)
    delta = (new_m / (1.0 - b1**t)) / (jnp.sqrt(new_v / (1.0 - b2**t)) + eps)
    delta = jnp.clip(delta, -QUANT_DELTA_CLIP, QUANT_DELTA_CLIP)
    nmq, nms = quantize_blockwise(new_m, block)
    nvq, nvs = quantize_blockwise(new_v, block)
    return nmq, nms, nvq, nvs, delta


# ---------------------------------------------------------------------------
# RMSNorm (kernel: rmsnorm.py) — model-side hot spot for long-context decode
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
