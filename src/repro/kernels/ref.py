"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *canonical semantics*: kernels must match them bit-for-bit in
fp32 (tests sweep shapes/dtypes with ``interpret=True``). They are also the
CPU execution path — ``ops.py`` dispatches to these off-TPU, so the whole
framework runs (slowly but exactly) in this container.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

QUANT_BLOCK = 256  # VPU lane width (128) x 2; absmax granularity for int8 states.
# Linear absmax int8 can quantize tiny second-moment entries to 0 while the
# first moment stays nonzero -> m/(sqrt(0)+eps) explodes (observed divergence
# in examples/finetune_compare.py). Dynamic-tree codebooks avoid this by
# construction; our TPU-friendly linear codec instead clips the bias-corrected
# update elementwise (normal Adam updates are |d| <~ 3, so 5 is inert).
QUANT_DELTA_CLIP = 5.0


# ---------------------------------------------------------------------------
# Fused COAP-Adam update (kernel: coap_update.py)
# ---------------------------------------------------------------------------
def coap_fused_update(
    g: jnp.ndarray,  # (m, n) canonical gradient tile
    p: jnp.ndarray,  # (n, r) projection
    m: jnp.ndarray,  # (m, r) first moment (fp32)
    v: jnp.ndarray,  # (m, r) second moment (fp32)
    count: jnp.ndarray,  # scalar int32, 1-based step for bias correction
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One projected-Adam step: G@P on the MXU + moment EMA + bias-corrected
    ΔW_proj epilogue. Returns (new_m, new_v, delta_w_proj) — all (m, r) fp32.
    Broadcasts over leading (layer/expert) stack axes.
    """
    g_proj = jnp.einsum(
        "...mn,...nr->...mr", g.astype(jnp.float32), p.astype(jnp.float32)
    )
    new_m = b1 * m + (1.0 - b1) * g_proj
    new_v = b2 * v + (1.0 - b2) * jnp.square(g_proj)
    t = count.astype(jnp.float32)
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t
    delta = (new_m / c1) / (jnp.sqrt(new_v / c2) + eps)
    return new_m, new_v, delta


def coap_fused_update_bp(
    g: jnp.ndarray,
    p: jnp.ndarray,
    m: jnp.ndarray,
    v: jnp.ndarray,
    count: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """``coap_fused_update`` with the back-projection fused in: returns
    (new_m, new_v, ΔW) where ``ΔW = Δ_proj Pᵀ`` is the full (m, n) canonical
    update — Δ_proj is never a caller-visible (HBM) tensor.
    """
    new_m, new_v, delta = coap_fused_update(g, p, m, v, count, b1, b2, eps)
    dw = jnp.einsum("...mr,...nr->...mn", delta, p.astype(jnp.float32))
    return new_m, new_v, dw


# ---------------------------------------------------------------------------
# Block-wise absmax int8 quantization (kernel: quant8.py)
# ---------------------------------------------------------------------------
def _flat_padded(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def quantize_blockwise(
    x: jnp.ndarray, block: int = QUANT_BLOCK
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (any shape) -> (q int8 [nblocks, block], scale f32 [nblocks])."""
    flat, _ = _flat_padded(x.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(blocks * inv[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_blockwise(
    q: jnp.ndarray, scale: jnp.ndarray, shape: Tuple[int, ...], dtype=jnp.float32
) -> jnp.ndarray:
    """(q [nblocks, block], scale [nblocks]) -> original-shape array."""
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape).astype(dtype)


def quantized_adam_update(
    g_proj: jnp.ndarray,  # (m, r) fresh projected gradient
    m_q: jnp.ndarray,
    m_scale: jnp.ndarray,
    v_q: jnp.ndarray,
    v_scale: jnp.ndarray,
    count: jnp.ndarray,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    block: int = QUANT_BLOCK,
):
    """Fused dequant -> Adam moment update -> requant (8-bit COAP step).

    Returns (new_m_q, new_m_scale, new_v_q, new_v_scale, delta_w_proj).
    """
    shape = g_proj.shape
    m = dequantize_blockwise(m_q, m_scale, shape)
    v = dequantize_blockwise(v_q, v_scale, shape)
    g32 = g_proj.astype(jnp.float32)
    new_m = b1 * m + (1.0 - b1) * g32
    new_v = b2 * v + (1.0 - b2) * jnp.square(g32)
    t = count.astype(jnp.float32)
    delta = (new_m / (1.0 - b1**t)) / (jnp.sqrt(new_v / (1.0 - b2**t)) + eps)
    delta = jnp.clip(delta, -QUANT_DELTA_CLIP, QUANT_DELTA_CLIP)
    nmq, nms = quantize_blockwise(new_m, block)
    nvq, nvs = quantize_blockwise(new_v, block)
    return nmq, nms, nvq, nvs, delta


# ---------------------------------------------------------------------------
# Row-block int8 codec + single-pass fused 8-bit COAP step (kernel: quant8.py)
# ---------------------------------------------------------------------------
# The flat codec above views a tensor as (nblocks, 256) after ravel — fine
# for dense Adam states, but its blocks straddle row boundaries of an
# (..., m, r) moment, so a kernel tiled over rows cannot dequantize a tile
# without neighbouring rows' scales. The ROW-BLOCK codec quantizes along the
# LAST axis only: each row carries ceil(r/block) scales for its own
# ``block``-wide segments (ragged tail allowed). Row tiles are then
# self-contained: (bm, r) int8 + (bm, nblk) scales dequantize in VMEM with
# no cross-tile traffic, which is what lets the 8-bit optimizer step run as
# ONE kernel. For r a multiple of ``block`` the codes are identical to the
# flat codec's; only the scale layout differs.


def rowblock_nblocks(r: int, block: int = QUANT_BLOCK) -> int:
    return -(-int(r) // int(block))


def quantize_rowblock(
    x: jnp.ndarray, block: int = QUANT_BLOCK
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., r) -> (q int8 (..., r), scale f32 (..., nblk))."""
    r = x.shape[-1]
    nblk = rowblock_nblocks(r, block)
    pad = nblk * block - r
    x32 = x.astype(jnp.float32)
    if pad:
        x32 = jnp.pad(x32, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    b = x32.reshape(x.shape[:-1] + (nblk, block))
    absmax = jnp.max(jnp.abs(b), axis=-1)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(b * inv[..., None]), -127, 127)
    q = q.reshape(x.shape[:-1] + (nblk * block,))[..., :r]
    return q.astype(jnp.int8), scale


def dequantize_rowblock(
    q: jnp.ndarray,
    scale: jnp.ndarray,
    block: int = QUANT_BLOCK,
    dtype=jnp.float32,
) -> jnp.ndarray:
    """(q (..., r), scale (..., nblk)) -> fp tensor of q's shape."""
    r = q.shape[-1]
    nblk = scale.shape[-1]
    pad = nblk * block - r
    q32 = q.astype(jnp.float32)
    if pad:
        q32 = jnp.pad(q32, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    b = q32.reshape(q.shape[:-1] + (nblk, block)) * scale[..., None]
    return b.reshape(q.shape[:-1] + (nblk * block,))[..., :r].astype(dtype)


def rowblock_code_stats(
    q: jnp.ndarray, scale: jnp.ndarray, block: int = QUANT_BLOCK
) -> dict:
    """Codec-health stats of a row-block-coded tensor (``obs/health``).

    Absmax scaling never clips by construction (the block max maps onto
    ±127 exactly), so "saturation" here is the EXCESS rail fraction: the
    share of codes at |q| == 127 beyond the one absmax element each
    nonzero block is guaranteed to park there. That baseline-corrects the
    metric against block geometry (a rank-4 moment row has 1/4 of its
    codes at the rail when healthy) — ~0 for a well-spread block, rising
    when a block's mass collapses onto its absmax — complemented by the
    non-finite-scale fraction (an inf/nan input poisons its block's
    absmax, the loud overflow signal the int8-v underflow/overflow guards
    key on). ``err_rel`` is the uniform quant-noise model:
    rms(step)/sqrt(12) over rms(value), with step == scale
    (scale = absmax/127 IS the quantization step).
    Returns jnp scalars (caller does one device_get)."""
    absq = jnp.abs(q.astype(jnp.int32))
    n_codes = jnp.asarray(absq.size, jnp.float32)
    n_rail = jnp.sum((absq == 127).astype(jnp.float32))
    # One guaranteed rail element per block that has any nonzero code.
    finite0 = jnp.isfinite(scale)
    n_live = jnp.sum(
        ((scale > 0) | ~finite0).astype(jnp.float32)
    )
    sat_rate = jnp.maximum(n_rail - n_live, 0.0) / jnp.maximum(n_codes, 1.0)
    finite = finite0
    nonfinite = 1.0 - jnp.mean(finite.astype(jnp.float32))
    safe_scale = jnp.where(finite, scale, 0.0)
    n_finite = jnp.maximum(jnp.sum(finite.astype(jnp.float32)), 1.0)
    step_ms = jnp.sum(jnp.square(safe_scale)) / n_finite
    err_rms = jnp.sqrt(step_ms / 12.0)
    deq = dequantize_rowblock(q, safe_scale, block)
    val_rms = jnp.sqrt(jnp.mean(jnp.square(deq)))
    return {
        "sat_rate": sat_rate,
        "scale_nonfinite": nonfinite,
        "err_rel": err_rms / jnp.maximum(val_rms, 1e-30),
    }


def coap_fused_update_q8(
    g: jnp.ndarray,  # (..., m, n) canonical gradient
    p: jnp.ndarray,  # (..., n, r) projection
    m_q: jnp.ndarray,  # (..., m, r) int8 first moment (row-block codec)
    m_scale: jnp.ndarray,  # (..., m, nblk) f32
    v_q: jnp.ndarray,
    v_scale: jnp.ndarray,
    count: jnp.ndarray,  # scalar int32, 1-based step
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    block: int = QUANT_BLOCK,
):
    """One 8-bit COAP step in a single logical pass (the paper's quantized
    hot loop): project ``G P``, dequantize the int8 moments, moment EMA +
    bias-corrected Δ with the underflow clip, requantize M'/V', and
    back-project ``Δ Pᵀ``. Neither fp32 moments nor Δ_proj are caller-visible
    tensors. Returns (new_m_q, new_m_scale, new_v_q, new_v_scale, ΔW).
    """
    m = dequantize_rowblock(m_q, m_scale, block)
    v = dequantize_rowblock(v_q, v_scale, block)
    g_proj = jnp.einsum(
        "...mn,...nr->...mr", g.astype(jnp.float32), p.astype(jnp.float32)
    )
    new_m = b1 * m + (1.0 - b1) * g_proj
    new_v = b2 * v + (1.0 - b2) * jnp.square(g_proj)
    t = count.astype(jnp.float32)
    delta = (new_m / (1.0 - b1**t)) / (jnp.sqrt(new_v / (1.0 - b2**t)) + eps)
    delta = jnp.clip(delta, -QUANT_DELTA_CLIP, QUANT_DELTA_CLIP)
    dw = jnp.einsum("...mr,...nr->...mn", delta, p.astype(jnp.float32))
    nmq, nms = quantize_rowblock(new_m, block)
    nvq, nvs = quantize_rowblock(new_v, block)
    return nmq, nms, nvq, nvs, dw


# ---------------------------------------------------------------------------
# Fused Eqn-6 refresh (kernel: eqn6.py)
# ---------------------------------------------------------------------------
def eqn6_sgd_update(
    p: jnp.ndarray,  # (..., n, r) projection
    g: jnp.ndarray,  # (..., m, n) canonical gradient (fp32 or bf16)
    m_proj: jnp.ndarray,  # (..., m, r) projected first moment
    lr: float = 0.1,
    steps: int = 1,
    normalize: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for the fused Eqn-6 kernel: ``steps`` SGD iterations on the
    paper's Eqn-6 objective. The closed-form math lives in
    ``core/correlation.py`` (single source of truth — lazily imported here
    because core sits above the kernels layer); this wrapper only re-exposes
    it in the kernel's signature: returns ``(new_p, last_val, last_grad)``
    where val/grad belong to the last iteration's pre-update P.
    ``normalize=True`` pre-scales G and M_proj by 1/rms(G) exactly as
    ``correlation.sgd_update(normalize=True)`` does (the kernel's first
    grid phase computes the same factor).
    """
    from repro.core import correlation  # lazy: avoids core<->kernels cycle

    p32 = p.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    mp32 = m_proj.astype(jnp.float32)
    if normalize:
        rms = jnp.sqrt(
            jnp.mean(jnp.square(g32), axis=(-1, -2), keepdims=True)
        ) + correlation._EPS
        g32 = g32 / rms
        mp32 = mp32 / rms

    def body(_, carry):
        p_cur, _, _ = carry
        val, grad = correlation.loss_and_grad(p_cur, g32, mp32)
        return (p_cur - lr * grad, val, grad)

    init = (p32, jnp.zeros(g.shape[:-2], jnp.float32), jnp.zeros_like(p32))
    new_p, val, grad = jax.lax.fori_loop(0, steps, body, init)
    return new_p.astype(p.dtype), val, grad


# ---------------------------------------------------------------------------
# RMSNorm (kernel: rmsnorm.py) — model-side hot spot for long-context decode
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
