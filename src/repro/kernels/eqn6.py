"""Single-pass fused Eqn-6 refresh kernel (loss+grad+SGD step over one G sweep).

The unfused refresh (``core/correlation.loss_and_grad`` as separate einsum
dispatches) streams the full m×n gradient from HBM ~6 times per SGD step:
``GP``, ``GᵀGP``, ``Gᵀ(GP·PᵀP)``, the MSE value (via Ĝ), the row-cosine
D-term, and ``DᵀM_proj`` each re-read G or an m×n intermediate. This kernel
computes the exact same closed-form value+gradient in ONE tiled sweep over
G's row-blocks, because every Eqn-6 term reduces to accumulators that are
local to a (bm, n) row tile:

    A  = (GP)ᵀ(GP)              (r, r)   MXU, per-tile gpᵀgp
    C  = Gᵀ(GP)                 (n, r)   MXU, per-tile Gᵀgp
    E  = Σᵢ αᵢ Gᵢᵀ M_projᵢ      (n, r)   αᵢ from row norms (VPU, local)
    F  = Σᵢ βᵢ M_projᵢᵀM_projᵢ  (r, r)
    ‖G‖²_F, Σᵢ cosᵢ             scalars (SMEM)

with the non-local pieces recovered at sweep end WITHOUT re-reading G:

    t3      = Gᵀ(GP·PᵀP) = C·PᵀP          (PᵀP from resident P)
    ‖Ĝ‖²_F  = ⟨A, PᵀP⟩,  ⟨Ĝ, G⟩ = tr(A)   (so MSE needs no Ĝ materialized)
    ‖M̂ᵢ‖²  = rowᵢ(M_proj·PᵀP)·M_projᵢ     (so M̂ is never formed)
    ∂Cos    = DᵀM_proj = E − P·F           (D is never formed)

The epilogue combines the product rule (see core/correlation.py for the
paper-typo note) and applies ``P ← P − lr·∇`` to the VMEM-resident P, so a
refresh streams G exactly ``steps`` times (grid = (steps, m/bm)) and writes
only (n, r)-sized outputs — no m×n intermediate ever exists in HBM.

bf16 gradient streaming: G (and M_proj) tiles are upcast to fp32 in VMEM
after the DMA, so bf16 training halves refresh G traffic with fp32 math.

VMEM budget: six (n, r) fp32 buffers stay resident — the P input block, the
new-P and grad output blocks, and the P/C/E scratch — plus A/F/PᵀP (3·r²),
one (bm, n) G tile and one (bm, r) M tile. At LLaMA-1B attention shapes
(n=2048, r=512) that is ~25 MB of (n, r) buffers alone, OVER the 16 MB/core
budget: the compiled TPU path currently fits r ≤ 256 at n=2048 (~13 MB with
bm=256). Larger n·r needs an n-split variant, dropping the grad output, or
smaller blocks — ROADMAP open item ("Eqn-6 kernel n-split variant");
interpret mode (the CPU test path) is unconstrained.

``eqn6_normalize=True`` (scale-invariant variant) needs a ‖G‖ pre-pass and
is NOT fused — callers fall back to the jnp path (see correlation.sgd_update).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only compiler params; absent/renamed on some builds
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    _HAS_PLTPU = False

from repro.kernels.coap_update import _pad_to as _pad_to_axis

DEFAULT_BM = 256
_EPS = 1e-12  # must match core/correlation._EPS exactly (oracle parity)


def _sequential_compiler_params():
    """Both grid dims carry state (SGD steps outer, row sweep inner)."""
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        )
    except Exception:  # older naming
        return pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        )


def _eqn6_kernel(p_ref, g_ref, mp_ref, p_out_ref, val_ref, grad_ref,
                 p_s, ptp_s, a_s, c_s, e_s, f_s, sc_s,
                 *, lr, nm, m_true, n_true, eps):
    s = pl.program_id(0)  # SGD step
    k = pl.program_id(1)  # row-block of G

    @pl.when((s == 0) & (k == 0))
    def _load_p():
        p_s[...] = p_ref[...].astype(jnp.float32)

    @pl.when(k == 0)
    def _start_sweep():
        # PᵀP from the resident (possibly already-updated) P.
        ptp_s[...] = jax.lax.dot_general(
            p_s[...], p_s[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        a_s[...] = jnp.zeros_like(a_s)
        c_s[...] = jnp.zeros_like(c_s)
        e_s[...] = jnp.zeros_like(e_s)
        f_s[...] = jnp.zeros_like(f_s)
        sc_s[0] = 0.0
        sc_s[1] = 0.0

    # ---- per-row-block accumulation (G/M tiles upcast in VMEM) ----------
    g = g_ref[...].astype(jnp.float32)  # (bm, n)
    mp = mp_ref[...].astype(jnp.float32)  # (bm, r)
    gp = jnp.dot(g, p_s[...], preferred_element_type=jnp.float32)  # (bm, r)
    a_s[...] += jax.lax.dot_general(
        gp, gp, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    c_s[...] += jax.lax.dot_general(
        g, gp, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    gn2 = jnp.sum(g * g, axis=1, keepdims=True)  # (bm, 1)
    sc_s[0] = sc_s[0] + jnp.sum(gn2)
    # ‖M̂ᵢ‖² and ⟨M̂ᵢ, Gᵢ⟩ via PᵀP / GP — M̂ never formed. Padded rows
    # (zero G and M) contribute exactly 0 everywhere: denom reduces to eps
    # and every numerator is 0.
    w = jnp.dot(mp, ptp_s[...], preferred_element_type=jnp.float32)
    mh2 = jnp.sum(w * mp, axis=1, keepdims=True)
    inner = jnp.sum(mp * gp, axis=1, keepdims=True)
    mh = jnp.sqrt(mh2)
    gn = jnp.sqrt(gn2)
    denom = mh * gn + eps
    sc_s[1] = sc_s[1] + jnp.sum(inner / denom)
    alpha = 1.0 / (m_true * denom)
    beta = inner / (m_true * (mh * mh2 * gn + eps))  # mh³ = mh·mh²
    e_s[...] += jax.lax.dot_general(
        g, alpha * mp, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    f_s[...] += jax.lax.dot_general(
        beta * mp, mp, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nm - 1)
    def _finalize():
        a = a_s[...]
        ptp = ptp_s[...]
        c = c_s[...]
        p_cur = p_s[...]
        r = a.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
        tr_a = jnp.sum(jnp.where(row == col, a, 0.0))  # ⟨Ĝ, G⟩
        mn = m_true * n_true
        v_mse = (jnp.sum(a * ptp) - 2.0 * tr_a + sc_s[0]) / mn
        g_mse = (2.0 / mn) * (
            jnp.dot(p_cur, a, preferred_element_type=jnp.float32)
            - 2.0 * c
            + jnp.dot(c, ptp, preferred_element_type=jnp.float32)
        )
        v_cos = sc_s[1] / m_true
        g_cos = e_s[...] - jnp.dot(
            p_cur, f_s[...], preferred_element_type=jnp.float32
        )
        grad = g_mse * (1.0 - v_cos) - g_cos * v_mse
        val_ref[0] = v_mse * (1.0 - v_cos)
        grad_ref[...] = grad
        new_p = p_cur - lr * grad
        p_s[...] = new_p  # next SGD step (outer grid dim) sees the update
        p_out_ref[...] = new_p


@functools.partial(
    jax.jit, static_argnames=("lr", "steps", "eps", "interpret", "bm")
)
def eqn6_sgd_update_pallas(
    p, g, m_proj, lr=0.1, steps=1, eps=_EPS,
    interpret: bool = False, bm: int = DEFAULT_BM,
):
    """Fused Eqn-6 refresh. p (...,n,r), g (...,m,n), m_proj (...,m,r) ->
    (new_p, last_val, last_grad); grad/val are those of the LAST SGD step
    (computed at the pre-update P, like the oracle). Broadcasts over leading
    (layer/expert) stack axes via vmap; g/m_proj may be bf16 (upcast
    per-tile in VMEM)."""
    if g.ndim > 2:
        fn = functools.partial(
            eqn6_sgd_update_pallas, lr=lr, steps=steps, eps=eps,
            interpret=interpret, bm=bm,
        )
        for _ in range(g.ndim - 2):
            fn = jax.vmap(fn, in_axes=(0, 0, 0))
        return fn(p, g, m_proj)

    m_dim, n_dim = g.shape
    r = p.shape[-1]
    bm_eff = min(bm, max(8, m_dim))
    # Zero padding is exact: padded G rows/cols and M rows/cols contribute 0
    # to every accumulator, and padded P rows/cols stay 0 through the update
    # (their gradient is identically 0) — sliced off on exit.
    g_p = _pad_to_axis(_pad_to_axis(g, bm_eff, 0), 128, 1)
    mp_p = _pad_to_axis(_pad_to_axis(m_proj, bm_eff, 0), 128, 1)
    p_p = _pad_to_axis(_pad_to_axis(p, 128, 0), 128, 1)
    mp_pad, np_pad = g_p.shape
    r_pad = p_p.shape[1]
    nm = mp_pad // bm_eff
    grid = (steps, nm)

    kernel = functools.partial(
        _eqn6_kernel, lr=lr, nm=nm,
        m_true=float(m_dim), n_true=float(n_dim), eps=eps,
    )
    out_shape = [
        jax.ShapeDtypeStruct((np_pad, r_pad), jnp.float32),  # new P
        jax.ShapeDtypeStruct((1,), jnp.float32),  # last objective value
        jax.ShapeDtypeStruct((np_pad, r_pad), jnp.float32),  # last grad
    ]
    in_specs = [
        pl.BlockSpec((np_pad, r_pad), lambda s, k: (0, 0)),  # P (resident)
        pl.BlockSpec((bm_eff, np_pad), lambda s, k: (k, 0)),  # G row-block
        pl.BlockSpec((bm_eff, r_pad), lambda s, k: (k, 0)),  # M_proj rows
    ]
    out_specs = [
        pl.BlockSpec((np_pad, r_pad), lambda s, k: (0, 0)),
        pl.BlockSpec((1,), lambda s, k: (0,)),
        pl.BlockSpec((np_pad, r_pad), lambda s, k: (0, 0)),
    ]
    kwargs = dict(
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    if _HAS_PLTPU:
        kwargs["scratch_shapes"] = [
            pltpu.VMEM((np_pad, r_pad), jnp.float32),  # resident P
            pltpu.VMEM((r_pad, r_pad), jnp.float32),  # PᵀP
            pltpu.VMEM((r_pad, r_pad), jnp.float32),  # A
            pltpu.VMEM((np_pad, r_pad), jnp.float32),  # C
            pltpu.VMEM((np_pad, r_pad), jnp.float32),  # E
            pltpu.VMEM((r_pad, r_pad), jnp.float32),  # F
            pltpu.SMEM((2,), jnp.float32),  # ‖G‖², Σ row-cos
        ]
        if not interpret:
            kwargs["compiler_params"] = _sequential_compiler_params()
    else:  # pragma: no cover
        raise RuntimeError("Pallas TPU backend unavailable; use ops ref path")

    p_new, val, grad = pl.pallas_call(kernel, **kwargs)(p_p, g_p, mp_p)
    return (
        p_new[:n_dim, :r].astype(p.dtype),
        val[0],
        grad[:n_dim, :r],
    )
