"""Single-pass fused Eqn-6 refresh kernel (loss+grad+SGD step over one G sweep).

The unfused refresh (``core/correlation.loss_and_grad`` as separate einsum
dispatches) streams the full m×n gradient from HBM ~6 times per SGD step:
``GP``, ``GᵀGP``, ``Gᵀ(GP·PᵀP)``, the MSE value (via Ĝ), the row-cosine
D-term, and ``DᵀM_proj`` each re-read G or an m×n intermediate. This kernel
computes the exact same closed-form value+gradient in ONE tiled sweep over
G's row-blocks, because every Eqn-6 term reduces to accumulators that are
local to a (bm, n) row tile:

    A  = (GP)ᵀ(GP)              (r, r)   MXU, per-tile gpᵀgp
    C  = Gᵀ(GP)                 (n, r)   MXU, per-tile Gᵀgp
    E  = Σᵢ αᵢ Gᵢᵀ M_projᵢ      (n, r)   αᵢ from row norms (VPU, local)
    F  = Σᵢ βᵢ M_projᵢᵀM_projᵢ  (r, r)
    ‖G‖²_F, Σᵢ cosᵢ             scalars (SMEM)

with the non-local pieces recovered at sweep end WITHOUT re-reading G:

    t3      = Gᵀ(GP·PᵀP) = C·PᵀP          (PᵀP from resident P)
    ‖Ĝ‖²_F  = ⟨A, PᵀP⟩,  ⟨Ĝ, G⟩ = tr(A)   (so MSE needs no Ĝ materialized)
    ‖M̂ᵢ‖²  = rowᵢ(M_proj·PᵀP)·M_projᵢ     (so M̂ is never formed)
    ∂Cos    = DᵀM_proj = E − P·F           (D is never formed)

The epilogue combines the product rule (see core/correlation.py for the
paper-typo note) and applies ``P ← P − lr·∇`` to the VMEM-resident P, so a
refresh streams G exactly ``steps`` times (grid = (steps, m/bm)) and writes
only (n, r)-sized outputs — no m×n intermediate ever exists in HBM.

bf16 gradient streaming: G (and M_proj) tiles are upcast to fp32 in VMEM
after the DMA, so bf16 training halves refresh G traffic with fp32 math.

``normalize=True`` (the beyond-paper scale-invariant variant) IS fused: the
required ‖G‖ pre-pass runs as a FIRST GRID PHASE — grid becomes
(1 + steps, m/bm), phase s=0 only accumulates Σ‖G‖²_F into SMEM and derives
``1/rms`` at its last row-block; every update sweep then scales the G and
M_proj tiles by that factor in VMEM, exactly matching the jnp oracle
(``correlation.sgd_update(normalize=True)``: G/rms and M_proj/rms with
rms = √mean(G²) + 1e-12). One extra G stream per refresh, still zero m×n
HBM intermediates.

VMEM GUARD. Six (n, r) fp32 buffers stay resident — the P input block, the
new-P and grad output blocks, and the P/C/E scratch — plus A/F/PᵀP (3·r²)
and one (bm, n) G + (bm, r) M tile. At LLaMA-1B attention shapes (n=2048,
r=512) the (n, r) buffers alone are ~25 MB, over the 16 MB/core budget.
:func:`plan_bm` estimates the footprint at trace time (``eqn6_vmem_bytes``)
and auto-shrinks ``bm`` (halving, floor 8) until the tile traffic fits; if
the bm-independent resident buffers already exceed the budget it returns
``None`` and :func:`eqn6_sgd_update_pallas` raises :class:`Eqn6VmemError`,
which ``kernels/ops.eqn6_sgd_update`` catches to fall back to the unfused
jnp path (identical numerics by construction). Budget: the
``vmem_budget`` argument, else ``REPRO_EQN6_VMEM_BUDGET`` (bytes), else
16 MiB. A true n-split kernel variant remains a ROADMAP item; the guard
makes wide layers *correct* (never a kernel that cannot fit), not fast.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-only compiler params; absent/renamed on some builds
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    _HAS_PLTPU = False

from repro.kernels.coap_update import _pad_to as _pad_to_axis

DEFAULT_BM = 256
_EPS = 1e-12  # must match core/correlation._EPS exactly (oracle parity)
_MIN_BM = 8
_DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024  # bytes/core, TPU VMEM
_VMEM_ENV = "REPRO_EQN6_VMEM_BUDGET"


class Eqn6VmemError(RuntimeError):
    """The fused Eqn-6 kernel cannot fit VMEM at any row-tile size."""


def _round_up(x: int, mult: int) -> int:
    return -(-int(x) // mult) * mult


def _vmem_budget(budget=None) -> int:
    if budget is not None:
        return int(budget)
    return int(os.environ.get(_VMEM_ENV, _DEFAULT_VMEM_BUDGET))


def eqn6_vmem_bytes(bm: int, n: int, r: int, g_itemsize: int = 4,
                    mp_itemsize: int = 4) -> int:
    """Trace-time VMEM footprint estimate for one (n, r, bm) tiling.

    Conservative: counts the six resident (n_pad, r_pad) fp32 buffers, the
    three r_pad² accumulators, and the G/M row tiles BOTH as their DMA'd
    dtype and as the in-VMEM fp32 upcast."""
    n_pad = _round_up(n, 128)
    r_pad = _round_up(r, 128)
    fixed = 4 * (6 * n_pad * r_pad + 3 * r_pad * r_pad)
    tiles = bm * n_pad * (g_itemsize + 4) + bm * r_pad * (mp_itemsize + 4)
    return fixed + tiles


def plan_bm(m: int, n: int, r: int, bm: int = DEFAULT_BM,
            g_itemsize: int = 4, mp_itemsize: int = 4, budget=None):
    """Largest feasible row-tile ≤ ``bm`` under the VMEM budget, or None.

    Halves ``bm`` down to 8 while the estimated footprint exceeds the
    budget; returns ``None`` when even bm=8 cannot fit (the resident (n, r)
    buffers are bm-independent — wide layers must fall back to the unfused
    path until the n-split variant lands)."""
    budget = _vmem_budget(budget)
    bm_eff = min(int(bm), max(_MIN_BM, int(m)))
    while True:
        if eqn6_vmem_bytes(bm_eff, n, r, g_itemsize, mp_itemsize) <= budget:
            return bm_eff
        if bm_eff <= _MIN_BM:
            return None
        bm_eff = max(_MIN_BM, bm_eff // 2)


def _sequential_compiler_params():
    """Both grid dims carry state (SGD steps outer, row sweep inner)."""
    try:
        return pltpu.CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        )
    except Exception:  # older naming
        return pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")
        )


def _eqn6_kernel(p_ref, g_ref, mp_ref, p_out_ref, val_ref, grad_ref,
                 p_s, ptp_s, a_s, c_s, e_s, f_s, sc_s,
                 *, lr, nm, m_true, n_true, eps, normalize):
    s = pl.program_id(0)  # SGD step (shifted +1 when normalize: s=0 = ‖G‖)
    k = pl.program_id(1)  # row-block of G

    @pl.when((s == 0) & (k == 0))
    def _load_p():
        p_s[...] = p_ref[...].astype(jnp.float32)
        if normalize:
            sc_s[2] = 0.0
            sc_s[3] = 1.0

    if normalize:
        # ---- first grid phase: ‖G‖ pre-pass (no P math, no outputs) -----
        @pl.when(s == 0)
        def _norm_accum():
            g = g_ref[...].astype(jnp.float32)
            sc_s[2] = sc_s[2] + jnp.sum(g * g)

        @pl.when((s == 0) & (k == nm - 1))
        def _norm_final():
            # Matches the oracle: rms = sqrt(mean(G²)) + _EPS (padded
            # rows/cols are zero, so the tile sum IS the true Σ G²).
            rms = jnp.sqrt(sc_s[2] / (m_true * n_true)) + eps
            sc_s[3] = 1.0 / rms

    def _update_sweep():
        @pl.when(k == 0)
        def _start_sweep():
            # PᵀP from the resident (possibly already-updated) P.
            ptp_s[...] = jax.lax.dot_general(
                p_s[...], p_s[...],
                dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            a_s[...] = jnp.zeros_like(a_s)
            c_s[...] = jnp.zeros_like(c_s)
            e_s[...] = jnp.zeros_like(e_s)
            f_s[...] = jnp.zeros_like(f_s)
            sc_s[0] = 0.0
            sc_s[1] = 0.0

        # ---- per-row-block accumulation (G/M tiles upcast in VMEM) ------
        g = g_ref[...].astype(jnp.float32)  # (bm, n)
        mp = mp_ref[...].astype(jnp.float32)  # (bm, r)
        if normalize:  # scale-invariant variant: tiles scaled by 1/rms
            g = g * sc_s[3]
            mp = mp * sc_s[3]
        gp = jnp.dot(g, p_s[...], preferred_element_type=jnp.float32)  # (bm, r)
        a_s[...] += jax.lax.dot_general(
            gp, gp, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        c_s[...] += jax.lax.dot_general(
            g, gp, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        gn2 = jnp.sum(g * g, axis=1, keepdims=True)  # (bm, 1)
        sc_s[0] = sc_s[0] + jnp.sum(gn2)
        # ‖M̂ᵢ‖² and ⟨M̂ᵢ, Gᵢ⟩ via PᵀP / GP — M̂ never formed. Padded rows
        # (zero G and M) contribute exactly 0 everywhere: denom reduces to
        # eps and every numerator is 0.
        w = jnp.dot(mp, ptp_s[...], preferred_element_type=jnp.float32)
        mh2 = jnp.sum(w * mp, axis=1, keepdims=True)
        inner = jnp.sum(mp * gp, axis=1, keepdims=True)
        mh = jnp.sqrt(mh2)
        gn = jnp.sqrt(gn2)
        denom = mh * gn + eps
        sc_s[1] = sc_s[1] + jnp.sum(inner / denom)
        alpha = 1.0 / (m_true * denom)
        beta = inner / (m_true * (mh * mh2 * gn + eps))  # mh³ = mh·mh²
        e_s[...] += jax.lax.dot_general(
            g, alpha * mp, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        f_s[...] += jax.lax.dot_general(
            beta * mp, mp, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(k == nm - 1)
        def _finalize():
            a = a_s[...]
            ptp = ptp_s[...]
            c = c_s[...]
            p_cur = p_s[...]
            r = a.shape[0]
            row = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)
            col = jax.lax.broadcasted_iota(jnp.int32, (r, r), 1)
            tr_a = jnp.sum(jnp.where(row == col, a, 0.0))  # ⟨Ĝ, G⟩
            mn = m_true * n_true
            v_mse = (jnp.sum(a * ptp) - 2.0 * tr_a + sc_s[0]) / mn
            g_mse = (2.0 / mn) * (
                jnp.dot(p_cur, a, preferred_element_type=jnp.float32)
                - 2.0 * c
                + jnp.dot(c, ptp, preferred_element_type=jnp.float32)
            )
            v_cos = sc_s[1] / m_true
            g_cos = e_s[...] - jnp.dot(
                p_cur, f_s[...], preferred_element_type=jnp.float32
            )
            grad = g_mse * (1.0 - v_cos) - g_cos * v_mse
            val_ref[0] = v_mse * (1.0 - v_cos)
            grad_ref[...] = grad
            new_p = p_cur - lr * grad
            p_s[...] = new_p  # next SGD step (outer grid dim) sees the update
            p_out_ref[...] = new_p

    if normalize:
        pl.when(s >= 1)(_update_sweep)
    else:
        _update_sweep()


@functools.partial(
    jax.jit,
    static_argnames=("lr", "steps", "eps", "interpret", "bm", "normalize",
                     "vmem_budget"),
)
def eqn6_sgd_update_pallas(
    p, g, m_proj, lr=0.1, steps=1, eps=_EPS,
    interpret: bool = False, bm: int = DEFAULT_BM,
    normalize: bool = False, vmem_budget=None,
):
    """Fused Eqn-6 refresh. p (...,n,r), g (...,m,n), m_proj (...,m,r) ->
    (new_p, last_val, last_grad); grad/val are those of the LAST SGD step
    (computed at the pre-update P, like the oracle). Broadcasts over leading
    (layer/expert) stack axes via vmap; g/m_proj may be bf16 (upcast
    per-tile in VMEM). ``normalize=True`` runs the ‖G‖ pre-pass as a first
    grid phase (module docstring). Raises :class:`Eqn6VmemError` when the
    estimated VMEM footprint cannot fit at any row-tile size."""
    if g.ndim > 2:
        fn = functools.partial(
            eqn6_sgd_update_pallas, lr=lr, steps=steps, eps=eps,
            interpret=interpret, bm=bm, normalize=normalize,
            vmem_budget=vmem_budget,
        )
        for _ in range(g.ndim - 2):
            fn = jax.vmap(fn, in_axes=(0, 0, 0))
        return fn(p, g, m_proj)

    m_dim, n_dim = g.shape
    r = p.shape[-1]
    bm_eff = plan_bm(
        m_dim, n_dim, r, bm=bm,
        g_itemsize=jnp.dtype(g.dtype).itemsize,
        mp_itemsize=jnp.dtype(m_proj.dtype).itemsize,
        budget=vmem_budget,
    )
    if bm_eff is None:
        raise Eqn6VmemError(
            f"fused Eqn-6 at (m={m_dim}, n={n_dim}, r={r}) needs "
            f"{eqn6_vmem_bytes(_MIN_BM, n_dim, r)} bytes of VMEM at the "
            f"smallest tile — over the {_vmem_budget(vmem_budget)}-byte "
            "budget; falling back to the unfused path (ROADMAP: n-split "
            "variant)"
        )
    # Zero padding is exact: padded G rows/cols and M rows/cols contribute 0
    # to every accumulator, and padded P rows/cols stay 0 through the update
    # (their gradient is identically 0) — sliced off on exit.
    g_p = _pad_to_axis(_pad_to_axis(g, bm_eff, 0), 128, 1)
    mp_p = _pad_to_axis(_pad_to_axis(m_proj, bm_eff, 0), 128, 1)
    p_p = _pad_to_axis(_pad_to_axis(p, 128, 0), 128, 1)
    mp_pad, np_pad = g_p.shape
    r_pad = p_p.shape[1]
    nm = mp_pad // bm_eff
    grid = (steps + (1 if normalize else 0), nm)

    kernel = functools.partial(
        _eqn6_kernel, lr=lr, nm=nm,
        m_true=float(m_dim), n_true=float(n_dim), eps=eps,
        normalize=normalize,
    )
    out_shape = [
        jax.ShapeDtypeStruct((np_pad, r_pad), jnp.float32),  # new P
        jax.ShapeDtypeStruct((1,), jnp.float32),  # last objective value
        jax.ShapeDtypeStruct((np_pad, r_pad), jnp.float32),  # last grad
    ]
    in_specs = [
        pl.BlockSpec((np_pad, r_pad), lambda s, k: (0, 0)),  # P (resident)
        pl.BlockSpec((bm_eff, np_pad), lambda s, k: (k, 0)),  # G row-block
        pl.BlockSpec((bm_eff, r_pad), lambda s, k: (k, 0)),  # M_proj rows
    ]
    out_specs = [
        pl.BlockSpec((np_pad, r_pad), lambda s, k: (0, 0)),
        pl.BlockSpec((1,), lambda s, k: (0,)),
        pl.BlockSpec((np_pad, r_pad), lambda s, k: (0, 0)),
    ]
    kwargs = dict(
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )
    if _HAS_PLTPU:
        kwargs["scratch_shapes"] = [
            pltpu.VMEM((np_pad, r_pad), jnp.float32),  # resident P
            pltpu.VMEM((r_pad, r_pad), jnp.float32),  # PᵀP
            pltpu.VMEM((r_pad, r_pad), jnp.float32),  # A
            pltpu.VMEM((np_pad, r_pad), jnp.float32),  # C
            pltpu.VMEM((np_pad, r_pad), jnp.float32),  # E
            pltpu.VMEM((r_pad, r_pad), jnp.float32),  # F
            pltpu.SMEM((4,), jnp.float32),  # ‖G‖², Σ row-cos, ΣG²_raw, 1/rms
        ]
        if not interpret:
            kwargs["compiler_params"] = _sequential_compiler_params()
    else:  # pragma: no cover
        raise RuntimeError("Pallas TPU backend unavailable; use ops ref path")

    p_new, val, grad = pl.pallas_call(kernel, **kwargs)(p_p, g_p, mp_p)
    return (
        p_new[:n_dim, :r].astype(p.dtype),
        val[0],
        grad[:n_dim, :r],
    )
