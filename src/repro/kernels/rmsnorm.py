"""Fused RMSNorm kernel — the per-token normalization on the serving path.

Grid over row-tiles of the flattened (tokens, d_model) activations; each
program normalizes ``bm`` rows in VMEM (reduce + rsqrt + scale in one pass,
fp32 math, input-dtype output). d_model up to 8192 fits comfortably:
bm=256 rows × 8192 × 4B = 8MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret", "bm"))
def rmsnorm_pallas(x, scale, eps=1e-6, interpret=False, bm=256):
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    bm_eff = min(bm, rows)
    pad = (-rows) % bm_eff
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // bm_eff,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_eff, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm_eff, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
