"""Block-wise absmax int8 quantization kernels (8-bit COAP states).

Layout: optimizer tensors are viewed as (nblocks, 256) — 256 = 2×VPU lane
width — with one fp32 scale per block. Three kernels:

  * quantize:   x -> (q, scale)         scale = absmax/127, q = round(x/scale)
  * dequantize: (q, scale) -> x
  * fused 8-bit Adam step: dequant M,V -> moment EMA + ΔW -> requant, one
    VMEM round trip (the 8-bit COAP optimizer step; avoids materializing
    fp32 M/V in HBM, which would forfeit the memory savings).

Hardware adaptation note (DESIGN.md §3): Dettmers' dynamic-tree codebook is
a CUDA-LUT trick; linear absmax maps onto the TPU VPU (mul + round + clip)
with no gather. Same state size, slightly coarser tails.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    _HAS_PLTPU = False

from repro.kernels.ref import QUANT_BLOCK, QUANT_DELTA_CLIP

ROWS_PER_PROGRAM = 64  # (64, 256) int8 tiles: fits the int8 (32,128) layout


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(x * inv), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def _fused8_kernel(corr_ref, g_ref, mq_ref, ms_ref, vq_ref, vs_ref,
                   nmq_ref, nms_ref, nvq_ref, nvs_ref, delta_ref,
                   *, b1, b2, eps):
    g = g_ref[...].astype(jnp.float32)
    m = mq_ref[...].astype(jnp.float32) * ms_ref[...]
    v = vq_ref[...].astype(jnp.float32) * vs_ref[...]
    new_m = b1 * m + (1.0 - b1) * g
    new_v = b2 * v + (1.0 - b2) * g * g
    delta = (new_m / corr_ref[0]) / (jnp.sqrt(new_v / corr_ref[1]) + eps)
    delta_ref[...] = jnp.clip(delta, -QUANT_DELTA_CLIP, QUANT_DELTA_CLIP)

    def requant(x, q_out, s_out):
        absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = absmax / 127.0
        inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
        q_out[...] = jnp.clip(jnp.round(x * inv), -127, 127).astype(jnp.int8)
        s_out[...] = scale

    requant(new_m, nmq_ref, nms_ref)
    requant(new_v, nvq_ref, nvs_ref)


def _to_blocks(x, block):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, block)


def _row_pad(x, rows):
    pad = (-x.shape[0]) % rows
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_blockwise_pallas(x, block=QUANT_BLOCK, interpret=False):
    blocks = _to_blocks(x.astype(jnp.float32), block)
    nblocks = blocks.shape[0]
    rows = min(ROWS_PER_PROGRAM, nblocks)
    bp = _row_pad(blocks, rows)
    grid = (bp.shape[0] // rows,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(bp.shape, jnp.int8),
            jax.ShapeDtypeStruct((bp.shape[0], 1), jnp.float32),
        ],
        interpret=interpret,
    )(bp)
    return q[:nblocks], s[:nblocks, 0]


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "block", "interpret"))
def dequantize_blockwise_pallas(q, scale, shape, dtype=jnp.float32,
                                block=QUANT_BLOCK, interpret=False):
    nblocks = q.shape[0]
    rows = min(ROWS_PER_PROGRAM, nblocks)
    qp = _row_pad(q, rows)
    sp = _row_pad(scale[:, None], rows)
    grid = (qp.shape[0] // rows,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, jnp.float32),
        interpret=interpret,
    )(qp, sp)
    size = 1
    for s_ in shape:
        size *= s_
    return x.reshape(-1)[:size].reshape(shape).astype(dtype)


@functools.partial(
    jax.jit, static_argnames=("b1", "b2", "eps", "block", "interpret")
)
def quantized_adam_update_pallas(
    g_proj, m_q, m_scale, v_q, v_scale, count,
    b1=0.9, b2=0.999, eps=1e-8, block=QUANT_BLOCK, interpret=False,
):
    shape = g_proj.shape
    gb = _to_blocks(g_proj.astype(jnp.float32), block)
    nblocks = gb.shape[0]
    assert m_q.shape[0] == nblocks, (m_q.shape, nblocks)
    rows = min(ROWS_PER_PROGRAM, nblocks)
    gp = _row_pad(gb, rows)
    mqp, vqp = _row_pad(m_q, rows), _row_pad(v_q, rows)
    msp, vsp = _row_pad(m_scale[:, None], rows), _row_pad(v_scale[:, None], rows)
    grid = (gp.shape[0] // rows,)
    t = count.astype(jnp.float32)
    corr = jnp.stack([1.0 - b1**t, 1.0 - b2**t])

    row_spec = pl.BlockSpec((rows, block), lambda i: (i, 0))
    s_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    npad = gp.shape[0]
    nmq, nms, nvq, nvs, delta = pl.pallas_call(
        functools.partial(_fused8_kernel, b1=b1, b2=b2, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((2,), lambda i: (0,)), row_spec, row_spec,
                  s_spec, row_spec, s_spec],
        out_specs=[row_spec, s_spec, row_spec, s_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((npad, block), jnp.int8),
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
            jax.ShapeDtypeStruct((npad, block), jnp.int8),
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
            jax.ShapeDtypeStruct((npad, block), jnp.float32),
        ],
        interpret=interpret,
    )(corr, gp, mqp, msp, vqp, vsp)
    size = 1
    for s_ in shape:
        size *= s_
    delta_full = delta.reshape(-1)[:size].reshape(shape)
    return nmq[:nblocks], nms[:nblocks, 0], nvq[:nblocks], nvs[:nblocks, 0], delta_full
