"""Block-wise absmax int8 quantization kernels (8-bit COAP states).

Two codecs, two families of kernels:

FLAT codec (dense Adam states): tensors are viewed as (nblocks, 256) after
ravel — 256 = 2×VPU lane width — with one fp32 scale per block:

  * quantize:   x -> (q, scale)         scale = absmax/127, q = round(x/scale)
  * dequantize: (q, scale) -> x
  * fused 8-bit Adam step: dequant M,V -> moment EMA + ΔW -> requant, one
    VMEM round trip.

ROW-BLOCK codec (projected COAP states, see kernels/ref.py): an (..., m, r)
moment keeps its shape in int8 with ceil(r/256) scales per row, so a
row-tile (bm, r) dequantizes in VMEM from its own scales alone. On top of it
``coap_fused_update_q8_pallas`` runs the ENTIRE 8-bit COAP step as one
kernel — a single HBM pass per tensor:

    phase 1 (k < kn):    acc += G(i,k) @ P(k)          (MXU)
    epilogue (k = kn-1): dequant int8 M/V tiles in VMEM; moment EMA;
                         bias-corrected Δ with the QUANT_DELTA_CLIP
                         underflow guard; requant M'/V' -> int8 outputs;
                         park Δ in the accumulator scratch          (VPU)
    phase 2 (k >= kn):   ΔW(i,k-kn) = Δ @ P(k-kn)ᵀ                 (MXU)

Neither fp32 M/V nor Δ_proj ever exist in HBM — the memory AND traffic wins
of the paper's 8-bit path hold at peak, instead of only for the at-rest
state. The unfused schedule (dequant + project + Adam + requant +
backproject as separate dispatches) reads/writes every intermediate through
HBM and is kept only as the benchmark baseline (benchmarks/overhead.py).

Like the fp32 fused kernels, ``coap_fused_update_q8_pallas`` accepts bf16 G
and upcasts per-tile in VMEM — with int8 states AND a bf16 gradient stream
the whole 8-bit step moves ~mn·2 + 2mr·1 bytes of tensor traffic.

Hardware adaptation note (DESIGN.md §3): Dettmers' dynamic-tree codebook is
a CUDA-LUT trick; linear absmax maps onto the TPU VPU (mul + round + clip)
with no gather. Same state size, slightly coarser tails. TPU tiling note:
int8 tiles are (32, 128); the fused kernel's row tiles (bm, r) satisfy this
for bm ≥ 32 and r a lane multiple — the wrapper pads rows, and ragged r is
exercised under interpret mode (tests) where tiling is unconstrained.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _HAS_PLTPU = True
except Exception:  # pragma: no cover
    _HAS_PLTPU = False

from repro.kernels import ref as _ref
from repro.kernels.ref import QUANT_BLOCK, QUANT_DELTA_CLIP, rowblock_nblocks

ROWS_PER_PROGRAM = 64  # (64, 256) int8 tiles: fits the int8 (32,128) layout
DEFAULT_BM = 512  # fused-q8 row tile: fewer P sweeps (2·ceil(m/bm)·nr words
# of internal re-stream); working set ~7MB at r=1024 stays under 16MB VMEM.
DEFAULT_BN = 512  # fused-q8 G column block


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
    q = jnp.clip(jnp.round(x * inv), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def _fused8_kernel(corr_ref, g_ref, mq_ref, ms_ref, vq_ref, vs_ref,
                   nmq_ref, nms_ref, nvq_ref, nvs_ref, delta_ref,
                   *, b1, b2, eps):
    g = g_ref[...].astype(jnp.float32)
    m = mq_ref[...].astype(jnp.float32) * ms_ref[...]
    v = vq_ref[...].astype(jnp.float32) * vs_ref[...]
    new_m = b1 * m + (1.0 - b1) * g
    new_v = b2 * v + (1.0 - b2) * g * g
    delta = (new_m / corr_ref[0]) / (jnp.sqrt(new_v / corr_ref[1]) + eps)
    delta_ref[...] = jnp.clip(delta, -QUANT_DELTA_CLIP, QUANT_DELTA_CLIP)

    def requant(x, q_out, s_out):
        absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = absmax / 127.0
        inv = jnp.where(scale > 0, 1.0 / jnp.maximum(scale, 1e-30), 0.0)
        q_out[...] = jnp.clip(jnp.round(x * inv), -127, 127).astype(jnp.int8)
        s_out[...] = scale

    requant(new_m, nmq_ref, nms_ref)
    requant(new_v, nvq_ref, nvs_ref)


def _to_blocks(x, block):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, block)


def _row_pad(x, rows):
    pad = (-x.shape[0]) % rows
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x


# shared two-phase grid pieces (same tiling semantics as the fp32 fused
# kernel — see coap_update.py)
from repro.kernels.coap_update import (  # noqa: E402
    _pad_to as _pad_to_axis,
    park_out_index,
    pin_g_index,
    two_phase_compiler_params,
)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_blockwise_pallas(x, block=QUANT_BLOCK, interpret=False):
    blocks = _to_blocks(x.astype(jnp.float32), block)
    nblocks = blocks.shape[0]
    rows = min(ROWS_PER_PROGRAM, nblocks)
    bp = _row_pad(blocks, rows)
    grid = (bp.shape[0] // rows,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(bp.shape, jnp.int8),
            jax.ShapeDtypeStruct((bp.shape[0], 1), jnp.float32),
        ],
        interpret=interpret,
    )(bp)
    return q[:nblocks], s[:nblocks, 0]


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "block", "interpret"))
def dequantize_blockwise_pallas(q, scale, shape, dtype=jnp.float32,
                                block=QUANT_BLOCK, interpret=False):
    nblocks = q.shape[0]
    rows = min(ROWS_PER_PROGRAM, nblocks)
    qp = _row_pad(q, rows)
    sp = _row_pad(scale[:, None], rows)
    grid = (qp.shape[0] // rows,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, jnp.float32),
        interpret=interpret,
    )(qp, sp)
    size = 1
    for s_ in shape:
        size *= s_
    return x.reshape(-1)[:size].reshape(shape).astype(dtype)


@functools.partial(
    jax.jit, static_argnames=("b1", "b2", "eps", "block", "interpret")
)
def quantized_adam_update_pallas(
    g_proj, m_q, m_scale, v_q, v_scale, count,
    b1=0.9, b2=0.999, eps=1e-8, block=QUANT_BLOCK, interpret=False,
):
    shape = g_proj.shape
    gb = _to_blocks(g_proj.astype(jnp.float32), block)
    nblocks = gb.shape[0]
    assert m_q.shape[0] == nblocks, (m_q.shape, nblocks)
    rows = min(ROWS_PER_PROGRAM, nblocks)
    gp = _row_pad(gb, rows)
    mqp, vqp = _row_pad(m_q, rows), _row_pad(v_q, rows)
    msp, vsp = _row_pad(m_scale[:, None], rows), _row_pad(v_scale[:, None], rows)
    grid = (gp.shape[0] // rows,)
    t = count.astype(jnp.float32)
    corr = jnp.stack([1.0 - b1**t, 1.0 - b2**t])

    row_spec = pl.BlockSpec((rows, block), lambda i: (i, 0))
    s_spec = pl.BlockSpec((rows, 1), lambda i: (i, 0))
    npad = gp.shape[0]
    nmq, nms, nvq, nvs, delta = pl.pallas_call(
        functools.partial(_fused8_kernel, b1=b1, b2=b2, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((2,), lambda i: (0,)), row_spec, row_spec,
                  s_spec, row_spec, s_spec],
        out_specs=[row_spec, s_spec, row_spec, s_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((npad, block), jnp.int8),
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
            jax.ShapeDtypeStruct((npad, block), jnp.int8),
            jax.ShapeDtypeStruct((npad, 1), jnp.float32),
            jax.ShapeDtypeStruct((npad, block), jnp.float32),
        ],
        interpret=interpret,
    )(corr, gp, mqp, msp, vqp, vsp)
    size = 1
    for s_ in shape:
        size *= s_
    delta_full = delta.reshape(-1)[:size].reshape(shape)
    return nmq[:nblocks], nms[:nblocks, 0], nvq[:nblocks], nvs[:nblocks, 0], delta_full


# ---------------------------------------------------------------------------
# Single-pass fused 8-bit COAP step (row-block codec; see module docstring)
# ---------------------------------------------------------------------------
def _dequant_rowblock_tile(q, s, block):
    """(bm, r) int8 tile + (bm, nblk) scales -> fp32, in VMEM. The codec is
    defined ONCE in kernels/ref.py — this just traces those jnp ops inside
    the kernel body (with a cheap broadcast shortcut for the 1-block case).
    """
    if s.shape[-1] == 1:
        return q.astype(jnp.float32) * s
    return _ref.dequantize_rowblock(q, s, block)


def _requant_rowblock_tile(x, q_ref, s_ref, block):
    """fp32 (bm, r) tile -> int8 codes + per-row-block scales, in VMEM.
    Bit-for-bit the ref codec, by construction: it IS ref.quantize_rowblock
    traced into the kernel."""
    q, s = _ref.quantize_rowblock(x, block)
    q_ref[...] = q
    s_ref[...] = s


def _fused8_proj_kernel(corr_ref, g_ref, p_ref, mq_ref, ms_ref, vq_ref, vs_ref,
                        nmq_ref, nms_ref, nvq_ref, nvs_ref, dw_ref, acc_ref,
                        *, b1, b2, eps, kn, block):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < kn)
    def _accumulate():
        acc_ref[...] += jnp.dot(
            g_ref[...].astype(jnp.float32),
            p_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )

    @pl.when(k == kn - 1)
    def _epilogue():
        g_proj = acc_ref[...]
        m = _dequant_rowblock_tile(mq_ref[...], ms_ref[...], block)
        v = _dequant_rowblock_tile(vq_ref[...], vs_ref[...], block)
        new_m = b1 * m + (1.0 - b1) * g_proj
        new_v = b2 * v + (1.0 - b2) * jnp.square(g_proj)
        delta = (new_m / corr_ref[0]) / (jnp.sqrt(new_v / corr_ref[1]) + eps)
        delta = jnp.clip(delta, -QUANT_DELTA_CLIP, QUANT_DELTA_CLIP)
        _requant_rowblock_tile(new_m, nmq_ref, nms_ref, block)
        _requant_rowblock_tile(new_v, nvq_ref, nvs_ref, block)
        acc_ref[...] = delta  # scratch reuse: phase 2 consumes Δ_proj

    @pl.when(k >= kn)
    def _backproject():
        dw_ref[...] = jax.lax.dot_general(
            acc_ref[...], p_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )


@functools.partial(
    jax.jit,
    static_argnames=("b1", "b2", "eps", "block", "interpret", "bm", "bn"),
)
def coap_fused_update_q8_pallas(
    g, p, m_q, m_scale, v_q, v_scale, count,
    b1=0.9, b2=0.999, eps=1e-8, block=QUANT_BLOCK,
    interpret: bool = False, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
):
    """One-kernel 8-bit COAP step. g (...,m,n), p (...,n,r), int8 moments
    (...,m,r) with (...,m,nblk) scales -> (m_q', m_s', v_q', v_s', ΔW).
    Broadcasts over leading (layer/expert) stack axes via vmap."""
    if g.ndim > 2:
        fn = functools.partial(
            coap_fused_update_q8_pallas, b1=b1, b2=b2, eps=eps, block=block,
            interpret=interpret, bm=bm, bn=bn,
        )
        for _ in range(g.ndim - 2):
            fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, None))
        return fn(g, p, m_q, m_scale, v_q, v_scale, count)

    m_dim, n_dim = g.shape
    r = p.shape[-1]
    nblk = rowblock_nblocks(r, block)
    assert m_scale.shape[-1] == nblk, (m_scale.shape, nblk)
    t = count.astype(jnp.float32)
    corr = jnp.stack([1.0 - b1**t, 1.0 - b2**t])

    bm_eff = min(bm, max(8, m_dim))
    bn_eff = min(bn, max(128, n_dim))
    g_p = _pad_to_axis(_pad_to_axis(g, bm_eff, 0), bn_eff, 1)
    p_p = _pad_to_axis(p, bn_eff, 0)
    mq_p = _pad_to_axis(m_q, bm_eff, 0)
    vq_p = _pad_to_axis(v_q, bm_eff, 0)
    ms_p = _pad_to_axis(m_scale, bm_eff, 0)
    vs_p = _pad_to_axis(v_scale, bm_eff, 0)
    mp, np_ = g_p.shape
    kn = np_ // bn_eff
    grid = (mp // bm_eff, 2 * kn)

    kernel = functools.partial(
        _fused8_proj_kernel, b1=b1, b2=b2, eps=eps, kn=kn, block=block
    )
    row_q = pl.BlockSpec((bm_eff, r), lambda i, k: (i, 0))
    row_s = pl.BlockSpec((bm_eff, nblk), lambda i, k: (i, 0))
    in_specs = [
        pl.BlockSpec((2,), lambda i, k: (0,)),  # corr coefficients
        pl.BlockSpec((bm_eff, bn_eff), pin_g_index(kn)),  # G
        pl.BlockSpec((bn_eff, r), lambda i, k: (k % kn, 0)),  # P (both phases)
        row_q, row_s, row_q, row_s,  # int8 M/V + scales
    ]
    out_specs = [
        row_q, row_s, row_q, row_s,
        pl.BlockSpec((bm_eff, bn_eff), park_out_index(kn)),  # ΔW (phase 2)
    ]
    kwargs = dict(
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((mp, r), jnp.int8),
            jax.ShapeDtypeStruct((mp, nblk), jnp.float32),
            jax.ShapeDtypeStruct((mp, r), jnp.int8),
            jax.ShapeDtypeStruct((mp, nblk), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=interpret,
    )
    if _HAS_PLTPU:
        kwargs["scratch_shapes"] = [pltpu.VMEM((bm_eff, r), jnp.float32)]
        if not interpret:
            kwargs["compiler_params"] = two_phase_compiler_params()
    else:  # pragma: no cover
        raise RuntimeError("Pallas TPU backend unavailable; use ops ref path")

    nmq, nms, nvq, nvs, dw = pl.pallas_call(kernel, **kwargs)(
        corr, g_p, p_p, mq_p, ms_p, vq_p, vs_p
    )
    return (
        nmq[:m_dim],
        nms[:m_dim],
        nvq[:m_dim],
        nvs[:m_dim],
        dw[:m_dim, :n_dim],
    )
