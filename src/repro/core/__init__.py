"""COAP core: correlation-aware low-rank gradient projection (the paper).

Public surface:
  * ``make_optimizer``            — factory for every optimizer in the paper
                                    (AdamW/Adafactor × {full, COAP, GaLore,
                                    Flora} × {fp32/bf16, 8-bit}).
  * ``scale_by_projected_adam``   — Algorithm 1 (and GaLore/Flora variants).
  * ``scale_by_projected_adafactor`` — Algorithm 2.
  * ``correlation``               — Eqn 6 objective + closed-form gradient.
  * ``recalibrate``               — Eqn 7 low-cost SVD.
"""
from repro.core.api import make_optimizer, OptimizerConfig
from repro.core.coap_adam import (
    scale_by_projected_adam,
    coap_adamw,
    galore_adamw,
    flora_adamw,
)
from repro.core.coap_adafactor import scale_by_projected_adafactor, coap_adafactor
from repro.core import correlation, recalibrate, projector, accounting

__all__ = [
    "make_optimizer",
    "OptimizerConfig",
    "scale_by_projected_adam",
    "scale_by_projected_adafactor",
    "coap_adamw",
    "coap_adafactor",
    "galore_adamw",
    "flora_adamw",
    "correlation",
    "recalibrate",
    "projector",
    "accounting",
]
