"""Eqn 7: occasional low-cost SVD recalibration of P.

    Q_red      = QR_red(G P_{t-1})          # (m, r), orthonormal columns
    U, Σ, Zᵀ   = SVD(Q_redᵀ G)              # SVD of an r×n matrix
    P_t        = Z                          # (n, r)

This is a projection-seeded randomized SVD: the previous subspace P_{t-1}
plays the role of the sketch, so cost drops from O(mn²) (GaLore's full SVD)
to O(mr² + nr²) while recalibrating toward the top right-singular subspace
of the *current* gradient. Also provides GaLore's full-SVD projection for the
baseline. Everything broadcasts over leading stack axes (vmapped linalg).

SVD/QR run in float32 regardless of gradient dtype — bf16 Householder/Jacobi
on TPU is ill-conditioned (see DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lowcost_svd(g: jnp.ndarray, p_prev: jnp.ndarray) -> jnp.ndarray:
    """Paper Eqn 7. g: (..., m, n) canonical (m >= n); p_prev: (..., n, r)."""
    dtype = p_prev.dtype
    g32 = g.astype(jnp.float32)
    p32 = p_prev.astype(jnp.float32)
    y = jnp.einsum("...mn,...nr->...mr", g32, p32)  # G P
    q, _ = jnp.linalg.qr(y)  # reduced QR, (..., m, r)
    b = jnp.einsum("...mr,...mn->...rn", q, g32)  # Qᵀ G, (..., r, n)
    _, _, zt = jnp.linalg.svd(b, full_matrices=False)  # zt: (..., r, n)
    p_new = jnp.swapaxes(zt, -1, -2)  # (..., n, r)
    return p_new.astype(dtype)


def galore_svd(g: jnp.ndarray, rank: int) -> jnp.ndarray:
    """GaLore baseline: truncated right-singular vectors of the full SVD.

    O(mn²) — this is the cost the paper's Eqn 7 removes. g canonical (m>=n);
    returns (..., n, rank).
    """
    g32 = g.astype(jnp.float32)
    _, _, vt = jnp.linalg.svd(g32, full_matrices=False)  # vt: (..., n, n)
    p = jnp.swapaxes(vt, -1, -2)[..., :, :rank]
    return p


def random_projection(key: jax.Array, g_shape, rank: int, dtype=jnp.float32):
    """Flora baseline: fresh Gaussian projection N(0, 1/r). g canonical."""
    lead = tuple(g_shape[:-2])
    n = g_shape[-1]
    p = jax.random.normal(key, lead + (n, rank), jnp.float32) / jnp.sqrt(
        jnp.asarray(rank, jnp.float32)
    )
    return p.astype(dtype)


def subspace_overlap(p_a: jnp.ndarray, p_b: jnp.ndarray) -> jnp.ndarray:
    """Diagnostic: ‖P_aᵀ P_b‖_F² / r ∈ [0, 1] — 1 ⇒ identical subspaces.

    Used by tests and the CEU benchmark to show COAP's inter-projection
    correlation (high overlap across refreshes) vs Flora (≈ r/n).
    """
    qa, _ = jnp.linalg.qr(p_a.astype(jnp.float32))
    qb, _ = jnp.linalg.qr(p_b.astype(jnp.float32))
    x = jnp.einsum("...nr,...nk->...rk", qa, qb)
    r = p_a.shape[-1]
    return jnp.sum(x * x, axis=(-1, -2)) / r
