"""Algorithm 2: Adafactor with COAP (and GaLore/Flora strategy variants).

Projected leaves hold: ``P (n,r)``, first moment ``M_proj (m,r)``, and the
*factored* second moment of the projected gradient: ``R (m,)``, ``C (r,)``
with the paper's β₂ schedule ``β₂ = 1 − t^{−γ}``. Per Algorithm 2:

    R_t = β₂R + (1−β₂)·Sum(G_proj², −1)
    C_t = β₂C + (1−β₂)·Sum(G_proj², −2)
    V̂_t = sqrt(Mean(R_t) / (R_t C_t))          # note: reciprocal-sqrt form
    ΔW_proj = β₁·M + (1−β₁)·η·V̂ ⊙ G_proj
    W ← W − ΔW_proj Pᵀ

FAITHFULNESS NOTE: Algorithm 2 as printed also contains the line
``M_t ← β₁M + (1−β₁)G_proj`` which is unit-inconsistent with the ΔW line
(it would subtract an *unscaled* gradient EMA from W). We implement the
self-consistent reading — M accumulates the scaled update, i.e.
``M_t = ΔW_proj`` (momentum-on-update, as in Adafactor-with-momentum) — and
expose ``interpretation='literal'`` for the verbatim text. The consistent
reading reproduces the paper's convergence behaviour in our small-scale
benchmarks; the literal one diverges for any η < 1, corroborating the typo
(see DESIGN.md §8).

Because learning rate is *inside* ΔW here, this transformation is terminal:
chain it with ``scale(-1)`` only (no extra lr scaling).

The update runs on the SAME bucket+phase hot-path machinery as the Adam
variant (``coap_adam.update_fn``): congruent projected leaves compute as
one stacked launch per bucket (``stacked_state=True`` additionally STORES
them pre-stacked — no gather/scatter copies), refreshes follow the shared
staggered schedule (``bucket_phases`` — the same allocation the elastic
supervisor and the cross-pod compression path derive cadence from), and a
plan's per-bucket overrides apply through ``_bucket_cfg``. Dense buckets
vmap the per-leaf Adafactor step (the factored-iff-ndim≥2 branch is a
static per-leaf property, preserved under vmap). Both storage modes share
the bucketed compute, so they stay bit-identical by construction
(``tests/test_stacked_state.py::test_stacked_adafactor_matches_per_leaf_bitwise``).

CONV NOTE. Algorithm 2 has no Tucker-2 path: every non-projected leaf —
conv ``(O,I,K1,K2)`` kernels included — takes the dense Adafactor path.
``_af_classify`` therefore maps conv specs to ``BUCKET_DENSE``, never to
the ``stacked-bucket/v2`` conv bucket class the Adam transform uses: the
adafactor layout has no conv buckets and no tail, and the v1→v2 codec bump
(which only changed where KIND_CONV leaves live under the DEFAULT
classification) does not alter its bucket assignment
(``tests/test_conv_bucketing.py::test_adafactor_layout_unaffected_by_v2``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import correlation, projector, recalibrate
from repro.core import stacked_state
from repro.core.coap_adam import (
    ProjectedAdamConfig,
    _bucket_cfg,
    _maybe_transplant,
    _refresh_p,
    bucket_phases,
)
from repro.core.projector import (
    KIND_DENSE,
    KIND_PROJECT,
    ProjectionRules,
    path_str,
)
from repro.obs import health
from repro.optim.transform import GradientTransformation, chain, scale

_EPS = 1e-30


class ProjFactorLeaf(NamedTuple):
    p: Any  # (..., n, r)
    m: Any  # (..., M, r)
    row: Any  # (..., M)
    col: Any  # (..., r)


class DenseFactorLeaf(NamedTuple):
    row: Any
    col: Any
    nu: Any  # unfactored fallback for <2-D


class ProjectedAdafactorState(NamedTuple):
    count: jnp.ndarray
    leaves: Any


@dataclasses.dataclass(frozen=True)
class ProjectedAdafactorConfig(ProjectedAdamConfig):
    gamma: float = 0.8  # β₂ decay-rate exponent
    learning_rate: float = 1e-4  # η lives inside ΔW (Algorithm 2)
    interpretation: str = "consistent"  # 'consistent' | 'literal'


def _af_classify(spec) -> str:
    """Adafactor has no conv path: everything non-projected is dense."""
    if spec.kind == KIND_PROJECT:
        return stacked_state.BUCKET_PROJECT
    return stacked_state.BUCKET_DENSE


def _af_layout(cfg, flat) -> stacked_state.StackedLayout:
    return stacked_state.layout_for_flat(
        cfg.rules.spec_for, flat, classify=_af_classify
    )


def scale_by_projected_adafactor(cfg: ProjectedAdafactorConfig) -> GradientTransformation:
    def init_fn(params):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        key = jax.random.key(cfg.seed)
        leaves = []
        for idx, (kp, leaf) in enumerate(flat):
            spec = cfg.rules.spec_for(path_str(kp), leaf.shape)
            if spec.kind == KIND_PROJECT:
                p0 = projector.init_p(
                    jax.random.fold_in(key, idx), leaf.shape, spec, jnp.float32
                )
                msh = projector.moment_shape(leaf.shape, spec)
                leaves.append(
                    ProjFactorLeaf(
                        p=p0,
                        m=jnp.zeros(msh, jnp.float32),
                        row=jnp.zeros(msh[:-1], jnp.float32),
                        col=jnp.zeros(msh[:-2] + msh[-1:], jnp.float32),
                    )
                )
            else:
                # Dense leaves: classic Adafactor (factored iff ndim >= 2).
                if leaf.ndim >= 2:
                    leaves.append(
                        DenseFactorLeaf(
                            row=jnp.zeros(leaf.shape[:-1], jnp.float32),
                            col=jnp.zeros(leaf.shape[:-2] + leaf.shape[-1:], jnp.float32),
                            nu=jnp.zeros((1,), jnp.float32),
                        )
                    )
                else:
                    leaves.append(
                        DenseFactorLeaf(
                            row=jnp.zeros((1,), jnp.float32),
                            col=jnp.zeros((1,), jnp.float32),
                            nu=jnp.zeros(leaf.shape, jnp.float32),
                        )
                    )
        if cfg.stacked_state:
            return ProjectedAdafactorState(
                count=jnp.zeros([], jnp.int32),
                leaves=stacked_state.encode(_af_layout(cfg, flat), leaves),
            )
        return ProjectedAdafactorState(
            count=jnp.zeros([], jnp.int32),
            leaves=jax.tree_util.tree_unflatten(treedef, leaves),
        )

    def _vhat(row, col):
        """V̂ = sqrt(Mean(R)/(R C)) — the reciprocal-sqrt normalizer."""
        mean_r = jnp.mean(row, axis=-1, keepdims=True)
        denom = row[..., :, None] * col[..., None, :] + _EPS
        return jnp.sqrt(mean_r[..., None] / denom)

    def _update_proj_bucket(bcfg, leaf: ProjFactorLeaf, g, spec, count, t,
                            idx_arr, b2, phases):
        """Algorithm 2 for a stacked bucket of congruent projected leaves
        (leading (B,) axis everywhere; B == 1 for singleton buckets).
        ``bcfg`` is the bucket-effective config (plan overrides applied);
        ``phases`` staggers the refresh cadence exactly as in the Adam
        variant — same ``_refresh_p`` group dispatch, same transplant
        group structure."""
        gc = projector.to_canonical(g, spec).astype(jnp.float32)
        p_old = leaf.p

        def m_loader(sl=slice(None)):
            return leaf.m[sl].astype(jnp.float32)

        new_p, refreshed = _refresh_p(
            bcfg, spec, p_old, gc, m_loader, count, idx_arr, phases
        )
        # Projection-health emit (obs/health): refresh-boundary metrics
        # (captured energy, Eqn-6 residual, subspace overlap) ride the
        # refresh branch that already holds G — zero extra HBM reads of G
        # on non-refresh steps, and a trace-time no-op when the monitor is
        # disabled (bit-identical compiled program).
        health.emit_refresh_matrix(
            health.bucket_label("project", g.shape[1:], g.dtype),
            gc, p_old, new_p, refreshed, count,
        )
        m = _maybe_transplant(
            bcfg, leaf.m, p_old, new_p, refreshed, phases, count
        )
        g_proj = projector.project(gc, new_p)
        g2 = jnp.square(g_proj)
        new_row = b2 * leaf.row + (1.0 - b2) * jnp.sum(g2, axis=-1)
        new_col = b2 * leaf.col + (1.0 - b2) * jnp.sum(g2, axis=-2)
        vhat = _vhat(new_row, new_col)
        if bcfg.interpretation == "literal":
            new_m = bcfg.b1 * m + (1.0 - bcfg.b1) * g_proj
            delta = bcfg.b1 * new_m + (1.0 - bcfg.b1) * bcfg.learning_rate * vhat * g_proj
        else:
            delta = bcfg.b1 * m + (1.0 - bcfg.b1) * bcfg.learning_rate * vhat * g_proj
            new_m = delta  # momentum over scaled updates (consistent units)
        upd_c = projector.backproject(delta, new_p)
        upd = projector.from_canonical(upd_c, spec) * bcfg.update_scale
        return upd.astype(g.dtype), ProjFactorLeaf(
            p=new_p, m=new_m, row=new_row, col=new_col
        )

    def _update_dense(leaf: DenseFactorLeaf, g, t, b2):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + _EPS
        if g.ndim >= 2:
            new_row = b2 * leaf.row + (1.0 - b2) * jnp.sum(g2, axis=-1)
            new_col = b2 * leaf.col + (1.0 - b2) * jnp.sum(g2, axis=-2)
            vhat = _vhat(new_row, new_col)
            upd = cfg.learning_rate * vhat * g32
            new_leaf = DenseFactorLeaf(row=new_row, col=new_col, nu=leaf.nu)
        else:
            new_nu = b2 * leaf.nu + (1.0 - b2) * g2
            upd = cfg.learning_rate * g32 / jnp.sqrt(new_nu + _EPS)
            new_leaf = DenseFactorLeaf(row=leaf.row, col=leaf.col, nu=new_nu)
        return upd.astype(g.dtype), new_leaf

    def update_fn(updates, state, params=None):
        del params
        count = state.count
        t = count + 1
        b2 = 1.0 - (t.astype(jnp.float32)) ** (-cfg.gamma)
        flat_u, treedef = jax.tree_util.tree_flatten_with_path(updates)
        n_leaves = len(flat_u)

        # THE bucket assignment (shared with the stacked-state codec, the
        # checkpoint/accounting stack and the elastic supervisor) — under
        # the adafactor classification: project buckets + dense buckets,
        # never conv, never tail (module docstring CONV NOTE).
        layout = _af_layout(cfg, flat_u)

        if cfg.stacked_state:
            prev = state.leaves
            if (
                not isinstance(prev, stacked_state.StackedLeaves)
                or prev.layout.signature() != layout.signature()
            ):
                raise ValueError(
                    "stacked adafactor state does not match the gradient "
                    "tree (rules / model structure changed since init?)"
                )
            flat_s = None
        else:
            prev = None
            flat_s = treedef.flatten_up_to(state.leaves)

        bucket_cfgs = [_bucket_cfg(cfg, info) for info in layout.buckets]
        # Per-leaf refresh phases: THE staggered allocation, shared with
        # the Adam variant and every schedule consumer.
        phase_by_bucket = bucket_phases(cfg, layout)

        new_updates = [None] * n_leaves
        new_buckets = [None] * len(layout.buckets)
        new_flat = [None] * n_leaves  # per-leaf mode only

        for bi, info in enumerate(layout.buckets):
            is_proj = info.kind == stacked_state.BUCKET_PROJECT
            bcfg = bucket_cfgs[bi]
            phases = phase_by_bucket.get(bi)
            if cfg.bucket_leaves:
                slot_groups = [tuple(range(len(info.indices)))]
            else:  # per-leaf A/B mode (stacked_state forbids this)
                slot_groups = [(k,) for k in range(len(info.indices))]
            for slots in slot_groups:
                idxs = [info.indices[k] for k in slots]
                g_stack = jnp.stack([flat_u[i][1] for i in idxs])
                if cfg.stacked_state:
                    # Hot-path win: the bucket state is ALREADY stacked —
                    # no stack copy in, no scatter copy out.
                    leaf_stack = prev.buckets[bi]
                else:
                    leaf_stack = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs),
                        *[flat_s[i] for i in idxs],
                    )
                if is_proj:
                    u_stack, nl_stack = _update_proj_bucket(
                        bcfg, leaf_stack, g_stack, info.spec, count, t,
                        jnp.asarray(idxs, jnp.int32), b2,
                        tuple(phases[k] for k in slots),
                    )
                else:
                    # The factored-iff-ndim>=2 branch is static per leaf
                    # shape; vmap keeps it per-element while batching the
                    # congruent bucket into one launch.
                    u_stack, nl_stack = jax.vmap(
                        lambda lf, gg: _update_dense(lf, gg, t, b2)
                    )(leaf_stack, g_stack)
                for b, i in enumerate(idxs):
                    new_updates[i] = u_stack[b]
                    if not cfg.stacked_state:
                        new_flat[i] = jax.tree_util.tree_map(
                            lambda x: x[b], nl_stack
                        )
                if cfg.stacked_state:
                    new_buckets[bi] = nl_stack

        if cfg.stacked_state:
            leaves_out = stacked_state.StackedLeaves(
                new_buckets, prev.tail, prev.layout
            )
        else:
            leaves_out = jax.tree_util.tree_unflatten(treedef, new_flat)
        return (
            jax.tree_util.tree_unflatten(treedef, new_updates),
            ProjectedAdafactorState(count=count + 1, leaves=leaves_out),
        )

    return GradientTransformation(init_fn, update_fn)


def coap_adafactor(
    learning_rate: float,
    rules: ProjectionRules,
    *,
    strategy: str = "coap",
    b1: float = 0.9,
    gamma: float = 0.8,
    t_update: int = 200,
    lam: int = 5,
    eqn6_lr: float = 0.1,
    eqn6_steps: int = 1,
    seed: int = 0,
    update_scale: float = 1.0,
    stacked_state: bool = False,
    stagger: bool = True,
    stagger_groups: int = 8,
) -> GradientTransformation:
    """Adafactor+COAP per Algorithm 2 (η inside; terminal sign flip only)."""
    cfg = ProjectedAdafactorConfig(
        rules=rules,
        strategy=strategy,
        b1=b1,
        gamma=gamma,
        t_update=t_update,
        lam=lam,
        eqn6_lr=eqn6_lr,
        eqn6_steps=eqn6_steps,
        seed=seed,
        learning_rate=learning_rate,
        update_scale=update_scale,
        stacked_state=stacked_state,
        stagger=stagger,
        stagger_groups=stagger_groups,
    )
    return chain(scale_by_projected_adafactor(cfg), scale(-1.0))
