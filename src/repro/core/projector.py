"""Projection-shape policy and canonical project/backproject primitives.

Conventions (paper §3.1): for a weight ``W ∈ R^{m×n}`` with ``m ≥ n`` the
projection is on the right: ``P ∈ R^{n×r}``, ``G_proj = G P ∈ R^{m×r}`` —
moments live on the *large* side (matches the paper's memory accounting for
LLaMA-1B, −61% at rank 512). Weights with ``m < n`` are transposed into this
canonical orientation on entry and transposed back on exit.

All primitives operate on the **last two axes** and broadcast over leading
axes. This is how scan-over-layers models (stacked ``(L, m, n)`` weights) and
per-expert MoE weights (``(L, E, m, n)``) get a projector per layer/expert
with a single einsum — the TPU-friendly equivalent of the paper's per-layer
Python loop.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# Param kinds decided statically at init time.
KIND_PROJECT = "project"  # last-two-axes matrix (possibly stacked) -> low-rank
KIND_CONV = "conv"  # (O, I, K1, K2) conv kernel -> Tucker-2 (core/conv.py)
KIND_DENSE = "dense"  # full-rank Adam/Adafactor


class ProjSpec(NamedTuple):
    """Static per-leaf projection decision."""

    kind: str
    transpose: bool  # swap last two axes to make m >= n
    rank: int  # effective rank r (0 for dense)
    # Conv-only Tucker-2 ranks:
    rank_o: int = 0
    rank_i: int = 0


@dataclasses.dataclass(frozen=True)
class ProjectionRules:
    """Shape/path policy for which leaves get projected and at what rank.

    Either ``rank`` (fixed, clipped to min-dim) or ``rank_ratio`` (paper's
    ``c``: r = min(m, n) / c) must be set. ``min_dim`` guards tiny matrices
    (router heads, norms reshaped as 2-D, ...) from projection — they stay on
    full-rank Adam, matching GaLore/paper practice.
    """

    rank: Optional[int] = None
    rank_ratio: Optional[float] = None
    min_dim: int = 128
    # Paths matching any of these regexes are never projected (embeddings and
    # norms by default — the paper and GaLore keep them full-rank).
    exclude_patterns: Tuple[str, ...] = (r"embed", r"norm", r"scale", r"bias", r"\bpos\b")
    # Paths matching these are always treated as conv kernels.
    conv_patterns: Tuple[str, ...] = (r"conv",)
    project_conv: bool = True

    def __post_init__(self):
        if (self.rank is None) == (self.rank_ratio is None):
            raise ValueError("set exactly one of rank / rank_ratio")

    def rank_for(self, m: int, n: int) -> int:
        small = min(m, n)
        if self.rank is not None:
            return int(min(self.rank, small))
        return max(1, int(small // self.rank_ratio))

    def spec_for(self, path: str, shape: Sequence[int]) -> ProjSpec:
        shape = tuple(int(s) for s in shape)
        lpath = path.lower()
        if any(re.search(p, lpath) for p in self.exclude_patterns):
            return ProjSpec(KIND_DENSE, False, 0)
        is_conv = any(re.search(p, lpath) for p in self.conv_patterns) or (
            len(shape) == 4 and shape[-1] <= 7 and shape[-2] <= 7 and shape[0] > 7
        )
        if is_conv:
            if not self.project_conv:
                return ProjSpec(KIND_DENSE, False, 0)
            o, i = shape[0], shape[1]
            if min(o, i) < self.min_dim:
                return ProjSpec(KIND_DENSE, False, 0)
            ratio = self.rank_ratio if self.rank_ratio is not None else None
            if ratio is not None:
                # Tucker-2: split the rank ratio across the two modes (α per
                # Algorithm 3; total state compression ≈ α).
                import math

                ro = max(1, int(o / math.sqrt(ratio)))
                ri = max(1, int(i / math.sqrt(ratio)))
            else:
                ro = min(self.rank, o)
                ri = min(self.rank, i)
            return ProjSpec(KIND_CONV, False, 0, rank_o=ro, rank_i=ri)
        if len(shape) < 2:
            return ProjSpec(KIND_DENSE, False, 0)
        m, n = shape[-2], shape[-1]
        if min(m, n) < self.min_dim:
            return ProjSpec(KIND_DENSE, False, 0)
        r = self.rank_for(m, n)
        if r >= min(m, n):
            return ProjSpec(KIND_DENSE, False, 0)
        return ProjSpec(KIND_PROJECT, m < n, r)


@dataclasses.dataclass(frozen=True)
class PlannedRules(ProjectionRules):
    """Per-path spec overrides layered over a base :class:`ProjectionRules`.

    This is how a memory plan (``repro/plan``, ``coap-plan/v1``) drives the
    optimizer: the planner decides one :class:`ProjSpec` per bucket and pins
    it here for every member path; any path without an override falls back
    to the base policy. Overrides are EXACT path matches (the planner and
    the optimizer flatten the same tree, so paths agree by construction) and
    the tuple storage keeps the rules hashable — layouts built from planned
    rules stay valid jit-static aux data.
    """

    spec_overrides: Tuple[Tuple[str, ProjSpec], ...] = ()

    def __post_init__(self):
        super().__post_init__()
        object.__setattr__(self, "_spec_map", dict(self.spec_overrides))

    def spec_for(self, path: str, shape: Sequence[int]) -> ProjSpec:
        spec = self._spec_map.get(path)
        if spec is not None:
            return spec
        return super().spec_for(path, shape)


def path_str(key_path) -> str:
    """jax tree key-path -> 'a/b/0/c' string for regex policies."""
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def to_canonical(g: jnp.ndarray, spec: ProjSpec) -> jnp.ndarray:
    """Transpose last two axes so that m >= n."""
    if spec.transpose:
        return jnp.swapaxes(g, -1, -2)
    return g


def from_canonical(g: jnp.ndarray, spec: ProjSpec) -> jnp.ndarray:
    if spec.transpose:
        return jnp.swapaxes(g, -1, -2)
    return g


def project(g_canon: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """``G_proj = G P`` over the last two axes: (...,m,n)@(...,n,r)->(...,m,r)."""
    return jnp.einsum("...mn,...nr->...mr", g_canon, p)


def backproject(u_proj: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """``ΔW = ΔW_proj Pᵀ``: (...,m,r)@(...,n,r)ᵀ -> (...,m,n)."""
    return jnp.einsum("...mr,...nr->...mn", u_proj, p)


def reconstruct(g_canon: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """``Ĝ = G P Pᵀ`` (paper Eqn 6 reconstruction operand)."""
    return backproject(project(g_canon, p), p)


def init_p(key: jax.Array, shape: Sequence[int], spec: ProjSpec, dtype=jnp.float32):
    """Random init for P (Algorithm 1 'Randomly Initialize'): orthonormal-ish
    Gaussian N(0, 1/r), batched over leading axes."""
    shape = tuple(shape)
    lead = shape[:-2]
    m, n = shape[-2], shape[-1]
    if spec.transpose:
        m, n = n, m
    p_shape = lead + (n, spec.rank)
    return jax.random.normal(key, p_shape, dtype) / jnp.sqrt(
        jnp.asarray(spec.rank, dtype)
    )


def moment_shape(shape: Sequence[int], spec: ProjSpec) -> Tuple[int, ...]:
    shape = tuple(shape)
    lead = shape[:-2]
    m, n = shape[-2], shape[-1]
    if spec.transpose:
        m, n = n, m
    return lead + (m, spec.rank)
