"""Algorithm 3: COAP for conv tensors via Tucker-2 factorized projection.

A conv weight ``W ∈ R^{O×I×K1×K2}`` gets two factor projections
``P_O ∈ R^{O×r_O}`` and ``P_I ∈ R^{I×r_I}`` (kernel dims are tiny and left
alone — the appendix's Tucker-2 ablation shows this beats Tucker-1/full
Tucker). The projected gradient is the Tucker-2 core

    G_proj = G ×₁ P_Oᵀ ×₂ P_Iᵀ  ∈ R^{r_O×r_I×K1×K2}

and moments live in that core shape. Each factor is refreshed with the same
Eqn-6 / Eqn-7 machinery as the matrix case applied to the mode-1 / mode-2
unfoldings of G (appendix §1.5): for the ``P_O`` update the canonical matrix
is ``unfold₁(G)ᵀ ∈ R^{(I·K1·K2)×O}`` so the half-restored first moment
``M_proj ×₂ P_I`` provides the direction term.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import correlation, recalibrate
from repro.core.projector import ProjSpec


def init_factors(key, w_shape, spec: ProjSpec):
    o, i = int(w_shape[0]), int(w_shape[1])
    ko, ki = jax.random.split(key)
    p_o = jax.random.normal(ko, (o, spec.rank_o), jnp.float32) / jnp.sqrt(
        jnp.asarray(spec.rank_o, jnp.float32)
    )
    p_i = jax.random.normal(ki, (i, spec.rank_i), jnp.float32) / jnp.sqrt(
        jnp.asarray(spec.rank_i, jnp.float32)
    )
    return p_o, p_i


def core_shape(w_shape, spec: ProjSpec) -> Tuple[int, ...]:
    return (spec.rank_o, spec.rank_i) + tuple(int(s) for s in w_shape[2:])


def mode1_canonical(g: jnp.ndarray) -> jnp.ndarray:
    """(O,I,K1,K2) -> unfold₁ᵀ = (I·K1·K2, O): canonical m≥n matrix whose
    right-projection P is P_O."""
    o = g.shape[0]
    return jnp.moveaxis(g, 0, -1).reshape(-1, o)


def mode2_canonical(g: jnp.ndarray) -> jnp.ndarray:
    """(O,I,K1,K2) -> (O·K1·K2, I): right-projection P is P_I."""
    i = g.shape[1]
    return jnp.moveaxis(g, 1, -1).reshape(-1, i)


def project_core(g: jnp.ndarray, p_o: jnp.ndarray, p_i: jnp.ndarray) -> jnp.ndarray:
    """G ×₁ P_Oᵀ ×₂ P_Iᵀ.

    Contracted mode-2 first, then mode-1, as two pinned einsums: n-mode
    products commute exactly but not in float32, and the unfolding identities
    (tests/test_core_conv.py) assume this order. A single three-operand
    einsum lets the contraction path vary by backend.
    """
    half = jnp.einsum("oikl,ib->obkl", g, p_i)
    return jnp.einsum("obkl,oa->abkl", half, p_o)


def restore_core(core: jnp.ndarray, p_o: jnp.ndarray, p_i: jnp.ndarray) -> jnp.ndarray:
    """ΔW = core ×₁ P_O ×₂ P_I (mode-1 first; adjoint of ``project_core``)."""
    half = jnp.einsum("abkl,oa->obkl", core, p_o)
    return jnp.einsum("obkl,ib->oikl", half, p_i)


def _half_restored_m(m_core, p_o, p_i, mode: int):
    """First moment restored on the *other* mode, reshaped to the canonical
    projected layout for the Eqn-6 direction term of this mode's factor."""
    if mode == 1:  # updating P_O: restore mode-2 -> (r_O, I, K1, K2)
        half = jnp.einsum("abkl,ib->aikl", m_core, p_i)
        # canonical m_proj: (I*K1*K2, r_O)
        return jnp.moveaxis(half, 0, -1).reshape(-1, p_o.shape[1])
    half = jnp.einsum("abkl,oa->obkl", m_core, p_o)  # (O, r_I, K1, K2)
    return jnp.moveaxis(half, 1, -1).reshape(-1, p_i.shape[1])


def _refresh_factor(cfg, p, g_canon, m_proj_canon, count, leaf_idx, rank, mode):
    """Same schedule as the matrix case (strategy-aware)."""
    if cfg.strategy == "coap":
        do_ref = (count % cfg.t_update) == 0
        do_recal = (count % (cfg.lam * cfg.t_update)) == 0

        def refreshed():
            return lax.cond(
                do_recal,
                lambda: recalibrate.lowcost_svd(g_canon, p),
                lambda: correlation.sgd_update(
                    p, g_canon, m_proj_canon, lr=cfg.eqn6_lr, steps=cfg.eqn6_steps,
                    normalize=cfg.eqn6_normalize,
                ),
            )

        return lax.cond(do_ref, refreshed, lambda: p)
    if cfg.strategy == "galore":
        do_ref = (count % cfg.t_update) == 0
        return lax.cond(
            do_ref,
            lambda: recalibrate.galore_svd(g_canon, rank).astype(p.dtype),
            lambda: p,
        )
    do_ref = (count % cfg.t_update) == 0
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(cfg.seed), 7919 * leaf_idx + mode), count
    )
    return lax.cond(
        do_ref,
        lambda: recalibrate.random_projection(key, g_canon.shape, rank, p.dtype),
        lambda: p,
    )


def update_conv_leaf(cfg, leaf, g, spec: ProjSpec, count, t, leaf_idx):
    """One Algorithm-3 step for a conv leaf. Returns (update, new_leaf)."""
    from repro.core.coap_adam import ConvLeaf, _load, _store  # circular-safe

    g32 = g.astype(jnp.float32)
    csh = core_shape(g.shape, spec)
    m = _load(leaf.m, leaf.m_scale, csh, cfg)
    v = _load(leaf.v, leaf.v_scale, csh, cfg)

    g1 = mode1_canonical(g32)
    g2 = mode2_canonical(g32)
    m1 = _half_restored_m(m, leaf.p_o, leaf.p_i, mode=1)
    m2 = _half_restored_m(m, leaf.p_o, leaf.p_i, mode=2)
    p_o = _refresh_factor(cfg, leaf.p_o, g1, m1, count, leaf_idx, spec.rank_o, 1)
    p_i = _refresh_factor(cfg, leaf.p_i, g2, m2, count, leaf_idx, spec.rank_i, 2)

    g_core = project_core(g32, p_o, p_i)
    new_m = cfg.b1 * m + (1.0 - cfg.b1) * g_core
    new_v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g_core)
    tf = t.astype(jnp.float32)
    delta_core = (new_m / (1.0 - cfg.b1**tf)) / (
        jnp.sqrt(new_v / (1.0 - cfg.b2**tf)) + cfg.eps
    )
    if cfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
        from repro.kernels.ref import QUANT_DELTA_CLIP

        delta_core = jnp.clip(delta_core, -QUANT_DELTA_CLIP, QUANT_DELTA_CLIP)
    update = restore_core(delta_core, p_o, p_i) * cfg.update_scale
    sm, sms = _store(new_m, cfg)
    sv, svs = _store(new_v, cfg)
    return update.astype(g.dtype), ConvLeaf(
        p_o=p_o, p_i=p_i, m=sm, v=sv, m_scale=sms, v_scale=svs
    )
