"""Algorithm 3: COAP for conv tensors via Tucker-2 factorized projection.

A conv weight ``W ∈ R^{O×I×K1×K2}`` gets two factor projections
``P_O ∈ R^{O×r_O}`` and ``P_I ∈ R^{I×r_I}`` (kernel dims are tiny and left
alone — the appendix's Tucker-2 ablation shows this beats Tucker-1/full
Tucker). The projected gradient is the Tucker-2 core

    G_proj = G ×₁ P_Oᵀ ×₂ P_Iᵀ  ∈ R^{r_O×r_I×K1×K2}

and moments live in that core shape. Each factor is refreshed with the same
Eqn-6 / Eqn-7 machinery as the matrix case applied to the mode-1 / mode-2
unfoldings of G (appendix §1.5): for the ``P_O`` update the canonical matrix
is ``unfold₁(G)ᵀ ∈ R^{(I·K1·K2)×O}`` so the half-restored first moment
``M_proj ×₂ P_I`` provides the direction term.

Every primitive broadcasts over leading (bucket) axes — the same conv
weight shape stacked ``(B, O, I, K1, K2)`` projects/restores with the
identical pinned contraction order — which is what lets
``scale_by_projected_adam`` run one Algorithm-3 launch per congruent conv
bucket (:func:`update_conv_bucket`) instead of a per-leaf Python loop,
with the staggered ``lax.switch`` phase-group refresh shared with the
matrix path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import correlation, recalibrate
from repro.core.projector import ProjSpec
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.obs import health


def init_factors(key, w_shape, spec: ProjSpec):
    o, i = int(w_shape[0]), int(w_shape[1])
    ko, ki = jax.random.split(key)
    p_o = jax.random.normal(ko, (o, spec.rank_o), jnp.float32) / jnp.sqrt(
        jnp.asarray(spec.rank_o, jnp.float32)
    )
    p_i = jax.random.normal(ki, (i, spec.rank_i), jnp.float32) / jnp.sqrt(
        jnp.asarray(spec.rank_i, jnp.float32)
    )
    return p_o, p_i


def core_shape(w_shape, spec: ProjSpec) -> Tuple[int, ...]:
    return (spec.rank_o, spec.rank_i) + tuple(int(s) for s in w_shape[2:])


def mode1_canonical(g: jnp.ndarray) -> jnp.ndarray:
    """(...,O,I,K1,K2) -> unfold₁ᵀ = (...,I·K1·K2, O): canonical m≥n matrix
    whose right-projection P is P_O. Leading (bucket) axes broadcast."""
    o = g.shape[-4]
    return jnp.moveaxis(g, -4, -1).reshape(g.shape[:-4] + (-1, o))


def mode2_canonical(g: jnp.ndarray) -> jnp.ndarray:
    """(...,O,I,K1,K2) -> (...,O·K1·K2, I): right-projection P is P_I."""
    i = g.shape[-3]
    return jnp.moveaxis(g, -3, -1).reshape(g.shape[:-4] + (-1, i))


def project_core(g: jnp.ndarray, p_o: jnp.ndarray, p_i: jnp.ndarray) -> jnp.ndarray:
    """G ×₁ P_Oᵀ ×₂ P_Iᵀ.

    Contracted mode-2 first, then mode-1, as two pinned einsums: n-mode
    products commute exactly but not in float32, and the unfolding identities
    (tests/test_core_conv.py) assume this order. A single three-operand
    einsum lets the contraction path vary by backend.
    """
    half = jnp.einsum("...oikl,...ib->...obkl", g, p_i)
    return jnp.einsum("...obkl,...oa->...abkl", half, p_o)


def restore_core(core: jnp.ndarray, p_o: jnp.ndarray, p_i: jnp.ndarray) -> jnp.ndarray:
    """ΔW = core ×₁ P_O ×₂ P_I (mode-1 first; adjoint of ``project_core``)."""
    half = jnp.einsum("...abkl,...oa->...obkl", core, p_o)
    return jnp.einsum("...obkl,...ib->...oikl", half, p_i)


def _half_restored_m(m_core, p_o, p_i, mode: int):
    """First moment restored on the *other* mode, reshaped to the canonical
    projected layout for the Eqn-6 direction term of this mode's factor.
    Leading (bucket) axes broadcast."""
    lead = m_core.shape[:-4]
    if mode == 1:  # updating P_O: restore mode-2 -> (..., r_O, I, K1, K2)
        half = jnp.einsum("...abkl,...ib->...aikl", m_core, p_i)
        # canonical m_proj: (..., I*K1*K2, r_O)
        return jnp.moveaxis(half, -4, -1).reshape(lead + (-1, p_o.shape[-1]))
    half = jnp.einsum("...abkl,...oa->...obkl", m_core, p_o)
    return jnp.moveaxis(half, -3, -1).reshape(lead + (-1, p_i.shape[-1]))


def _refresh_factor(cfg, p, g_canon, m_proj_canon, count, leaf_idx, rank, mode):
    """Same schedule as the matrix case (strategy-aware)."""
    if cfg.strategy == "coap":
        do_ref = (count % cfg.t_update) == 0
        do_recal = (count % (cfg.lam * cfg.t_update)) == 0

        def refreshed():
            return lax.cond(
                do_recal,
                lambda: recalibrate.lowcost_svd(g_canon, p),
                lambda: correlation.sgd_update(
                    p, g_canon, m_proj_canon, lr=cfg.eqn6_lr, steps=cfg.eqn6_steps,
                    normalize=cfg.eqn6_normalize,
                ),
            )

        return lax.cond(do_ref, refreshed, lambda: p)
    if cfg.strategy == "galore":
        do_ref = (count % cfg.t_update) == 0
        return lax.cond(
            do_ref,
            lambda: recalibrate.galore_svd(g_canon, rank).astype(p.dtype),
            lambda: p,
        )
    do_ref = (count % cfg.t_update) == 0
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.key(cfg.seed), 7919 * leaf_idx + mode), count
    )
    return lax.cond(
        do_ref,
        lambda: recalibrate.random_projection(key, g_canon.shape, rank, p.dtype),
        lambda: p,
    )


def refresh_factors(cfg, p_o, p_i, g1, g2, m_core, do_recal):
    """THE coap-strategy Tucker-2 factor refresh, defined once: Eqn-7
    low-cost SVD of both mode unfoldings when ``do_recal``, else one Eqn-6
    SGD step per factor with the half-restored first moment as direction
    term. ``g1``/``g2`` are the mode-1/mode-2 canonicals of the (averaged)
    gradient; everything broadcasts over leading bucket axes. Shared by the
    bucketed hot path (:func:`update_conv_bucket`) and the cross-pod
    compression path so the two can never drift apart."""

    def recal():
        return (
            recalibrate.lowcost_svd(g1, p_o),
            recalibrate.lowcost_svd(g2, p_i),
        )

    def eqn6():
        m1 = _half_restored_m(m_core, p_o, p_i, mode=1)
        m2 = _half_restored_m(m_core, p_o, p_i, mode=2)
        kw = dict(lr=cfg.eqn6_lr, steps=cfg.eqn6_steps,
                  normalize=cfg.eqn6_normalize)
        return (
            correlation.sgd_update(p_o, g1, m1, **kw),
            correlation.sgd_update(p_i, g2, m2, **kw),
        )

    return lax.cond(do_recal, recal, eqn6)


def _load_stack(stored, scale, csh, cfg):
    """Stacked conv moments -> fp32 (B, *csh), one dequant launch.

    Quantized conv states keep the flat (nblocks, 256) codec per leaf; a
    stacked bucket holds (B, nblocks, 256) codes + (B, nblocks) scales.
    Blocks are PER-LEAF (each leaf zero-padded to a block multiple on its
    own), so reshaping to (B·nblocks, 256) and dequantizing once yields the
    bit-identical values per-leaf dequantization would."""
    if not cfg.quantize:
        return stored.astype(jnp.float32)
    b, nblocks, blk = stored.shape
    flat = kops.dequantize_blockwise(
        stored.reshape(b * nblocks, blk), scale.reshape(b * nblocks),
        (b * nblocks * blk,), block=blk,
    )
    numel = 1
    for s in csh:
        numel *= int(s)
    return flat.reshape(b, nblocks * blk)[:, :numel].reshape((b,) + tuple(csh))


def _store_stack(x, cfg):
    """fp32 (B, *csh) -> stacked flat-codec storage, one quantize launch.

    Pads each leaf row to a block multiple independently (matching the
    per-leaf codec's zero padding) so the emitted int8 codes and scales are
    bit-identical to quantizing each leaf separately."""
    if not cfg.quantize:
        return x.astype(cfg.state_dtype), jnp.zeros((x.shape[0], 1), jnp.float32)
    b = x.shape[0]
    flat = x.reshape(b, -1)
    pad = (-flat.shape[1]) % cfg.quant_block
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((b, pad), flat.dtype)], axis=1
        )
    q, s = kops.quantize_blockwise(flat, block=cfg.quant_block)
    nblocks = flat.shape[1] // cfg.quant_block
    return q.reshape(b, nblocks, cfg.quant_block), s.reshape(b, nblocks)


def update_conv_bucket(cfg, leaf, g, spec: ProjSpec, count, t, idx_arr,
                       phases=None):
    """One Algorithm-3 step for a STACKED bucket of congruent conv leaves.

    Every ``ConvLeaf`` field and ``g`` carry a leading ``(B,)`` bucket axis
    (B == 1 for singleton buckets). Both Tucker modes refresh inside the
    same staggered ``lax.switch`` group dispatch the matrix path uses —
    leaf b refreshes when ``(count + phases[b]) % T_u == 0`` (recalibrates
    at ``λ·T_u`` likewise) plus the mandatory Eqn-7 initialization for the
    whole bucket at count == 0 — and the per-step Tucker-2 core projection
    + Adam moment update run as ONE batched launch per bucket. ``idx_arr``
    (B,) holds the ORIGINAL flat leaf indices: flora folds ``7919·idx +
    mode`` into its per-leaf RNG keys, so bucketing never changes the
    random stream. Returns (update (B,O,I,K1,K2), new_leaf).
    """
    from repro.core.coap_adam import (  # circular-safe
        ConvLeaf,
        _phase_groups,
        _refresh_mask,
        _sched_preds,
        _stagger_dispatch,
    )

    b = g.shape[0]
    if phases is None:
        phases = (0,) * b
    groups = _phase_groups(phases)
    t_u = cfg.t_update

    g32 = g.astype(jnp.float32)
    csh = core_shape(g.shape[1:], spec)
    m = _load_stack(leaf.m, leaf.m_scale, csh, cfg)
    v = _load_stack(leaf.v, leaf.v_scale, csh, cfg)

    # Per-leaf canonical unfolding shapes (flora's resample target): the
    # transposed copies themselves are built only inside refresh branches,
    # so non-refresh steps never pay the two extra G-sized streams.
    o, i = g.shape[1], g.shape[2]
    k = 1
    for s in g.shape[3:]:
        k *= int(s)
    g1_shape = (i * k, o)  # mode-1 canonical, per leaf
    g2_shape = (o * k, i)  # mode-2 canonical, per leaf

    def refresh_slice(sl, ph):
        """New (p_o, p_i) for the bucket-axis slice ``sl`` (strategy-aware;
        same schedule as the matrix _refresh_p, applied to both modes)."""
        p_o_g, p_i_g = leaf.p_o[sl], leaf.p_i[sl]
        g1_g = mode1_canonical(g32[sl])  # (B_g, I*K1*K2, O)
        g2_g = mode2_canonical(g32[sl])  # (B_g, O*K1*K2, I)
        if cfg.strategy == "coap":
            _, do_recal = _sched_preds(count, ph, t_u, cfg.lam)
            return refresh_factors(
                cfg, p_o_g, p_i_g, g1_g, g2_g, m[sl], do_recal
            )
        if cfg.strategy == "galore":
            return (
                recalibrate.galore_svd(g1_g, spec.rank_o).astype(leaf.p_o.dtype),
                recalibrate.galore_svd(g2_g, spec.rank_i).astype(leaf.p_i.dtype),
            )

        # flora: per-leaf keys fold in the ORIGINAL flat index and mode,
        # exactly as the per-leaf path (update_conv_leaf._refresh_factor).
        def resample(mode, canon_shape, rank, dtype):
            def one(i):
                key = jax.random.fold_in(
                    jax.random.fold_in(
                        jax.random.key(cfg.seed), 7919 * i + mode
                    ),
                    count,
                )
                return recalibrate.random_projection(
                    key, canon_shape, rank, dtype
                )

            return jax.vmap(one)(idx_arr[sl])

        return (
            resample(1, g1_shape, spec.rank_o, leaf.p_o.dtype),
            resample(2, g2_shape, spec.rank_i, leaf.p_i.dtype),
        )

    if len(groups) == 1:
        do_ref, _ = _sched_preds(count, groups[0][2], t_u, cfg.lam)
        p_o, p_i = lax.cond(
            do_ref,
            lambda: refresh_slice(slice(None), groups[0][2]),
            lambda: (leaf.p_o, leaf.p_i),
        )
    else:
        def group_fn(s0, sz, ph):
            po_g, pi_g = refresh_slice(slice(s0, s0 + sz), ph)
            return (
                leaf.p_o.at[s0:s0 + sz].set(po_g),
                leaf.p_i.at[s0:s0 + sz].set(pi_g),
            )

        p_o, p_i = _stagger_dispatch(
            groups, count, t_u,
            noop=lambda: (leaf.p_o, leaf.p_i),
            group_fn=group_fn,
            # t=0: Eqn-7 initialization for the whole bucket regardless of
            # phase (do_recal is True at count==0 inside refresh_slice).
            full_fn=lambda: refresh_slice(slice(None), 0),
        )

    # Projection-health emit (obs/health): Tucker-2 refresh metrics under
    # the refresh cond (G already materialized there); trace-time no-op
    # with no monitor configured, zero extra G traffic off-refresh.
    health.emit_refresh_conv(
        health.bucket_label("conv", g.shape[1:], g.dtype),
        g32, leaf.p_o, leaf.p_i, p_o, p_i,
        _refresh_mask(count, phases, t_u), count,
    )

    g_core = project_core(g32, p_o, p_i)
    new_m = cfg.b1 * m + (1.0 - cfg.b1) * g_core
    new_v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g_core)
    tf = t.astype(jnp.float32)
    delta_core = (new_m / (1.0 - cfg.b1**tf)) / (
        jnp.sqrt(new_v / (1.0 - cfg.b2**tf)) + cfg.eps
    )
    if cfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
        delta_core = jnp.clip(
            delta_core, -kref.QUANT_DELTA_CLIP, kref.QUANT_DELTA_CLIP
        )
    update = restore_core(delta_core, p_o, p_i) * cfg.update_scale
    sm, sms = _store_stack(new_m, cfg)
    sv, svs = _store_stack(new_v, cfg)
    return update.astype(g.dtype), ConvLeaf(
        p_o=p_o, p_i=p_i, m=sm, v=sv, m_scale=sms, v_scale=svs, ef=leaf.ef
    )


def update_conv_leaf(cfg, leaf, g, spec: ProjSpec, count, t, leaf_idx):
    """One Algorithm-3 step for a conv leaf. Returns (update, new_leaf)."""
    from repro.core.coap_adam import ConvLeaf, _load, _store  # circular-safe

    g32 = g.astype(jnp.float32)
    csh = core_shape(g.shape, spec)
    m = _load(leaf.m, leaf.m_scale, csh, cfg)
    v = _load(leaf.v, leaf.v_scale, csh, cfg)

    g1 = mode1_canonical(g32)
    g2 = mode2_canonical(g32)
    m1 = _half_restored_m(m, leaf.p_o, leaf.p_i, mode=1)
    m2 = _half_restored_m(m, leaf.p_o, leaf.p_i, mode=2)
    p_o = _refresh_factor(cfg, leaf.p_o, g1, m1, count, leaf_idx, spec.rank_o, 1)
    p_i = _refresh_factor(cfg, leaf.p_i, g2, m2, count, leaf_idx, spec.rank_i, 2)

    g_core = project_core(g32, p_o, p_i)
    new_m = cfg.b1 * m + (1.0 - cfg.b1) * g_core
    new_v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g_core)
    tf = t.astype(jnp.float32)
    delta_core = (new_m / (1.0 - cfg.b1**tf)) / (
        jnp.sqrt(new_v / (1.0 - cfg.b2**tf)) + cfg.eps
    )
    if cfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
        from repro.kernels.ref import QUANT_DELTA_CLIP

        delta_core = jnp.clip(delta_core, -QUANT_DELTA_CLIP, QUANT_DELTA_CLIP)
    update = restore_core(delta_core, p_o, p_i) * cfg.update_scale
    sm, sms = _store(new_m, cfg)
    sv, svs = _store(new_v, cfg)
    return update.astype(g.dtype), ConvLeaf(
        p_o=p_o, p_i=p_i, m=sm, v=sv, m_scale=sms, v_scale=svs, ef=leaf.ef
    )
