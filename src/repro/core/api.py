"""Optimizer factory — every optimizer used anywhere in the paper, by name.

Names: ``adamw``, ``adam``, ``adafactor``, ``sgd``,
``coap-adamw``, ``galore-adamw``, ``flora-adamw``,
``coap-adafactor``, ``galore-adafactor``, ``flora-adafactor``,
and an ``8bit-`` prefix for quantized states (``8bit-adamw``,
``8bit-coap-adamw``, ``8bit-galore-adamw``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro import optim
from repro.core.coap_adafactor import coap_adafactor
from repro.core.coap_adam import _projected_adamw, coap_adamw
from repro.core.projector import ProjectionRules


@dataclasses.dataclass
class OptimizerConfig:
    name: str = "coap-adamw"
    learning_rate: Any = 1e-3  # float or schedule
    weight_decay: float = 0.0
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: Optional[float] = 1.0
    # Projection (COAP/GaLore/Flora) knobs:
    rank: Optional[int] = 512
    rank_ratio: Optional[float] = None  # paper's c: r = min(m,n)/c
    min_dim: int = 128
    t_update: int = 200  # T_u
    lam: int = 5  # λ
    eqn6_lr: float = 0.1
    eqn6_steps: int = 1
    update_scale: float = 1.0
    moment_transplant: bool = False
    stagger: bool = True  # phase-staggered refresh schedule (coap_adam doc)
    stagger_groups: int = 8
    stacked_state: bool = False  # pre-stacked bucket state (coap_adam doc)
    seed: int = 0
    state_dtype: Any = jnp.float32
    # A coap-plan/v1 artifact (repro.plan.Plan, dict, or JSON path). When
    # set, the projection rules, per-bucket quantize/T_u/stagger_groups and
    # the storage layout all come from the plan; the per-knob fields above
    # keep governing run-level knobs only (lr, betas, clip, weight decay).
    plan: Optional[Any] = None

    def rules(self) -> ProjectionRules:
        return ProjectionRules(
            rank=self.rank if self.rank_ratio is None else None,
            rank_ratio=self.rank_ratio,
            min_dim=self.min_dim,
        )


def make_optimizer(cfg: OptimizerConfig) -> optim.GradientTransformation:
    if cfg.plan is not None:
        # Budget-planned optimizer: the coap-plan/v1 artifact drives rules,
        # storage layout and per-bucket knobs (repro/plan/apply.py).
        from repro.plan import apply as plan_apply

        txs = []
        if cfg.grad_clip:
            txs.append(optim.clip_by_global_norm(cfg.grad_clip))
        txs.append(plan_apply.transform(plan_apply.resolve(cfg.plan), cfg))
        return optim.chain(*txs)

    name = cfg.name.lower()
    quantize = name.startswith("8bit-")
    if quantize:
        name = name[len("8bit-") :]

    txs = []
    if cfg.grad_clip:
        txs.append(optim.clip_by_global_norm(cfg.grad_clip))

    if name in ("adam", "adamw"):
        if quantize:
            # 8-bit Adam baseline (Dettmers): dense Adam with int8 states —
            # expressed as the projected transform with a nothing-projects rule.
            rules = ProjectionRules(rank=1, min_dim=10**9)
            tx = _projected_adamw(
                "coap",
                cfg.learning_rate,
                rules,
                b1=cfg.b1,
                b2=cfg.b2,
                eps=cfg.eps,
                weight_decay=cfg.weight_decay if name == "adamw" else 0.0,
                quantize=True,
                seed=cfg.seed,
            )
        else:
            tx = optim.adamw(
                cfg.learning_rate,
                b1=cfg.b1,
                b2=cfg.b2,
                eps=cfg.eps,
                weight_decay=cfg.weight_decay if name == "adamw" else 0.0,
                mu_dtype=cfg.state_dtype,
            )
        txs.append(tx)
    elif name == "adafactor":
        txs.append(
            optim.adafactor(cfg.learning_rate, weight_decay=cfg.weight_decay)
        )
    elif name == "sgd":
        txs.append(optim.sgd(cfg.learning_rate, momentum_decay=cfg.b1))
    elif name in ("coap-adamw", "galore-adamw", "flora-adamw"):
        strategy = name.split("-")[0]
        kw = dict(
            b1=cfg.b1,
            b2=cfg.b2,
            eps=cfg.eps,
            weight_decay=cfg.weight_decay,
            t_update=cfg.t_update,
            lam=cfg.lam,
            eqn6_lr=cfg.eqn6_lr,
            eqn6_steps=cfg.eqn6_steps,
            seed=cfg.seed,
            quantize=quantize,
            state_dtype=cfg.state_dtype,
            moment_transplant=cfg.moment_transplant,
            stagger=cfg.stagger,
            stagger_groups=cfg.stagger_groups,
            stacked_state=cfg.stacked_state,
        )
        if strategy == "galore":
            kw["update_scale"] = (
                cfg.update_scale if cfg.update_scale != 1.0 else 0.25
            )
            # GaLore's official implementation projects nn.Linear only —
            # conv tensors keep full-rank Adam states (why paper Table 3
            # shows COAP's Tucker-2 far ahead on conv nets).
            kw["rules"] = dataclasses.replace(cfg.rules(), project_conv=False)
        elif cfg.update_scale != 1.0:
            kw["update_scale"] = cfg.update_scale
        if strategy == "flora":
            kw["t_update"] = 1 if cfg.t_update == 200 else cfg.t_update
        rules = kw.pop("rules", cfg.rules())
        txs.append(_projected_adamw(strategy, cfg.learning_rate, rules, **kw))
    elif name in ("coap-adafactor", "galore-adafactor", "flora-adafactor"):
        strategy = name.split("-")[0]
        lr = cfg.learning_rate if not callable(cfg.learning_rate) else 1e-4
        txs.append(
            coap_adafactor(
                lr,
                cfg.rules(),
                strategy=strategy,
                b1=cfg.b1,
                t_update=cfg.t_update if strategy != "flora" else 1,
                lam=cfg.lam,
                eqn6_lr=cfg.eqn6_lr,
                eqn6_steps=cfg.eqn6_steps,
                seed=cfg.seed,
                update_scale=0.25 if strategy == "galore" else cfg.update_scale,
                stacked_state=cfg.stacked_state,
                stagger=cfg.stagger,
                stagger_groups=cfg.stagger_groups,
            )
        )
    else:
        raise ValueError(f"unknown optimizer: {cfg.name}")

    return optim.chain(*txs)
