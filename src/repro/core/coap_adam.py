"""Algorithm 1: Adam with COAP — plus GaLore/Flora strategy variants.

One GradientTransformation covers the whole family because the only
difference between COAP, GaLore and Flora is the projection-refresh rule:

  * ``coap``   — every ``T_u`` steps refresh P by Eqn-6 SGD; every
                 ``λ·T_u`` steps recalibrate by Eqn-7 low-cost SVD; at t=0
                 initialize by Eqn 7 from the first gradient (Algorithm 1).
  * ``galore`` — every ``T_u`` steps recompute P as the truncated SVD of the
                 current gradient (O(mn²)).
  * ``flora``  — resample a Gaussian P every ``T_u`` steps (paper: every
                 step, T_u=1) and transplant the first moment into the new
                 subspace.

Leaves are classified statically (see ``projector.ProjectionRules``):
2-D-matrix leaves (with arbitrary leading stack axes — scan-over-layers
weights ``(L,m,n)``, per-expert weights ``(L,E,m,n)``) are projected;
conv ``(O,I,K1,K2)`` kernels take the Tucker-2 path (Algorithm 3, in
``core/conv.py``); everything else gets dense Adam. Refreshes happen inside
the jitted step under ``lax.cond`` — no host round-trips (DESIGN.md §3).

Optimizer states are fp32 by default or block-wise int8 when
``quantize=True`` (8-bit COAP / 8-bit Adam baselines, via kernels/quant8).
Projected int8 moments use the ROW-BLOCK codec (shape-preserving int8 +
per-row-block scales; kernels/ref.py) so the whole quantized step — project,
dequant, moment EMA, requant, back-project — runs as ONE fused kernel with
no fp32 M/V or Δ_proj ever materialized in HBM. Dense and conv int8 states
keep the flat (nblocks, 256) codec.

``update_fn`` batches congruent leaves: all projected, conv or dense leaves
sharing a ``(shape, spec, dtype)`` signature are stacked along a new leading
axis and updated by a single (vmapped) kernel launch — a transformer's
dozens of per-layer matrices, or a vision tower's per-block conv kernels,
become a handful of dispatches per step instead of one per leaf. Bucketing
is numerics-neutral: every code path broadcasts over leading axes, and
flora's per-leaf RNG keys fold in the ORIGINAL flat leaf index, so bucketed
and per-leaf execution produce identical bits (``bucket_leaves=False``
keeps the per-leaf loop for A/B checks).

STAGGERED REFRESH (``stagger=True``, default): the paper-faithful schedule
refreshes EVERY projected leaf at ``count % T_u == 0`` — a synchronized
QR/SVD + Eqn-6 stall across the whole tree every ``T_u`` steps (the GaLore
cost cliff the paper's cheap refresh is meant to remove). With stagger on,
each leaf gets a deterministic phase offset and refreshes when
``(count + phase) % T_u == 0`` (recalibration likewise at
``(count + phase) % (λ·T_u) == 0``), so refresh work spreads nearly
uniformly over the interval and the worst step pays ~1/U of the
synchronized cost (U = total phase groups). Semantics preserved exactly:

  * every leaf still refreshes with period ``T_u`` and recalibrates with
    period ``λ·T_u`` — only the phase differs per leaf;
  * Eqn-7 initialization at t=0 runs for ALL leaves regardless of phase
    (Algorithm 1 line 3 — the first gradient seeds every P);
  * phases are a pure function of the bucket structure
    (``stagger_phases``), so they are identical across restarts and
    identical between bucketed and per-leaf execution;
  * within a congruent bucket, leaves are partitioned into at most
    ``stagger_groups`` contiguous phase groups; on a refresh step only the
    matching group's slice runs QR/SVD/Eqn-6 (``lax.switch`` over static
    slices), and the per-step fused update stays ONE launch per bucket.

``stagger=False`` restores the synchronized schedule bit-for-bit.
Flora's per-step resample (T_u=1) degenerates to a single phase-0 group and
is unchanged; with T_u>1 its resamples stagger for free. Conv (Tucker-2)
leaves are on the SAME staggered schedule since stacked-bucket/v2: each
conv bucket's phase units are allocated by ``stagger_phases`` right after
the projected buckets' (``layout.staggerable_bucket_sizes()``), and both
Tucker factors of a phase group refresh inside one ``lax.switch`` branch
(``conv.update_conv_bucket``).

PRE-STACKED STATE (``stacked_state=True``): with per-leaf state storage the
stack/scatter round-trip at the bucket boundary is real copy traffic every
step (XLA fuses some fp32 copies into kernel operands, but never the int8
state round-trip). Setting ``stacked_state=True`` stores the optimizer
state pre-stacked along the bucket axis (``core/stacked_state.py``): the
fused kernels and the staggered ``lax.switch`` refresh consume bucket
slices directly, and only the gradient stack and update scatter — pure
bf16/fp32 copies at the kernel boundary — remain on the hot path
(``benchmarks/overhead.run_state`` quantifies the removed traffic;
``BENCH_state.json``). State-tree/param-tree congruence is recovered on
demand through the stacked-state codec (``encode``/``decode``/
``leaf_view``/``manifest_entries``), which checkpointing, accounting and
the cross-pod compression path all understand — a checkpoint written in
either mode restores into the other. ``stacked_state=False`` (the default)
keeps today's per-leaf layout bit-for-bit, and the two modes produce
bit-identical updates and states — fp32, bf16 streaming, int8 codes and
flora RNG included (``tests/test_stacked_state.py``). Conv (Tucker-2)
leaves bucket and pre-stack like everything else under the
``stacked-bucket/v2`` codec (``tests/test_conv_bucketing.py``); a custom
``classify`` can still route leaves to the per-leaf residual tail.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import conv as conv_mod
from repro.core import correlation, projector, recalibrate
from repro.core import stacked_state
from repro.core.projector import (
    KIND_CONV,
    KIND_DENSE,
    KIND_PROJECT,
    ProjSpec,
    ProjectionRules,
    path_str,
)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.obs import health
from repro.optim.transform import (
    GradientTransformation,
    add_decayed_weights,
    chain,
    scale_by_learning_rate,
)

STRATEGIES = ("coap", "galore", "flora")


class ProjLeaf(NamedTuple):
    """Low-rank leaf state: P (…,n,r); moments on the large side (…,m,r).

    Quantized moments are shape-preserving int8 under the row-block codec:
    ``m``/``v`` stay (…,m,r) int8 and ``*_scale`` are (…,m,ceil(r/block))
    fp32 — the layout the fused q8 kernel consumes tile-locally.

    ``ef`` is the int8-collective error-feedback accumulator (fp32, moment
    shape) used by the cross-pod ``sync_codes`` path; ``None`` (an empty
    pytree slot — zero bytes, zero checkpoint entries) unless the config
    enables ``sync_codes``. Single-pod updates carry it through untouched."""

    p: Any
    m: Any
    v: Any
    m_scale: Any  # codec scales; zeros((1,)) placeholders when fp32
    v_scale: Any
    ef: Any = None  # sync_codes error-feedback sidecar (distributed only)


class DenseLeaf(NamedTuple):
    mu: Any
    nu: Any
    mu_scale: Any
    nu_scale: Any


class ConvLeaf(NamedTuple):
    """Tucker-2 leaf (Algorithm 3): two factor projections + core moments."""

    p_o: Any  # (O, r_O)
    p_i: Any  # (I, r_I)
    m: Any  # (r_O, r_I, K1, K2)
    v: Any
    m_scale: Any
    v_scale: Any
    ef: Any = None  # sync_codes error-feedback sidecar (core shape; see ProjLeaf)


class ProjectedAdamState(NamedTuple):
    count: jnp.ndarray
    leaves: Any  # pytree congruent with params; leaf = Proj/Dense/ConvLeaf


@dataclasses.dataclass(frozen=True)
class LeafOverrides:
    """Per-leaf knob overrides a memory plan may pin (``None`` = inherit the
    global :class:`ProjectedAdamConfig` value). Rank overrides do NOT live
    here — they ride in the rules (``projector.PlannedRules``) because the
    rank is part of the ProjSpec and therefore of the bucket identity."""

    quantize: Optional[bool] = None
    t_update: Optional[int] = None
    stagger_groups: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class PlanOverrides:
    """Exact-path -> :class:`LeafOverrides` map (hashable; plan-driven).

    Congruence buckets group leaves by ``(spec, shape, dtype)``; storage
    codec and refresh cadence are bucket-level properties, so every path of
    a bucket must resolve to the SAME overrides — ``update_fn`` enforces
    this and raises on a mixed bucket (a plan assigns knobs per bucket, so
    this only triggers on hand-edited plans)."""

    entries: Tuple[Tuple[str, LeafOverrides], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "_map", dict(self.entries))

    def for_path(self, path: str) -> Optional[LeafOverrides]:
        return self._map.get(path)

    def any_quantized(self) -> bool:
        return any(ov.quantize for _, ov in self.entries)


@dataclasses.dataclass(frozen=True)
class ProjectedAdamConfig:
    rules: ProjectionRules
    strategy: str = "coap"
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    t_update: int = 200  # T_u (refresh interval; GaLore SVD interval; Flora=1)
    lam: int = 5  # λ: Eqn-7 recalibration every λ·T_u steps
    eqn6_lr: float = 0.1  # paper appendix: SGD lr for Eqn 6, default 0.1
    eqn6_steps: int = 1
    eqn6_normalize: bool = False  # beyond-paper scale-invariant Eqn-6 step
    seed: int = 0
    state_dtype: Any = jnp.float32
    quantize: bool = False  # 8-bit block-wise states
    quant_block: int = kref.QUANT_BLOCK
    update_scale: float = 1.0  # GaLore's α (their repo default 0.25)
    moment_transplant: bool = False  # carry M into the new subspace at refresh
    use_fused_kernel: bool = True  # route through kernels/ops (Pallas on TPU)
    bucket_leaves: bool = True  # batch congruent leaves into stacked launches
    stagger: bool = True  # phase-staggered refresh schedule (module docstring)
    stagger_groups: int = 8  # max phase groups per congruent bucket
    stacked_state: bool = False  # store state pre-stacked (module docstring)
    # Cross-pod int8 collective (distributed/compression.py): all-reduce the
    # int8 codes + per-block scales of G_proj instead of fp32 values, with a
    # per-leaf fp32 error-feedback accumulator (ProjLeaf/ConvLeaf.ef). The
    # knob lives here so init_fn allocates the sidecar and the byte model
    # (plan/bytes.py) predicts it; single-pod updates ignore it.
    sync_codes: bool = False
    # Plan-driven per-bucket knob overrides (quantize / T_u / stagger_groups;
    # repro/plan consumes coap-plan/v1 artifacts into this field).
    overrides: Optional[PlanOverrides] = None

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}")
        if self.stacked_state and not self.bucket_leaves:
            raise ValueError(
                "stacked_state=True stores the state along the bucket axis "
                "and requires bucket_leaves=True"
            )

    def any_quantized(self) -> bool:
        """True when ANY leaf stores int8 state (global flag or a per-leaf
        plan override) — the conservative check for consumers that cannot
        handle quantized states (e.g. compressed cross-pod sync)."""
        if self.quantize:
            return True
        return self.overrides is not None and self.overrides.any_quantized()


def _zeros_scales(shape_numel: int, block: int):
    nblocks = -(-shape_numel // block)
    return jnp.zeros((nblocks,), jnp.float32)


def _store(x: jnp.ndarray, cfg: ProjectedAdamConfig):
    """fp32 array -> (stored, scale) under the configured codec."""
    if not cfg.quantize:
        return x.astype(cfg.state_dtype), jnp.zeros((1,), jnp.float32)
    q, s = kops.quantize_blockwise(x, block=cfg.quant_block)
    return q, s


def _load(stored: jnp.ndarray, scale: jnp.ndarray, shape, cfg: ProjectedAdamConfig):
    if not cfg.quantize:
        return stored.astype(jnp.float32)
    return kops.dequantize_blockwise(stored, scale, tuple(shape), block=cfg.quant_block)


def _init_stored(shape, cfg: ProjectedAdamConfig):
    numel = 1
    for s in shape:
        numel *= int(s)
    if not cfg.quantize:
        return jnp.zeros(shape, cfg.state_dtype), jnp.zeros((1,), jnp.float32)
    nblocks = -(-numel // cfg.quant_block)
    return (
        jnp.zeros((nblocks, cfg.quant_block), jnp.int8),
        jnp.zeros((nblocks,), jnp.float32),
    )


def _init_stored_proj(shape, cfg: ProjectedAdamConfig):
    """Projected-moment storage: row-block int8 when quantized, else dense."""
    if not cfg.quantize:
        return jnp.zeros(shape, cfg.state_dtype), jnp.zeros((1,), jnp.float32)
    nblk = kref.rowblock_nblocks(int(shape[-1]), cfg.quant_block)
    return (
        jnp.zeros(shape, jnp.int8),
        jnp.zeros(tuple(shape[:-1]) + (nblk,), jnp.float32),
    )


def _leaf_spec(cfg: ProjectedAdamConfig, path: str, shape) -> ProjSpec:
    return cfg.rules.spec_for(path, shape)


def _apply_overrides(
    cfg: ProjectedAdamConfig, ov: Optional[LeafOverrides]
) -> ProjectedAdamConfig:
    if ov is None:
        return cfg
    kw = {}
    if ov.quantize is not None and ov.quantize != cfg.quantize:
        kw["quantize"] = ov.quantize
    if ov.t_update is not None and ov.t_update != cfg.t_update:
        kw["t_update"] = ov.t_update
    if ov.stagger_groups is not None and ov.stagger_groups != cfg.stagger_groups:
        kw["stagger_groups"] = ov.stagger_groups
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _leaf_cfg(cfg: ProjectedAdamConfig, path: str) -> ProjectedAdamConfig:
    """The effective config for one leaf: plan overrides layered over the
    global knobs. With no overrides this is ``cfg`` itself."""
    if cfg.overrides is None:
        return cfg
    return _apply_overrides(cfg, cfg.overrides.for_path(path))


def _bucket_cfg(cfg: ProjectedAdamConfig, info) -> ProjectedAdamConfig:
    """The effective config for a congruence bucket. Storage codec and
    refresh cadence are bucket-level properties, so every member path must
    resolve to the same EFFECTIVE knobs. Overrides are normalized against
    the global config before comparing: an entry that merely restates the
    global value (or a reordered ``entries`` container) is not a conflict —
    only a genuinely different effective (quantize, T_u, stagger_groups)
    triple raises, and the error names a path from each side."""
    if cfg.overrides is None:
        return cfg

    def norm(ov: Optional[LeafOverrides]):
        if ov is None:
            return (cfg.quantize, cfg.t_update, cfg.stagger_groups)
        return (
            cfg.quantize if ov.quantize is None else ov.quantize,
            cfg.t_update if ov.t_update is None else ov.t_update,
            cfg.stagger_groups
            if ov.stagger_groups is None
            else ov.stagger_groups,
        )

    groups: dict = {}
    for p in info.paths:
        groups.setdefault(norm(cfg.overrides.for_path(p)), []).append(p)
    if len(groups) > 1:
        (ka, pa), (kb, pb) = list(groups.items())[:2]
        raise ValueError(
            f"plan overrides disagree within bucket {info.shape}/{info.dtype}:"
            f" {pa[0]!r} resolves to (quantize, t_update, stagger_groups)="
            f"{ka} but {pb[0]!r} to {kb} — a bucket's knobs must be uniform;"
            " assign overrides per bucket, not per leaf"
        )
    # All members normalize identically; any representative override yields
    # the same effective config (``_apply_overrides`` only replaces knobs
    # that actually differ from the global value).
    return _apply_overrides(cfg, cfg.overrides.for_path(info.paths[0]))


def _layout_of(cfg: ProjectedAdamConfig, flat) -> stacked_state.StackedLayout:
    """THE bucket assignment for this transform: projected, conv (Tucker-2)
    and dense leaves each bucket by congruence signature (the default
    ``classify_default`` — the stacked-bucket/v2 layout). Shared with the
    stacked-state codec so checkpoint / accounting / compression consumers
    see the identical grouping."""
    return stacked_state.layout_for_flat(cfg.rules.spec_for, flat)


def stagger_phases(
    bucket_sizes, t_update: int, stagger_groups: int
) -> list:
    """Deterministic per-leaf refresh phases for the staggered schedule.

    ``bucket_sizes`` lists the projected buckets' leaf counts in tree
    (insertion) order. Each bucket is split into at most ``stagger_groups``
    contiguous near-equal groups (``stagger_groups`` may be a sequence of
    per-bucket caps — how plan overrides stagger a bucket differently);
    the resulting units are spread uniformly
    over ``[0, t_update)`` so the worst refresh step carries ~1/U of the
    synchronized cost. Pure function of the tree structure — phases are
    identical across restarts and between bucketed and per-leaf execution.
    Returns one tuple of per-leaf-position phases per bucket.
    """
    t_u = max(1, int(t_update))
    if isinstance(stagger_groups, (list, tuple)):
        caps = [int(s) for s in stagger_groups]
    else:
        caps = [int(stagger_groups)] * len(bucket_sizes)
    n_groups = [
        max(1, min(int(b), cap, t_u)) for b, cap in zip(bucket_sizes, caps)
    ]
    total = sum(n_groups) or 1
    out = []
    u = 0
    for b, ng in zip(bucket_sizes, n_groups):
        unit_phases = [((u + j) * t_u) // total for j in range(ng)]
        out.append(tuple(unit_phases[(pos * ng) // b] for pos in range(b)))
        u += ng
    return out


def bucket_phases(
    cfg: ProjectedAdamConfig, layout: stacked_state.StackedLayout
) -> dict:
    """THE staggered phase allocation, bucket-indexed: maps every
    staggerable bucket (projected then conv, in layout order) to its
    per-slot refresh phases.

    A pure function of ``(layout, cfg)`` — no step, no RNG, no state — so
    phases re-derive identically across restarts, resumes and replans that
    preserve the layout; ``update_fn`` calls this every trace and the
    elastic supervisor (``train/elastic.py``) calls it to pin down the
    schedule a resumed run will follow. Buckets sharing an effective T_u
    are allocated jointly (phases spread uniformly over [0, T_u) across
    all of them); buckets a plan pins to a different T_u get their own
    allocation over their own interval. With no overrides this is exactly
    the single joint allocation of the global schedule.
    """
    bucket_cfgs = [_bucket_cfg(cfg, info) for info in layout.buckets]
    stag_bis = [
        bi for bi, info in enumerate(layout.buckets)
        if info.kind in (
            stacked_state.BUCKET_PROJECT, stacked_state.BUCKET_CONV
        )
    ]
    by_tu = {}
    for bi in stag_bis:
        by_tu.setdefault(bucket_cfgs[bi].t_update, []).append(bi)
    phase_by_bucket = {}
    for t_u, bis in by_tu.items():
        sizes = [len(layout.buckets[bi].indices) for bi in bis]
        if cfg.stagger and t_u > 1:
            pls = stagger_phases(
                sizes, t_u, [bucket_cfgs[bi].stagger_groups for bi in bis]
            )
        else:
            pls = [(0,) * sz for sz in sizes]
        for bi, pl in zip(bis, pls):
            phase_by_bucket[bi] = pl
    return phase_by_bucket


def _phase_groups(phases) -> list:
    """Maximal runs of equal phase -> [(start, size, phase)]. Phases are
    non-decreasing within a bucket (``stagger_phases`` allocates monotone
    units), so equal phases are always adjacent and groups carry distinct
    phases in [0, T_u) — at most one group matches any given step."""
    groups = []
    start = 0
    for i in range(1, len(phases) + 1):
        if i == len(phases) or phases[i] != phases[start]:
            groups.append((start, i - start, phases[start]))
            start = i
    return groups


def _expand_mask(mask: jnp.ndarray, ndim: int) -> jnp.ndarray:
    """(B,) bool -> (B, 1, ..., 1) broadcastable against a stacked leaf."""
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def _sched_preds(count, ph: int, t_u: int, lam: int):
    """THE staggered-schedule predicates, defined once: refresh when
    ``(count + phase) % T_u == 0``, recalibrate when ``(count + phase) %
    (λ·T_u) == 0`` — plus the mandatory Eqn-7 initialization for everyone at
    count == 0. ``_refresh_mask`` is the vectorized refresh predicate."""
    do_ref = ((count + ph) % t_u == 0) | (count == 0)
    do_recal = ((count + ph) % (lam * t_u) == 0) | (count == 0)
    return do_ref, do_recal


def _refresh_mask(count, phases, t_u: int) -> jnp.ndarray:
    phase_arr = jnp.asarray(phases, jnp.int32)
    return ((count + phase_arr) % t_u == 0) | (count == 0)


def _stagger_select(groups, count, t_u: int) -> jnp.ndarray:
    """Branch index for a staggered lax.switch: 0 = no-op, 1..G = the (at
    most one — groups carry distinct phases mod T_u) matching phase group,
    G+1 = whole-bucket t=0 initialization."""
    sel = jnp.zeros((), jnp.int32)
    for j, (_, _, ph) in enumerate(groups):
        sel = jnp.where((count + ph) % t_u == 0, j + 1, sel)
    return jnp.where(count == 0, len(groups) + 1, sel)


def _stagger_dispatch(groups, count, t_u: int, noop, group_fn, full_fn):
    """THE staggered group dispatch, shared by the refresh and both
    transplant paths: lax.switch over [no-op] + one branch per phase group +
    [whole-bucket t=0 init]. ``group_fn(s0, sz, ph)`` produces the branch
    result for that group's static slice."""
    branches = (
        [noop]
        + [
            (lambda s0=s0, sz=sz, ph=ph: group_fn(s0, sz, ph))
            for s0, sz, ph in groups
        ]
        + [full_fn]
    )
    return lax.switch(_stagger_select(groups, count, t_u), branches)


def _refresh_p(
    cfg: ProjectedAdamConfig,
    spec: ProjSpec,
    p: jnp.ndarray,
    gc: jnp.ndarray,
    m_loader,
    count: jnp.ndarray,
    idx_arr: jnp.ndarray,
    phases: Optional[Tuple[int, ...]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Strategy-specific P refresh on a stacked leaf bucket.

    ``p``/``gc`` carry a leading (B,) bucket axis; ``idx_arr`` (B,) holds the
    ORIGINAL flat leaf indices (flora folds them into its per-leaf RNG keys,
    so bucketing never changes the random stream). ``m_loader`` is invoked
    lazily inside the refresh branch — quantized M is only dequantized on the
    (rare) refresh steps, never in the per-step hot loop. Staggered group
    branches pass it a bucket-axis ``slice`` so only the refreshing slice is
    ever dequantized (per-leaf callers may supply a zero-arg loader: the
    single-group path calls it without arguments). ``gc`` may be bf16
    (every refresh primitive upcasts internally).

    ``phases`` (len B, non-decreasing) staggers the schedule: leaf b
    refreshes when ``(count + phases[b]) % T_u == 0`` — plus the mandatory
    Eqn-7 initialization for everyone at count==0. With a single phase group
    (the default / ``stagger=False``) this is exactly the synchronized
    Algorithm-1 schedule; with several, a ``lax.switch`` refreshes only the
    matching group's static slice.

    Returns (new_p, refreshed) where ``refreshed`` is a (B,) bool mask.
    """
    b = p.shape[0]
    if phases is None:
        phases = (0,) * b
    groups = _phase_groups(phases)
    t_u = cfg.t_update
    mask = _refresh_mask(count, phases, t_u)

    def eqn6(p_g, gc_g, m_g):
        return correlation.sgd_update(
            p_g, gc_g, m_g, lr=cfg.eqn6_lr, steps=cfg.eqn6_steps,
            normalize=cfg.eqn6_normalize, use_fused=cfg.use_fused_kernel,
        )

    def _staggered(refresh_slice, full_init):
        return _stagger_dispatch(
            groups, count, t_u,
            noop=lambda: p,
            group_fn=lambda s0, sz, ph: p.at[s0:s0 + sz].set(
                refresh_slice(s0, sz, ph)
            ),
            full_fn=full_init,
        )

    if cfg.strategy == "coap":
        if len(groups) == 1:
            do_ref, do_recal = _sched_preds(count, groups[0][2], t_u, cfg.lam)

            def refreshed():
                return lax.cond(
                    do_recal,
                    lambda: recalibrate.lowcost_svd(gc, p),
                    lambda: eqn6(p, gc, m_loader()),
                )

            return lax.cond(do_ref, refreshed, lambda: p), mask

        def refresh_slice(s0, sz, ph):
            p_g = p[s0:s0 + sz]
            gc_g = gc[s0:s0 + sz]
            _, do_recal = _sched_preds(count, ph, t_u, cfg.lam)
            return lax.cond(
                do_recal,
                lambda: recalibrate.lowcost_svd(gc_g, p_g),
                lambda: eqn6(p_g, gc_g, m_loader(slice(s0, s0 + sz))),
            )

        new_p = _staggered(
            refresh_slice, lambda: recalibrate.lowcost_svd(gc, p)
        )
        return new_p, mask

    if cfg.strategy == "galore":
        if len(groups) == 1:
            do_ref, _ = _sched_preds(count, groups[0][2], t_u, cfg.lam)
            new_p = lax.cond(
                do_ref,
                lambda: recalibrate.galore_svd(gc, spec.rank).astype(p.dtype),
                lambda: p,
            )
            return new_p, mask

        def refresh_slice(s0, sz, ph):
            return recalibrate.galore_svd(
                gc[s0:s0 + sz], spec.rank
            ).astype(p.dtype)

        new_p = _staggered(
            refresh_slice,
            lambda: recalibrate.galore_svd(gc, spec.rank).astype(p.dtype),
        )
        return new_p, mask

    # flora
    elem_shape = gc.shape[1:]

    def resample_idx(idx_slice):
        def one(i):
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.key(cfg.seed), i), count
            )
            return recalibrate.random_projection(
                key, elem_shape, spec.rank, p.dtype
            )

        return jax.vmap(one)(idx_slice)

    if len(groups) == 1:
        do_ref, _ = _sched_preds(count, groups[0][2], t_u, cfg.lam)
        new_p = lax.cond(do_ref, lambda: resample_idx(idx_arr), lambda: p)
        return new_p, mask

    new_p = _staggered(
        lambda s0, sz, ph: resample_idx(idx_arr[s0:s0 + sz]),
        lambda: resample_idx(idx_arr),
    )
    return new_p, mask


def _wants_transplant(cfg: ProjectedAdamConfig) -> bool:
    """Flora always transplants; COAP/GaLore only when opted in."""
    return cfg.strategy == "flora" or cfg.moment_transplant


def _maybe_transplant(
    cfg: ProjectedAdamConfig, m: jnp.ndarray, p_old, p_new, refreshed,
    phases=None, count=None,
) -> jnp.ndarray:
    """M_new = (M P_oldᵀ) P_new — keeps momentum direction across subspace
    switches. Flora's mechanism; optional (off = Algorithm 1 verbatim) for
    COAP/GaLore.

    ``refreshed`` is either a scalar bool (per-leaf callers, e.g. the
    adafactor variant) or a (B,) mask over a stacked bucket: under the
    staggered schedule only the refreshed slice may transplant — P is
    non-orthonormal, so project∘backproject is NOT the identity and must not
    touch leaves whose P did not change. When the caller supplies ``phases``
    and ``count``, the transplant follows the same group structure as
    ``_refresh_p``: only the refreshing slice's (B_g, m, n, r) work runs,
    not the whole bucket's."""
    if not _wants_transplant(cfg):
        return m
    if getattr(refreshed, "ndim", 0) == 0:
        def do():
            restored = projector.backproject(m, p_old)
            return projector.project(restored, p_new)

        return lax.cond(refreshed, do, lambda: m)

    def carry(sl):
        restored = projector.backproject(m[sl], p_old[sl])
        return projector.project(restored, p_new[sl])

    groups = _phase_groups(phases) if phases is not None else []
    if len(groups) <= 1:
        def do_masked():
            return jnp.where(
                _expand_mask(refreshed, m.ndim), carry(slice(None)), m
            )

        return lax.cond(jnp.any(refreshed), do_masked, lambda: m)

    return _stagger_dispatch(
        groups, count, cfg.t_update,
        noop=lambda: m,
        group_fn=lambda s0, sz, ph: m.at[s0:s0 + sz].set(
            carry(slice(s0, s0 + sz))
        ),
        full_fn=lambda: carry(slice(None)),  # t=0: everyone refreshed
    )


def scale_by_projected_adam(cfg: ProjectedAdamConfig) -> GradientTransformation:
    """The regularizer ρ_t of paper Eqn 5 as a GradientTransformation.

    Produces *positive* update directions (caller chains lr sign-flip).
    """

    def init_fn(params):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        if cfg.overrides is not None:
            # Fail at init, not first update: a mixed-quantize bucket would
            # otherwise stack int8 codes with fp32 moments silently.
            for info in _layout_of(cfg, flat).buckets:
                _bucket_cfg(cfg, info)
        key = jax.random.key(cfg.seed)
        leaves = []
        for idx, (kp, leaf) in enumerate(flat):
            path = path_str(kp)
            spec = _leaf_spec(cfg, path, leaf.shape)
            lcfg = _leaf_cfg(cfg, path)  # plan overrides (storage codec)
            if spec.kind == KIND_PROJECT:
                p0 = projector.init_p(
                    jax.random.fold_in(key, idx), leaf.shape, spec,
                    cfg.state_dtype,
                )
                msh = projector.moment_shape(leaf.shape, spec)
                m0, ms0 = _init_stored_proj(msh, lcfg)
                v0, vs0 = _init_stored_proj(msh, lcfg)
                ef0 = jnp.zeros(msh, jnp.float32) if cfg.sync_codes else None
                leaves.append(
                    ProjLeaf(p=p0, m=m0, v=v0, m_scale=ms0, v_scale=vs0, ef=ef0)
                )
            elif spec.kind == KIND_CONV:
                po, pi = conv_mod.init_factors(
                    jax.random.fold_in(key, idx), leaf.shape, spec
                )
                msh = conv_mod.core_shape(leaf.shape, spec)
                m0, ms0 = _init_stored(msh, lcfg)
                v0, vs0 = _init_stored(msh, lcfg)
                ef0 = jnp.zeros(msh, jnp.float32) if cfg.sync_codes else None
                leaves.append(
                    ConvLeaf(p_o=po, p_i=pi, m=m0, v=v0, m_scale=ms0,
                             v_scale=vs0, ef=ef0)
                )
            else:
                m0, ms0 = _init_stored(leaf.shape, lcfg)
                v0, vs0 = _init_stored(leaf.shape, lcfg)
                leaves.append(DenseLeaf(mu=m0, nu=v0, mu_scale=ms0, nu_scale=vs0))
        if cfg.stacked_state:
            # Same per-leaf states (identical RNG keys per flat index),
            # stored pre-stacked: encode is a bit-exact stack per field.
            return ProjectedAdamState(
                count=jnp.zeros([], jnp.int32),
                leaves=stacked_state.encode(_layout_of(cfg, flat), leaves),
            )
        return ProjectedAdamState(
            count=jnp.zeros([], jnp.int32),
            leaves=jax.tree_util.tree_unflatten(treedef, leaves),
        )

    def _update_proj_bucket(cfg, leaf: ProjLeaf, g, spec: ProjSpec, count, t,
                            idx_arr, phases=None):
        """One step for a stacked bucket of congruent projected leaves (all
        arrays carry a leading (B,) axis; B == 1 for singleton buckets).
        ``cfg`` is the BUCKET-effective config (plan overrides applied —
        shadows the transform's global config on purpose).
        ``gc`` keeps the gradient's dtype — bf16 gradients stream into the
        fused kernels as bf16 (upcast per-tile in VMEM, halving per-step G
        traffic); only the unfused jnp fallbacks materialize fp32."""
        gc = projector.to_canonical(g, spec)
        p_old = leaf.p

        # Loader takes an optional bucket-axis slice so staggered group
        # refreshes only dequantize/upcast the slice they actually update.
        if cfg.quantize:
            def m_loader(sl=slice(None)):
                return kops.dequantize_rowblock(
                    leaf.m[sl], leaf.m_scale[sl], block=cfg.quant_block
                )
        else:
            def m_loader(sl=slice(None)):
                return leaf.m[sl].astype(jnp.float32)

        new_p, refreshed = _refresh_p(
            cfg, spec, p_old, gc, m_loader, count, idx_arr, phases
        )

        # Projection-health emit (obs/health): refresh-boundary metrics
        # computed where G is already materialized, under the same
        # lax.cond as the refresh — non-refresh steps execute nothing, so
        # the hot path keeps zero extra G round-trips. Trace-time no-op
        # (identical compiled program) when no monitor is configured.
        health.emit_refresh_matrix(
            health.bucket_label("project", g.shape[1:], g.dtype),
            gc, p_old, new_p, refreshed, count,
        )

        if cfg.quantize:
            m_q, m_s = leaf.m, leaf.m_scale
            if _wants_transplant(cfg):
                # On refresh steps the transplanted M takes one extra int8
                # requant->dequant round-trip (requantized here, dequantized
                # again inside the fused kernel) vs a hypothetical
                # dequant->transplant->EMA->requant schedule: one added
                # block-absmax rounding per refresh, accepted so the hot
                # per-step path stays a single kernel with int8-only state.
                # Under stagger only the refreshing group's slice is
                # dequantized/transplanted/requantized (same group structure
                # as _refresh_p — the codec is row-wise, so slice-local
                # requant emits the identical codes).
                def carry_q(sl):
                    carried = projector.project(
                        projector.backproject(m_loader(sl), p_old[sl]),
                        new_p[sl],
                    )
                    return kops.quantize_rowblock(
                        carried, block=cfg.quant_block
                    )

                def q_group(s0, sz, _ph):
                    cq, cs = carry_q(slice(s0, s0 + sz))
                    return (
                        m_q.at[s0:s0 + sz].set(cq),
                        m_s.at[s0:s0 + sz].set(cs),
                    )

                tgroups = _phase_groups(phases) if phases else []
                if len(tgroups) <= 1:
                    def transplanted():
                        cq, cs = carry_q(slice(None))
                        return (
                            jnp.where(
                                _expand_mask(refreshed, cq.ndim), cq, m_q
                            ),
                            jnp.where(
                                _expand_mask(refreshed, cs.ndim), cs, m_s
                            ),
                        )

                    m_q, m_s = lax.cond(
                        jnp.any(refreshed), transplanted, lambda: (m_q, m_s)
                    )
                else:
                    m_q, m_s = _stagger_dispatch(
                        tgroups, count, cfg.t_update,
                        noop=lambda: (m_q, m_s),
                        group_fn=q_group,
                        full_fn=lambda: carry_q(slice(None)),  # t=0 init
                    )
            if cfg.use_fused_kernel:
                # Single-pass fused int8 step: no fp32 M/V, no Δ_proj in HBM.
                nmq, nms, nvq, nvs, update_c = kops.coap_fused_update_q8(
                    gc, new_p, m_q, m_s, leaf.v, leaf.v_scale, t,
                    b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, block=cfg.quant_block,
                )
            else:
                # Unfused 8-bit schedule — every intermediate round-trips
                # HBM; kept as the benchmark baseline (benchmarks/overhead).
                # The oracle IS that schedule expressed as jnp ops.
                nmq, nms, nvq, nvs, update_c = kref.coap_fused_update_q8(
                    gc, new_p, m_q, m_s, leaf.v, leaf.v_scale, t,
                    b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, block=cfg.quant_block,
                )
            new_leaf = ProjLeaf(p=new_p, m=nmq, v=nvq, m_scale=nms,
                                v_scale=nvs, ef=leaf.ef)
        else:
            m = m_loader()
            v = leaf.v.astype(jnp.float32)
            m = _maybe_transplant(
                cfg, m, p_old, new_p, refreshed, phases, count
            )
            if cfg.use_fused_kernel:
                new_m, new_v, update_c = kops.coap_fused_update_bp(
                    gc, new_p, m, v, t, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps
                )
            else:
                g_proj = projector.project(gc.astype(jnp.float32), new_p)
                new_m = cfg.b1 * m + (1.0 - cfg.b1) * g_proj
                new_v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g_proj)
                tf = t.astype(jnp.float32)
                delta_proj = (new_m / (1.0 - cfg.b1**tf)) / (
                    jnp.sqrt(new_v / (1.0 - cfg.b2**tf)) + cfg.eps
                )
                update_c = projector.backproject(delta_proj, new_p)
            new_leaf = ProjLeaf(
                p=new_p,
                m=new_m.astype(cfg.state_dtype),
                v=new_v.astype(cfg.state_dtype),
                m_scale=leaf.m_scale,  # fp32 placeholders pass through
                v_scale=leaf.v_scale,
                ef=leaf.ef,
            )
        update = projector.from_canonical(update_c, spec) * cfg.update_scale
        return update.astype(g.dtype), new_leaf

    def _update_dense_leaf(cfg, leaf: DenseLeaf, g, count, t):
        g32 = g.astype(jnp.float32)
        if cfg.quantize and cfg.use_fused_kernel:
            # 8-bit dense Adam as ONE fused dispatch (dequant -> EMA ->
            # bias-corrected Δ + underflow clip -> requant); same math as the
            # unfused schedule below, but mu/nu never round-trip HBM as
            # fp32 between separate jnp passes.
            nmq, nms, nvq, nvs, upd = kops.quantized_adam_update(
                g32, leaf.mu, leaf.mu_scale, leaf.nu, leaf.nu_scale, t,
                b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, block=cfg.quant_block,
            )
            return upd.astype(g.dtype), DenseLeaf(
                mu=nmq, nu=nvq, mu_scale=nms, nu_scale=nvs
            )
        mu = _load(leaf.mu, leaf.mu_scale, g.shape, cfg)
        nu = _load(leaf.nu, leaf.nu_scale, g.shape, cfg)
        new_mu = cfg.b1 * mu + (1.0 - cfg.b1) * g32
        new_nu = cfg.b2 * nu + (1.0 - cfg.b2) * jnp.square(g32)
        tf = t.astype(jnp.float32)
        upd = (new_mu / (1.0 - cfg.b1**tf)) / (
            jnp.sqrt(new_nu / (1.0 - cfg.b2**tf)) + cfg.eps
        )
        if cfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
            upd = jnp.clip(upd, -kref.QUANT_DELTA_CLIP, kref.QUANT_DELTA_CLIP)
        smu, smus = _store(new_mu, cfg)
        snu, snus = _store(new_nu, cfg)
        return upd.astype(g.dtype), DenseLeaf(
            mu=smu, nu=snu, mu_scale=smus, nu_scale=snus
        )

    def update_fn(updates, state, params=None):
        del params
        count = state.count  # 0-based: first call refreshes/initializes P
        t = count + 1  # 1-based for bias correction (Algorithm 1)
        flat_u, treedef = jax.tree_util.tree_flatten_with_path(updates)
        n_leaves = len(flat_u)
        new_updates = [None] * n_leaves

        # Bucket congruent leaves: one (vmapped) kernel launch per
        # (shape, spec, dtype) group instead of one per leaf — conv
        # (Tucker-2) leaves included since stacked-bucket/v2 (Algorithm 3
        # batched over the bucket axis; conv_mod.update_conv_bucket). The
        # layout is THE bucket assignment shared with the stacked-state
        # codec (checkpoint/accounting/compression).
        layout = _layout_of(cfg, flat_u)

        if cfg.stacked_state:
            prev = state.leaves
            if (
                not isinstance(prev, stacked_state.StackedLeaves)
                or prev.layout.signature() != layout.signature()
            ):
                raise ValueError(
                    "stacked optimizer state does not match the gradient "
                    "tree (optimizer rules / model structure changed since "
                    "init, or a per-leaf state was passed with "
                    "stacked_state=True)"
                )
            flat_s = None
        else:
            prev = None
            flat_s = treedef.flatten_up_to(state.leaves)

        # Bucket-effective configs (plan overrides: quantize / T_u /
        # stagger_groups per bucket; identity when no overrides are set).
        bucket_cfgs = [_bucket_cfg(cfg, info) for info in layout.buckets]

        # Per-leaf refresh phases (staggered schedule): THE allocation,
        # shared with the elastic supervisor (``bucket_phases`` — a pure
        # function of (layout, cfg), so phases re-derive identically on
        # every restart/resume).
        phase_by_bucket = bucket_phases(cfg, layout)

        new_buckets = [None] * len(layout.buckets)
        new_tail = [None] * len(layout.tail)
        new_flat = [None] * n_leaves  # per-leaf mode only

        # Residual tail (empty under the default v2 classification; a
        # custom classify may still route conv leaves here — they keep the
        # synchronized per-leaf Algorithm-3 path).
        for j, tinfo in enumerate(layout.tail):
            leaf = prev.tail[j] if cfg.stacked_state else flat_s[tinfo.index]
            u, nl = conv_mod.update_conv_leaf(
                _leaf_cfg(cfg, tinfo.path), leaf, flat_u[tinfo.index][1],
                tinfo.spec, count, t, tinfo.index,
            )
            new_updates[tinfo.index] = u
            new_tail[j] = nl
            new_flat[tinfo.index] = nl

        for bi, info in enumerate(layout.buckets):
            is_proj = info.kind == stacked_state.BUCKET_PROJECT
            is_conv = info.kind == stacked_state.BUCKET_CONV
            bcfg = bucket_cfgs[bi]
            phases = phase_by_bucket[bi] if (is_proj or is_conv) else None
            if cfg.bucket_leaves:
                slot_groups = [tuple(range(len(info.indices)))]
            else:  # per-leaf A/B mode (stacked_state forbids this)
                slot_groups = [(k,) for k in range(len(info.indices))]
            for slots in slot_groups:
                idxs = [info.indices[k] for k in slots]
                g_stack = jnp.stack([flat_u[i][1] for i in idxs])
                if cfg.stacked_state:
                    # The hot-path win: the bucket state is ALREADY stacked
                    # — no stack copy in, no scatter copy out.
                    leaf_stack = prev.buckets[bi]
                else:
                    leaf_stack = jax.tree_util.tree_map(
                        lambda *xs: jnp.stack(xs),
                        *[flat_s[i] for i in idxs],
                    )
                if is_proj:
                    u_stack, nl_stack = _update_proj_bucket(
                        bcfg, leaf_stack, g_stack, info.spec, count, t,
                        jnp.asarray(idxs, jnp.int32),
                        tuple(phases[k] for k in slots),
                    )
                elif is_conv:
                    u_stack, nl_stack = conv_mod.update_conv_bucket(
                        bcfg, leaf_stack, g_stack, info.spec, count, t,
                        jnp.asarray(idxs, jnp.int32),
                        tuple(phases[k] for k in slots),
                    )
                else:
                    u_stack, nl_stack = jax.vmap(
                        lambda lf, gg: _update_dense_leaf(bcfg, lf, gg, count, t)
                    )(leaf_stack, g_stack)
                for b, i in enumerate(idxs):
                    new_updates[i] = u_stack[b]
                    if not cfg.stacked_state:
                        new_flat[i] = jax.tree_util.tree_map(
                            lambda x: x[b], nl_stack
                        )
                if cfg.stacked_state:
                    new_buckets[bi] = nl_stack

        if cfg.stacked_state:
            new_leaves = stacked_state.StackedLeaves(
                new_buckets, new_tail, prev.layout
            )
        else:
            new_leaves = jax.tree_util.tree_unflatten(treedef, new_flat)
        return (
            jax.tree_util.tree_unflatten(treedef, new_updates),
            ProjectedAdamState(count=count + 1, leaves=new_leaves),
        )

    return GradientTransformation(init_fn, update_fn)


# ---------------------------------------------------------------------------
# User-facing optimizers
# ---------------------------------------------------------------------------
def _projected_adamw(
    strategy: str,
    learning_rate,
    rules: ProjectionRules,
    *,
    b1=0.9,
    b2=0.999,
    eps=1e-8,
    weight_decay=0.0,
    t_update=200,
    lam=5,
    eqn6_lr=0.1,
    eqn6_steps=1,
    seed=0,
    quantize=False,
    state_dtype=jnp.float32,
    update_scale=1.0,
    moment_transplant=False,
    stagger=True,
    stagger_groups=8,
    stacked_state=False,
    overrides=None,
    quant_block=kref.QUANT_BLOCK,
    mask=None,
) -> GradientTransformation:
    cfg = ProjectedAdamConfig(
        rules=rules,
        strategy=strategy,
        b1=b1,
        b2=b2,
        eps=eps,
        t_update=t_update,
        lam=lam,
        eqn6_lr=eqn6_lr,
        eqn6_steps=eqn6_steps,
        seed=seed,
        quantize=quantize,
        state_dtype=state_dtype,
        update_scale=update_scale,
        moment_transplant=moment_transplant,
        stagger=stagger,
        stagger_groups=stagger_groups,
        stacked_state=stacked_state,
        overrides=overrides,
        quant_block=quant_block,
    )
    return projected_adamw_from_config(
        cfg, learning_rate, weight_decay=weight_decay, mask=mask
    )


def projected_adamw_from_config(
    cfg: ProjectedAdamConfig, learning_rate, *, weight_decay=0.0, mask=None
) -> GradientTransformation:
    """AdamW chain around an explicit :class:`ProjectedAdamConfig` — the
    entry plan consumers use so the config object driving the optimizer is
    the SAME one schedule consumers (``bucket_phases``) introspect."""
    txs = [scale_by_projected_adam(cfg)]
    if weight_decay:
        txs.append(add_decayed_weights(weight_decay, mask=mask))
    txs.append(scale_by_learning_rate(learning_rate))
    return chain(*txs)


def coap_adamw(learning_rate, rules: ProjectionRules, **kw) -> GradientTransformation:
    """AdamW + COAP (paper Algorithm 1 + decoupled weight decay)."""
    return _projected_adamw("coap", learning_rate, rules, **kw)


def galore_adamw(learning_rate, rules: ProjectionRules, **kw) -> GradientTransformation:
    """GaLore baseline. Note their repo's update scale α defaults to 0.25."""
    kw.setdefault("update_scale", 0.25)
    return _projected_adamw("galore", learning_rate, rules, **kw)


def flora_adamw(learning_rate, rules: ProjectionRules, **kw) -> GradientTransformation:
    """Flora baseline: fresh random projections (+ moment transplant)."""
    kw.setdefault("t_update", 1)
    return _projected_adamw("flora", learning_rate, rules, **kw)
