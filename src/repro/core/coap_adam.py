"""Algorithm 1: Adam with COAP — plus GaLore/Flora strategy variants.

One GradientTransformation covers the whole family because the only
difference between COAP, GaLore and Flora is the projection-refresh rule:

  * ``coap``   — every ``T_u`` steps refresh P by Eqn-6 SGD; every
                 ``λ·T_u`` steps recalibrate by Eqn-7 low-cost SVD; at t=0
                 initialize by Eqn 7 from the first gradient (Algorithm 1).
  * ``galore`` — every ``T_u`` steps recompute P as the truncated SVD of the
                 current gradient (O(mn²)).
  * ``flora``  — resample a Gaussian P every ``T_u`` steps (paper: every
                 step, T_u=1) and transplant the first moment into the new
                 subspace.

Leaves are classified statically (see ``projector.ProjectionRules``):
2-D-matrix leaves (with arbitrary leading stack axes — scan-over-layers
weights ``(L,m,n)``, per-expert weights ``(L,E,m,n)``) are projected;
conv ``(O,I,K1,K2)`` kernels take the Tucker-2 path (Algorithm 3, in
``core/conv.py``); everything else gets dense Adam. Refreshes happen inside
the jitted step under ``lax.cond`` — no host round-trips (DESIGN.md §3).

Optimizer states are fp32 by default or block-wise int8 when
``quantize=True`` (8-bit COAP / 8-bit Adam baselines, via kernels/quant8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import conv as conv_mod
from repro.core import correlation, projector, recalibrate
from repro.core.projector import (
    KIND_CONV,
    KIND_DENSE,
    KIND_PROJECT,
    ProjSpec,
    ProjectionRules,
    path_str,
)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.optim.transform import (
    GradientTransformation,
    add_decayed_weights,
    chain,
    scale_by_learning_rate,
)

STRATEGIES = ("coap", "galore", "flora")


class ProjLeaf(NamedTuple):
    """Low-rank leaf state: P (…,n,r); moments on the large side (…,m,r)."""

    p: Any
    m: Any
    v: Any
    m_scale: Any  # int8-codec scales; zeros((1,)) placeholders when fp32
    v_scale: Any


class DenseLeaf(NamedTuple):
    mu: Any
    nu: Any
    mu_scale: Any
    nu_scale: Any


class ConvLeaf(NamedTuple):
    """Tucker-2 leaf (Algorithm 3): two factor projections + core moments."""

    p_o: Any  # (O, r_O)
    p_i: Any  # (I, r_I)
    m: Any  # (r_O, r_I, K1, K2)
    v: Any
    m_scale: Any
    v_scale: Any


class ProjectedAdamState(NamedTuple):
    count: jnp.ndarray
    leaves: Any  # pytree congruent with params; leaf = Proj/Dense/ConvLeaf


@dataclasses.dataclass(frozen=True)
class ProjectedAdamConfig:
    rules: ProjectionRules
    strategy: str = "coap"
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    t_update: int = 200  # T_u (refresh interval; GaLore SVD interval; Flora=1)
    lam: int = 5  # λ: Eqn-7 recalibration every λ·T_u steps
    eqn6_lr: float = 0.1  # paper appendix: SGD lr for Eqn 6, default 0.1
    eqn6_steps: int = 1
    eqn6_normalize: bool = False  # beyond-paper scale-invariant Eqn-6 step
    seed: int = 0
    state_dtype: Any = jnp.float32
    quantize: bool = False  # 8-bit block-wise states
    quant_block: int = kref.QUANT_BLOCK
    update_scale: float = 1.0  # GaLore's α (their repo default 0.25)
    moment_transplant: bool = False  # carry M into the new subspace at refresh
    use_fused_kernel: bool = True  # route through kernels/ops (Pallas on TPU)

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"strategy must be one of {STRATEGIES}")


def _zeros_scales(shape_numel: int, block: int):
    nblocks = -(-shape_numel // block)
    return jnp.zeros((nblocks,), jnp.float32)


def _store(x: jnp.ndarray, cfg: ProjectedAdamConfig):
    """fp32 array -> (stored, scale) under the configured codec."""
    if not cfg.quantize:
        return x.astype(cfg.state_dtype), jnp.zeros((1,), jnp.float32)
    q, s = kops.quantize_blockwise(x, block=cfg.quant_block)
    return q, s


def _load(stored: jnp.ndarray, scale: jnp.ndarray, shape, cfg: ProjectedAdamConfig):
    if not cfg.quantize:
        return stored.astype(jnp.float32)
    return kops.dequantize_blockwise(stored, scale, tuple(shape), block=cfg.quant_block)


def _init_stored(shape, cfg: ProjectedAdamConfig):
    numel = 1
    for s in shape:
        numel *= int(s)
    if not cfg.quantize:
        return jnp.zeros(shape, cfg.state_dtype), jnp.zeros((1,), jnp.float32)
    nblocks = -(-numel // cfg.quant_block)
    return (
        jnp.zeros((nblocks, cfg.quant_block), jnp.int8),
        jnp.zeros((nblocks,), jnp.float32),
    )


def _leaf_spec(cfg: ProjectedAdamConfig, path: str, shape) -> ProjSpec:
    return cfg.rules.spec_for(path, shape)


def _refresh_p(
    cfg: ProjectedAdamConfig,
    spec: ProjSpec,
    p: jnp.ndarray,
    gc: jnp.ndarray,
    m_full: jnp.ndarray,
    count: jnp.ndarray,
    leaf_idx: int,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Strategy-specific P refresh. Returns (new_p, refreshed?bool)."""
    if cfg.strategy == "coap":
        t_u = cfg.t_update
        do_ref = (count % t_u) == 0
        do_recal = (count % (cfg.lam * t_u)) == 0

        def refreshed():
            return lax.cond(
                do_recal,
                lambda: recalibrate.lowcost_svd(gc, p),
                lambda: correlation.sgd_update(
                    p, gc, m_full, lr=cfg.eqn6_lr, steps=cfg.eqn6_steps,
                    normalize=cfg.eqn6_normalize,
                ),
            )

        new_p = lax.cond(do_ref, refreshed, lambda: p)
        return new_p, do_ref
    if cfg.strategy == "galore":
        do_ref = (count % cfg.t_update) == 0
        new_p = lax.cond(
            do_ref, lambda: recalibrate.galore_svd(gc, spec.rank).astype(p.dtype),
            lambda: p,
        )
        return new_p, do_ref
    # flora
    do_ref = (count % cfg.t_update) == 0
    key = jax.random.fold_in(jax.random.fold_in(jax.random.key(cfg.seed), leaf_idx), count)
    new_p = lax.cond(
        do_ref,
        lambda: recalibrate.random_projection(key, gc.shape, spec.rank, p.dtype),
        lambda: p,
    )
    return new_p, do_ref


def _maybe_transplant(
    cfg: ProjectedAdamConfig, m: jnp.ndarray, p_old, p_new, refreshed
) -> jnp.ndarray:
    """M_new = (M P_oldᵀ) P_new — keeps momentum direction across subspace
    switches. Flora's mechanism; optional (off = Algorithm 1 verbatim) for
    COAP/GaLore."""
    transplant = cfg.strategy == "flora" or cfg.moment_transplant

    if not transplant:
        return m

    def do():
        restored = projector.backproject(m, p_old)
        return projector.project(restored, p_new)

    return lax.cond(refreshed, do, lambda: m)


def scale_by_projected_adam(cfg: ProjectedAdamConfig) -> GradientTransformation:
    """The regularizer ρ_t of paper Eqn 5 as a GradientTransformation.

    Produces *positive* update directions (caller chains lr sign-flip).
    """

    def init_fn(params):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        key = jax.random.key(cfg.seed)
        leaves = []
        for idx, (kp, leaf) in enumerate(flat):
            path = path_str(kp)
            spec = _leaf_spec(cfg, path, leaf.shape)
            if spec.kind == KIND_PROJECT:
                p0 = projector.init_p(
                    jax.random.fold_in(key, idx), leaf.shape, spec,
                    cfg.state_dtype,
                )
                msh = projector.moment_shape(leaf.shape, spec)
                m0, ms0 = _init_stored(msh, cfg)
                v0, vs0 = _init_stored(msh, cfg)
                leaves.append(ProjLeaf(p=p0, m=m0, v=v0, m_scale=ms0, v_scale=vs0))
            elif spec.kind == KIND_CONV:
                po, pi = conv_mod.init_factors(
                    jax.random.fold_in(key, idx), leaf.shape, spec
                )
                msh = conv_mod.core_shape(leaf.shape, spec)
                m0, ms0 = _init_stored(msh, cfg)
                v0, vs0 = _init_stored(msh, cfg)
                leaves.append(
                    ConvLeaf(p_o=po, p_i=pi, m=m0, v=v0, m_scale=ms0, v_scale=vs0)
                )
            else:
                m0, ms0 = _init_stored(leaf.shape, cfg)
                v0, vs0 = _init_stored(leaf.shape, cfg)
                leaves.append(DenseLeaf(mu=m0, nu=v0, mu_scale=ms0, nu_scale=vs0))
        return ProjectedAdamState(
            count=jnp.zeros([], jnp.int32),
            leaves=jax.tree_util.tree_unflatten(treedef, leaves),
        )

    def _update_proj_leaf(leaf: ProjLeaf, g, spec: ProjSpec, count, t, leaf_idx):
        gc = projector.to_canonical(g, spec).astype(jnp.float32)
        msh = projector.moment_shape(g.shape, spec)
        m = _load(leaf.m, leaf.m_scale, msh, cfg)
        v = _load(leaf.v, leaf.v_scale, msh, cfg)
        p_old = leaf.p
        new_p, refreshed = _refresh_p(cfg, spec, p_old, gc, m, count, leaf_idx)
        m = _maybe_transplant(cfg, m, p_old, new_p, refreshed)
        if cfg.use_fused_kernel and not cfg.quantize:
            new_m, new_v, delta_proj = kops.coap_fused_update(
                gc, new_p, m, v, t, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps
            )
        else:
            g_proj = projector.project(gc, new_p)
            new_m = cfg.b1 * m + (1.0 - cfg.b1) * g_proj
            new_v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g_proj)
            tf = t.astype(jnp.float32)
            delta_proj = (new_m / (1.0 - cfg.b1**tf)) / (
                jnp.sqrt(new_v / (1.0 - cfg.b2**tf)) + cfg.eps
            )
            if cfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
                delta_proj = jnp.clip(delta_proj, -kref.QUANT_DELTA_CLIP,
                                      kref.QUANT_DELTA_CLIP)
        update_c = projector.backproject(delta_proj, new_p)
        update = projector.from_canonical(update_c, spec) * cfg.update_scale
        sm, sms = _store(new_m, cfg)
        sv, svs = _store(new_v, cfg)
        return update.astype(g.dtype), ProjLeaf(
            p=new_p, m=sm, v=sv, m_scale=sms, v_scale=svs
        )

    def _update_dense_leaf(leaf: DenseLeaf, g, count, t):
        g32 = g.astype(jnp.float32)
        mu = _load(leaf.mu, leaf.mu_scale, g.shape, cfg)
        nu = _load(leaf.nu, leaf.nu_scale, g.shape, cfg)
        new_mu = cfg.b1 * mu + (1.0 - cfg.b1) * g32
        new_nu = cfg.b2 * nu + (1.0 - cfg.b2) * jnp.square(g32)
        tf = t.astype(jnp.float32)
        upd = (new_mu / (1.0 - cfg.b1**tf)) / (
            jnp.sqrt(new_nu / (1.0 - cfg.b2**tf)) + cfg.eps
        )
        if cfg.quantize:  # int8-v underflow guard (see kernels/ref.py)
            upd = jnp.clip(upd, -kref.QUANT_DELTA_CLIP, kref.QUANT_DELTA_CLIP)
        smu, smus = _store(new_mu, cfg)
        snu, snus = _store(new_nu, cfg)
        return upd.astype(g.dtype), DenseLeaf(
            mu=smu, nu=snu, mu_scale=smus, nu_scale=snus
        )

    def update_fn(updates, state, params=None):
        del params
        count = state.count  # 0-based: first call refreshes/initializes P
        t = count + 1  # 1-based for bias correction (Algorithm 1)
        flat_u, treedef = jax.tree_util.tree_flatten_with_path(updates)
        flat_s = treedef.flatten_up_to(state.leaves)
        new_updates, new_leaves = [], []
        for idx, ((kp, g), leaf) in enumerate(zip(flat_u, flat_s)):
            path = path_str(kp)
            spec = _leaf_spec(cfg, path, g.shape)
            if spec.kind == KIND_PROJECT:
                u, nl = _update_proj_leaf(leaf, g, spec, count, t, idx)
            elif spec.kind == KIND_CONV:
                u, nl = conv_mod.update_conv_leaf(cfg, leaf, g, spec, count, t, idx)
            else:
                u, nl = _update_dense_leaf(leaf, g, count, t)
            new_updates.append(u)
            new_leaves.append(nl)
        return (
            jax.tree_util.tree_unflatten(treedef, new_updates),
            ProjectedAdamState(
                count=count + 1,
                leaves=jax.tree_util.tree_unflatten(treedef, new_leaves),
            ),
        )

    return GradientTransformation(init_fn, update_fn)


# ---------------------------------------------------------------------------
# User-facing optimizers
# ---------------------------------------------------------------------------
def _projected_adamw(
    strategy: str,
    learning_rate,
    rules: ProjectionRules,
    *,
    b1=0.9,
    b2=0.999,
    eps=1e-8,
    weight_decay=0.0,
    t_update=200,
    lam=5,
    eqn6_lr=0.1,
    eqn6_steps=1,
    seed=0,
    quantize=False,
    state_dtype=jnp.float32,
    update_scale=1.0,
    moment_transplant=False,
    mask=None,
) -> GradientTransformation:
    cfg = ProjectedAdamConfig(
        rules=rules,
        strategy=strategy,
        b1=b1,
        b2=b2,
        eps=eps,
        t_update=t_update,
        lam=lam,
        eqn6_lr=eqn6_lr,
        eqn6_steps=eqn6_steps,
        seed=seed,
        quantize=quantize,
        state_dtype=state_dtype,
        update_scale=update_scale,
        moment_transplant=moment_transplant,
    )
    txs = [scale_by_projected_adam(cfg)]
    if weight_decay:
        txs.append(add_decayed_weights(weight_decay, mask=mask))
    txs.append(scale_by_learning_rate(learning_rate))
    return chain(*txs)


def coap_adamw(learning_rate, rules: ProjectionRules, **kw) -> GradientTransformation:
    """AdamW + COAP (paper Algorithm 1 + decoupled weight decay)."""
    return _projected_adamw("coap", learning_rate, rules, **kw)


def galore_adamw(learning_rate, rules: ProjectionRules, **kw) -> GradientTransformation:
    """GaLore baseline. Note their repo's update scale α defaults to 0.25."""
    kw.setdefault("update_scale", 0.25)
    return _projected_adamw("galore", learning_rate, rules, **kw)


def flora_adamw(learning_rate, rules: ProjectionRules, **kw) -> GradientTransformation:
    """Flora baseline: fresh random projections (+ moment transplant)."""
    kw.setdefault("t_update", 1)
    return _projected_adamw("flora", learning_rate, rules, **kw)
