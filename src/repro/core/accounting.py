"""Exact optimizer-state memory accounting.

The paper's headline numbers (Tables 1–6 'Optimizer Mem.') are byte counts
of the optimizer state; since our states are explicit pytrees we reproduce
those columns by *arithmetic over the actual state*, not estimation.

Stacked-state aware: a ``StackedLeaves`` node (core/stacked_state.py) is
walked through its buckets and tail — projected, conv (Tucker-2,
stacked-bucket/v2) and dense buckets alike — so its stacked leaf-states
land in the same categories as their per-leaf equivalents: stacking B
equal-shape arrays is byte-neutral, and ``tests/test_stacked_state.py`` /
``tests/test_conv_bucketing.py`` pin the byte tables of the layouts equal.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.coap_adam import ConvLeaf, DenseLeaf, ProjLeaf
from repro.core.coap_adafactor import DenseFactorLeaf, ProjFactorLeaf
from repro.core.stacked_state import StackedLeaves
from repro.optim.adamw import ScaleByAdamState

# Fine-grained categories roll up into the three the paper's accounting
# distinguishes (plus bookkeeping scalars): the paper's reduction columns
# count MOMENT state — the projector/factor matrices P are excluded from
# both sides of the ratio, and int8 runs carry their quantizer sidecar
# (scales) honestly in the numerator. ``repro/plan`` uses the same
# denominator, so planner gates and paper tables agree by construction.
CATEGORY_GROUPS = {
    "moments": "moment_state",
    "dense_moments": "moment_state",
    "factored_v": "moment_state",
    "projection": "projector",
    "quant_scales": "quant_sidecar",
    # The int8-collective error-feedback accumulator (sync_codes) is comms
    # state, not optimizer moments: grouped under 'other' so the paper's
    # moment-reduction ratios are unaffected by enabling the wire codec.
    "ef_sidecar": "other",
    "other": "other",
}


def group_categories(by_category: Dict[str, int]) -> Dict[str, int]:
    """Roll a by-category byte table up into the paper's groups. THE single
    roll-up — ``MemoryReport.grouped`` and the planner's reduction math
    both call this, so the 61%/81% gates and the byte tables cannot drift."""
    out = {"moment_state": 0, "projector": 0, "quant_sidecar": 0, "other": 0}
    for k, v in by_category.items():
        out[CATEGORY_GROUPS.get(k, "other")] += v
    return out


@dataclasses.dataclass
class MemoryReport:
    total_bytes: int
    by_category: Dict[str, int]
    param_bytes: int = 0

    def gb(self) -> float:
        return self.total_bytes / 1e9

    def grouped(self) -> Dict[str, int]:
        """by_category rolled up into moment_state / projector /
        quant_sidecar / other (CATEGORY_GROUPS). Totals are preserved:
        ``sum(grouped().values()) == total_bytes``."""
        return group_categories(self.by_category)

    @property
    def moment_state_bytes(self) -> int:
        return self.grouped()["moment_state"]

    @property
    def projector_bytes(self) -> int:
        return self.grouped()["projector"]

    @property
    def quant_sidecar_bytes(self) -> int:
        return self.grouped()["quant_sidecar"]

    def reduction_vs(self, baseline: "MemoryReport") -> float:
        """Fractional reduction (paper's −XX% columns)."""
        return 1.0 - self.total_bytes / max(1, baseline.total_bytes)

    def moment_reduction_vs(self, baseline: "MemoryReport") -> float:
        """The paper's denominator: moment state (+ quantizer sidecar)
        reduction, with projector/factor bytes excluded from both sides —
        Tables 1–6 count the moments AdamW would have stored, not P."""
        mine = self.moment_state_bytes + self.quant_sidecar_bytes
        base = baseline.moment_state_bytes + baseline.quant_sidecar_bytes
        return 1.0 - mine / max(1, base)

    def __str__(self) -> str:
        cats = ", ".join(f"{k}={v/1e6:.1f}MB" for k, v in sorted(self.by_category.items()))
        return f"MemoryReport(total={self.gb():.3f}GB; {cats})"


def _leaf_bytes(x) -> int:
    if hasattr(x, "nbytes"):
        return int(x.nbytes)
    size = 1
    for s in x.shape:
        size *= int(s)
    return size * jnp.dtype(x.dtype).itemsize


_CATEGORY_FIELDS = {
    ProjLeaf: {"p": "projection", "m": "moments", "v": "moments",
               "m_scale": "quant_scales", "v_scale": "quant_scales",
               "ef": "ef_sidecar"},
    ConvLeaf: {"p_o": "projection", "p_i": "projection", "m": "moments",
               "v": "moments", "m_scale": "quant_scales",
               "v_scale": "quant_scales", "ef": "ef_sidecar"},
    DenseLeaf: {"mu": "dense_moments", "nu": "dense_moments",
                "mu_scale": "quant_scales", "nu_scale": "quant_scales"},
    ProjFactorLeaf: {"p": "projection", "m": "moments", "row": "factored_v",
                     "col": "factored_v"},
    DenseFactorLeaf: {"row": "factored_v", "col": "factored_v", "nu": "dense_moments"},
    # Dense AdamW (the paper's baseline): its mu/nu SUBTREES are the moment
    # state every reduction column divides by — categorized so
    # ``moment_reduction_vs`` has a real denominator. Totals are unchanged
    # (previously everything here landed in 'other').
    ScaleByAdamState: {"count": "other", "mu": "dense_moments",
                       "nu": "dense_moments"},
}


def optimizer_state_bytes(opt_state: Any) -> MemoryReport:
    """Walks any optimizer state pytree; leaf-typed states get categorized,
    everything else counts as 'other' (counts, schedules, ...)."""
    by_cat: Dict[str, int] = {}

    def visit(node):
        t = type(node)
        if t in _CATEGORY_FIELDS:
            for field, cat in _CATEGORY_FIELDS[t].items():
                val = getattr(node, field)
                if val is None:  # absent sidecar (e.g. ef without sync_codes)
                    continue
                # A field may be a single array (leaf states) or a whole
                # param-shaped subtree (ScaleByAdamState.mu/nu).
                b = sum(
                    _leaf_bytes(x) for x in jax.tree_util.tree_leaves(val)
                )
                # fp32 placeholder scales on unquantized states are 4 bytes
                # of noise; still counted for honesty.
                by_cat[cat] = by_cat.get(cat, 0) + b
            return True
        return False

    def walk(node):
        if visit(node):
            return
        children = None
        if isinstance(node, StackedLeaves):
            # Stacked buckets hold the same typed leaf-states with a (B,)
            # axis; categorization (and totals) match per-leaf storage.
            children = list(node.buckets) + list(node.tail)
        elif isinstance(node, (list, tuple)):
            children = node
        elif isinstance(node, dict):
            children = node.values()
        elif hasattr(node, "_fields"):  # NamedTuple not in category map
            children = [getattr(node, f) for f in node._fields]
        if children is not None:
            for c in children:
                walk(c)
            return
        if hasattr(node, "shape"):
            by_cat["other"] = by_cat.get("other", 0) + _leaf_bytes(node)

    walk(opt_state)
    return MemoryReport(total_bytes=sum(by_cat.values()), by_category=by_cat)


def params_bytes(params: Any) -> int:
    return sum(_leaf_bytes(x) for x in jax.tree_util.tree_leaves(params))


def abstract_state_bytes(tx, params_shapes: Any) -> MemoryReport:
    """Memory report WITHOUT allocating: eval_shape over the init fn.

    Used for full-size architectures (e.g. the 314B grok config) where the
    benchmark must never materialize state on this host.
    """
    abstract = jax.eval_shape(tx.init, params_shapes)
    rep = optimizer_state_bytes(abstract)
    rep.param_bytes = sum(
        _leaf_bytes(x) for x in jax.tree_util.tree_leaves(params_shapes)
    )
    return rep
