"""Pre-stacked optimizer-state subsystem: bucket storage + portable codec.

WHY.  ``scale_by_projected_adam`` batches congruent leaves into one fused
launch per ``(shape, spec, dtype)`` bucket, but with per-leaf state storage
every step pays a stack/scatter round-trip at the bucket boundary — real HBM
copy traffic on the moment states (XLA fuses some fp32 copies into kernel
operands, but never the int8 code round-trip).  Storing the states
PRE-STACKED along the bucket axis removes those copies entirely: the fused
kernels read and write bucket arrays in place, and only the (cheap, fusable)
gradient stack and update scatter remain on the hot path.

LAYOUT.  A stacked optimizer state is a :class:`StackedLeaves` pytree node:

  * ``buckets`` — one stacked leaf-state (``ProjLeaf``/``ConvLeaf``/
    ``DenseLeaf``/…, every field carrying a leading ``(B,)`` bucket axis)
    per congruence bucket: projected buckets first, then conv (Tucker-2)
    buckets, then dense buckets, each in tree (insertion) order;
  * ``tail`` — a residual tuple of PER-LEAF states for leaves that a
    caller's ``classify`` routes to per-leaf storage (empty under the
    default classification since v2 buckets conv; ``classify_v1``
    reproduces the v1 conv-in-tail layout);
  * ``layout`` — static aux data (:class:`StackedLayout`): which original
    flat leaf index lives in which bucket slot, its tree path, and its
    ``ProjSpec``.  The layout is a pure function of the param tree and the
    projection rules, so it is identical across restarts and across hosts.

CODEC.  The codec maps a stacked state to and from the congruent per-leaf
pytree, and names every array portably so *state consumers* need no
knowledge of which mode produced it:

  * :func:`build_layout` — bucket assignment (THE single definition: the
    optimizer transforms, the checkpoint reader and the benchmarks all call
    this, so bucket order can never drift between producers and consumers);
  * :func:`encode` / :func:`decode` — per-leaf states <-> stacked buckets
    (``decode(encode(x)) == x`` bit-for-bit, int8 codes included);
  * :func:`leaf_view` — one leaf's state as a zero-copy slice of its bucket
    (how ``distributed/compression.py`` addresses bucket slices);
  * :func:`manifest_entries` — walks ANY pytree (stacked, per-leaf or
    mixed) and yields one entry per storable array, in standard
    ``tree_flatten`` order.  Stacked arrays carry their per-leaf *logical
    paths* (``slots``): the path each slice would have under per-leaf
    storage.  Both storage modes therefore share one logical-path
    namespace, which is what lets ``train/checkpoint.py`` restore a
    checkpoint written in either mode into a template of either mode.

VERSIONING.  Stacked checkpoint entries are tagged ``codec:
"stacked-bucket/v2"`` (:data:`STACKED_CODEC`).  Shared slice semantics
(v1 == v2 per entry): ``axis`` 0 is the bucket axis; ``slots[j]`` is the
logical per-leaf path of slice ``j``; slices are bit-exact views (no
transform is applied by the codec).  The version records the LAYOUT a
writer produces:

  * ``stacked-bucket/v1`` — conv (Tucker-2) leaves live in the per-leaf
    TAIL (plain 'leaf' manifest entries); only matrix/dense leaves stack.
  * ``stacked-bucket/v2`` — conv leaves bucket by ``(spec, shape, dtype)``
    like everything else (:data:`BUCKET_CONV`) and their ``ConvLeaf``
    fields stack along axis 0.

Because per-entry semantics did not change, v2 readers decode v1 entries
directly (:data:`DECODABLE_CODECS`) and assemble conv buckets slot-by-slot
from the v1 tail's per-leaf entries through the shared logical-path
namespace — and a v1-layout template restores from a v2 checkpoint by
slicing the conv bucket entries.  Any future change to the slice semantics
must bump the version string again so old readers fail loudly instead of
mis-slicing; readers reject every codec outside ``DECODABLE_CODECS``.

A/B GUARANTEE.  ``ProjectedAdamConfig(stacked_state=False)`` keeps today's
per-leaf layout bit-for-bit; ``stacked_state=True`` must produce the same
updates and (decoded) states bit-for-bit — fp32, bf16 streaming, int8 codes
and flora RNG included (``tests/test_stacked_state.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.projector import KIND_CONV, KIND_PROJECT, ProjSpec, path_str

STACKED_STATE_VERSION = 2
STACKED_CODEC_V1 = "stacked-bucket/v1"
STACKED_CODEC = "stacked-bucket/v2"
# Codecs this build can read (slice semantics are identical; the version
# names the writer's LAYOUT — see module docstring). Anything else fails
# loudly at restore time.
DECODABLE_CODECS = frozenset({STACKED_CODEC_V1, STACKED_CODEC})

# build_layout classifications.
BUCKET_PROJECT = "project"  # congruent low-rank leaves, stacked
BUCKET_CONV = "conv"  # congruent Tucker-2 conv leaves, stacked (v2)
BUCKET_DENSE = "dense"  # congruent dense leaves, stacked
BUCKET_TAIL = "tail"  # per-leaf residual (v1 conv layout, exotic leaves)


class BucketInfo(NamedTuple):
    """Static description of one congruence bucket."""

    kind: str  # BUCKET_PROJECT | BUCKET_CONV | BUCKET_DENSE
    spec: ProjSpec
    shape: Tuple[int, ...]  # original leaf shape
    dtype: str  # original leaf dtype name
    indices: Tuple[int, ...]  # original flat leaf indices, tree order
    paths: Tuple[str, ...]  # leaf tree paths, aligned with ``indices``


class TailInfo(NamedTuple):
    """One residual (non-bucketed) leaf."""

    index: int
    path: str
    spec: ProjSpec


@dataclasses.dataclass(frozen=True)
class StackedLayout:
    """Pure-structural bucket assignment (hashable: jit-static aux data)."""

    version: int
    buckets: Tuple[BucketInfo, ...]
    tail: Tuple[TailInfo, ...]
    n_leaves: int

    def __post_init__(self):
        pos = {}
        for b, info in enumerate(self.buckets):
            for slot, idx in enumerate(info.indices):
                pos[idx] = ("bucket", b, slot)
        for j, t in enumerate(self.tail):
            pos[t.index] = ("tail", j, 0)
        object.__setattr__(self, "_positions", pos)

    def position(self, index: int) -> Tuple[str, int, int]:
        """flat leaf index -> ('bucket', b, slot) | ('tail', j, 0)."""
        return self._positions[index]

    def proj_bucket_sizes(self) -> List[int]:
        return [
            len(b.indices) for b in self.buckets if b.kind == BUCKET_PROJECT
        ]

    def conv_bucket_sizes(self) -> List[int]:
        return [
            len(b.indices) for b in self.buckets if b.kind == BUCKET_CONV
        ]

    def staggerable_bucket_sizes(self) -> List[int]:
        """Leaf counts of every bucket on the staggered refresh schedule —
        projected buckets then conv buckets, in bucket order (the order
        ``stagger_phases`` allocates phase units over)."""
        return self.proj_bucket_sizes() + self.conv_bucket_sizes()

    def signature(self):
        """Dtype-erased structural identity. The state layout depends on
        shapes/specs only — gradients may legally stream in a different
        dtype than the params the state was initialized from (bf16
        training), so the hot-path compatibility check compares this, not
        full equality."""
        return (
            self.version,
            tuple(
                (b.kind, b.spec, b.shape, b.indices, b.paths)
                for b in self.buckets
            ),
            self.tail,
            self.n_leaves,
        )


def classify_default(spec: ProjSpec) -> str:
    """v2 classification: projected and conv leaves each bucket by their
    congruence signature; everything else is dense."""
    if spec.kind == KIND_PROJECT:
        return BUCKET_PROJECT
    if spec.kind == KIND_CONV:
        return BUCKET_CONV
    return BUCKET_DENSE


def classify_v1(spec: ProjSpec) -> str:
    """The ``stacked-bucket/v1`` classification: conv (Tucker-2) leaves go
    to the per-leaf tail. Kept for cross-version checkpoint tests and for
    re-encoding a state into the legacy layout."""
    if spec.kind == KIND_PROJECT:
        return BUCKET_PROJECT
    if spec.kind == KIND_CONV:
        return BUCKET_TAIL
    return BUCKET_DENSE


def build_layout(
    spec_fn: Callable[[str, Sequence[int]], ProjSpec],
    paths: Sequence[str],
    shapes: Sequence[Sequence[int]],
    dtypes: Sequence[str],
    classify: Optional[Callable[[ProjSpec], str]] = None,
) -> StackedLayout:
    """THE bucket assignment, shared by every producer and consumer.

    Identical grouping to ``scale_by_projected_adam.update_fn``: projected,
    conv and dense leaves each bucket by ``(spec, shape, dtype)`` in tree
    (insertion) order; ``classify`` maps a spec to project/conv/dense/tail
    (default :func:`classify_default`: ``KIND_PROJECT`` projects,
    ``KIND_CONV`` buckets as conv — the v2 layout — everything else is
    dense). Projected buckets come first in ``layout.buckets``, then conv
    buckets, then dense, so stagger phases line up with the per-leaf
    schedule (``staggerable_bucket_sizes``).
    """
    if classify is None:
        classify = classify_default

    proj, conv, dense = {}, {}, {}
    tail: List[TailInfo] = []
    for idx, (path, shape, dtype) in enumerate(zip(paths, shapes, dtypes)):
        shape = tuple(int(s) for s in shape)
        spec = spec_fn(path, shape)
        kind = classify(spec)
        if kind == BUCKET_TAIL:
            tail.append(TailInfo(index=idx, path=path, spec=spec))
        elif kind == BUCKET_PROJECT:
            proj.setdefault((spec, shape, dtype), []).append((idx, path))
        elif kind == BUCKET_CONV:
            conv.setdefault((spec, shape, dtype), []).append((idx, path))
        else:
            dense.setdefault((spec, shape, dtype), []).append((idx, path))

    buckets: List[BucketInfo] = []
    for kind, groups in (
        (BUCKET_PROJECT, proj), (BUCKET_CONV, conv), (BUCKET_DENSE, dense)
    ):
        for (spec, shape, dtype), members in groups.items():
            buckets.append(
                BucketInfo(
                    kind=kind,
                    spec=spec,
                    shape=shape,
                    dtype=dtype,
                    indices=tuple(i for i, _ in members),
                    paths=tuple(p for _, p in members),
                )
            )
    return StackedLayout(
        version=STACKED_STATE_VERSION,
        buckets=tuple(buckets),
        tail=tuple(tail),
        n_leaves=len(paths),
    )


def layout_for_flat(
    spec_fn, flat, classify: Optional[Callable[[ProjSpec], str]] = None
) -> StackedLayout:
    """``build_layout`` over an already path-flattened tree
    (``tree_flatten_with_path`` output — what the optimizer transforms
    hold at init/update time)."""
    return build_layout(
        spec_fn,
        [path_str(kp) for kp, _ in flat],
        [leaf.shape for _, leaf in flat],
        [jnp.dtype(leaf.dtype).name for _, leaf in flat],
        classify,
    )


def layout_for_tree(
    spec_fn, tree, classify: Optional[Callable[[ProjSpec], str]] = None
) -> StackedLayout:
    """``build_layout`` over a concrete (or abstract) param/gradient tree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return layout_for_flat(spec_fn, flat, classify)


@jax.tree_util.register_pytree_with_keys_class
class StackedLeaves:
    """Optimizer leaves stored pre-stacked by congruence bucket.

    A pytree node: children are the stacked bucket states and the per-leaf
    tail states; the :class:`StackedLayout` rides along as static aux data
    (hashable, so jit caches on it like any other static argument).
    """

    __slots__ = ("buckets", "tail", "layout")

    def __init__(self, buckets, tail, layout: StackedLayout):
        self.buckets = tuple(buckets)
        self.tail = tuple(tail)
        self.layout = layout

    def tree_flatten_with_keys(self):
        return (
            (
                (jax.tree_util.GetAttrKey("buckets"), self.buckets),
                (jax.tree_util.GetAttrKey("tail"), self.tail),
            ),
            self.layout,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        buckets, tail = children
        return cls(buckets, tail, aux)

    def __repr__(self):
        return (
            f"StackedLeaves(buckets={len(self.buckets)}, "
            f"tail={len(self.tail)}, leaves={self.layout.n_leaves})"
        )


def encode(layout: StackedLayout, flat_states: Sequence[Any]) -> StackedLeaves:
    """Per-leaf states (flat, tree order) -> pre-stacked buckets.

    Stacking is ``jnp.stack`` per field, so encoded arrays are bit-exact
    concatenations of the per-leaf arrays (int8 codes included).
    """
    if len(flat_states) != layout.n_leaves:
        raise ValueError(
            f"layout has {layout.n_leaves} leaves, got {len(flat_states)}"
        )
    buckets = [
        jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[flat_states[i] for i in info.indices]
        )
        for info in layout.buckets
    ]
    tail = [flat_states[t.index] for t in layout.tail]
    return StackedLeaves(buckets, tail, layout)


def decode(stacked: StackedLeaves) -> List[Any]:
    """Inverse of :func:`encode`: flat per-leaf states in tree order."""
    layout = stacked.layout
    out: List[Any] = [None] * layout.n_leaves
    for b, info in enumerate(layout.buckets):
        for slot, idx in enumerate(info.indices):
            out[idx] = jax.tree_util.tree_map(
                lambda x, s=slot: x[s], stacked.buckets[b]
            )
    for j, t in enumerate(layout.tail):
        out[t.index] = stacked.tail[j]
    return out


def leaf_view(stacked: StackedLeaves, index: int) -> Any:
    """One leaf's state, addressed as a slice of its bucket.

    The returned pytree has exactly the structure/dtypes the same leaf
    would have under per-leaf storage; inside jit the slice is a view XLA
    fuses into its consumer (this is how the cross-pod compression path
    reads per-leaf moments out of stacked storage)."""
    kind, b, slot = stacked.layout.position(index)
    if kind == "tail":
        return stacked.tail[b]
    return jax.tree_util.tree_map(lambda x: x[slot], stacked.buckets[b])


# ---------------------------------------------------------------------------
# Checkpoint codec: manifest entries
# ---------------------------------------------------------------------------
class ManifestEntry(NamedTuple):
    """One storable array of a state pytree.

    ``kind`` is 'leaf' (ordinary array; ``path`` is its logical per-leaf
    path) or 'stacked' (bucket array; ``path`` is its stacked tree path and
    ``slots`` the per-leaf logical paths of its axis-0 slices, in order).
    Entries are yielded in standard ``tree_flatten`` order of the walked
    tree, so a position-aligned sharding-spec list stays valid.
    """

    kind: str
    path: str
    value: Any
    slots: Optional[Tuple[str, ...]] = None


def _join(*parts: str) -> str:
    return "/".join(p for p in parts if p)


def _stacked_entries(node: StackedLeaves, prefix: str) -> List[ManifestEntry]:
    """Expand one StackedLeaves node in its own tree_flatten order."""
    out: List[ManifestEntry] = []
    layout = node.layout
    for b, (info, bucket) in enumerate(zip(layout.buckets, node.buckets)):
        flat, _ = jax.tree_util.tree_flatten_with_path(bucket)
        for kp, arr in flat:
            field = path_str(kp)
            out.append(
                ManifestEntry(
                    kind="stacked",
                    path=_join(prefix, "buckets", str(b), field),
                    value=arr,
                    slots=tuple(
                        _join(prefix, lp, field) for lp in info.paths
                    ),
                )
            )
    for t, state in zip(layout.tail, node.tail):
        flat, _ = jax.tree_util.tree_flatten_with_path(state)
        for kp, arr in flat:
            out.append(
                ManifestEntry(
                    kind="leaf",
                    path=_join(prefix, t.path, path_str(kp)),
                    value=arr,
                )
            )
    return out


def manifest_entries(tree: Any) -> List[ManifestEntry]:
    """Walk any pytree; one entry per storable array, flatten-ordered.

    Per-leaf states yield plain 'leaf' entries whose path IS the logical
    path; stacked states yield 'stacked' entries carrying their slices'
    logical paths — the shared namespace both checkpoint modes speak.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, StackedLeaves)
    )
    out: List[ManifestEntry] = []
    for kp, node in flat:
        prefix = path_str(kp)
        if isinstance(node, StackedLeaves):
            out.extend(_stacked_entries(node, prefix))
        else:
            out.append(ManifestEntry(kind="leaf", path=prefix, value=node))
    return out


# ---------------------------------------------------------------------------
# Plan migration: stacked-bucket/v2 -> stacked-bucket/v2
# ---------------------------------------------------------------------------
# When the elastic supervisor replans a run (topology shrink/grow, budget
# change), the new coap-plan/v1 artifact may pin different ranks, flip a
# bucket's quantize codec, or regroup leaves into different buckets.
# ``migrate`` expresses that change as a codec transform: decode the source
# state per leaf through the shared logical-path namespace, transform each
# leaf to the target spec/codec, re-encode under the target layout.
#
# Preservation contract (documented in README "Preemption-native training"):
#   * EXACT  — same spec + same codec: arrays pass through bit-for-bit
#     (int8 codes included — no dequant/requant round-trip is inserted);
#   * EXACT  — rank truncation keeps the LEADING r_new columns of P and of
#     both moments bit-for-bit (correlation-aware P orders energy by Eqn-7
#     recalibration, so leading columns are the ones worth keeping);
#   * EXACT  — rank expansion keeps all r_old existing columns of P and of
#     the moments; the NEW columns of P are fresh ``init_p``-style Gaussian
#     directions orthogonalized against the preserved subspace (the same
#     completion Eqn-7 applies at the next recalibration), and the new
#     moment columns start at zero (cold, like t=0);
#   * APPROX — quantize flips pay exactly one codec rounding
#     (dequantize→requantize); fp32→int8→fp32 round-trips land within
#     block-absmax rounding of the original;
#   * EXACT* — a transposed canonicalization (same kind, flipped
#     ``spec.transpose``) is architecture-preserving and transforms in
#     place: with the QR factorization m = QR of the projected first
#     moment, the flipped leaf takes P' = Q and m' = P·Rᵀ, which
#     reproduces the de-projected first moment EXACTLY
#     (m'·P'ᵀ = P·Rᵀ·Qᵀ = P·mᵀ = (m·Pᵀ)ᵀ) and leaves P' exactly
#     orthonormal; the second moment has no exact low-rank transport
#     (Adam's v is already a diagonal approximation) and moves through
#     the diagonal variance map v' = (P∘²)·vᵀ·(Q∘²) — nonnegative,
#     magnitude-preserving, zero iff v was zero;
#   * RESET  — a kind change (project↔conv↔dense) re-initializes that
#     leaf's state from scratch (there is no meaningful moment mapping
#     across kinds).
#
# Byte exactness: migrated storage reproduces the target optimizer's init
# storage shapes/dtypes exactly, so ``accounting.optimizer_state_bytes`` of
# the migrated state equals ``accounting.abstract_state_bytes`` of the
# target optimizer — ``tests/test_elastic.py`` enforces this per category.


def _resize_last(x: jnp.ndarray, r_new: int) -> jnp.ndarray:
    """Truncate or zero-pad the last axis to ``r_new`` (moment columns)."""
    r_old = x.shape[-1]
    if r_new == r_old:
        return x
    if r_new < r_old:
        return x[..., :r_new]
    pad = [(0, 0)] * (x.ndim - 1) + [(0, r_new - r_old)]
    return jnp.pad(x, pad)


def _resize_axis(x: jnp.ndarray, axis: int, n_new: int) -> jnp.ndarray:
    n_old = x.shape[axis]
    if n_new == n_old:
        return x
    if n_new < n_old:
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, n_new)
        return x[tuple(sl)]
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n_new - n_old)
    return jnp.pad(x, pad)


def _resize_p(p: jnp.ndarray, r_new: int, key, dtype) -> jnp.ndarray:
    """Rank change on a projection matrix (..., n, r_old) -> (..., n, r_new).

    Truncation keeps the leading columns bit-for-bit. Expansion keeps every
    existing column and appends fresh N(0, 1/r_new) directions (``init_p``
    magnitude) orthogonalized against the span of the kept columns — the
    Eqn-7-style completion: the preserved subspace is untouched and the new
    directions carry no redundant energy, so the next recalibration refines
    rather than restarts them.
    """
    p = p.astype(dtype)
    r_old = p.shape[-1]
    if r_new == r_old:
        return p
    if r_new < r_old:
        return p[..., :r_new]
    extra = jax.random.normal(
        key, p.shape[:-1] + (r_new - r_old,), dtype
    ) / jnp.sqrt(jnp.asarray(r_new, dtype))
    q, _ = jnp.linalg.qr(p.astype(jnp.float32))
    e32 = extra.astype(jnp.float32)
    e_perp = e32 - q @ (jnp.swapaxes(q, -1, -2) @ e32)
    return jnp.concatenate([p, e_perp.astype(dtype)], axis=-1)


def _is_quantized(moment: jnp.ndarray) -> bool:
    return jnp.dtype(moment.dtype) == jnp.int8


def _load_flat(stored, scale, shape, block):
    """Flat-codec moment -> fp32 at its logical shape."""
    from repro.kernels import ref as kref

    if _is_quantized(stored):
        # The flat codec's block size rides in the stored shape
        # ([nblocks, block]); the ``block`` argument only matters on store.
        del block
        return kref.dequantize_blockwise(stored, scale, tuple(shape))
    return stored.astype(jnp.float32)


def _store_flat(x32, quantize, block, state_dtype):
    from repro.kernels import ref as kref

    if quantize:
        return kref.quantize_blockwise(x32, block=block)
    return x32.astype(state_dtype), jnp.zeros((1,), jnp.float32)


def _load_rowblock(stored, scale, block):
    """Row-block-codec projected moment -> fp32 (shape-preserving)."""
    from repro.kernels import ref as kref

    if _is_quantized(stored):
        return kref.dequantize_rowblock(stored, scale, block=block)
    return stored.astype(jnp.float32)


def _store_rowblock(x32, quantize, block, state_dtype):
    from repro.kernels import ref as kref

    if quantize:
        return kref.quantize_rowblock(x32, block=block)
    return x32.astype(state_dtype), jnp.zeros((1,), jnp.float32)


def _fresh_leaf_state(spec: ProjSpec, shape, quantize, key, block, state_dtype):
    """A from-scratch leaf state with exactly the init storage layout of
    ``scale_by_projected_adam.init_fn`` (the RESET path of migration)."""
    from repro.core import coap_adam as _ca
    from repro.core import conv as _conv
    from repro.core import projector as _proj
    from repro.kernels import ref as kref

    def zeros_flat(msh):
        if not quantize:
            return jnp.zeros(msh, state_dtype), jnp.zeros((1,), jnp.float32)
        numel = 1
        for s in msh:
            numel *= int(s)
        nblocks = -(-numel // block)
        return (jnp.zeros((nblocks, block), jnp.int8),
                jnp.zeros((nblocks,), jnp.float32))

    def zeros_proj(msh):
        if not quantize:
            return jnp.zeros(msh, state_dtype), jnp.zeros((1,), jnp.float32)
        nblk = kref.rowblock_nblocks(int(msh[-1]), block)
        return (jnp.zeros(msh, jnp.int8),
                jnp.zeros(tuple(msh[:-1]) + (nblk,), jnp.float32))

    if spec.kind == KIND_PROJECT:
        p0 = _proj.init_p(key, shape, spec, state_dtype)
        msh = _proj.moment_shape(shape, spec)
        m0, ms0 = zeros_proj(msh)
        v0, vs0 = zeros_proj(msh)
        return _ca.ProjLeaf(p=p0, m=m0, v=v0, m_scale=ms0, v_scale=vs0)
    if spec.kind == KIND_CONV:
        po, pi = _conv.init_factors(key, shape, spec)
        msh = _conv.core_shape(shape, spec)
        m0, ms0 = zeros_flat(msh)
        v0, vs0 = zeros_flat(msh)
        return _ca.ConvLeaf(p_o=po, p_i=pi, m=m0, v=v0,
                            m_scale=ms0, v_scale=vs0)
    m0, ms0 = zeros_flat(shape)
    v0, vs0 = zeros_flat(shape)
    return _ca.DenseLeaf(mu=m0, nu=v0, mu_scale=ms0, nu_scale=vs0)


def _transpose_proj(state, src_spec, src_block, state_dtype):
    """Exact orientation flip of a projected leaf (same kind, flipped
    ``spec.transpose`` — see the preservation contract above).

    Canonical source: P (..., n, r), moments (..., m, r). The flip swaps
    canonical roles, so the target wants P' (..., m, r) and moments
    (..., n, r). Factor the first moment m = Q·R (Q orthonormal):

        P' = Q,   m' = P·Rᵀ   ⇒   m'·P'ᵀ = P·mᵀ = (m·Pᵀ)ᵀ

    i.e. the de-projected first moment is reproduced EXACTLY and P' is
    exactly orthonormal. The second moment moves through the diagonal
    variance map v' = (P∘²)·vᵀ·(Q∘²) — the same diagonal approximation
    Adam's v already makes; nonnegative and zero iff v was zero.

    Returns ``(leaf, spec)`` with fp32 (unquantized) moments at the
    SOURCE rank — the caller's generic rank/codec path finishes the job.
    """
    from repro.core import coap_adam as _ca

    p32 = state.p.astype(jnp.float32)
    m32 = _load_rowblock(state.m, state.m_scale, src_block)
    v32 = _load_rowblock(state.v, state.v_scale, src_block)
    q, r = jnp.linalg.qr(m32)  # (..., m, r), (..., r, r)
    m_new = jnp.einsum("...nr,...kr->...nk", p32, r)  # P @ Rᵀ
    v_new = jnp.einsum(
        "...nr,...mr,...mk->...nk", p32 * p32, v32, q * q
    )
    one = jnp.zeros((1,), jnp.float32)
    leaf = _ca.ProjLeaf(p=q.astype(state_dtype), m=m_new, v=v_new,
                        m_scale=one, v_scale=one)
    return leaf, src_spec._replace(transpose=not src_spec.transpose)


def _migrate_proj(state, src_spec, dst_spec, shape, dst_q, key,
                  block, src_block, state_dtype):
    from repro.core import coap_adam as _ca
    from repro.core import projector as _proj

    if src_spec.transpose != dst_spec.transpose:
        # Orientation flip first (exact, at the source rank, to fp32);
        # the generic rank/codec path below then lands it in the target
        # rank and storage codec like any other migration.
        state, src_spec = _transpose_proj(
            state, src_spec, src_block, state_dtype
        )
        src_block = block  # moments are fp32 now; no source codec left
    src_q = _is_quantized(state.m)
    same_codec = (src_q == dst_q) and (not src_q or src_block == block)
    p = _resize_p(state.p, dst_spec.rank, key, state_dtype)
    if src_spec.rank == dst_spec.rank and same_codec:
        # Same storage codec, same shape: bit-exact pass-through (int8
        # codes are NOT round-tripped).
        if dst_q:
            return state._replace(p=p)
        return state._replace(p=p, m=state.m.astype(state_dtype),
                              v=state.v.astype(state_dtype))
    msh = _proj.moment_shape(shape, dst_spec)
    m32 = _resize_last(_load_rowblock(state.m, state.m_scale, src_block),
                       msh[-1])
    v32 = _resize_last(_load_rowblock(state.v, state.v_scale, src_block),
                       msh[-1])
    m, ms = _store_rowblock(m32, dst_q, block, state_dtype)
    v, vs = _store_rowblock(v32, dst_q, block, state_dtype)
    return _ca.ProjLeaf(p=p, m=m, v=v, m_scale=ms, v_scale=vs)


def _migrate_conv(state, src_spec, dst_spec, shape, dst_q, key,
                  block, src_block, state_dtype):
    from repro.core import coap_adam as _ca
    from repro.core import conv as _conv

    src_q = _is_quantized(state.m)
    same_codec = (src_q == dst_q) and (not src_q or src_block == block)
    ko, ki = jax.random.split(key)
    p_o = _resize_p(state.p_o, dst_spec.rank_o, ko, jnp.float32)
    p_i = _resize_p(state.p_i, dst_spec.rank_i, ki, jnp.float32)
    same_rank = (src_spec.rank_o == dst_spec.rank_o
                 and src_spec.rank_i == dst_spec.rank_i)
    if same_rank and same_codec:
        if dst_q:
            return state._replace(p_o=p_o, p_i=p_i)
        return state._replace(p_o=p_o, p_i=p_i,
                              m=state.m.astype(state_dtype),
                              v=state.v.astype(state_dtype))
    src_core = _conv.core_shape(shape, src_spec)
    dst_core = _conv.core_shape(shape, dst_spec)

    def move(stored, scale):
        x32 = _load_flat(stored, scale, src_core, src_block)
        x32 = _resize_axis(_resize_axis(x32, 0, dst_core[0]), 1, dst_core[1])
        return _store_flat(x32, dst_q, block, state_dtype)

    m, ms = move(state.m, state.m_scale)
    v, vs = move(state.v, state.v_scale)
    return _ca.ConvLeaf(p_o=p_o, p_i=p_i, m=m, v=v, m_scale=ms, v_scale=vs)


def _migrate_dense(state, shape, dst_q, block, src_block, state_dtype):
    from repro.core import coap_adam as _ca

    src_q = _is_quantized(state.mu)
    if (src_q == dst_q) and (not src_q or src_block == block):
        if dst_q:
            return state
        return state._replace(mu=state.mu.astype(state_dtype),
                              nu=state.nu.astype(state_dtype))
    mu, mus = _store_flat(_load_flat(state.mu, state.mu_scale, shape,
                                     src_block), dst_q, block, state_dtype)
    nu, nus = _store_flat(_load_flat(state.nu, state.nu_scale, shape,
                                     src_block), dst_q, block, state_dtype)
    return _ca.DenseLeaf(mu=mu, nu=nu, mu_scale=mus, nu_scale=nus)


def _leaf_kind(state) -> str:
    if hasattr(state, "p"):
        return KIND_PROJECT
    if hasattr(state, "p_o"):
        return KIND_CONV
    return "dense"


def migrate(
    src: StackedLeaves,
    dst_layout: StackedLayout,
    *,
    quantize_for: Callable[[str], bool],
    quant_block: int = 256,
    src_quant_block: Optional[int] = None,
    state_dtype: Any = jnp.float32,
    seed: int = 0,
) -> StackedLeaves:
    """The ``stacked-bucket/v2`` -> ``stacked-bucket/v2`` plan-migration
    transform (see the section comment above for the preservation
    contract).

    ``dst_layout`` is the target bucket assignment (``build_layout`` under
    the new plan's rules); ``quantize_for(path)`` says whether the target
    plan stores that leaf's moments int8; ``seed`` drives the fresh
    directions of rank expansion and RESET re-initialization
    (``fold_in(key(seed), flat_index)`` — the same per-leaf keying
    ``init_fn`` uses). Source codec parameters are detected from the state
    itself (int8 dtype == quantized); pass ``src_quant_block`` if the
    source plan used a non-default block.

    Leaves are matched between source and target by LOGICAL PATH — the
    same namespace the checkpoint codec speaks — so re-bucketing (layout
    changes) falls out of re-encoding. A path present in only one layout
    is a model-structure change, not a migration, and raises.
    """
    sqb = quant_block if src_quant_block is None else src_quant_block
    src_layout = src.layout
    src_states = decode(src)
    # RESET — the sync_codes error-feedback sidecar (ProjLeaf/ConvLeaf.ef)
    # never migrates: it accumulates COLLECTIVE rounding residue of the
    # int8 all-reduce, which is meaningless under a new layout/topology
    # (and plans do not own the knob). Dropping it keeps every migration
    # byte-exact against a fresh target init, like the scale placeholders.
    src_states = [
        s._replace(ef=None) if getattr(s, "ef", None) is not None else s
        for s in src_states
    ]

    by_path = {}
    for info in src_layout.buckets:
        for idx, path in zip(info.indices, info.paths):
            by_path[path] = (src_states[idx], info.spec, info.shape)
    for t in src_layout.tail:
        by_path[t.path] = (src_states[t.index], t.spec, None)

    dst_paths = [p for info in dst_layout.buckets for p in info.paths]
    dst_paths += [t.path for t in dst_layout.tail]
    missing = sorted(set(dst_paths) - set(by_path))
    extra = sorted(set(by_path) - set(dst_paths))
    if missing or extra:
        raise ValueError(
            "migrate: source and target layouts describe different param "
            f"trees (missing from source: {missing[:3]}, absent from "
            f"target: {extra[:3]}) — migration transforms state for the "
            "SAME model; a structure change needs a fresh init"
        )

    key = jax.random.key(seed)
    out = [None] * dst_layout.n_leaves
    for info in dst_layout.buckets:
        for idx, path in zip(info.indices, info.paths):
            state, src_spec, _src_shape = by_path[path]
            dst_q = bool(quantize_for(path))
            lkey = jax.random.fold_in(key, idx)
            dst_spec = info.spec
            src_kind = _leaf_kind(state)
            # Only a KIND change resets: transposed canonicalization is
            # architecture-preserving and handled exactly by
            # _transpose_proj inside the projected path.
            reset = src_kind != dst_spec.kind
            if reset:
                out[idx] = _fresh_leaf_state(
                    dst_spec, info.shape, dst_q, lkey, quant_block,
                    state_dtype,
                )
            elif dst_spec.kind == KIND_PROJECT:
                out[idx] = _migrate_proj(
                    state, src_spec, dst_spec, info.shape, dst_q, lkey,
                    quant_block, sqb, state_dtype,
                )
            elif dst_spec.kind == KIND_CONV:
                out[idx] = _migrate_conv(
                    state, src_spec, dst_spec, info.shape, dst_q, lkey,
                    quant_block, sqb, state_dtype,
                )
            else:
                out[idx] = _migrate_dense(
                    state, info.shape, dst_q, quant_block, sqb, state_dtype
                )
    for t in dst_layout.tail:
        state, src_spec, _ = by_path[t.path]
        if src_spec != t.spec:
            raise ValueError(
                f"migrate: tail leaf {t.path!r} changed spec "
                f"({src_spec} -> {t.spec}); tail leaves carry no shape in "
                "the layout, so only pass-through migration is supported"
            )
        out[t.index] = state
    return encode(dst_layout, out)
