"""Pre-stacked optimizer-state subsystem: bucket storage + portable codec.

WHY.  ``scale_by_projected_adam`` batches congruent leaves into one fused
launch per ``(shape, spec, dtype)`` bucket, but with per-leaf state storage
every step pays a stack/scatter round-trip at the bucket boundary — real HBM
copy traffic on the moment states (XLA fuses some fp32 copies into kernel
operands, but never the int8 code round-trip).  Storing the states
PRE-STACKED along the bucket axis removes those copies entirely: the fused
kernels read and write bucket arrays in place, and only the (cheap, fusable)
gradient stack and update scatter remain on the hot path.

LAYOUT.  A stacked optimizer state is a :class:`StackedLeaves` pytree node:

  * ``buckets`` — one stacked leaf-state (``ProjLeaf``/``ConvLeaf``/
    ``DenseLeaf``/…, every field carrying a leading ``(B,)`` bucket axis)
    per congruence bucket: projected buckets first, then conv (Tucker-2)
    buckets, then dense buckets, each in tree (insertion) order;
  * ``tail`` — a residual tuple of PER-LEAF states for leaves that a
    caller's ``classify`` routes to per-leaf storage (empty under the
    default classification since v2 buckets conv; ``classify_v1``
    reproduces the v1 conv-in-tail layout);
  * ``layout`` — static aux data (:class:`StackedLayout`): which original
    flat leaf index lives in which bucket slot, its tree path, and its
    ``ProjSpec``.  The layout is a pure function of the param tree and the
    projection rules, so it is identical across restarts and across hosts.

CODEC.  The codec maps a stacked state to and from the congruent per-leaf
pytree, and names every array portably so *state consumers* need no
knowledge of which mode produced it:

  * :func:`build_layout` — bucket assignment (THE single definition: the
    optimizer transforms, the checkpoint reader and the benchmarks all call
    this, so bucket order can never drift between producers and consumers);
  * :func:`encode` / :func:`decode` — per-leaf states <-> stacked buckets
    (``decode(encode(x)) == x`` bit-for-bit, int8 codes included);
  * :func:`leaf_view` — one leaf's state as a zero-copy slice of its bucket
    (how ``distributed/compression.py`` addresses bucket slices);
  * :func:`manifest_entries` — walks ANY pytree (stacked, per-leaf or
    mixed) and yields one entry per storable array, in standard
    ``tree_flatten`` order.  Stacked arrays carry their per-leaf *logical
    paths* (``slots``): the path each slice would have under per-leaf
    storage.  Both storage modes therefore share one logical-path
    namespace, which is what lets ``train/checkpoint.py`` restore a
    checkpoint written in either mode into a template of either mode.

VERSIONING.  Stacked checkpoint entries are tagged ``codec:
"stacked-bucket/v2"`` (:data:`STACKED_CODEC`).  Shared slice semantics
(v1 == v2 per entry): ``axis`` 0 is the bucket axis; ``slots[j]`` is the
logical per-leaf path of slice ``j``; slices are bit-exact views (no
transform is applied by the codec).  The version records the LAYOUT a
writer produces:

  * ``stacked-bucket/v1`` — conv (Tucker-2) leaves live in the per-leaf
    TAIL (plain 'leaf' manifest entries); only matrix/dense leaves stack.
  * ``stacked-bucket/v2`` — conv leaves bucket by ``(spec, shape, dtype)``
    like everything else (:data:`BUCKET_CONV`) and their ``ConvLeaf``
    fields stack along axis 0.

Because per-entry semantics did not change, v2 readers decode v1 entries
directly (:data:`DECODABLE_CODECS`) and assemble conv buckets slot-by-slot
from the v1 tail's per-leaf entries through the shared logical-path
namespace — and a v1-layout template restores from a v2 checkpoint by
slicing the conv bucket entries.  Any future change to the slice semantics
must bump the version string again so old readers fail loudly instead of
mis-slicing; readers reject every codec outside ``DECODABLE_CODECS``.

A/B GUARANTEE.  ``ProjectedAdamConfig(stacked_state=False)`` keeps today's
per-leaf layout bit-for-bit; ``stacked_state=True`` must produce the same
updates and (decoded) states bit-for-bit — fp32, bf16 streaming, int8 codes
and flora RNG included (``tests/test_stacked_state.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.projector import KIND_CONV, KIND_PROJECT, ProjSpec, path_str

STACKED_STATE_VERSION = 2
STACKED_CODEC_V1 = "stacked-bucket/v1"
STACKED_CODEC = "stacked-bucket/v2"
# Codecs this build can read (slice semantics are identical; the version
# names the writer's LAYOUT — see module docstring). Anything else fails
# loudly at restore time.
DECODABLE_CODECS = frozenset({STACKED_CODEC_V1, STACKED_CODEC})

# build_layout classifications.
BUCKET_PROJECT = "project"  # congruent low-rank leaves, stacked
BUCKET_CONV = "conv"  # congruent Tucker-2 conv leaves, stacked (v2)
BUCKET_DENSE = "dense"  # congruent dense leaves, stacked
BUCKET_TAIL = "tail"  # per-leaf residual (v1 conv layout, exotic leaves)


class BucketInfo(NamedTuple):
    """Static description of one congruence bucket."""

    kind: str  # BUCKET_PROJECT | BUCKET_CONV | BUCKET_DENSE
    spec: ProjSpec
    shape: Tuple[int, ...]  # original leaf shape
    dtype: str  # original leaf dtype name
    indices: Tuple[int, ...]  # original flat leaf indices, tree order
    paths: Tuple[str, ...]  # leaf tree paths, aligned with ``indices``


class TailInfo(NamedTuple):
    """One residual (non-bucketed) leaf."""

    index: int
    path: str
    spec: ProjSpec


@dataclasses.dataclass(frozen=True)
class StackedLayout:
    """Pure-structural bucket assignment (hashable: jit-static aux data)."""

    version: int
    buckets: Tuple[BucketInfo, ...]
    tail: Tuple[TailInfo, ...]
    n_leaves: int

    def __post_init__(self):
        pos = {}
        for b, info in enumerate(self.buckets):
            for slot, idx in enumerate(info.indices):
                pos[idx] = ("bucket", b, slot)
        for j, t in enumerate(self.tail):
            pos[t.index] = ("tail", j, 0)
        object.__setattr__(self, "_positions", pos)

    def position(self, index: int) -> Tuple[str, int, int]:
        """flat leaf index -> ('bucket', b, slot) | ('tail', j, 0)."""
        return self._positions[index]

    def proj_bucket_sizes(self) -> List[int]:
        return [
            len(b.indices) for b in self.buckets if b.kind == BUCKET_PROJECT
        ]

    def conv_bucket_sizes(self) -> List[int]:
        return [
            len(b.indices) for b in self.buckets if b.kind == BUCKET_CONV
        ]

    def staggerable_bucket_sizes(self) -> List[int]:
        """Leaf counts of every bucket on the staggered refresh schedule —
        projected buckets then conv buckets, in bucket order (the order
        ``stagger_phases`` allocates phase units over)."""
        return self.proj_bucket_sizes() + self.conv_bucket_sizes()

    def signature(self):
        """Dtype-erased structural identity. The state layout depends on
        shapes/specs only — gradients may legally stream in a different
        dtype than the params the state was initialized from (bf16
        training), so the hot-path compatibility check compares this, not
        full equality."""
        return (
            self.version,
            tuple(
                (b.kind, b.spec, b.shape, b.indices, b.paths)
                for b in self.buckets
            ),
            self.tail,
            self.n_leaves,
        )


def classify_default(spec: ProjSpec) -> str:
    """v2 classification: projected and conv leaves each bucket by their
    congruence signature; everything else is dense."""
    if spec.kind == KIND_PROJECT:
        return BUCKET_PROJECT
    if spec.kind == KIND_CONV:
        return BUCKET_CONV
    return BUCKET_DENSE


def classify_v1(spec: ProjSpec) -> str:
    """The ``stacked-bucket/v1`` classification: conv (Tucker-2) leaves go
    to the per-leaf tail. Kept for cross-version checkpoint tests and for
    re-encoding a state into the legacy layout."""
    if spec.kind == KIND_PROJECT:
        return BUCKET_PROJECT
    if spec.kind == KIND_CONV:
        return BUCKET_TAIL
    return BUCKET_DENSE


def build_layout(
    spec_fn: Callable[[str, Sequence[int]], ProjSpec],
    paths: Sequence[str],
    shapes: Sequence[Sequence[int]],
    dtypes: Sequence[str],
    classify: Optional[Callable[[ProjSpec], str]] = None,
) -> StackedLayout:
    """THE bucket assignment, shared by every producer and consumer.

    Identical grouping to ``scale_by_projected_adam.update_fn``: projected,
    conv and dense leaves each bucket by ``(spec, shape, dtype)`` in tree
    (insertion) order; ``classify`` maps a spec to project/conv/dense/tail
    (default :func:`classify_default`: ``KIND_PROJECT`` projects,
    ``KIND_CONV`` buckets as conv — the v2 layout — everything else is
    dense). Projected buckets come first in ``layout.buckets``, then conv
    buckets, then dense, so stagger phases line up with the per-leaf
    schedule (``staggerable_bucket_sizes``).
    """
    if classify is None:
        classify = classify_default

    proj, conv, dense = {}, {}, {}
    tail: List[TailInfo] = []
    for idx, (path, shape, dtype) in enumerate(zip(paths, shapes, dtypes)):
        shape = tuple(int(s) for s in shape)
        spec = spec_fn(path, shape)
        kind = classify(spec)
        if kind == BUCKET_TAIL:
            tail.append(TailInfo(index=idx, path=path, spec=spec))
        elif kind == BUCKET_PROJECT:
            proj.setdefault((spec, shape, dtype), []).append((idx, path))
        elif kind == BUCKET_CONV:
            conv.setdefault((spec, shape, dtype), []).append((idx, path))
        else:
            dense.setdefault((spec, shape, dtype), []).append((idx, path))

    buckets: List[BucketInfo] = []
    for kind, groups in (
        (BUCKET_PROJECT, proj), (BUCKET_CONV, conv), (BUCKET_DENSE, dense)
    ):
        for (spec, shape, dtype), members in groups.items():
            buckets.append(
                BucketInfo(
                    kind=kind,
                    spec=spec,
                    shape=shape,
                    dtype=dtype,
                    indices=tuple(i for i, _ in members),
                    paths=tuple(p for _, p in members),
                )
            )
    return StackedLayout(
        version=STACKED_STATE_VERSION,
        buckets=tuple(buckets),
        tail=tuple(tail),
        n_leaves=len(paths),
    )


def layout_for_flat(
    spec_fn, flat, classify: Optional[Callable[[ProjSpec], str]] = None
) -> StackedLayout:
    """``build_layout`` over an already path-flattened tree
    (``tree_flatten_with_path`` output — what the optimizer transforms
    hold at init/update time)."""
    return build_layout(
        spec_fn,
        [path_str(kp) for kp, _ in flat],
        [leaf.shape for _, leaf in flat],
        [jnp.dtype(leaf.dtype).name for _, leaf in flat],
        classify,
    )


def layout_for_tree(
    spec_fn, tree, classify: Optional[Callable[[ProjSpec], str]] = None
) -> StackedLayout:
    """``build_layout`` over a concrete (or abstract) param/gradient tree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return layout_for_flat(spec_fn, flat, classify)


@jax.tree_util.register_pytree_with_keys_class
class StackedLeaves:
    """Optimizer leaves stored pre-stacked by congruence bucket.

    A pytree node: children are the stacked bucket states and the per-leaf
    tail states; the :class:`StackedLayout` rides along as static aux data
    (hashable, so jit caches on it like any other static argument).
    """

    __slots__ = ("buckets", "tail", "layout")

    def __init__(self, buckets, tail, layout: StackedLayout):
        self.buckets = tuple(buckets)
        self.tail = tuple(tail)
        self.layout = layout

    def tree_flatten_with_keys(self):
        return (
            (
                (jax.tree_util.GetAttrKey("buckets"), self.buckets),
                (jax.tree_util.GetAttrKey("tail"), self.tail),
            ),
            self.layout,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        buckets, tail = children
        return cls(buckets, tail, aux)

    def __repr__(self):
        return (
            f"StackedLeaves(buckets={len(self.buckets)}, "
            f"tail={len(self.tail)}, leaves={self.layout.n_leaves})"
        )


def encode(layout: StackedLayout, flat_states: Sequence[Any]) -> StackedLeaves:
    """Per-leaf states (flat, tree order) -> pre-stacked buckets.

    Stacking is ``jnp.stack`` per field, so encoded arrays are bit-exact
    concatenations of the per-leaf arrays (int8 codes included).
    """
    if len(flat_states) != layout.n_leaves:
        raise ValueError(
            f"layout has {layout.n_leaves} leaves, got {len(flat_states)}"
        )
    buckets = [
        jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[flat_states[i] for i in info.indices]
        )
        for info in layout.buckets
    ]
    tail = [flat_states[t.index] for t in layout.tail]
    return StackedLeaves(buckets, tail, layout)


def decode(stacked: StackedLeaves) -> List[Any]:
    """Inverse of :func:`encode`: flat per-leaf states in tree order."""
    layout = stacked.layout
    out: List[Any] = [None] * layout.n_leaves
    for b, info in enumerate(layout.buckets):
        for slot, idx in enumerate(info.indices):
            out[idx] = jax.tree_util.tree_map(
                lambda x, s=slot: x[s], stacked.buckets[b]
            )
    for j, t in enumerate(layout.tail):
        out[t.index] = stacked.tail[j]
    return out


def leaf_view(stacked: StackedLeaves, index: int) -> Any:
    """One leaf's state, addressed as a slice of its bucket.

    The returned pytree has exactly the structure/dtypes the same leaf
    would have under per-leaf storage; inside jit the slice is a view XLA
    fuses into its consumer (this is how the cross-pod compression path
    reads per-leaf moments out of stacked storage)."""
    kind, b, slot = stacked.layout.position(index)
    if kind == "tail":
        return stacked.tail[b]
    return jax.tree_util.tree_map(lambda x: x[slot], stacked.buckets[b])


# ---------------------------------------------------------------------------
# Checkpoint codec: manifest entries
# ---------------------------------------------------------------------------
class ManifestEntry(NamedTuple):
    """One storable array of a state pytree.

    ``kind`` is 'leaf' (ordinary array; ``path`` is its logical per-leaf
    path) or 'stacked' (bucket array; ``path`` is its stacked tree path and
    ``slots`` the per-leaf logical paths of its axis-0 slices, in order).
    Entries are yielded in standard ``tree_flatten`` order of the walked
    tree, so a position-aligned sharding-spec list stays valid.
    """

    kind: str
    path: str
    value: Any
    slots: Optional[Tuple[str, ...]] = None


def _join(*parts: str) -> str:
    return "/".join(p for p in parts if p)


def _stacked_entries(node: StackedLeaves, prefix: str) -> List[ManifestEntry]:
    """Expand one StackedLeaves node in its own tree_flatten order."""
    out: List[ManifestEntry] = []
    layout = node.layout
    for b, (info, bucket) in enumerate(zip(layout.buckets, node.buckets)):
        flat, _ = jax.tree_util.tree_flatten_with_path(bucket)
        for kp, arr in flat:
            field = path_str(kp)
            out.append(
                ManifestEntry(
                    kind="stacked",
                    path=_join(prefix, "buckets", str(b), field),
                    value=arr,
                    slots=tuple(
                        _join(prefix, lp, field) for lp in info.paths
                    ),
                )
            )
    for t, state in zip(layout.tail, node.tail):
        flat, _ = jax.tree_util.tree_flatten_with_path(state)
        for kp, arr in flat:
            out.append(
                ManifestEntry(
                    kind="leaf",
                    path=_join(prefix, t.path, path_str(kp)),
                    value=arr,
                )
            )
    return out


def manifest_entries(tree: Any) -> List[ManifestEntry]:
    """Walk any pytree; one entry per storable array, flatten-ordered.

    Per-leaf states yield plain 'leaf' entries whose path IS the logical
    path; stacked states yield 'stacked' entries carrying their slices'
    logical paths — the shared namespace both checkpoint modes speak.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, StackedLeaves)
    )
    out: List[ManifestEntry] = []
    for kp, node in flat:
        prefix = path_str(kp)
        if isinstance(node, StackedLeaves):
            out.extend(_stacked_entries(node, prefix))
        else:
            out.append(ManifestEntry(kind="leaf", path=prefix, value=node))
    return out
