"""Eqn 6: inter-projection correlation-aware P update.

Objective (paper Eqn 6):

    L(P) = MSE(Ĝ, G) · (1 − CosSim(M̂, G)),
    Ĝ = G P Pᵀ,   M̂ = M_proj Pᵀ,
    CosSim = row-wise cosine averaged over the m rows (appendix Eqn 5).

We implement the appendix's closed-form gradients (Eqn 4 for the MSE term,
Eqn 6 for the cosine term) and combine them with the product rule:

    ∇L = ∇MSE · (1 − CosSim) − MSE · ∇CosSim.

NOTE ON A PAPER TYPO: appendix Eqn 3/7 write the combination as
``∂MSE·(1−CosSim) + ∂CosSim·MSE``; descending that expression *decreases*
cosine similarity, contradicting the stated goal (the direction term
``1 − CosSim`` is minimized by *increasing* CosSim). The product rule gives
the minus sign used here; ``tests/test_core_correlation.py`` verifies our
closed form equals ``jax.grad`` of the printed objective to float32 precision,
so the implementation is faithful to Eqn 6 itself.

All functions broadcast over leading (layer/expert stack) axes; reductions
are per-matrix so every stacked matrix optimizes its own P independently.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _dot_last2(a, b):
    """Frobenius inner product over last two axes, keeps leading axes."""
    return jnp.sum(a * b, axis=(-1, -2))


def mse(g_hat: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Per-matrix MSE over last two axes (leading axes preserved)."""
    d = g_hat - g
    return jnp.mean(jnp.square(d), axis=(-1, -2))


def cos_sim_rows(m_hat: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Appendix Eqn 5: mean over rows of row-cosine(m_hat_i, g_i)."""
    num = jnp.sum(m_hat * g, axis=-1)
    den = jnp.linalg.norm(m_hat, axis=-1) * jnp.linalg.norm(g, axis=-1) + _EPS
    return jnp.mean(num / den, axis=-1)


def objective(p: jnp.ndarray, g: jnp.ndarray, m_proj: jnp.ndarray) -> jnp.ndarray:
    """Paper Eqn 6, per matrix. p:(...,n,r) g:(...,m,n) m_proj:(...,m,r)."""
    g_hat = jnp.einsum("...mr,...nr->...mn", jnp.einsum("...mn,...nr->...mr", g, p), p)
    m_hat = jnp.einsum("...mr,...nr->...mn", m_proj, p)
    return mse(g_hat, g) * (1.0 - cos_sim_rows(m_hat, g))


def mse_grad(p: jnp.ndarray, g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Appendix Eqn 4: ∂MSE/∂P = 2/(mn) (ĜᵀGP − 2GᵀGP + GᵀĜP).

    Returns (grad, mse_value). Computed right-to-left so cost is O(mnr),
    never materializing the n×n Gram matrix.
    """
    m = g.shape[-2]
    n = g.shape[-1]
    gp = jnp.einsum("...mn,...nr->...mr", g, p)  # G P
    g_hat = jnp.einsum("...mr,...nr->...mn", gp, p)  # G P Pᵀ
    # ĜᵀGP = P (GP)ᵀ (GP)
    t1 = jnp.einsum("...nr,...mr,...ms->...ns", p, gp, gp)
    t2 = jnp.einsum("...mn,...mr->...nr", g, gp)  # GᵀG P
    # t3 = Gᵀ Ĝ P = Gᵀ (G P Pᵀ) P — computed as Gᵀ @ (GP @ (PᵀP)).
    ptp = jnp.einsum("...nr,...nk->...rk", p, p)
    t3 = jnp.einsum("...mn,...mk->...nk", g, jnp.einsum("...mr,...rk->...mk", gp, ptp))
    grad = (2.0 / (m * n)) * (t1 - 2.0 * t2 + t3)
    val = mse(g_hat, g)
    return grad, val


def cos_grad(
    p: jnp.ndarray, g: jnp.ndarray, m_proj: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Appendix Eqn 6: ∂CosSim/∂P = Dᵀ M_proj with
    D_i = (1/m)(G_i/(‖M̂_i‖‖G_i‖) − M̂_i⟨M̂_i,G_i⟩/(‖M̂_i‖³‖G_i‖)).

    Returns (grad, cos_value).
    """
    m = g.shape[-2]
    m_hat = jnp.einsum("...mr,...nr->...mn", m_proj, p)
    mh_norm = jnp.linalg.norm(m_hat, axis=-1, keepdims=True)  # (...,m,1)
    g_norm = jnp.linalg.norm(g, axis=-1, keepdims=True)
    inner = jnp.sum(m_hat * g, axis=-1, keepdims=True)  # (...,m,1)
    denom = mh_norm * g_norm + _EPS
    d = (g / denom - m_hat * inner / (mh_norm**3 * g_norm + _EPS)) / m
    grad = jnp.einsum("...mn,...mr->...nr", d, m_proj)
    val = jnp.mean(jnp.squeeze(inner / denom, -1), axis=-1)
    return grad, val


def loss_and_grad(
    p: jnp.ndarray, g: jnp.ndarray, m_proj: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Closed-form value+gradient of Eqn 6 (product rule; see module note)."""
    g_mse, v_mse = mse_grad(p, g)
    g_cos, v_cos = cos_grad(p, g, m_proj)
    one_minus = 1.0 - v_cos
    val = v_mse * one_minus
    grad = (
        g_mse * one_minus[..., None, None] - g_cos * v_mse[..., None, None]
    )
    return val, grad


def sgd_update(
    p: jnp.ndarray,
    g: jnp.ndarray,
    m_proj: jnp.ndarray,
    lr: float = 0.1,
    steps: int = 1,
    normalize: bool = False,
    use_fused: bool = False,
) -> jnp.ndarray:
    """Paper: 'use SGD to iteratively update P_t' — lr 0.1 by default.

    ``normalize=True`` is a beyond-paper option: Eqn 6's MSE factor makes the
    P-gradient scale like ‖G‖², so for small/clipped gradients the refresh is
    numerically inert at any fixed lr. Normalizing G to unit RMS per matrix
    makes the step scale-invariant (the direction term is already
    scale-free). Off by default for faithfulness; ablated in
    benchmarks/table7_ablation.py.

    ``use_fused=True`` routes through the single-pass fused loss+grad kernel
    (``kernels/eqn6.py``: one G sweep per step instead of ~6 separate
    einsums; bf16 G streams without an fp32 materialization). Semantics are
    identical; the jnp path below is the oracle the kernel is pinned
    against. ``normalize`` fuses too: its ‖G‖ pre-pass runs as a first grid
    phase of the same kernel (one extra G stream per refresh).
    """
    if use_fused:
        from repro.kernels import ops as kops  # lazy: kernels layer is below

        return kops.eqn6_sgd_update(
            p, g, m_proj, lr=lr, steps=steps, normalize=normalize
        )
    dtype = p.dtype
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    m_proj = m_proj.astype(jnp.float32)
    if normalize:
        rms = jnp.sqrt(jnp.mean(jnp.square(g), axis=(-1, -2), keepdims=True)) + _EPS
        g = g / rms
        m_proj = m_proj / rms

    def body(_, p_cur):
        _, grad = loss_and_grad(p_cur, g, m_proj)
        return p_cur - lr * grad

    return jax.lax.fori_loop(0, steps, body, p).astype(dtype)
