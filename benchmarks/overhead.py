"""Training-time overhead columns (Tables 1/2/3/5/6) + fused-8-bit traffic.

Per-step optimizer overhead = measured P-update cost amortized over its
interval + measured per-step projection cost, divided by the analytic step
time at the paper's hardware (8xH100 @ 40% MFU). Printed alongside the
paper's claimed +x% columns. Absolute CPU times are reported in the CSV so
the derivation is auditable.

The quantized section compares the single-pass fused int8 COAP step
(kernels/quant8.coap_fused_update_q8_pallas) against the unfused 8-bit
schedule (dequant M, dequant V, project, moment EMA, Δ+clip, backproject,
requant M, requant V — 8 separate dispatches) on LLaMA-1B shapes, two ways:

  * ``unfused``: XLA ``cost_analysis()`` 'bytes accessed' summed over the 8
    separately-jitted stages — each stage boundary is a real HBM
    materialization when dispatched separately.
  * ``fused``: what ``cost_analysis`` reports for the one-kernel dispatch —
    its operand+result buffers (the custom call's HBM I/O) — plus,
    conservatively, the kernel's internal P re-stream traffic derived from
    its BlockSpec index maps (2·ceil(m/bm)·n·r·4 bytes: P is swept once per
    row-block in each MXU phase). Both variants are recorded.

Results land in ``BENCH_overhead.json`` next to the repo root, including
per-shape bytes, the headline ratio (conservative accounting), and launch
counts per step.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, analytic_step_seconds, time_fn
from repro.core import correlation, recalibrate
from repro.kernels import ref as kref


# (m, n) matrices of LLaMA-1B with multiplicity per step
LLAMA1B_MATS = [
    ((2048, 2048), 4 * 24), ((5461, 2048), 3 * 24), ((32000, 2048), 1),
]
LLAMA1B_N = 1.1e9
LLAMA1B_TOKENS = 512 * 256  # batch 512, seq 256 (paper's GaLore recipe)


def _p_update_cost(mats, rank, strategy: str) -> float:
    """Wall seconds to refresh ALL projections once."""
    total = 0.0
    for (m, n), count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        g = jax.random.normal(jax.random.key(0), (mm, nn))
        p = jax.random.normal(jax.random.key(1), (nn, r)) / np.sqrt(r)
        mp = 0.1 * jax.random.normal(jax.random.key(2), (mm, r))
        if strategy == "galore":
            fn = jax.jit(lambda gg: recalibrate.galore_svd(gg, r))
            t = time_fn(fn, g, iters=1)
        elif strategy == "coap_recal":
            fn = jax.jit(recalibrate.lowcost_svd)
            t = time_fn(fn, g, p, iters=1)
        elif strategy == "coap_eqn6":
            fn = jax.jit(lambda pp, gg, m2: correlation.sgd_update(pp, gg, m2))
            t = time_fn(fn, p, g, mp, iters=2)
        else:  # flora
            fn = jax.jit(lambda k: recalibrate.random_projection(k, (mm, nn), r))
            t = time_fn(fn, jax.random.key(3), iters=2)
        total += t * count
    return total


def _per_step_projection_cost(mats, rank) -> float:
    """G@P + moment update + backproject per step (the fused-kernel path)."""
    total = 0.0
    for (m, n), count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        g = jax.random.normal(jax.random.key(0), (mm, nn))
        p = jax.random.normal(jax.random.key(1), (nn, r)) / np.sqrt(r)
        mo = jnp.zeros((mm, r))
        vo = jnp.zeros((mm, r))
        cnt = jnp.asarray(3, jnp.int32)
        fn = jax.jit(lambda *a: kref.coap_fused_update(*a))
        t = time_fn(fn, g, p, mo, vo, cnt, iters=2)
        total += t * count
    return total


def _bytes_accessed(fn, *args) -> float:
    """XLA cost-model 'bytes accessed' of fn jitted as one dispatch."""
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    d = ca[0] if isinstance(ca, list) else ca
    return float(d["bytes accessed"])


def _nbytes(*arrays) -> float:
    return float(sum(a.size * a.dtype.itemsize for a in arrays))


def quantized_fused_vs_unfused(mats, rank, block=kref.QUANT_BLOCK,
                               bm=None):
    """Per-shape bytes-accessed comparison for the 8-bit COAP step.

    Returns {shape_label: {...}} with fused/unfused bytes, the conservative
    ratio, and per-step launch counts. See module docstring for methodology.
    ``bm`` defaults to the fused kernel's own row tile so the P re-stream
    charge tracks the real tiling.
    """
    if bm is None:
        from repro.kernels.quant8 import DEFAULT_BM as bm
    b1, b2, eps = 0.9, 0.999, 1e-8
    out = {}
    for (m, n), _count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        nblk = kref.rowblock_nblocks(r, block)
        g = jnp.zeros((mm, nn))
        p = jnp.zeros((nn, r))
        mq = jnp.zeros((mm, r), jnp.int8)
        ms = jnp.zeros((mm, nblk))
        vq, vs = mq, ms
        m_f = jnp.zeros((mm, r))
        v_f = jnp.zeros((mm, r))
        gp = jnp.zeros((mm, r))
        d_ = jnp.zeros((mm, r))

        # --- unfused schedule: 8 separate dispatches (t=3 baked into the
        # bias-correction stage; traffic is t-independent) ----------------
        tf = 3.0
        stages = [
            ("dequant_m", lambda q, s: kref.dequantize_rowblock(q, s, block),
             (mq, ms)),
            ("dequant_v", lambda q, s: kref.dequantize_rowblock(q, s, block),
             (vq, vs)),
            ("project", lambda g_, p_: jnp.einsum("mn,nr->mr", g_, p_),
             (g, p)),
            ("moments", lambda gp_, m_, v_: (
                b1 * m_ + (1 - b1) * gp_, b2 * v_ + (1 - b2) * jnp.square(gp_)
            ), (gp, m_f, v_f)),
            ("delta_clip", lambda m_, v_: jnp.clip(
                (m_ / (1 - b1**tf)) / (jnp.sqrt(v_ / (1 - b2**tf)) + eps),
                -kref.QUANT_DELTA_CLIP, kref.QUANT_DELTA_CLIP,
            ), (m_f, v_f)),
            ("backproject", lambda d2, p_: jnp.einsum("mr,nr->mn", d2, p_),
             (d_, p)),
            ("requant_m", lambda m_: kref.quantize_rowblock(m_, block), (m_f,)),
            ("requant_v", lambda v_: kref.quantize_rowblock(v_, block), (v_f,)),
        ]
        unfused_cost = {
            name: _bytes_accessed(fn, *args) for name, fn, args in stages
        }
        unfused_bytes = sum(unfused_cost.values())

        # --- fused single-pass kernel ------------------------------------
        # operand+result buffers of the one dispatch (what cost_analysis
        # reports for the pallas custom call on TPU):
        dw = jnp.zeros((mm, nn))
        fused_io = _nbytes(g, p, mq, ms, vq, vs) + _nbytes(mq, ms, vq, vs, dw)
        # + internal P re-stream per index maps (phase 1 + phase 2):
        p_restream = 2.0 * np.ceil(mm / bm) * nn * r * 4.0
        fused_bytes = fused_io + p_restream

        out[f"{mm}x{nn}"] = {
            "rank": int(r),
            "unfused_bytes": unfused_bytes,
            "unfused_per_stage": unfused_cost,
            "fused_io_bytes": fused_io,
            "fused_p_restream_bytes": p_restream,
            "fused_bytes_conservative": fused_bytes,
            # 'ratio' follows cost_analysis semantics on both sides: summed
            # per-dispatch bytes for the 8-stage schedule vs the single
            # custom call's operand+result bytes.
            "ratio": unfused_bytes / fused_io,
            "ratio_conservative": unfused_bytes / fused_bytes,
            "launches_unfused": len(stages),
            "launches_fused": 1,
        }
    return out


# ---------------------------------------------------------------------------
# Refresh-cost section (BENCH_refresh.json)
# ---------------------------------------------------------------------------
# LLaMA-1B projected buckets exactly as scale_by_projected_adam forms them
# over a non-stacked 24-layer tree: (canonical m, n, leaf count). q/k/v/o are
# one congruent (2048, 2048) bucket; gate/up transpose into canonical
# (5461, 2048) but keep their own bucket key (original shape differs from
# down's), so the tree has three staggerable buckets.
LLAMA1B_REFRESH_BUCKETS = [
    ("attn_qkvo", (2048, 2048), 96),
    ("mlp_gate_up", (5461, 2048), 48),
    ("mlp_down", (5461, 2048), 24),
]


def _staggered_schedule_stats(bucket_list, shape_cost, phase_lists, t_u, lam):
    """THE schedule-cost accounting, shared by the matrix and conv refresh
    reports: worst/total refresh cost of a phase schedule over the
    steady-state ``[1, λ·T_u]`` window (step 0 is the one-time Eqn-7 init,
    identical under every schedule by design). ``bucket_list`` rows are
    ``(label, shape_key, leaf_count)``; ``shape_cost[shape_key]`` supplies
    ``{eqn6,recal}_{bytes,s}`` per leaf; a leaf refreshes when
    ``(count + phase) % T_u == 0`` and that refresh is an Eqn-7 recal when
    ``(count + phase) % (λ·T_u) == 0``."""
    from repro.core.coap_adam import _phase_groups

    def step_cost(count):
        bytes_, secs = 0.0, 0.0
        for (_, shape, _cnt), phases in zip(bucket_list, phase_lists):
            for _s0, sz, ph in _phase_groups(phases):
                if (count + ph) % t_u == 0:
                    kind = (
                        "recal" if (count + ph) % (lam * t_u) == 0 else "eqn6"
                    )
                    bytes_ += sz * shape_cost[shape][f"{kind}_bytes"]
                    secs += sz * shape_cost[shape][f"{kind}_s"]
        return bytes_, secs

    per_step = [step_cost(c) for c in range(1, lam * t_u + 1)]
    return {
        "worst_step_bytes": max(b for b, _ in per_step),
        "worst_step_seconds": max(s for _, s in per_step),
        "total_bytes_per_period": sum(b for b, _ in per_step),
        "refresh_steps": sum(1 for b, _ in per_step if b > 0),
    }


def refresh_stagger_report(t_u=40, lam=5, rank=512, stagger_groups=8,
                           measure=True):
    """Worst-step refresh cost, synchronized vs staggered schedule.

    Accounting: per-leaf refresh cost is (a) bytes — the gradient words the
    refresh must stream (fused Eqn-6: one m·n·4 G sweep; Eqn-7 recal: two,
    for G P and Qᵀ G) — and (b) optionally measured wall seconds per leaf at
    the true shapes. A schedule's step cost is the sum over leaves refreshing
    at that step; the worst step is taken over the steady-state window
    ``[1, λ·T_u]`` (step 0 is the one-time Eqn-7 initialization and is
    identical under both schedules by design). Phases come from the real
    ``stagger_phases`` allocator, so this measures the shipped schedule.
    """
    from repro.core.coap_adam import stagger_phases

    sizes = [cnt for _, _, cnt in LLAMA1B_REFRESH_BUCKETS]
    staggered = stagger_phases(sizes, t_u, stagger_groups)
    synchronized = [(0,) * cnt for cnt in sizes]

    # Per-leaf cost per unique canonical shape.
    shape_cost = {}
    for _, (m, n), _cnt in LLAMA1B_REFRESH_BUCKETS:
        if (m, n) in shape_cost:
            continue
        r = min(rank, n)
        row = {
            "eqn6_bytes": float(m * n * 4),
            "recal_bytes": float(2 * m * n * 4),
            "eqn6_s": 0.0,
            "recal_s": 0.0,
        }
        if measure:
            g = jax.random.normal(jax.random.key(0), (m, n))
            p = jax.random.normal(jax.random.key(1), (n, r)) / np.sqrt(r)
            mp = 0.1 * jax.random.normal(jax.random.key(2), (m, r))
            row["eqn6_s"] = time_fn(
                jax.jit(lambda pp, gg, m2: correlation.sgd_update(
                    pp, gg, m2, use_fused=True)),
                p, g, mp, iters=1,
            )
            row["recal_s"] = time_fn(
                jax.jit(recalibrate.lowcost_svd), g, p, iters=1
            )
        shape_cost[(m, n)] = row

    sync = _staggered_schedule_stats(
        LLAMA1B_REFRESH_BUCKETS, shape_cost, synchronized, t_u, lam
    )
    stag = _staggered_schedule_stats(
        LLAMA1B_REFRESH_BUCKETS, shape_cost, staggered, t_u, lam
    )
    assert sync["total_bytes_per_period"] == stag["total_bytes_per_period"], (
        "stagger must not change the total refresh work per period"
    )
    report = {
        "t_update": t_u,
        "lam": lam,
        "rank": rank,
        "stagger_groups": stagger_groups,
        "buckets": [
            {"label": lbl, "canonical_shape": list(shape), "leaves": cnt,
             "phases": list(ph)}
            for (lbl, shape, cnt), ph in zip(
                LLAMA1B_REFRESH_BUCKETS, staggered
            )
        ],
        "synchronized": sync,
        "staggered": stag,
        "worst_step_bytes_ratio": (
            sync["worst_step_bytes"] / stag["worst_step_bytes"]
        ),
        # None (not 0.0) when timing was skipped — 0.0 would read as a
        # wall-time degradation instead of an absent measurement.
        "worst_step_seconds_ratio": (
            sync["worst_step_seconds"] / stag["worst_step_seconds"]
            if stag["worst_step_seconds"] else None
        ),
        "per_shape_leaf_cost": {
            f"{m}x{n}": c for (m, n), c in shape_cost.items()
        },
    }
    return report


def eqn6_fused_vs_unfused(mats, rank, lr=0.1, steps=1):
    """Bytes-accessed comparison for ONE Eqn-6 SGD refresh step.

    ``unfused``: the pre-fusion schedule — ``correlation.loss_and_grad``'s
    einsum chain plus the P update as separately-jitted dispatches, each a
    real HBM materialization boundary; summed XLA ``cost_analysis`` bytes
    (same methodology as the q8 section above).

    ``fused``: operand+result bytes of the single ``kernels/eqn6.py``
    dispatch — G, P, M_proj in; new-P, grad, val out — plus, conservatively,
    the kernel's internal G re-stream for multi-step SGD ((steps−1)·m·n
    words; P and every accumulator stay VMEM-resident across the grid).

    ``g_bytes_*`` isolates the m×n traffic the tentpole targets: the number
    of (m, n)-sized tensor reads+writes each schedule performs, in bytes.
    The unfused chain touches G (or an m×n intermediate: Ĝ, M̂, D) 11 times
    per step; the fused kernel streams G exactly once per step — and half
    that again in bytes when G is bf16.
    """
    from repro.core.correlation import _EPS

    out = {}
    for (m, n), _count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        g = jnp.zeros((mm, nn))
        p = jnp.zeros((nn, r))
        mp = jnp.zeros((mm, r))
        gp = jnp.zeros((mm, r))
        ghat = jnp.zeros((mm, nn))
        mhat = jnp.zeros((mm, nn))
        d = jnp.zeros((mm, nn))
        ptp = jnp.zeros((r, r))
        nr = jnp.zeros((nn, r))
        sc = jnp.zeros(())

        stages = [
            ("project", lambda g_, p_: jnp.einsum("mn,nr->mr", g_, p_),
             (g, p)),
            ("reconstruct", lambda gp_, p_: jnp.einsum("mr,nr->mn", gp_, p_),
             (gp, p)),
            ("mse_val", lambda gh_, g_: jnp.mean(jnp.square(gh_ - g_)),
             (ghat, g)),
            ("t1", lambda p_, gp_: jnp.einsum("nr,mr,ms->ns", p_, gp_, gp_),
             (p, gp)),
            ("t2", lambda g_, gp_: jnp.einsum("mn,mr->nr", g_, gp_),
             (g, gp)),
            ("ptp", lambda p_: jnp.einsum("nr,nk->rk", p_, p_), (p,)),
            ("gp_ptp", lambda gp_, pt_: jnp.einsum("mr,rk->mk", gp_, pt_),
             (gp, ptp)),
            ("t3", lambda g_, x_: jnp.einsum("mn,mk->nk", g_, x_),
             (g, gp)),
            ("m_hat", lambda mp_, p_: jnp.einsum("mr,nr->mn", mp_, p_),
             (mp, p)),
            ("cos_d", lambda mh_, g_: (
                (g_ / (jnp.linalg.norm(mh_, axis=-1, keepdims=True)
                       * jnp.linalg.norm(g_, axis=-1, keepdims=True) + _EPS)
                 - mh_ * jnp.sum(mh_ * g_, axis=-1, keepdims=True)
                 / (jnp.linalg.norm(mh_, axis=-1, keepdims=True) ** 3
                    * jnp.linalg.norm(g_, axis=-1, keepdims=True) + _EPS))
                / mh_.shape[-2]
            ), (mhat, g)),
            ("cos_grad", lambda d_, mp_: jnp.einsum("mn,mr->nr", d_, mp_),
             (d, mp)),
            ("combine_update",
             lambda p_, a_, b_, c_, gc_, vm_, vc_: p_ - lr * (
                 (2.0 / (mm * nn)) * (a_ - 2.0 * b_ + c_) * (1.0 - vc_)
                 - gc_ * vm_
             ), (p, nr, nr, nr, nr, sc, sc)),
        ]
        unfused_cost = {
            name: _bytes_accessed(fn, *args) for name, fn, args in stages
        }
        unfused_bytes = float(steps) * sum(unfused_cost.values())

        # fused single-dispatch I/O + conservative multi-step G re-stream
        p_new, grad, val = p, nr, sc
        fused_io = _nbytes(g, p, mp) + _nbytes(p_new, grad, val)
        g_restream = (steps - 1) * float(mm * nn * 4)
        fused_bytes = fused_io + g_restream

        g_bytes_unfused = 11.0 * mm * nn * 4 * steps
        g_bytes_fused = float(mm * nn * 4 * steps)
        g_bytes_fused_bf16 = float(mm * nn * 2 * steps)

        out[f"{mm}x{nn}"] = {
            "rank": int(r),
            "steps": int(steps),
            "unfused_bytes": unfused_bytes,
            "unfused_per_stage": unfused_cost,
            "fused_io_bytes": fused_io,
            "fused_bytes_conservative": fused_bytes,
            "ratio": unfused_bytes / fused_io,
            "ratio_conservative": unfused_bytes / fused_bytes,
            "g_bytes_unfused": g_bytes_unfused,
            "g_bytes_fused": g_bytes_fused,
            "g_bytes_fused_bf16": g_bytes_fused_bf16,
            "g_stream_ratio": g_bytes_unfused / g_bytes_fused,
            "launches_unfused": len(stages),
            "launches_fused": 1,
        }
    return out


def run_refresh(csv: Csv, fast: bool = False):
    """Refresh-cost section: staggered-vs-synchronized worst step + fused
    Eqn-6 traffic. Writes ``BENCH_refresh.json`` next to the repo root."""
    rank, t_u, lam = 512, 40, 5  # paper's LLaMA-1B recipe
    print("# refresh cost (LLaMA-1B shapes)")
    stag = refresh_stagger_report(
        t_u=t_u, lam=lam, rank=rank, measure=not fast
    )
    rb = stag["worst_step_bytes_ratio"]
    rs = stag["worst_step_seconds_ratio"]
    rs_str = f"{rs:.1f}x" if rs is not None else "n/a"
    csv.add("refresh/stagger_worst_step", 0.0,
            f"bytes_ratio={rb:.1f}x;seconds_ratio={rs_str}")
    print(
        f"  worst-step refresh: sync "
        f"{stag['synchronized']['worst_step_bytes']/1e6:9.1f} MB -> "
        f"staggered {stag['staggered']['worst_step_bytes']/1e6:9.1f} MB "
        f"({rb:.1f}x better; wall-time ratio {rs_str})"
    )

    mats = LLAMA1B_MATS[:1] if fast else LLAMA1B_MATS
    eqn6 = eqn6_fused_vs_unfused(mats, rank)
    for label, row in eqn6.items():
        csv.add(
            f"refresh/eqn6_fused_vs_unfused/{label}", 0.0,
            f"ratio={row['ratio']:.2f}x;g_stream={row['g_stream_ratio']:.1f}x"
            f";launches={row['launches_unfused']}->{row['launches_fused']}",
        )
        print(
            f"  eqn6 {label:12s} unfused {row['unfused_bytes']/1e6:8.1f} MB "
            f"({row['launches_unfused']} launches) -> fused "
            f"{row['fused_io_bytes']/1e6:8.1f} MB (1 launch): "
            f"{row['ratio']:.2f}x total, {row['g_stream_ratio']:.1f}x on "
            f"G-sized streams"
        )
    report = {
        "stagger": stag,
        "eqn6": eqn6,
        "eqn6_g_stream_ratio_min": min(
            r_["g_stream_ratio"] for r_ in eqn6.values()
        ),
        "eqn6_ratio_min": min(r_["ratio"] for r_ in eqn6.values()),
        "method": (
            "stagger: per-leaf refresh cost = streamed-G bytes (fused Eqn-6 "
            "one sweep, Eqn-7 recal two) and optionally measured per-leaf "
            "wall seconds; worst step over the steady-state lam*T_u window, "
            "phases from the shipped stagger_phases allocator. eqn6: "
            "unfused = sum of XLA cost_analysis 'bytes accessed' over the "
            "12 separately-dispatched stages of the pre-fusion refresh "
            "(loss_and_grad einsum chain + P update); fused = operand+"
            "result bytes of the single eqn6 kernel dispatch plus the "
            "conservative (steps-1) G re-stream."
        ),
    }
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_refresh.json",
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(
        f"  wrote {out_path} (stagger {rb:.1f}x, eqn6 G-stream "
        f"{report['eqn6_g_stream_ratio_min']:.1f}x)"
    )


# ---------------------------------------------------------------------------
# Conv/Tucker-2 bucketing section (BENCH_conv.json)
# ---------------------------------------------------------------------------
# Conv-heavy reference tree (the vision/multimodal settings of paper §4.2):
# three congruent conv buckets of a ConvNeXt/U-Net-scale tower, exactly as
# ``scale_by_projected_adam`` buckets them under stacked-bucket/v2:
# (label, (O, I, K1, K2), leaf count).
CONV_REFRESH_BUCKETS = [
    ("stage2_3x3", (256, 256, 3, 3), 8),
    ("stage3_3x3", (512, 512, 3, 3), 16),
    ("stage4_3x3", (1024, 1024, 3, 3), 4),
]


def conv_refresh_report(t_u=40, lam=5, stagger_groups=8, rank_ratio=4.0,
                        measure=True):
    """Worst-step Tucker-2 refresh cost + launch count: conv bucketed vs
    per-leaf.

    The v1 conv path was a per-leaf Python loop with a SYNCHRONIZED
    refresh: every ``T_u`` steps every conv leaf pays both factor
    refreshes at once (the stall PR 2 removed for matrices), and every
    step dispatches one update per leaf. The v2 path buckets congruent
    conv leaves and joins them to the staggered schedule: one launch per
    bucket per step, and on a refresh step only the matching phase group's
    slice recomputes its factors.

    Accounting mirrors ``refresh_stagger_report``: a factor refresh must
    stream the leaf's gradient — Eqn-6 sweeps each mode's canonical
    unfolding once (2·numel·4 bytes per leaf for both factors), Eqn-7
    recalibration twice per mode (4·numel·4) — and the worst step is taken
    over the steady-state ``[1, λ·T_u]`` window with phases from the
    shipped ``stagger_phases`` allocator. Optionally measures per-leaf
    wall seconds of both refresh kinds at the true canonical shapes.
    """
    import math

    from repro.core import conv as conv_mod
    from repro.core.coap_adam import stagger_phases

    sizes = [cnt for _, _, cnt in CONV_REFRESH_BUCKETS]
    staggered = stagger_phases(sizes, t_u, stagger_groups)
    synchronized = [(0,) * cnt for cnt in sizes]

    shape_cost = {}
    for _, shp, _cnt in CONV_REFRESH_BUCKETS:
        if shp in shape_cost:
            continue
        o, i, k1, k2 = shp
        numel = o * i * k1 * k2
        row = {
            "eqn6_bytes": float(2 * numel * 4),  # one G sweep per mode
            "recal_bytes": float(4 * numel * 4),  # two sweeps per mode
            "eqn6_s": 0.0,
            "recal_s": 0.0,
        }
        if measure:
            ro = max(1, int(o / math.sqrt(rank_ratio)))
            ri = max(1, int(i / math.sqrt(rank_ratio)))
            g = jax.random.normal(jax.random.key(0), shp)
            p_o = jax.random.normal(jax.random.key(1), (o, ro)) / np.sqrt(ro)
            p_i = jax.random.normal(jax.random.key(2), (i, ri)) / np.sqrt(ri)
            g1 = conv_mod.mode1_canonical(g)
            g2 = conv_mod.mode2_canonical(g)
            m1 = 0.1 * jax.random.normal(jax.random.key(3), (g1.shape[0], ro))
            m2 = 0.1 * jax.random.normal(jax.random.key(4), (g2.shape[0], ri))
            eqn6_fn = jax.jit(
                lambda po, pi, a, b2_, ma, mb: (
                    correlation.sgd_update(po, a, ma),
                    correlation.sgd_update(pi, b2_, mb),
                )
            )
            row["eqn6_s"] = time_fn(eqn6_fn, p_o, p_i, g1, g2, m1, m2,
                                    iters=1)
            recal_fn = jax.jit(
                lambda a, b2_, po, pi: (
                    recalibrate.lowcost_svd(a, po),
                    recalibrate.lowcost_svd(b2_, pi),
                )
            )
            row["recal_s"] = time_fn(recal_fn, g1, g2, p_o, p_i, iters=1)
        shape_cost[shp] = row

    sync = _staggered_schedule_stats(
        CONV_REFRESH_BUCKETS, shape_cost, synchronized, t_u, lam
    )
    stag = _staggered_schedule_stats(
        CONV_REFRESH_BUCKETS, shape_cost, staggered, t_u, lam
    )
    assert sync["total_bytes_per_period"] == stag["total_bytes_per_period"], (
        "stagger must not change the total refresh work per period"
    )
    n_leaves = sum(sizes)
    report = {
        "t_update": t_u,
        "lam": lam,
        "rank_ratio": rank_ratio,
        "stagger_groups": stagger_groups,
        "buckets": [
            {"label": lbl, "shape": list(shp), "leaves": cnt,
             "phases": list(ph)}
            for (lbl, shp, cnt), ph in zip(CONV_REFRESH_BUCKETS, staggered)
        ],
        "synchronized_per_leaf": sync,
        "staggered_bucketed": stag,
        "worst_step_bytes_ratio": (
            sync["worst_step_bytes"] / stag["worst_step_bytes"]
        ),
        "worst_step_seconds_ratio": (
            sync["worst_step_seconds"] / stag["worst_step_seconds"]
            if stag["worst_step_seconds"] else None
        ),
        # Per-step update dispatches: the per-leaf loop launches one
        # Algorithm-3 update per conv leaf; the bucketed path launches one
        # per congruence bucket.
        "launches_per_step_per_leaf": n_leaves,
        "launches_per_step_bucketed": len(CONV_REFRESH_BUCKETS),
        "per_shape_leaf_cost": {
            f"{o}x{i}x{k1}x{k2}": c
            for (o, i, k1, k2), c in shape_cost.items()
        },
    }
    return report


def run_conv(csv: Csv, fast: bool = False):
    """Conv/Tucker-2 bucketing section; writes ``BENCH_conv.json``."""
    print("# conv/Tucker-2 refresh (conv-heavy tree, bucketed vs per-leaf)")
    rep = conv_refresh_report(measure=not fast)
    rb = rep["worst_step_bytes_ratio"]
    rs = rep["worst_step_seconds_ratio"]
    rs_str = f"{rs:.1f}x" if rs is not None else "n/a"
    csv.add(
        "conv/stagger_worst_step", 0.0,
        f"bytes_ratio={rb:.1f}x;seconds_ratio={rs_str};launches="
        f"{rep['launches_per_step_per_leaf']}->"
        f"{rep['launches_per_step_bucketed']}",
    )
    print(
        f"  worst-step conv refresh: per-leaf sync "
        f"{rep['synchronized_per_leaf']['worst_step_bytes']/1e6:9.1f} MB -> "
        f"bucketed staggered "
        f"{rep['staggered_bucketed']['worst_step_bytes']/1e6:9.1f} MB "
        f"({rb:.1f}x better; wall-time ratio {rs_str})"
    )
    print(
        f"  per-step update launches: {rep['launches_per_step_per_leaf']} "
        f"(per-leaf loop) -> {rep['launches_per_step_bucketed']} "
        f"(one per bucket)"
    )
    report = {
        "conv_refresh": rep,
        "method": (
            "per-leaf refresh cost = gradient bytes both Tucker factor "
            "refreshes must stream (Eqn-6: one canonical-unfolding sweep "
            "per mode = 2*numel*4 B; Eqn-7 recal: two per mode) and "
            "optionally measured per-leaf wall seconds at the true "
            "canonical shapes; worst step over the steady-state lam*T_u "
            "window, phases from the shipped stagger_phases allocator over "
            "the conv buckets. launch counts: per-leaf Algorithm-3 loop = "
            "one update dispatch per conv leaf per step; bucketed = one "
            "per (shape, spec, dtype) bucket."
        ),
    }
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_conv.json",
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"  wrote {out_path} (worst-step bytes ratio {rb:.1f}x)")


# ---------------------------------------------------------------------------
# Stacked-state traffic section (BENCH_state.json)
# ---------------------------------------------------------------------------
def _proj_state_bytes(m, n, r, quantize, block=kref.QUANT_BLOCK):
    """Per-leaf ProjLeaf state bytes (p, m, v, scales) at canonical shape."""
    p = n * r * 4
    if quantize:
        mv = 2 * m * r * 1
        scales = 2 * m * kref.rowblock_nblocks(r, block) * 4
    else:
        mv = 2 * m * r * 4
        scales = 2 * 4  # (1,) fp32 placeholders
    return p + mv + scales


def state_traffic_report(rank=512, quantize=True, block=kref.QUANT_BLOCK):
    """Per-step optimizer-STATE bytes moved: per-leaf vs pre-stacked layout.

    Accounting (state arrays only — gradient stacking and update scatter
    are identical in both modes and excluded):

      * ``per_leaf``: every step the bucket boundary stacks the state in
        (read each per-leaf array + write the stacked copy = 2·S) and
        scatters the new state out (another 2·S), around the kernel's own
        read S + write S — 6·S total per bucket of state bytes S.
      * ``stacked``: the kernel reads and writes the pre-stacked arrays in
        place — 2·S, no boundary copies.

    The LLaMA-1B bucket structure is the same one the refresh benchmark
    uses (``LLAMA1B_REFRESH_BUCKETS``: how ``scale_by_projected_adam``
    buckets the real 24-layer tree). XLA can fuse *some* fp32 copies into
    kernel operands but never the int8 state round-trip, so this is exact
    for the quantized states the paper ships and conservative-in-reverse
    for fp32 (the measured section reports what XLA actually does on a
    small tree).
    """
    rows = {}
    tot_perleaf = tot_stacked = tot_state = 0.0
    for label, (m, n), cnt in LLAMA1B_REFRESH_BUCKETS:
        r = min(rank, n)
        s_leaf = _proj_state_bytes(m, n, r, quantize, block)
        s_bucket = float(cnt * s_leaf)
        per_leaf = 6.0 * s_bucket
        stacked = 2.0 * s_bucket
        rows[label] = {
            "canonical_shape": [m, n],
            "leaves": cnt,
            "rank": int(r),
            "state_bytes": s_bucket,
            "per_step_bytes_per_leaf_mode": per_leaf,
            "per_step_bytes_stacked_mode": stacked,
            "copy_bytes_removed_per_step": per_leaf - stacked,
        }
        tot_perleaf += per_leaf
        tot_stacked += stacked
        tot_state += s_bucket
    return {
        "rank": rank,
        "quantize": quantize,
        "buckets": rows,
        "state_bytes_total": tot_state,
        "per_step_bytes_per_leaf_mode": tot_perleaf,
        "per_step_bytes_stacked_mode": tot_stacked,
        "copy_bytes_removed_per_step": tot_perleaf - tot_stacked,
        "ratio": tot_perleaf / tot_stacked,
    }


def measured_state_step_bytes(quantize=True, n_leaves=8, shape=(512, 256),
                              rank=64):
    """XLA cost_analysis 'bytes accessed' of ONE jitted optimizer step on a
    small congruent tree, per storage mode. Whole-step numbers (gradients,
    updates and refresh branches included), so the ratio understates the
    state-only win — reported as ground truth that the copies removed are
    real, not as the gate."""
    import jax

    from repro.core.coap_adam import (
        ProjectedAdamConfig,
        scale_by_projected_adam,
    )
    from repro.core.projector import ProjectionRules

    out = {}
    for stacked in (False, True):
        params = {f"l{i}": {"w": jnp.zeros(shape)} for i in range(n_leaves)}
        cfg = ProjectedAdamConfig(
            rules=ProjectionRules(rank=rank, min_dim=8), quantize=quantize,
            t_update=1000, stagger=False, stacked_state=stacked,
        )
        tx = scale_by_projected_adam(cfg)
        state = tx.init(params)
        flat, treedef = jax.tree_util.tree_flatten(params)
        key = jax.random.key(0)
        g = jax.tree_util.tree_unflatten(
            treedef,
            [
                0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
                for i, p in enumerate(flat)
            ],
        )
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        _, state = step(g, state)  # past the t=0 Eqn-7 init
        ca = step.lower(g, state).compile().cost_analysis()
        d = ca[0] if isinstance(ca, list) else ca
        out["stacked" if stacked else "per_leaf"] = float(d["bytes accessed"])
    out["ratio"] = out["per_leaf"] / out["stacked"]
    out["bytes_removed_per_step"] = out["per_leaf"] - out["stacked"]
    return out


def run_state(csv: Csv, fast: bool = False):
    """Stacked-vs-scatter state traffic; writes ``BENCH_state.json``."""
    print("# stacked-state traffic (LLaMA-1B bucket structure, rank 512)")
    report = {"analytic": {}, "method": (
        "analytic: per-step optimizer-state bytes moved on the LLaMA-1B "
        "bucket structure — per-leaf mode pays stack-in (2S) + kernel "
        "(2S) + scatter-out (2S) per bucket of state bytes S, stacked "
        "mode pays the kernel's 2S only; gradient stacking and update "
        "scatter are identical in both modes and excluded. measured: XLA "
        "cost_analysis 'bytes accessed' of one whole jitted step on a "
        "small congruent tree (includes gradients/updates, so its ratio "
        "understates the state-only win)."
    )}
    for label, quantize in (("int8", True), ("fp32", False)):
        rep = state_traffic_report(quantize=quantize)
        report["analytic"][label] = rep
        csv.add(
            f"state/stacked_vs_scatter/{label}", 0.0,
            f"ratio={rep['ratio']:.2f}x;removed_mb_per_step="
            f"{rep['copy_bytes_removed_per_step']/1e6:.1f}",
        )
        print(
            f"  {label}: per-leaf {rep['per_step_bytes_per_leaf_mode']/1e6:8.1f}"
            f" MB/step -> stacked {rep['per_step_bytes_stacked_mode']/1e6:8.1f}"
            f" MB/step ({rep['ratio']:.2f}x; "
            f"{rep['copy_bytes_removed_per_step']/1e6:.1f} MB copies removed)"
        )
    if not fast:
        meas = {q: measured_state_step_bytes(quantize=(q == "int8"))
                for q in ("int8", "fp32")}
        report["measured_small_tree"] = meas
        for label, row in meas.items():
            csv.add(
                f"state/measured_step_bytes/{label}", 0.0,
                f"ratio={row['ratio']:.2f}x;removed_mb="
                f"{row['bytes_removed_per_step']/1e6:.1f}",
            )
            print(
                f"  measured ({label}, whole step, small tree): "
                f"{row['per_leaf']/1e6:.1f} -> {row['stacked']/1e6:.1f} MB "
                f"({row['ratio']:.2f}x)"
            )
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_state.json",
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"  wrote {out_path} (analytic int8 ratio "
          f"{report['analytic']['int8']['ratio']:.2f}x)")


# ---------------------------------------------------------------------------
# Memory-plan section (BENCH_plan.json)
# ---------------------------------------------------------------------------
def plan_report(fast: bool = False):
    """The paper's headline memory vectors as PLANNED artifacts.

    Plans LLaMA-1B twice — fp32 under the 40 GB reference budget (Table 5's
    −61% setting) and full 8-bit (the −81% setting) — records predicted
    state bytes, the AdamW baseline from ``accounting``, both reduction
    ratios, and (unless ``fast``) cross-checks the predictions against
    ``accounting.abstract_state_bytes`` of the constructed optimizers
    (must match exactly). ``tests/test_plan.py`` gates the ratios at the
    paper's >=61% / >=81%.
    """
    from repro import plan as plan_mod

    params = None
    if not fast:
        from repro.configs import get_config
        from repro.models.model import build_model

        params = build_model(get_config("llama-1b")).abstract_params()

    out = {}
    for label, kw in (
        ("fp32", dict(budget_bytes=int(40e9))),
        ("q8", dict(budget_bytes=None, quantize="force")),
    ):
        budget = kw.pop("budget_bytes")
        plan = plan_mod.plan_for_arch("llama-1b", budget, **kw)
        p = plan.predicted
        row = {
            "budget_bytes": plan.budget_bytes,
            "state_bytes": p["state_bytes_total"],
            "baseline_adamw_bytes": p["baseline"]["state_bytes_total"],
            "reduction_vs_adamw": p["reduction_vs_adamw"],
            "reduction_vs_adamw_total": p["reduction_vs_adamw_total"],
            "n_quantized_buckets": p["n_quantized_buckets"],
            "n_buckets": len(plan.buckets),
            "predicted_step_seconds": plan.cost["step_seconds"],
            "buckets": [
                {"kind": b.kind, "shape": list(b.shape), "count": b.count,
                 "rank": (
                     b.spec.rank if b.kind == "project"
                     else [b.spec.rank_o, b.spec.rank_i]
                     if b.kind == "conv" else "dense"
                 ),
                 "quantize": b.quantize,
                 "bytes": b.predicted_bytes_total,
                 "eqn6_fused": b.eqn6_fused}
                for b in plan.buckets
            ],
        }
        if params is not None:
            # raise_on_mismatch=False: a drifted byte model must still
            # produce the labeled MISMATCH row + json, not a traceback.
            vrep = plan_mod.verify(plan, params, raise_on_mismatch=False)
            row["accounted_state_bytes"] = vrep["accounted_total"]
            row["exact_match"] = vrep["match"]
        out[label] = row
    return out


def run_plan(csv: Csv, fast: bool = False):
    """Planner memory vectors; writes ``BENCH_plan.json``."""
    print("# memory plan (LLaMA-1B paper vectors, planned)")
    rep = plan_report(fast=fast)
    for label, row in rep.items():
        gate = 0.61 if label == "fp32" else 0.81
        verified = row.get("exact_match")
        v_str = {True: "exact", False: "MISMATCH", None: "unverified"}[
            verified
        ]
        csv.add(
            f"plan/llama1b_{label}", 0.0,
            f"reduction={row['reduction_vs_adamw']:.3f};gate>={gate};"
            f"bytes={v_str}",
        )
        print(
            f"  {label}: state {row['state_bytes']/1e9:.2f} GB vs AdamW "
            f"{row['baseline_adamw_bytes']/1e9:.2f} GB -> "
            f"-{row['reduction_vs_adamw']:.1%} moment-state "
            f"(-{row['reduction_vs_adamw_total']:.1%} total; paper gate "
            f">={gate:.0%}; bytes {v_str})"
        )
    report = {
        "llama1b": rep,
        "gates": {"fp32": 0.61, "q8": 0.81},
        "method": (
            "planner (repro/plan) vectors for the paper's LLaMA-1B "
            "settings: reduction_vs_adamw divides moment state (+ int8 "
            "sidecar) by the AdamW moment bytes — the paper's denominator, "
            "projector P excluded from both sides (accounting."
            "CATEGORY_GROUPS); reduction_vs_adamw_total includes P. "
            "exact_match = predicted by-category bytes equal accounting."
            "abstract_state_bytes of the optimizer the plan constructs."
        ),
    }
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_plan.json",
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(
        f"  wrote {out_path} (fp32 -{rep['fp32']['reduction_vs_adamw']:.1%}"
        f", q8 -{rep['q8']['reduction_vs_adamw']:.1%})"
    )


def run(csv: Csv, fast: bool = False):
    rank = 512
    t_u, lam = 40, 5  # paper's LLaMA-1B recipe
    step_s = analytic_step_seconds(LLAMA1B_N, LLAMA1B_TOKENS)
    print(f"# overhead (LLaMA-1B shapes; analytic step {step_s*1e3:.0f} ms "
          f"@8xH100 40% MFU)")

    costs = {
        "galore_svd": _p_update_cost(LLAMA1B_MATS, rank, "galore"),
        "coap_recal": _p_update_cost(LLAMA1B_MATS, rank, "coap_recal"),
        "coap_eqn6": _p_update_cost(LLAMA1B_MATS, rank, "coap_eqn6"),
        "flora_random": _p_update_cost(LLAMA1B_MATS, rank, "flora"),
    }
    proj_step = _per_step_projection_cost(LLAMA1B_MATS, rank)

    # CPU->accelerator scaling: P updates are dense linalg; scale measured
    # CPU time by the same factor for all strategies (ratios exact, levels
    # approximate). Factor = measured CPU matmul rate vs A100 ~ measured
    # below via one reference matmul.
    a = jax.random.normal(jax.random.key(0), (2048, 2048))
    t_mm = time_fn(jax.jit(lambda x: x @ x), a, iters=3)
    cpu_flops = 2 * 2048**3 / t_mm
    scale = cpu_flops / 150e12  # vs ~150 TF/s effective dense linalg on A100

    # amortized per-step seconds (accelerator-scaled)
    rows = {
        "galore(+SVD/T_u)": costs["galore_svd"] / t_u * scale,
        "coap(eqn6/T_u + recal/λT_u)": (
            costs["coap_eqn6"] / t_u + costs["coap_recal"] / (lam * t_u)
        ) * scale,
        "flora(resample each step)": costs["flora_random"] * scale,
    }
    for label, s in rows.items():
        overhead = s / step_s
        csv.add(f"overhead/{label}", s * 1e6,
                f"overhead_vs_step={overhead:+.1%}")
        print(f"  {label:34s} {s*1e3:8.2f} ms/step  ({overhead:+.1%} of step)"
              )
    ratio = costs["galore_svd"] / costs["coap_recal"]
    csv.add("overhead/fullsvd_vs_lowcost_ratio", 0.0,
            f"ratio={ratio:.1f}x;paper_claim=20x+")
    print(f"  full-SVD vs low-cost-SVD ratio: {ratio:.1f}x (paper: >20x)")
    csv.add("overhead/per_step_projection", proj_step * scale * 1e6,
            f"fused_update_all_mats_cpu_s={proj_step:.3f}")

    # --- fused vs unfused 8-bit step: bytes accessed + launch counts ------
    # (fast: one shape is enough signal; the full sweep jits 8 stages each)
    q8_mats = LLAMA1B_MATS[:1] if fast else LLAMA1B_MATS
    q8 = quantized_fused_vs_unfused(q8_mats, rank)
    for label, row in q8.items():
        csv.add(
            f"overhead/q8_fused_vs_unfused/{label}", 0.0,
            f"ratio={row['ratio']:.2f}x;conservative="
            f"{row['ratio_conservative']:.2f}x;launches="
            f"{row['launches_unfused']}->{row['launches_fused']}",
        )
        print(
            f"  q8 {label:12s} unfused {row['unfused_bytes']/1e6:8.1f} MB "
            f"({row['launches_unfused']} launches) -> fused "
            f"{row['fused_io_bytes']/1e6:8.1f} MB (1 launch): "
            f"{row['ratio']:.2f}x ({row['ratio_conservative']:.2f}x incl. "
            f"P re-stream)"
        )
    report = {
        "llama1b_rank": rank,
        "shapes": q8,
        "ratio_min": min(r_["ratio"] for r_ in q8.values()),
        "ratio_min_conservative": min(
            r_["ratio_conservative"] for r_ in q8.values()
        ),
        "method": (
            "unfused = sum of XLA cost_analysis 'bytes accessed' over the 8 "
            "separately-dispatched stages of the unfused 8-bit schedule; "
            "fused = operand+result bytes of the single fused-q8 kernel "
            "dispatch (custom-call cost_analysis semantics), with the "
            "kernel's internal P re-stream added in the conservative "
            "variant."
        ),
    }
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_overhead.json",
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"  wrote {out_path} (min ratio {report['ratio_min']:.2f}x)")


# ---------------------------------------------------------------------------
# Elastic resume-latency section (BENCH_elastic.json)
# ---------------------------------------------------------------------------
def run_elastic(csv: Csv, fast: bool = False):
    """Resume-latency breakdown for the elastic supervisor's 8→4 shrink
    scenario (train/elastic.py): restore the newest checkpoint, MIGRATE
    its optimizer state into the replanned (quantizing) layout
    (stacked_state.migrate), and recompile the train step under the new
    plan — each phase timed cold, the way a real preempted resume pays
    it. Writes ``BENCH_elastic.json``.
    """
    import shutil
    import tempfile
    import time as _time

    from repro.configs import get_smoke
    from repro.core.api import OptimizerConfig
    from repro.data.synthetic import SyntheticLM
    from repro.models.model import build_model
    from repro.plan.solver import solve_for_topology
    from repro.train import checkpoint as ckpt_mod
    from repro.train.elastic import (
        ElasticConfig, ElasticSupervisor, Topology, migrate_opt_state,
    )
    from repro.train.step import make_train_step

    print("# elastic resume latency (8→4 shrink: restore/migrate/recompile)")
    kw = dict(min_dim=16, t_update=4, lam=2, stagger_groups=2)
    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    params_abs = model.abstract_params()
    h32 = solve_for_topology(params_abs, 1, 10**12, quantize="off",
                             **kw).predicted["hbm_total_bytes"]
    h8 = solve_for_topology(params_abs, 1, 10**12, quantize="force",
                            **kw).predicted["hbm_total_bytes"]
    per_dev = (h32 + h8) // 2 // 4  # 8 devs fit fp32; 4 devs force int8

    data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.2)
    batch_fn = lambda step, host: data.batch(step, batch=4, seq=16, host=host)
    ocfg = OptimizerConfig(name="coap-adamw", learning_rate=1e-3)
    tmp = tempfile.mkdtemp(prefix="bench_elastic_")
    try:
        steps = 4 if fast else 6
        ecfg = ElasticConfig(
            ckpt_dir=os.path.join(tmp, "ckpt"), total_steps=steps,
            topology=(Topology(8, per_dev),), solve_kw=kw,
            ckpt_every=2, log_every=100,
        )
        sup = ElasticSupervisor(model, batch_fn, ecfg, ocfg=ocfg)
        sup.run()

        plan8 = sup.plan_for(Topology(8, per_dev))
        plan4 = solve_for_topology(params_abs, 4, per_dev, **kw)
        tx8 = sup._tx_for(plan8)
        tx4 = sup._tx_for(plan4)

        t0 = _time.perf_counter()
        state8 = ckpt_mod.restore(ecfg.ckpt_dir, sup._template(tx8))
        restore_s = _time.perf_counter() - t0

        t0 = _time.perf_counter()
        opt4 = migrate_opt_state(
            state8.opt_state, plan8, plan4, params_abs, ocfg
        )
        opt4 = jax.tree_util.tree_map(jnp.asarray, opt4)
        jax.block_until_ready(jax.tree_util.tree_leaves(opt4))
        migrate_s = _time.perf_counter() - t0
        state4 = state8._replace(opt_state=opt4)

        batch = batch_fn(steps, 0)
        t0 = _time.perf_counter()
        jax.jit(make_train_step(model, tx4, donate=False)).lower(
            state4, batch
        ).compile()
        recompile_s = _time.perf_counter() - t0

        # Drained vs reactive preemption: steps LOST at an injected
        # preemption. A notice-honoring drain checkpoints at its exact
        # stop step (zero lost); a no-warning kill rolls back to the
        # last periodic checkpoint (up to ckpt_every lost).
        from repro.train.faults import FaultInjector, FaultSchedule

        steps2 = 8 if fast else 12
        fault_step = steps2 - 3

        def lost_steps(schedule, sub):
            ecfg2 = ElasticConfig(
                ckpt_dir=os.path.join(tmp, sub), total_steps=steps2,
                topology=(Topology(8, per_dev),), solve_kw=kw,
                ckpt_every=2, log_every=100,
            )
            sup2 = ElasticSupervisor(
                model, batch_fn, ecfg2, ocfg=ocfg,
                fault_injector=FaultInjector(schedule, seed=0),
            )
            sup2.run()
            resumes = [e for e in sup2.events if e[0] == "resume"]
            return fault_step - int(resumes[-1][2])

        drain_lost = lost_steps(
            FaultSchedule(notice_at=((fault_step, 30.0),)), "drain"
        )
        reactive_lost = lost_steps(
            FaultSchedule(kill_at=(fault_step,)), "reactive"
        )
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    total = restore_s + migrate_s + recompile_s
    report = {
        "scenario": {
            "arch": "tinyllama-1.1b (smoke)",
            "shrink": "8 -> 4 devices, same per-device HBM",
            "hbm_per_device": int(per_dev),
            "src_quantized_buckets": sum(b.quantize for b in plan8.buckets),
            "dst_quantized_buckets": sum(b.quantize for b in plan4.buckets),
            "n_buckets": len(plan4.buckets),
        },
        "restore_s": restore_s,
        "migrate_s": migrate_s,
        "recompile_s": recompile_s,
        "total_resume_s": total,
        "preemption": {
            "fault_step": fault_step,
            "ckpt_every": 2,
            "drained_lost_steps": drain_lost,
            "reactive_lost_steps": reactive_lost,
        },
        "method": (
            "cold timings, one pass each (a preempted resume pays every "
            "phase uncached): restore = checkpoint.restore of the newest "
            "ckpt into the source-plan template; migrate = "
            "elastic.migrate_opt_state (stacked_state.migrate: rank "
            "resize + fp32->int8 requant into the 4-device plan's "
            "layout) materialized; recompile = AOT lower+compile of the "
            "train step under the new plan. preemption = resume-step "
            "delta after an injected notice (drained: checkpoint at the "
            "exact stop step) vs an injected no-warning kill (reactive: "
            "roll back to the last periodic checkpoint). The restore/"
            "migrate/recompile split also calibrates the solver's "
            "resume-latency-aware mode (plan/cost.Calibration resume_*)."
        ),
    }
    for k in ("restore_s", "migrate_s", "recompile_s"):
        csv.add(f"elastic/{k[:-2]}", report[k] * 1e6, "resume phase")
        print(f"  {k[:-2]:>9}: {report[k]*1e3:8.1f} ms "
              f"({report[k]/total:5.1%} of resume)")
    csv.add("elastic/drained_lost_steps", drain_lost, "preemption")
    csv.add("elastic/reactive_lost_steps", reactive_lost, "preemption")
    print(f"  preemption at step {fault_step}: drained loses {drain_lost} "
          f"steps, reactive loses {reactive_lost} (ckpt_every=2)")
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_elastic.json",
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"  wrote {out_path} (total resume {total:.2f}s)")


# ---------------------------------------------------------------------------
# Observability overhead section (BENCH_obs.json)
# ---------------------------------------------------------------------------
def run_obs(csv: Csv, fast: bool = False):
    """Span-tracing hot-path overhead; writes ``BENCH_obs.json``.

    The gate is deterministic, not an end-to-end A/B (CPU smoke steps are
    microseconds, so two wall-clock runs differ by scheduler noise larger
    than the effect): measure the per-``span()`` cost directly — disabled
    (the attribute load + truthiness check every untraced run pays) and
    enabled (clock reads + json + locked write + flush) — count the spans
    a traced step actually emits, and require

        spans_per_step * enabled_span_s  <  3% of the measured step time

    with the step time taken from the same traced run's own ``loop/step``
    durations (compile-tagged spans excluded). The disabled cost is also
    gated (< 0.1%): that is the tax EVERY run pays.
    """
    import shutil
    import tempfile
    import time as _time

    from repro.obs.trace import Tracer, read_trace

    print("# observability overhead (span tracing hot path)")
    n_dis = 50_000 if fast else 200_000
    n_en = 2_000 if fast else 10_000

    t_dis = Tracer(None)
    t0 = _time.perf_counter()
    for i in range(n_dis):
        with t_dis.span("loop/step", step=i):
            pass
    disabled_span_s = (_time.perf_counter() - t0) / n_dis

    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    try:
        t_en = Tracer(os.path.join(tmp, "bench.jsonl"), host="bench")
        t0 = _time.perf_counter()
        for i in range(n_en):
            with t_en.span("loop/step", step=i, refresh=[
                {"bucket": 0, "phase": 0, "size": 1, "frac": 0.5,
                 "kind": "eqn6"},
            ]):
                pass
        enabled_span_s = (_time.perf_counter() - t0) / n_en
        t_en.close()

        # A real traced smoke run: how many spans does one step emit, and
        # how long is a step? (ElasticSupervisor + TrainLoop, the same
        # path `make test`'s obs-smoke drives.)
        from repro.configs import get_smoke
        from repro.core.api import OptimizerConfig
        from repro.data.synthetic import SyntheticLM
        from repro.train.elastic import (
            ElasticConfig,
            ElasticSupervisor,
            Topology,
        )

        from repro.models.model import build_model

        steps = 8 if fast else 12
        cfg = get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.2)
        trace_path = os.path.join(tmp, "trace.jsonl")
        sup = ElasticSupervisor(
            model,
            lambda step, host: data.batch(step, batch=4, seq=16, host=host),
            ElasticConfig(
                ckpt_dir=os.path.join(tmp, "run"), total_steps=steps,
                topology=(Topology(1, 10**12),),
                solve_kw=dict(min_dim=16, t_update=4, lam=2,
                              stagger_groups=2),
                ckpt_every=steps, log_every=steps,
                trace_path=trace_path, host_id="bench",
            ),
            ocfg=OptimizerConfig(name="coap-adamw", learning_rate=1e-3),
        )
        sup.run()
        rows = read_trace(trace_path)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    step_rows = [r for r in rows if r["name"] == "loop/step"
                 and not (r.get("attrs") or {}).get("compile")]
    measured_step_s = sum(r["dur"] for r in step_rows) / len(step_rows)
    # Spans written per hot step: everything the loop emits per iteration
    # (loop/step itself + amortized share of per-run spans).
    spans_per_step = len(rows) / max(1, len(step_rows))

    overhead_frac = spans_per_step * enabled_span_s / measured_step_s
    disabled_frac = disabled_span_s / measured_step_s
    gate, disabled_gate = 0.03, 0.001
    print(f"  disabled span: {disabled_span_s*1e9:7.1f} ns/call "
          f"({disabled_frac:.5%} of a {measured_step_s*1e3:.2f} ms step; "
          f"gate <{disabled_gate:.1%})")
    print(f"  enabled span:  {enabled_span_s*1e6:7.2f} us/span x "
          f"{spans_per_step:.2f} spans/step -> {overhead_frac:.3%} of step "
          f"(gate <{gate:.0%})")
    csv.add("obs/disabled_span", disabled_span_s * 1e6,
            f"frac={disabled_frac:.6f}")
    csv.add("obs/enabled_span", enabled_span_s * 1e6,
            f"spans_per_step={spans_per_step:.2f};frac={overhead_frac:.5f}")

    report = {
        "disabled_span_s": disabled_span_s,
        "enabled_span_s": enabled_span_s,
        "spans_per_step": spans_per_step,
        "measured_step_s": measured_step_s,
        "tracing_overhead_frac": overhead_frac,
        "disabled_overhead_frac": disabled_frac,
        "gate_frac": gate,
        "disabled_gate_frac": disabled_gate,
        "gate_pass": bool(overhead_frac < gate
                          and disabled_frac < disabled_gate),
        "n_trace_rows": len(rows),
        "method": (
            "disabled = per-call cost of span() with no path configured "
            "(shared no-op object); enabled = per-span cost including the "
            "refresh-attribution attrs, clock reads, json encode and "
            "locked write+flush; spans_per_step and measured_step_s come "
            "from a real traced ElasticSupervisor smoke run's own "
            "loop/step durations (compile-tagged spans excluded). gate: "
            "spans_per_step * enabled_span_s < 3% of measured_step_s, "
            "and the disabled cost < 0.1% (every run pays that one)."
        ),
    }
    out_path = _write_bench_obs(report)
    print(f"  wrote {out_path} (overhead {overhead_frac:.3%}, "
          f"gate {'PASS' if report['gate_pass'] else 'FAIL'})")
    assert report["gate_pass"], (
        f"tracing overhead gate failed: {overhead_frac:.3%} (enabled) / "
        f"{disabled_frac:.5%} (disabled) vs gates {gate:.0%} / "
        f"{disabled_gate:.1%}"
    )


def _write_bench_obs(update: dict) -> str:
    """Merge ``update`` into ``BENCH_obs.json``: ``run_obs`` owns the
    top-level tracing keys, ``run_health`` owns the ``health`` block —
    either can run first (or alone) without clobbering the other."""
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_obs.json",
    )
    existing = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                existing = json.load(f)
        except (OSError, json.JSONDecodeError):
            existing = {}
    existing.update(update)
    with open(out_path, "w") as f:
        json.dump(existing, f, indent=2, sort_keys=True)
    return out_path


def run_health(csv: Csv, fast: bool = False):
    """Projection-health overhead + the zero-extra-G contract; merges the
    ``health`` block into ``BENCH_obs.json``.

    Two hard claims, both asserted:

      * **<1% of step wall-time at default cadence** — per-call costs of
        the journal writer (what a refresh emit pays host-side) and of
        ``observe_state`` (the sampled int8/EF read) are measured
        directly, then amortized at the shipped cadence (refresh rows at
        the run's own observed rate, samples every
        ``DEFAULT_SAMPLE_EVERY`` steps) against the measured step time of
        a real health-journaled ElasticSupervisor smoke run.
      * **exactly 0 extra HBM round-trips of G outside refresh steps** —
        the refresh emit lives inside the optimizer's existing
        ``lax.cond`` refresh branch, so its journal rows can only appear
        on scheduled refresh steps. A journaled ``t_update=4`` run is
        checked row by row: any refresh row on a non-refresh step would
        be an extra read of G and fails the gate.
    """
    import shutil
    import tempfile
    import time as _time

    import jax
    import jax.numpy as jnp

    from repro.obs import health
    from repro.obs.trace import read_trace

    print("# projection-health overhead (obs/health hot paths)")
    tmp = tempfile.mkdtemp(prefix="bench_health_")
    try:
        # (1) journal-writer per-call cost (one refresh emit's host side).
        n_rec = 2_000 if fast else 10_000
        health.configure(
            os.path.join(tmp, "cost.jsonl"), host="bench", sample_every=1
        )
        mon = health.get_monitor()
        t0 = _time.perf_counter()
        for i in range(n_rec):
            mon.record(i, "project:64x48:float32", "refresh",
                       {"energy": 0.5, "eqn6_residual": 0.1,
                        "subspace_overlap": 0.9, "n_refreshed": 1.0})
        record_call_s = (_time.perf_counter() - t0) / n_rec

        # (2) observe_state per-call cost on a real quantized stacked
        # state (codec stats + one device_get per bucket).
        from repro.core.coap_adam import coap_adamw
        from repro.core.projector import ProjectionRules

        params = {"w": jnp.zeros((64, 48), jnp.float32)}
        opt = coap_adamw(
            learning_rate=1e-3, rules=ProjectionRules(rank=4, min_dim=8),
            t_update=4, stacked_state=True, quantize=True,
        )
        state = opt.init(params)
        g0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 48),
                                     jnp.float32)}
        _, state = opt.update(g0, state, params)
        health.observe_state(state, 0)  # warm the jitted stats fn
        n_obs = 50 if fast else 200
        t0 = _time.perf_counter()
        for i in range(n_obs):
            health.observe_state(state, i)
        observe_call_s = (_time.perf_counter() - t0) / n_obs

        # (3) zero-extra-G contract on a journaled t_update=4 run.
        zpath = os.path.join(tmp, "zero_g.jsonl")
        health.configure(zpath, host="bench", sample_every=1)
        state = opt.init(params)
        key = jax.random.PRNGKey(1)
        n_steps = 12
        for i in range(n_steps):
            key, k = jax.random.split(key)
            _, state = opt.update(
                {"w": jax.random.normal(k, (64, 48), jnp.float32)},
                state, params,
            )
        refresh_steps = sorted({
            r["step"] for r in health.read_health(zpath)
            if r["event"] == "refresh"
        })
        allowed = {s for s in range(n_steps) if s % 4 == 0}
        extra_g = [s for s in refresh_steps if s not in allowed]

        # (4) real health-journaled elastic smoke: measured step time and
        # the observed refresh-row rate at the planned stagger cadence.
        from repro.configs import get_smoke
        from repro.core.api import OptimizerConfig
        from repro.data.synthetic import SyntheticLM
        from repro.models.model import build_model
        from repro.train.elastic import (
            ElasticConfig,
            ElasticSupervisor,
            Topology,
        )

        steps = 8 if fast else 12
        cfg = get_smoke("tinyllama-1.1b")
        model = build_model(cfg)
        data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.2)
        trace_path = os.path.join(tmp, "trace.jsonl")
        hpath = os.path.join(tmp, "health.jsonl")
        sup = ElasticSupervisor(
            model,
            lambda step, host: data.batch(step, batch=4, seq=16, host=host),
            ElasticConfig(
                ckpt_dir=os.path.join(tmp, "run"), total_steps=steps,
                topology=(Topology(1, 10**12),),
                solve_kw=dict(min_dim=16, t_update=4, lam=2,
                              stagger_groups=2),
                ckpt_every=steps, log_every=steps,
                trace_path=trace_path, health_path=hpath,
                health_every=1, host_id="bench",
            ),
            ocfg=OptimizerConfig(name="coap-adamw", learning_rate=1e-3),
        )
        sup.run()
        trace_rows = read_trace(trace_path)
        hrows = health.read_health(hpath)
    finally:
        health.configure(None)
        from repro.obs.trace import configure as _tc

        _tc(None)
        shutil.rmtree(tmp, ignore_errors=True)

    step_rows = [r for r in trace_rows if r["name"] == "loop/step"
                 and not (r.get("attrs") or {}).get("compile")]
    measured_step_s = sum(r["dur"] for r in step_rows) / len(step_rows)
    refresh_rows_per_step = (
        sum(1 for r in hrows if r["event"] == "refresh") / steps
    )
    # Amortized per-step health cost at the SHIPPED cadence: refresh rows
    # at the run's own rate (they ride the existing refresh branch), one
    # observe_state every DEFAULT_SAMPLE_EVERY steps.
    per_step_s = (
        refresh_rows_per_step * record_call_s
        + observe_call_s / health.DEFAULT_SAMPLE_EVERY
    )
    overhead_frac = per_step_s / measured_step_s
    gate = 0.01
    gate_pass = bool(overhead_frac < gate and not extra_g)
    print(f"  record():        {record_call_s*1e6:7.2f} us/row x "
          f"{refresh_rows_per_step:.2f} refresh rows/step")
    print(f"  observe_state(): {observe_call_s*1e6:7.2f} us/call / "
          f"{health.DEFAULT_SAMPLE_EVERY} steps")
    print(f"  -> {overhead_frac:.4%} of a {measured_step_s*1e3:.2f} ms "
          f"step (gate <{gate:.0%})")
    print(f"  refresh rows on steps {refresh_steps} (t_update=4): "
          f"{len(extra_g)} outside the schedule")
    csv.add("health/record", record_call_s * 1e6,
            f"refresh_rows_per_step={refresh_rows_per_step:.3f}")
    csv.add("health/observe_state", observe_call_s * 1e6,
            f"frac={overhead_frac:.6f}")

    hreport = {
        "record_call_s": record_call_s,
        "observe_state_call_s": observe_call_s,
        "measured_step_s": measured_step_s,
        "refresh_rows_per_step": refresh_rows_per_step,
        "sample_every": health.DEFAULT_SAMPLE_EVERY,
        "overhead_frac": overhead_frac,
        "gate_frac": gate,
        "extra_g_roundtrips_outside_refresh": len(extra_g),
        "n_journal_rows": len(hrows),
        "gate_pass": gate_pass,
        "method": (
            "record() and observe_state() per-call costs measured "
            "directly, amortized at the default cadence (refresh rows at "
            "the smoke run's observed rate, observe_state every "
            "sample_every steps) against the health-journaled "
            "ElasticSupervisor smoke run's own loop/step durations "
            "(compile excluded). extra_g counts refresh journal rows on "
            "steps the t_update=4 schedule does not refresh — each would "
            "be an extra HBM round-trip of G; the contract is exactly 0."
        ),
    }
    out_path = _write_bench_obs({"health": hreport})
    print(f"  wrote {out_path} health block "
          f"(gate {'PASS' if gate_pass else 'FAIL'})")
    assert gate_pass, (
        f"health gate failed: overhead {overhead_frac:.4%} (gate "
        f"<{gate:.0%}), extra G reads outside refresh: {extra_g}"
    )


# ---------------------------------------------------------------------------
# Cross-pod compressed-sync wire section (BENCH_sync.json)
# ---------------------------------------------------------------------------
def sync_report(rank=512, t_update=40, quant_block=kref.QUANT_BLOCK):
    """Cross-pod bytes/step wire model of ``distributed/compression.py`` on
    the LLaMA-1B bucket structure, three ways:

      * ``full_fp32``        — the baseline all-reduce: every step ships
        the full fp32 gradient (numel·4 B per matrix);
      * ``compressed_fp32``  — r-rank fp32 sync: G_proj (m·r·4 B) every
        step + the full fp32 gradient on refresh steps, amortized as
        numel·4/T_u (steady-state average over the refresh interval);
      * ``compressed_int8``  — the ``sync_codes=True`` collective: int8
        codes (m·r·1 B) + one fp32 scale per ``quant_block`` elements
        (the pmax'd block absmaxes) every step, same amortized fp32
        refresh term (the rare full-G exchange stays fp32 by design).

    The EF accumulator is resident state ('ef_sidecar' in the byte
    tables), NOT wire traffic: real hardware keeps the rounding residual
    pod-local. Ratios are per-link, steady-state averages; bucket entries
    expose the per-(shape, multiplicity) decomposition.
    """
    import math

    buckets = []
    tot_full = tot_fp32 = tot_int8 = 0.0
    for (m, n), count in LLAMA1B_MATS:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        numel = m * n
        proj = mm * r
        nblocks = math.ceil(proj / quant_block)
        full = numel * 4.0
        refresh_amort = numel * 4.0 / t_update
        fp32c = proj * 4.0 + refresh_amort
        int8c = proj * 1.0 + nblocks * 4.0 + refresh_amort
        buckets.append({
            "shape": [m, n],
            "count": count,
            "rank": r,
            "per_leaf_bytes_per_step": {
                "full_fp32": full,
                "compressed_fp32": fp32c,
                "compressed_int8": int8c,
                "refresh_amortized_fp32": refresh_amort,
                "int8_scale_bytes": nblocks * 4.0,
            },
        })
        tot_full += count * full
        tot_fp32 += count * fp32c
        tot_int8 += count * int8c
    return {
        "arch": "llama1b",
        "rank": rank,
        "t_update": t_update,
        "quant_block": quant_block,
        "buckets": buckets,
        "totals_bytes_per_step": {
            "full_fp32": tot_full,
            "compressed_fp32": tot_fp32,
            "compressed_int8": tot_int8,
        },
        "full_vs_compressed_fp32_ratio": tot_full / tot_fp32,
        "int8_vs_fp32_compressed_ratio": tot_fp32 / tot_int8,
        "full_vs_compressed_int8_ratio": tot_full / tot_int8,
    }


def run_sync(csv: Csv, fast: bool = False):
    """Cross-pod compressed-sync wire bytes; writes ``BENCH_sync.json``.

    Analytic only (the wire model prices payloads, not this host's CPU
    collectives); equivalence/bit-exactness of the three paths is pinned
    by tests/test_distributed.py, and the int8-vs-fp32 ratio gate is
    enforced by tests/test_benchmarks_sync.py against this exact report.
    """
    del fast  # no measured component — the model is closed-form
    print("# cross-pod compressed sync (LLaMA-1B buckets, bytes/step/link)")
    rep = sync_report()
    tots = rep["totals_bytes_per_step"]
    r_fp32 = rep["full_vs_compressed_fp32_ratio"]
    r_int8 = rep["int8_vs_fp32_compressed_ratio"]
    csv.add(
        "sync/llama1b_wire", 0.0,
        f"full_vs_fp32={r_fp32:.1f}x;int8_vs_fp32={r_int8:.1f}x;"
        f"full_vs_int8={rep['full_vs_compressed_int8_ratio']:.1f}x",
    )
    print(
        f"  full fp32 {tots['full_fp32']/1e6:9.1f} MB -> r-rank fp32 "
        f"{tots['compressed_fp32']/1e6:9.1f} MB ({r_fp32:.1f}x) -> r-rank "
        f"int8+scales {tots['compressed_int8']/1e6:9.1f} MB "
        f"({r_int8:.1f}x further)"
    )
    report = {
        "sync": rep,
        "method": (
            "per-link steady-state bytes/step on the LLaMA-1B matrix "
            "buckets: baseline ships the full fp32 gradient every step; "
            "compressed fp32 ships G_proj (m*r*4 B) plus the full-G "
            "refresh exchange amortized over T_u; the sync_codes int8 "
            "collective ships int8 codes (m*r B) + one fp32 scale per "
            "quant_block elements under the pmax'd shared block scale, "
            "with the same amortized fp32 refresh term. The EF sidecar "
            "is resident state, never wire traffic."
        ),
    }
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_sync.json",
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"  wrote {out_path} (int8 vs fp32-compressed {r_int8:.2f}x)")
