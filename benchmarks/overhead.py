"""Training-time overhead columns (Tables 1/2/3/5/6) + fused-8-bit traffic.

Per-step optimizer overhead = measured P-update cost amortized over its
interval + measured per-step projection cost, divided by the analytic step
time at the paper's hardware (8xH100 @ 40% MFU). Printed alongside the
paper's claimed +x% columns. Absolute CPU times are reported in the CSV so
the derivation is auditable.

The quantized section compares the single-pass fused int8 COAP step
(kernels/quant8.coap_fused_update_q8_pallas) against the unfused 8-bit
schedule (dequant M, dequant V, project, moment EMA, Δ+clip, backproject,
requant M, requant V — 8 separate dispatches) on LLaMA-1B shapes, two ways:

  * ``unfused``: XLA ``cost_analysis()`` 'bytes accessed' summed over the 8
    separately-jitted stages — each stage boundary is a real HBM
    materialization when dispatched separately.
  * ``fused``: what ``cost_analysis`` reports for the one-kernel dispatch —
    its operand+result buffers (the custom call's HBM I/O) — plus,
    conservatively, the kernel's internal P re-stream traffic derived from
    its BlockSpec index maps (2·ceil(m/bm)·n·r·4 bytes: P is swept once per
    row-block in each MXU phase). Both variants are recorded.

Results land in ``BENCH_overhead.json`` next to the repo root, including
per-shape bytes, the headline ratio (conservative accounting), and launch
counts per step.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, analytic_step_seconds, time_fn
from repro.core import correlation, recalibrate
from repro.kernels import ref as kref


# (m, n) matrices of LLaMA-1B with multiplicity per step
LLAMA1B_MATS = [
    ((2048, 2048), 4 * 24), ((5461, 2048), 3 * 24), ((32000, 2048), 1),
]
LLAMA1B_N = 1.1e9
LLAMA1B_TOKENS = 512 * 256  # batch 512, seq 256 (paper's GaLore recipe)


def _p_update_cost(mats, rank, strategy: str) -> float:
    """Wall seconds to refresh ALL projections once."""
    total = 0.0
    for (m, n), count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        g = jax.random.normal(jax.random.key(0), (mm, nn))
        p = jax.random.normal(jax.random.key(1), (nn, r)) / np.sqrt(r)
        mp = 0.1 * jax.random.normal(jax.random.key(2), (mm, r))
        if strategy == "galore":
            fn = jax.jit(lambda gg: recalibrate.galore_svd(gg, r))
            t = time_fn(fn, g, iters=1)
        elif strategy == "coap_recal":
            fn = jax.jit(recalibrate.lowcost_svd)
            t = time_fn(fn, g, p, iters=1)
        elif strategy == "coap_eqn6":
            fn = jax.jit(lambda pp, gg, m2: correlation.sgd_update(pp, gg, m2))
            t = time_fn(fn, p, g, mp, iters=2)
        else:  # flora
            fn = jax.jit(lambda k: recalibrate.random_projection(k, (mm, nn), r))
            t = time_fn(fn, jax.random.key(3), iters=2)
        total += t * count
    return total


def _per_step_projection_cost(mats, rank) -> float:
    """G@P + moment update + backproject per step (the fused-kernel path)."""
    total = 0.0
    for (m, n), count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        g = jax.random.normal(jax.random.key(0), (mm, nn))
        p = jax.random.normal(jax.random.key(1), (nn, r)) / np.sqrt(r)
        mo = jnp.zeros((mm, r))
        vo = jnp.zeros((mm, r))
        cnt = jnp.asarray(3, jnp.int32)
        fn = jax.jit(lambda *a: kref.coap_fused_update(*a))
        t = time_fn(fn, g, p, mo, vo, cnt, iters=2)
        total += t * count
    return total


def _bytes_accessed(fn, *args) -> float:
    """XLA cost-model 'bytes accessed' of fn jitted as one dispatch."""
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    d = ca[0] if isinstance(ca, list) else ca
    return float(d["bytes accessed"])


def _nbytes(*arrays) -> float:
    return float(sum(a.size * a.dtype.itemsize for a in arrays))


def quantized_fused_vs_unfused(mats, rank, block=kref.QUANT_BLOCK,
                               bm=None):
    """Per-shape bytes-accessed comparison for the 8-bit COAP step.

    Returns {shape_label: {...}} with fused/unfused bytes, the conservative
    ratio, and per-step launch counts. See module docstring for methodology.
    ``bm`` defaults to the fused kernel's own row tile so the P re-stream
    charge tracks the real tiling.
    """
    if bm is None:
        from repro.kernels.quant8 import DEFAULT_BM as bm
    b1, b2, eps = 0.9, 0.999, 1e-8
    out = {}
    for (m, n), _count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        nblk = kref.rowblock_nblocks(r, block)
        g = jnp.zeros((mm, nn))
        p = jnp.zeros((nn, r))
        mq = jnp.zeros((mm, r), jnp.int8)
        ms = jnp.zeros((mm, nblk))
        vq, vs = mq, ms
        m_f = jnp.zeros((mm, r))
        v_f = jnp.zeros((mm, r))
        gp = jnp.zeros((mm, r))
        d_ = jnp.zeros((mm, r))

        # --- unfused schedule: 8 separate dispatches (t=3 baked into the
        # bias-correction stage; traffic is t-independent) ----------------
        tf = 3.0
        stages = [
            ("dequant_m", lambda q, s: kref.dequantize_rowblock(q, s, block),
             (mq, ms)),
            ("dequant_v", lambda q, s: kref.dequantize_rowblock(q, s, block),
             (vq, vs)),
            ("project", lambda g_, p_: jnp.einsum("mn,nr->mr", g_, p_),
             (g, p)),
            ("moments", lambda gp_, m_, v_: (
                b1 * m_ + (1 - b1) * gp_, b2 * v_ + (1 - b2) * jnp.square(gp_)
            ), (gp, m_f, v_f)),
            ("delta_clip", lambda m_, v_: jnp.clip(
                (m_ / (1 - b1**tf)) / (jnp.sqrt(v_ / (1 - b2**tf)) + eps),
                -kref.QUANT_DELTA_CLIP, kref.QUANT_DELTA_CLIP,
            ), (m_f, v_f)),
            ("backproject", lambda d2, p_: jnp.einsum("mr,nr->mn", d2, p_),
             (d_, p)),
            ("requant_m", lambda m_: kref.quantize_rowblock(m_, block), (m_f,)),
            ("requant_v", lambda v_: kref.quantize_rowblock(v_, block), (v_f,)),
        ]
        unfused_cost = {
            name: _bytes_accessed(fn, *args) for name, fn, args in stages
        }
        unfused_bytes = sum(unfused_cost.values())

        # --- fused single-pass kernel ------------------------------------
        # operand+result buffers of the one dispatch (what cost_analysis
        # reports for the pallas custom call on TPU):
        dw = jnp.zeros((mm, nn))
        fused_io = _nbytes(g, p, mq, ms, vq, vs) + _nbytes(mq, ms, vq, vs, dw)
        # + internal P re-stream per index maps (phase 1 + phase 2):
        p_restream = 2.0 * np.ceil(mm / bm) * nn * r * 4.0
        fused_bytes = fused_io + p_restream

        out[f"{mm}x{nn}"] = {
            "rank": int(r),
            "unfused_bytes": unfused_bytes,
            "unfused_per_stage": unfused_cost,
            "fused_io_bytes": fused_io,
            "fused_p_restream_bytes": p_restream,
            "fused_bytes_conservative": fused_bytes,
            # 'ratio' follows cost_analysis semantics on both sides: summed
            # per-dispatch bytes for the 8-stage schedule vs the single
            # custom call's operand+result bytes.
            "ratio": unfused_bytes / fused_io,
            "ratio_conservative": unfused_bytes / fused_bytes,
            "launches_unfused": len(stages),
            "launches_fused": 1,
        }
    return out


def run(csv: Csv, fast: bool = False):
    rank = 512
    t_u, lam = 40, 5  # paper's LLaMA-1B recipe
    step_s = analytic_step_seconds(LLAMA1B_N, LLAMA1B_TOKENS)
    print(f"# overhead (LLaMA-1B shapes; analytic step {step_s*1e3:.0f} ms "
          f"@8xH100 40% MFU)")

    costs = {
        "galore_svd": _p_update_cost(LLAMA1B_MATS, rank, "galore"),
        "coap_recal": _p_update_cost(LLAMA1B_MATS, rank, "coap_recal"),
        "coap_eqn6": _p_update_cost(LLAMA1B_MATS, rank, "coap_eqn6"),
        "flora_random": _p_update_cost(LLAMA1B_MATS, rank, "flora"),
    }
    proj_step = _per_step_projection_cost(LLAMA1B_MATS, rank)

    # CPU->accelerator scaling: P updates are dense linalg; scale measured
    # CPU time by the same factor for all strategies (ratios exact, levels
    # approximate). Factor = measured CPU matmul rate vs A100 ~ measured
    # below via one reference matmul.
    a = jax.random.normal(jax.random.key(0), (2048, 2048))
    t_mm = time_fn(jax.jit(lambda x: x @ x), a, iters=3)
    cpu_flops = 2 * 2048**3 / t_mm
    scale = cpu_flops / 150e12  # vs ~150 TF/s effective dense linalg on A100

    # amortized per-step seconds (accelerator-scaled)
    rows = {
        "galore(+SVD/T_u)": costs["galore_svd"] / t_u * scale,
        "coap(eqn6/T_u + recal/λT_u)": (
            costs["coap_eqn6"] / t_u + costs["coap_recal"] / (lam * t_u)
        ) * scale,
        "flora(resample each step)": costs["flora_random"] * scale,
    }
    for label, s in rows.items():
        overhead = s / step_s
        csv.add(f"overhead/{label}", s * 1e6,
                f"overhead_vs_step={overhead:+.1%}")
        print(f"  {label:34s} {s*1e3:8.2f} ms/step  ({overhead:+.1%} of step)"
              )
    ratio = costs["galore_svd"] / costs["coap_recal"]
    csv.add("overhead/fullsvd_vs_lowcost_ratio", 0.0,
            f"ratio={ratio:.1f}x;paper_claim=20x+")
    print(f"  full-SVD vs low-cost-SVD ratio: {ratio:.1f}x (paper: >20x)")
    csv.add("overhead/per_step_projection", proj_step * scale * 1e6,
            f"fused_update_all_mats_cpu_s={proj_step:.3f}")

    # --- fused vs unfused 8-bit step: bytes accessed + launch counts ------
    # (fast: one shape is enough signal; the full sweep jits 8 stages each)
    q8_mats = LLAMA1B_MATS[:1] if fast else LLAMA1B_MATS
    q8 = quantized_fused_vs_unfused(q8_mats, rank)
    for label, row in q8.items():
        csv.add(
            f"overhead/q8_fused_vs_unfused/{label}", 0.0,
            f"ratio={row['ratio']:.2f}x;conservative="
            f"{row['ratio_conservative']:.2f}x;launches="
            f"{row['launches_unfused']}->{row['launches_fused']}",
        )
        print(
            f"  q8 {label:12s} unfused {row['unfused_bytes']/1e6:8.1f} MB "
            f"({row['launches_unfused']} launches) -> fused "
            f"{row['fused_io_bytes']/1e6:8.1f} MB (1 launch): "
            f"{row['ratio']:.2f}x ({row['ratio_conservative']:.2f}x incl. "
            f"P re-stream)"
        )
    report = {
        "llama1b_rank": rank,
        "shapes": q8,
        "ratio_min": min(r_["ratio"] for r_ in q8.values()),
        "ratio_min_conservative": min(
            r_["ratio_conservative"] for r_ in q8.values()
        ),
        "method": (
            "unfused = sum of XLA cost_analysis 'bytes accessed' over the 8 "
            "separately-dispatched stages of the unfused 8-bit schedule; "
            "fused = operand+result bytes of the single fused-q8 kernel "
            "dispatch (custom-call cost_analysis semantics), with the "
            "kernel's internal P re-stream added in the conservative "
            "variant."
        ),
    }
    out_path = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_overhead.json",
    )
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"  wrote {out_path} (min ratio {report['ratio_min']:.2f}x)")
