"""Training-time overhead columns (Tables 1/2/3/5/6).

Per-step optimizer overhead = measured P-update cost amortized over its
interval + measured per-step projection cost, divided by the analytic step
time at the paper's hardware (8xH100 @ 40% MFU). Printed alongside the
paper's claimed +x% columns. Absolute CPU times are reported in the CSV so
the derivation is auditable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, analytic_step_seconds, time_fn
from repro.core import correlation, recalibrate
from repro.kernels import ref as kref


# (m, n) matrices of LLaMA-1B with multiplicity per step
LLAMA1B_MATS = [
    ((2048, 2048), 4 * 24), ((5461, 2048), 3 * 24), ((32000, 2048), 1),
]
LLAMA1B_N = 1.1e9
LLAMA1B_TOKENS = 512 * 256  # batch 512, seq 256 (paper's GaLore recipe)


def _p_update_cost(mats, rank, strategy: str) -> float:
    """Wall seconds to refresh ALL projections once."""
    total = 0.0
    for (m, n), count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        g = jax.random.normal(jax.random.key(0), (mm, nn))
        p = jax.random.normal(jax.random.key(1), (nn, r)) / np.sqrt(r)
        mp = 0.1 * jax.random.normal(jax.random.key(2), (mm, r))
        if strategy == "galore":
            fn = jax.jit(lambda gg: recalibrate.galore_svd(gg, r))
            t = time_fn(fn, g, iters=1)
        elif strategy == "coap_recal":
            fn = jax.jit(recalibrate.lowcost_svd)
            t = time_fn(fn, g, p, iters=1)
        elif strategy == "coap_eqn6":
            fn = jax.jit(lambda pp, gg, m2: correlation.sgd_update(pp, gg, m2))
            t = time_fn(fn, p, g, mp, iters=2)
        else:  # flora
            fn = jax.jit(lambda k: recalibrate.random_projection(k, (mm, nn), r))
            t = time_fn(fn, jax.random.key(3), iters=2)
        total += t * count
    return total


def _per_step_projection_cost(mats, rank) -> float:
    """G@P + moment update + backproject per step (the fused-kernel path)."""
    total = 0.0
    for (m, n), count in mats:
        mm, nn = max(m, n), min(m, n)
        r = min(rank, nn)
        g = jax.random.normal(jax.random.key(0), (mm, nn))
        p = jax.random.normal(jax.random.key(1), (nn, r)) / np.sqrt(r)
        mo = jnp.zeros((mm, r))
        vo = jnp.zeros((mm, r))
        cnt = jnp.asarray(3, jnp.int32)
        fn = jax.jit(lambda *a: kref.coap_fused_update(*a))
        t = time_fn(fn, g, p, mo, vo, cnt, iters=2)
        total += t * count
    return total


def run(csv: Csv, fast: bool = False):
    rank = 512
    t_u, lam = 40, 5  # paper's LLaMA-1B recipe
    step_s = analytic_step_seconds(LLAMA1B_N, LLAMA1B_TOKENS)
    print(f"# overhead (LLaMA-1B shapes; analytic step {step_s*1e3:.0f} ms "
          f"@8xH100 40% MFU)")

    costs = {
        "galore_svd": _p_update_cost(LLAMA1B_MATS, rank, "galore"),
        "coap_recal": _p_update_cost(LLAMA1B_MATS, rank, "coap_recal"),
        "coap_eqn6": _p_update_cost(LLAMA1B_MATS, rank, "coap_eqn6"),
        "flora_random": _p_update_cost(LLAMA1B_MATS, rank, "flora"),
    }
    proj_step = _per_step_projection_cost(LLAMA1B_MATS, rank)

    # CPU->accelerator scaling: P updates are dense linalg; scale measured
    # CPU time by the same factor for all strategies (ratios exact, levels
    # approximate). Factor = measured CPU matmul rate vs A100 ~ measured
    # below via one reference matmul.
    a = jax.random.normal(jax.random.key(0), (2048, 2048))
    t_mm = time_fn(jax.jit(lambda x: x @ x), a, iters=3)
    cpu_flops = 2 * 2048**3 / t_mm
    scale = cpu_flops / 150e12  # vs ~150 TF/s effective dense linalg on A100

    # amortized per-step seconds (accelerator-scaled)
    rows = {
        "galore(+SVD/T_u)": costs["galore_svd"] / t_u * scale,
        "coap(eqn6/T_u + recal/λT_u)": (
            costs["coap_eqn6"] / t_u + costs["coap_recal"] / (lam * t_u)
        ) * scale,
        "flora(resample each step)": costs["flora_random"] * scale,
    }
    for label, s in rows.items():
        overhead = s / step_s
        csv.add(f"overhead/{label}", s * 1e6,
                f"overhead_vs_step={overhead:+.1%}")
        print(f"  {label:34s} {s*1e3:8.2f} ms/step  ({overhead:+.1%} of step)"
              )
    ratio = costs["galore_svd"] / costs["coap_recal"]
    csv.add("overhead/fullsvd_vs_lowcost_ratio", 0.0,
            f"ratio={ratio:.1f}x;paper_claim=20x+")
    print(f"  full-SVD vs low-cost-SVD ratio: {ratio:.1f}x (paper: >20x)")
    csv.add("overhead/per_step_projection", proj_step * scale * 1e6,
            f"fused_update_all_mats_cpu_s={proj_step:.3f}")
