"""Paper Tables 1/2/3/5/6 + appendix Table 2: optimizer-state memory.

Every number is exact byte arithmetic over the real optimizer-state pytree
at the paper's full model shapes (no allocation). Each table prints the
paper's claimed reduction next to ours.
"""
from __future__ import annotations

import jax.numpy as jnp

import jax
import jax.numpy as jnp2  # noqa: F401 (dtype args)

from benchmarks import param_trees as PT
from benchmarks.common import Csv, shapes_of, state_bytes_for
from repro.core.accounting import abstract_state_bytes, _leaf_bytes
from repro.core.api import OptimizerConfig, make_optimizer
from repro.models.lora import LoRAConfig, lora_init


def _lora_row(csv, table, tree, rank, dtype, claim_opt, claim_model,
              min_dim=128):
    """LoRA baseline: Adam over adapters only + model-size growth."""
    shapes = shapes_of(tree)
    lcfg = LoRAConfig(rank=rank, min_dim=min_dim)
    adapters = jax.eval_shape(
        lambda: lora_init(jax.random.key(0), shapes, lcfg))
    tx = make_optimizer(OptimizerConfig(name="adamw", learning_rate=1e-3,
                                        state_dtype=dtype, grad_clip=None))
    opt_b = abstract_state_bytes(tx, adapters).total_bytes
    ad_b = sum(_leaf_bytes(x) for x in jax.tree_util.tree_leaves(adapters))
    model_b = sum(_leaf_bytes(x) for x in jax.tree_util.tree_leaves(shapes))
    csv.add(f"{table}/lora_rank{rank}", 0.0,
            f"opt_gb={opt_b/1e9:.3f};model_growth={ad_b/model_b:+.1%};"
            f"paper_opt={claim_opt};paper_model={claim_model}")
    print(f"  {'lora_rank%d' % rank:28s} {opt_b/1e9:7.3f} GB opt "
          f"(+{ad_b/model_b:.1%} model) paper: {claim_opt} opt, "
          f"{claim_model} model")


def _report(csv: Csv, table: str, tree, rows, dtype=jnp.float32):
    shapes = shapes_of(tree)
    base_name = rows[0][1]
    base = state_bytes_for(shapes, base_name, rank=rows[0][2],
                           rank_ratio=rows[0][3], state_dtype=dtype,
                           min_dim=rows[0][4] if len(rows[0]) > 4 else 128)
    print(f"# {table} (baseline {base_name}: {base/1e9:.2f} GB)")
    for row in rows:
        label, name, rank, ratio = row[:4]
        min_dim = row[4] if len(row) > 4 else 128
        claim = row[5] if len(row) > 5 else None
        b = state_bytes_for(shapes, name, rank=rank, rank_ratio=ratio,
                            state_dtype=dtype, min_dim=min_dim)
        red = 1 - b / base
        claim_s = f" paper_claim={claim}" if claim else ""
        csv.add(f"{table}/{label}", 0.0,
                f"state_gb={b/1e9:.3f};reduction={red:+.1%}{claim_s}")
        print(f"  {label:28s} {b/1e9:7.3f} GB  ({red:+.1%}){claim_s}")


def run(csv: Csv, fast: bool = False):
    # ---- Table 5: LLaMA-1B pre-training (paper: GaLore/COAP -61%) ----
    _report(csv, "table5_llama1b", PT.LLAMA_1B, [
        ("adamw", "adamw", None, None),
        ("galore_rank512", "galore-adamw", 512, None, 128, "-61%"),
        ("coap_rank512", "coap-adamw", 512, None, 128, "-61%"),
        ("8bit_coap_rank512", "8bit-coap-adamw", 512, None),
    ], dtype=jnp.bfloat16)  # paper Table 5 reports states in BF16
    _lora_row(csv, "table5_llama1b", PT.LLAMA_1B, 512, jnp.bfloat16,
              "-55%", "+36%")

    # ---- Table 5 (7B, 8-bit): 8bit-GaLore/COAP -58% vs 8bit Adam ----
    _report(csv, "table5_llama7b_8bit", PT.LLAMA_7B, [
        ("8bit_adam", "8bit-adamw", None, None),
        ("8bit_galore_rank1024", "8bit-galore-adamw", 1024, None, 128, "-58%"),
        ("8bit_coap_rank1024", "8bit-coap-adamw", 1024, None, 128, "-58%"),
    ])

    # ---- Table 6: LLaVA-7B fine-tune (rank ratio 4; -49% / 8bit -81%) ----
    _report(csv, "table6_llava7b", PT.LLAVA_7B, [
        ("adamw", "adamw", None, None),
        ("coap_ratio4", "coap-adamw", None, 4.0, 128, "-49%"),
        ("galore_ratio4", "galore-adamw", None, 4.0, 128, "-49%"),
        ("8bit_coap_ratio4", "8bit-coap-adamw", None, 4.0, 128, "-81%"),
    ], dtype=jnp.bfloat16)
    _lora_row(csv, "table6_llava7b", PT.LLAVA_7B, 1024, jnp.bfloat16,
              "-49%", "+30%")

    # ---- Table 2: SiT-XL/2 (rank 512; COAP/GaLore -49% AdamW fp32) ----
    _report(csv, "table2_sit_xl2", PT.SIT_XL_2, [
        ("adamw", "adamw", None, None),
        ("coap_rank512", "coap-adamw", 512, None, 128, "-49%"),
        ("galore_rank512", "galore-adamw", 512, None, 128, "-49%"),
        ("flora_rank512", "flora-adamw", 512, None, 128, "-36%(adafactor)"),
    ])
    _lora_row(csv, "table2_sit_xl2", PT.SIT_XL_2, 512, jnp.float32,
              "-29%", "+48%")

    # ---- Table 1: LDM U-Net conv (ratio 2; COAP -40% AdamW fp32) ----
    _report(csv, "table1_ldm_unet", PT.LDM_UNET, [
        ("adamw", "adamw", None, None),
        ("coap_tucker2_ratio2", "coap-adamw", None, 2.0, 96, "-40%"),
        ("galore_ratio2", "galore-adamw", None, 2.0, 96, "-33%"),
    ])

    # ---- Table 3: ControlNet-SDXL rank-ratio sweep ----
    _report(csv, "table3_controlnet_sdxl", PT.SDXL_CONTROLNET, [
        ("adamw", "adamw", None, None),
        ("coap_ratio2", "coap-adamw", None, 2.0, 96, "-29%(vs adafactor)"),
        ("coap_ratio4", "coap-adamw", None, 4.0, 96, "-65%"),
        ("coap_ratio8", "coap-adamw", None, 8.0, 96, "-82%"),
        ("8bit_coap_ratio8", "8bit-coap-adamw", None, 8.0, 96, "-90%"),
        ("galore_ratio8", "galore-adamw", None, 8.0, 96, "-47%"),
    ], dtype=jnp.bfloat16)

    # ---- appendix Table 2: DDPM U-Nets ----
    if not fast:
        _report(csv, "app_table2_ddpm_cifar", PT.DDPM_CIFAR_UNET, [
            ("adamw", "adamw", None, None),
            ("coap_ratio1p5", "coap-adamw", None, 1.5, 96, "214.66MB"),
            ("galore_ratio1p5", "galore-adamw", None, 1.5, 96, "302.43MB"),
        ])
        _report(csv, "app_table2_ddpm_celeba", PT.DDPM_CELEBA_UNET, [
            ("adamw", "adamw", None, None),
            ("coap_ratio2", "coap-adamw", None, 2.0, 96, "525.18MB"),
            ("galore_ratio2", "galore-adamw", None, 2.0, 96, "562.56MB"),
        ])
