"""Quality reproductions at reduced scale: Fig 3 (CEU + accuracy ordering),
Table 7 (component ablation), Fig 4 (λ / T_u / r sensitivity), and the
Table-5 "COAP ≈ AdamW" convergence claim.

All runs use the synthetic-Markov LM (known CE floor), a 2-layer llama-style
model, identical seeds/LRs across optimizers — only the optimizer differs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.configs import get_smoke
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM
from repro.models.model import build_model
from repro.optim import apply_updates


@dataclasses.dataclass
class RunResult:
    final_ce: float
    ceu_total: float
    steps_per_s: float


def _train(name: str, steps: int = 200, seed: int = 0, rank: int = 16,
           t_update: int = 10, lam: int = 4, lr: float = 8e-3,
           eqn6_lr: float = 0.1, eqn6_steps: int = 1,
           opt_overrides: Optional[dict] = None,
           data: Optional[SyntheticLM] = None,
           health_every: int = 0) -> RunResult:
    cfg = dataclasses.replace(get_smoke("llama-1b"), dtype=jnp.float32)
    model = build_model(cfg)
    data = data or SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.1)
    ocfg = OptimizerConfig(name=name, learning_rate=lr, rank=rank,
                           t_update=t_update, lam=lam, min_dim=32,
                           eqn6_lr=eqn6_lr, eqn6_steps=eqn6_steps,
                           grad_clip=None)
    for k, v in (opt_overrides or {}).items():
        setattr(ocfg, k, v)
    tx = make_optimizer(ocfg)
    params = model.init(jax.random.key(seed))
    opt_state = tx.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        (loss, m), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch
        )
        updates, opt_state = tx.update(grads, opt_state, params)
        ceu = sum(jnp.sum(jnp.abs(u)) for u in jax.tree_util.tree_leaves(updates))
        return apply_updates(params, updates), opt_state, loss, ceu

    ceu_total, final_ce = 0.0, 0.0
    t0 = time.perf_counter()
    for i in range(steps):
        batch = data.batch(i, batch=8, seq=64)
        params, opt_state, loss, ceu = step(params, opt_state, batch)
        ceu_total += float(ceu)
        final_ce = float(loss)
        if health_every and i % health_every == 0:
            from repro.obs import health as _health

            _health.observe_state(opt_state, i)
    dt = time.perf_counter() - t0
    # eval CE on held-out steps
    ces = []
    for i in range(5):
        batch = data.batch(10_000 + i, batch=8, seq=64)
        _, m = jax.jit(model.loss)(params, batch)
        ces.append(float(m["ce"]))
    return RunResult(float(np.mean(ces)), ceu_total, steps / dt)


def fig3_ceu(csv: Csv, steps: int = 200):
    """CEU + eval-CE ordering: COAP ≈/> Adam ≫ Flora; GaLore in between."""
    print(f"# fig3_ceu ({steps} steps, rank 16, synthetic-Markov LM)")
    data = SyntheticLM(vocab=256, order=1, noise=0.1)
    results: Dict[str, RunResult] = {}
    for name in ["adamw", "coap-adamw", "galore-adamw", "flora-adamw"]:
        r = _train(name, steps=steps, data=data)
        results[name] = r
        csv.add(f"fig3_ceu/{name}", 1e6 / r.steps_per_s,
                f"eval_ce={r.final_ce:.4f};ceu_total={r.ceu_total:.1f};"
                f"ce_floor={data.ce_floor():.4f}")
        print(f"  {name:14s} eval_ce={r.final_ce:.4f} ceu={r.ceu_total:9.1f} "
              f"({r.steps_per_s:.1f} steps/s)")
    return results


def table7_ablation(csv: Csv, steps: int = 150):
    """Component ablation: Eqn 7 recal + Eqn 6 terms, as in paper Table 7."""
    print("# table7_ablation (from-scratch; paper: Eqn7 dominant, both best)")
    data = SyntheticLM(vocab=256, order=1, noise=0.1)
    variants = {
        # (t_update, lam, eqn6_lr): lam huge disables recal after init;
        # eqn6_lr=0 disables the correlation-aware SGD refinement.
        "full_coap": dict(t_update=10, lam=4, eqn6_lr=0.1, eqn6_steps=2),
        "eqn6_only": dict(t_update=10, lam=10**6, eqn6_lr=0.1, eqn6_steps=2),
        "eqn7_only": dict(t_update=10, lam=4, eqn6_lr=0.0),
        "neither(fixed_P)": dict(t_update=10**6, lam=1, eqn6_lr=0.0),
    }
    out = {}
    for label, kw in variants.items():
        r = _train("coap-adamw", steps=steps, data=data, **kw)
        out[label] = r
        csv.add(f"table7_ablation/{label}", 1e6 / r.steps_per_s,
                f"eval_ce={r.final_ce:.4f}")
        print(f"  {label:18s} eval_ce={r.final_ce:.4f}")
    return out


def fig4_hparams(csv: Csv, steps: int = 120):
    """λ × T_u × r sensitivity grid (paper Fig 4, reduced)."""
    print("# fig4_hparams (λ x T_u x r grid)")
    data = SyntheticLM(vocab=256, order=1, noise=0.1)
    for r_ in [8, 16]:
        for t_u in [5, 20]:
            for lam in [2, 10]:
                res = _train("coap-adamw", steps=steps, rank=r_, t_update=t_u,
                             lam=lam, data=data)
                csv.add(f"fig4/r{r_}_Tu{t_u}_lam{lam}", 1e6 / res.steps_per_s,
                        f"eval_ce={res.final_ce:.4f}")
                print(f"  r={r_:3d} T_u={t_u:3d} λ={lam:3d} "
                      f"eval_ce={res.final_ce:.4f}")


def quality_sweep(csv: Csv, steps: int = 150):
    """The plan–quality feedback loop's evidence base: eval CE as a
    function of the rank floor, each run health-journaled. Writes
    ``BENCH_quality.json`` — {baseline, configs: [{rank, c, final_ce,
    ceu, health}]} — the per-rank quality ladder ``plan.solver``'s
    tighten/relax thresholds are judged against: ranks whose runs fire
    RANK_STARVED should be exactly the ranks whose eval CE visibly
    degrades vs the AdamW baseline."""
    import json
    import os
    import tempfile

    from repro.obs import health

    print(f"# quality_sweep ({steps} steps, rank ladder, health-journaled)")
    data = SyntheticLM(vocab=256, order=1, noise=0.1)
    adam = _train("adamw", steps=steps, data=data)
    csv.add("quality_sweep/adamw", 1e6 / adam.steps_per_s,
            f"eval_ce={adam.final_ce:.4f}")
    print(f"  adamw (baseline)    eval_ce={adam.final_ce:.4f}")
    # min projected dim is d_model=64 (smoke llama), so c = 64/rank.
    min_proj_dim = 64
    configs = []
    tmp = tempfile.mkdtemp(prefix="coap_quality_")
    for rank in [32, 16, 8, 4, 2]:
        jpath = os.path.join(tmp, f"health_r{rank}.jsonl")
        health.configure(jpath, host="bench", sample_every=1)
        try:
            r = _train("coap-adamw", steps=steps, rank=rank, data=data,
                       opt_overrides={"stacked_state": True},
                       health_every=10)
        finally:
            health.configure(None)
        rep = health.analyze_journal(jpath)
        verdicts = sorted(
            {v for b in rep.buckets.values() for v in b["verdicts"]}
        )
        energies = [
            b["metrics"].get("energy_median")
            for b in rep.buckets.values()
            if b["metrics"].get("energy_median") is not None
        ]
        e_med = float(np.median(energies)) if energies else None
        configs.append({
            "rank": rank,
            "c": min_proj_dim / rank,
            "final_ce": r.final_ce,
            "ceu": r.ceu_total,
            "gap_vs_adamw": r.final_ce - adam.final_ce,
            "health": {"energy_median": e_med, "verdicts": verdicts},
        })
        csv.add(f"quality_sweep/coap_r{rank}", 1e6 / r.steps_per_s,
                f"eval_ce={r.final_ce:.4f};verdicts={'|'.join(verdicts)}")
        print(f"  coap r={rank:3d} (c={min_proj_dim/rank:4.1f}) "
              f"eval_ce={r.final_ce:.4f} gap={r.final_ce-adam.final_ce:+.4f} "
              f"energy_med={e_med if e_med is None else round(e_med, 3)} "
              f"verdicts={verdicts or '-'}")
    report = {
        "baseline": {"optimizer": "adamw", "final_ce": adam.final_ce,
                     "ceu": adam.ceu_total},
        "configs": configs,
        "method": (
            f"synthetic-Markov LM (ce_floor={data.ce_floor():.4f}), 2-layer "
            f"llama-style smoke model, {steps} steps, identical seed/LR; "
            "only the COAP rank floor varies. Each COAP run journals "
            "refresh health (obs/health) and is analyzed for verdicts; "
            "c = min_proj_dim/rank."
        ),
    }
    out = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_quality.json",
    )
    with open(out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"  -> {out}")
    return report


def table5_quality(csv: Csv, steps: int = 250):
    """Table-5 claim: COAP PPL == AdamW PPL (at −61% memory)."""
    print("# table5_quality (COAP vs AdamW convergence)")
    data = SyntheticLM(vocab=256, order=1, noise=0.1)
    adam = _train("adamw", steps=steps, data=data)
    coap = _train("coap-adamw", steps=steps, rank=16, t_update=40, lam=5,
                  data=data)
    gap = coap.final_ce - adam.final_ce
    csv.add("table5_quality/adamw", 1e6 / adam.steps_per_s,
            f"eval_ce={adam.final_ce:.4f}")
    csv.add("table5_quality/coap", 1e6 / coap.steps_per_s,
            f"eval_ce={coap.final_ce:.4f};gap_vs_adam={gap:+.4f}")
    print(f"  adamw ce={adam.final_ce:.4f}  coap ce={coap.final_ce:.4f} "
          f"(gap {gap:+.4f}; floor {data.ce_floor():.4f})")
