"""§3.2 / Eqn 7 claim: low-cost SVD ≈ 20x cheaper than GaLore's full SVD.

Measures wall time of one P-update per strategy at the paper's true matrix
shapes (LLaMA-1B / LLaVA-7B / grok-scale). The paper quotes 540s (full SVD)
vs 23s (Eqn 7) for all LLaVA-7B projections on one A100 — a 23x ratio; on
CPU the absolute numbers differ but the complexity ratio O(mn²)/O(mr²)
reproduces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv, time_fn
from repro.core import correlation, recalibrate


SHAPES = {
    # (m, n, r): canonical m >= n
    "llama1b_ffn(5461x2048)": (5461, 2048, 512),
    "llava7b_ffn(11008x4096)": (11008, 4096, 1024),
    "grok_expert(32768x6144)": (32768, 6144, 1024),
}


def run(csv: Csv, fast: bool = False):
    shapes = dict(SHAPES)
    if fast:
        shapes.pop("grok_expert(32768x6144)")
    print("# svd_cost: P-update wall time per strategy (one matrix)")
    for name, (m, n, r) in shapes.items():
        key = jax.random.key(0)
        g = jax.random.normal(key, (m, n), jnp.float32)
        p = jax.random.normal(jax.random.fold_in(key, 1), (n, r)) / np.sqrt(r)
        mp = 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (m, r))

        full = jax.jit(lambda gg: recalibrate.galore_svd(gg, r))
        low = jax.jit(recalibrate.lowcost_svd)
        eqn6 = jax.jit(lambda pp, gg, mm: correlation.sgd_update(pp, gg, mm))
        rand = jax.jit(
            lambda kk: recalibrate.random_projection(kk, (m, n), r)
        )

        t_full = time_fn(full, g, iters=2)
        t_low = time_fn(low, g, p, iters=2)
        t_eqn6 = time_fn(eqn6, p, g, mp, iters=3)
        t_rand = time_fn(rand, key, iters=3)
        csv.add(f"svd_cost/galore_full_svd/{name}", t_full * 1e6,
                f"speedup_vs_full=1.0")
        csv.add(f"svd_cost/coap_lowcost_svd/{name}", t_low * 1e6,
                f"speedup_vs_full={t_full/t_low:.1f}x")
        csv.add(f"svd_cost/coap_eqn6_sgd/{name}", t_eqn6 * 1e6,
                f"speedup_vs_full={t_full/t_eqn6:.1f}x")
        csv.add(f"svd_cost/flora_random/{name}", t_rand * 1e6,
                f"speedup_vs_full={t_full/t_rand:.1f}x")
        print(f"  {name}: full {t_full:.3f}s | lowcost {t_low:.3f}s "
              f"({t_full/t_low:.1f}x) | eqn6 {t_eqn6:.3f}s | rand {t_rand:.3f}s")
