"""Full-size parameter-shape trees for the paper's models (memory tables).

Shapes only (ShapeDtypeStructs downstream) — nothing is allocated. Sources:
public configs of each model; conv nets list every (O, I, k, k) at its real
channel widths.
"""
from __future__ import annotations


def llama(n_layers: int, d: int, ffn: int, vocab: int, kv_heads=None, heads=32,
          head_dim=None):
    head_dim = head_dim or d // heads
    kv = (kv_heads or heads) * head_dim
    layers = {
        "wq": (n_layers, d, heads * head_dim),
        "wk": (n_layers, d, kv),
        "wv": (n_layers, d, kv),
        "wo": (n_layers, heads * head_dim, d),
        "gate": (n_layers, d, ffn),
        "up": (n_layers, d, ffn),
        "down": (n_layers, ffn, d),
        "ln1_scale": (n_layers, d),
        "ln2_scale": (n_layers, d),
    }
    return {"layers": layers, "embed": {"embedding": (vocab, d)},
            "lm_head": {"w": (d, vocab)}, "final_norm_scale": (d,)}


LLAMA_1B = llama(24, 2048, 5461, 32000)
LLAMA_7B = llama(32, 4096, 11008, 32000)


def vit(n_layers: int, d: int, ffn: int, patches=196, n_classes=1000):
    return {
        "layers": {
            "wq": (n_layers, d, d), "wk": (n_layers, d, d),
            "wv": (n_layers, d, d), "wo": (n_layers, d, d),
            "fc1": (n_layers, d, ffn), "fc2": (n_layers, ffn, d),
            "ln1_scale": (n_layers, d), "ln2_scale": (n_layers, d),
        },
        "patch_embed": {"w": (d, 768)},  # 16x16x3 flattened
        "pos_embedding": (patches + 1, d),
        "head": {"w": (d, n_classes)},
    }


DEIT_BASE = vit(12, 768, 3072)
def dit(n_layers: int, d: int, ffn: int):
    """DiT/SiT: ViT blocks + adaLN-zero modulation (d -> 6d per block)."""
    tree = vit(n_layers, d, ffn)
    tree["layers"]["adaln"] = (n_layers, d, 6 * d)
    tree["t_embed"] = {"fc1": (256, d), "fc2": (d, d)}
    tree["y_embed"] = {"w": (1001, d)}
    return tree


SIT_XL_2 = dit(28, 1152, 4608)  # SiT-XL/2 backbone (~675M)


def _unet_convs(base: int, mults, in_ch=4, attn_from=1, ctx=768,
                tfmr_depth=1):
    """Representative LDM/SDXL-style U-Net: resnet convs + (cross-)attention
    transformer blocks at the deeper resolutions + time-embedding MLPs —
    the mix matters because GaLore projects only the linear (attention/MLP)
    weights while COAP's Tucker-2 also covers the convs (paper Table 1/3)."""
    tree = {}
    chans = [base * m for m in mults]
    prev = base
    tree["conv_in"] = (base, in_ch, 3, 3)
    t_dim = base * 4
    tree["time_embed_fc1"] = (base, t_dim)
    tree["time_embed_fc2"] = (t_dim, t_dim)

    def attn_block(prefix, d):
        for rep in range(tfmr_depth):
            p = f"{prefix}_t{rep}"
            tree[f"{p}_self_wq"] = (d, d)
            tree[f"{p}_self_wk"] = (d, d)
            tree[f"{p}_self_wv"] = (d, d)
            tree[f"{p}_self_wo"] = (d, d)
            tree[f"{p}_cross_wq"] = (d, d)
            tree[f"{p}_cross_wk"] = (ctx, d)
            tree[f"{p}_cross_wv"] = (ctx, d)
            tree[f"{p}_cross_wo"] = (d, d)
            tree[f"{p}_ff1"] = (d, 4 * d)
            tree[f"{p}_ff2"] = (4 * d, d)

    for i, ch in enumerate(chans):
        for blk in range(2):
            tree[f"down{i}_res{blk}_conv1"] = (ch, prev, 3, 3)
            tree[f"down{i}_res{blk}_conv2"] = (ch, ch, 3, 3)
            tree[f"down{i}_res{blk}_temb"] = (t_dim, ch)
            prev = ch
            if i >= attn_from:
                attn_block(f"down{i}_b{blk}", ch)
        if i < len(chans) - 1:
            tree[f"down{i}_ds_conv"] = (ch, ch, 3, 3)
    attn_block("mid", chans[-1])
    tree["mid_res_conv1"] = (chans[-1], chans[-1], 3, 3)
    tree["mid_res_conv2"] = (chans[-1], chans[-1], 3, 3)
    for i, ch in enumerate(reversed(chans)):
        lvl = len(chans) - 1 - i
        for blk in range(3):
            tree[f"up{i}_res{blk}_conv1"] = (ch, prev + ch, 3, 3)
            tree[f"up{i}_res{blk}_conv2"] = (ch, ch, 3, 3)
            tree[f"up{i}_res{blk}_temb"] = (t_dim, ch)
            prev = ch
            if lvl >= attn_from:
                attn_block(f"up{i}_b{blk}", ch)
    tree["conv_out"] = (in_ch, base, 3, 3)
    return tree


LDM_UNET = _unet_convs(224, (1, 2, 3, 4), attn_from=1)
SDXL_CONTROLNET = _unet_convs(320, (1, 2, 4), ctx=2048,
                              attn_from=1, tfmr_depth=2)
DDPM_CIFAR_UNET = _unet_convs(128, (1, 2, 2, 2), in_ch=3, attn_from=2)
DDPM_CELEBA_UNET = _unet_convs(128, (1, 1, 2, 2, 4), in_ch=3, attn_from=3)


def llava_7b():
    """LLaVA-v1.5-7B = Vicuna-7B + CLIP ViT-L/14 + mm projector."""
    tree = llama(32, 4096, 11008, 32000)
    tree["vision"] = vit(24, 1024, 4096, patches=576, n_classes=0)["layers"]
    tree["mm_projector"] = {"fc1": (1024, 4096), "fc2": (4096, 4096)}
    return tree


LLAVA_7B = llava_7b()
