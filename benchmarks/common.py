"""Shared benchmark utilities.

Measurement policy (CPU container, TPU target):
  * MEMORY numbers are exact byte-arithmetic over the real optimizer-state
    pytrees at the paper's full shapes (``abstract_state_bytes`` — no
    allocation), so every "Optimizer Mem." column is validated exactly.
  * P-UPDATE COSTS are wall-clock measured at the true per-matrix shapes
    (SVD/QR/Eqn-6 run fine on CPU); per-step overhead percentages are then
    derived against an analytic baseline step time at the paper's stated
    hardware (8xH100 ~ 40% MFU), since full-model step time is not
    measurable on one CPU core. The method is printed with each table.
  * QUALITY comparisons (CEU, convergence orderings) run at reduced scale on
    a synthetic-Markov LM with a known CE floor.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accounting import abstract_state_bytes
from repro.core.api import OptimizerConfig, make_optimizer

H100_BF16_FLOPS = 989e12
ASSUMED_MFU = 0.4


def time_fn(fn: Callable, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time (s) of jit'd fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def state_bytes_for(params_shapes, name: str, *, rank=None, rank_ratio=None,
                    min_dim=128, state_dtype=jnp.float32, t_update=200,
                    lam=5) -> int:
    cfg = OptimizerConfig(name=name, learning_rate=1e-3, rank=rank,
                          rank_ratio=rank_ratio, min_dim=min_dim,
                          state_dtype=state_dtype, t_update=t_update, lam=lam,
                          grad_clip=None)
    tx = make_optimizer(cfg)
    return abstract_state_bytes(tx, params_shapes).total_bytes


def shapes_of(tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(tuple(s), jnp.float32), tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def analytic_step_seconds(n_params: float, tokens_per_step: float) -> float:
    """6·N·D / (8xH100 x MFU) — the denominator for overhead percentages."""
    return 6.0 * n_params * tokens_per_step / (8 * H100_BF16_FLOPS * ASSUMED_MFU)


class Csv:
    """Collects `name,us_per_call,derived` rows (the run.py contract)."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, us_per_call: float, derived: str):
        self.rows.append(f"{name},{us_per_call:.2f},{derived}")

    def emit(self):
        for r in self.rows:
            print(r)
