"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only memory,fig3,...]

Prints human-readable tables followed by the machine-readable
``name,us_per_call,derived`` CSV block (the run.py contract).
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="trim the largest shapes / fewest steps")
    ap.add_argument("--only", default="",
                    help="comma list: memory,svd,overhead,refresh,state,"
                         "conv,plan,elastic,obs,sync,health,fig3,table7,"
                         "fig4,t5q,quality")
    ap.add_argument("--record", action="store_true",
                    help="append the gated ratios to "
                         "artifacts/bench_history.jsonl (benchmarks.ledger)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import convergence, memory_tables, overhead, svd_cost
    from benchmarks.common import Csv

    csv = Csv()
    t0 = time.time()

    def want(key):
        return only is None or key in only

    if want("memory"):
        memory_tables.run(csv, fast=args.fast)
    if want("svd"):
        svd_cost.run(csv, fast=args.fast)
    if want("overhead"):
        overhead.run(csv, fast=args.fast)
    if want("refresh"):
        overhead.run_refresh(csv, fast=args.fast)
    if want("state"):
        overhead.run_state(csv, fast=args.fast)
    if want("conv"):
        overhead.run_conv(csv, fast=args.fast)
    if want("plan"):
        overhead.run_plan(csv, fast=args.fast)
    if want("elastic"):
        overhead.run_elastic(csv, fast=args.fast)
    if want("obs"):
        overhead.run_obs(csv, fast=args.fast)
    if want("sync"):
        overhead.run_sync(csv, fast=args.fast)
    if want("health"):
        overhead.run_health(csv, fast=args.fast)
    steps = 80 if args.fast else 200
    if want("fig3"):
        convergence.fig3_ceu(csv, steps=steps)
    if want("table7"):
        convergence.table7_ablation(csv, steps=max(60, steps // 2))
    if want("fig4"):
        convergence.fig4_hparams(csv, steps=max(50, steps // 2))
    if want("t5q"):
        convergence.table5_quality(csv, steps=max(100, steps))
    if want("quality"):
        convergence.quality_sweep(csv, steps=max(60, steps // 2))

    print(f"\n# total benchmark wall time: {time.time()-t0:.1f}s")
    print("name,us_per_call,derived")
    csv.emit()

    if args.record:
        from benchmarks import ledger

        ledger.record()


if __name__ == "__main__":
    main()
