"""Bench ledger: append-only history of the gated benchmark ratios.

``python -m benchmarks.run --record`` (or ``python -m benchmarks.ledger
--record``) appends one row to ``artifacts/bench_history.jsonl``::

    {"ts_utc": ..., "git_sha": ..., "benches": {<metric>: <value>, ...}}

harvested from the BENCH_*.json artifacts at the repo root — only the
GATED metrics (the numbers the suite asserts on), each with a known good
direction. ``python -m benchmarks.ledger --check`` (``make bench-check``)
compares the newest row against the previous one and FAILS on any >20%
regression in the bad direction: a kernel speedup ratio that fell to
three-quarters of what the last recorded run measured is a perf
regression even while it still clears its absolute gate.

History is committed under ``artifacts/`` precisely so the comparison
crosses sessions and machines; the 20% band absorbs normal CPU-container
noise (the gated metrics are ratios of same-machine measurements, which
cancels most host variance).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
from typing import Any, Dict, Optional, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HISTORY_PATH = os.path.join(_ROOT, "artifacts", "bench_history.jsonl")
REGRESSION_BAND = 0.20

# metric -> (bench file, path inside the json, direction). Direction
# "higher" = bigger is better (a drop regresses); "lower" = smaller is
# better (a rise regresses).
GATED: Dict[str, Tuple[str, Tuple[str, ...], str]] = {
    "overhead/ratio_min": (
        "BENCH_overhead.json", ("ratio_min",), "higher"),
    "overhead/ratio_min_conservative": (
        "BENCH_overhead.json", ("ratio_min_conservative",), "higher"),
    "refresh/eqn6_ratio_min": (
        "BENCH_refresh.json", ("eqn6_ratio_min",), "higher"),
    "refresh/stagger_worst_step_bytes_ratio": (
        "BENCH_refresh.json", ("stagger", "worst_step_bytes_ratio"),
        "higher"),
    "conv/worst_step_bytes_ratio": (
        "BENCH_conv.json", ("conv_refresh", "worst_step_bytes_ratio"),
        "higher"),
    "plan/q8_reduction_vs_adamw": (
        "BENCH_plan.json", ("llama1b", "q8", "reduction_vs_adamw"),
        "higher"),
    "sync/full_vs_compressed_int8_ratio": (
        "BENCH_sync.json", ("sync", "full_vs_compressed_int8_ratio"),
        "higher"),
    "obs/tracing_overhead_frac": (
        "BENCH_obs.json", ("tracing_overhead_frac",), "lower"),
    "obs/disabled_overhead_frac": (
        "BENCH_obs.json", ("disabled_overhead_frac",), "lower"),
    "health/overhead_frac": (
        "BENCH_obs.json", ("health", "overhead_frac"), "lower"),
}


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=_ROOT, check=True,
        )
        return out.stdout.strip() or None
    except (OSError, subprocess.CalledProcessError):
        return None


def _dig(doc: Any, path: Tuple[str, ...]) -> Optional[float]:
    for k in path:
        if not isinstance(doc, dict) or k not in doc:
            return None
        doc = doc[k]
    return float(doc) if isinstance(doc, (int, float)) else None


def harvest() -> Dict[str, float]:
    """The gated metrics currently on disk (missing files/keys skipped —
    a partial bench run records what it produced)."""
    out: Dict[str, float] = {}
    cache: Dict[str, Optional[Dict]] = {}
    for metric, (fname, path, _direction) in GATED.items():
        if fname not in cache:
            try:
                with open(os.path.join(_ROOT, fname)) as f:
                    cache[fname] = json.load(f)
            except (OSError, json.JSONDecodeError):
                cache[fname] = None
        doc = cache[fname]
        if doc is None:
            continue
        v = _dig(doc, path)
        if v is not None:
            out[metric] = v
    return out


def read_history(path: str = HISTORY_PATH) -> list:
    rows = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    row = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn tail
                if isinstance(row, dict) and "benches" in row:
                    rows.append(row)
    except OSError:
        pass
    return rows


def record(path: str = HISTORY_PATH) -> Dict[str, Any]:
    """Append one ledger row from the BENCH artifacts on disk."""
    benches = harvest()
    row = {
        "ts_utc": datetime.datetime.now(
            datetime.timezone.utc
        ).isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
        "benches": benches,
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")
    print(f"ledger: recorded {len(benches)} gated metric(s) -> {path}")
    return row


def check(path: str = HISTORY_PATH, band: float = REGRESSION_BAND) -> int:
    """Newest row vs the previous one: fail on any >``band`` regression
    in the bad direction. Returns a process exit code."""
    rows = read_history(path)
    if len(rows) < 2:
        print(f"ledger: {len(rows)} row(s) in {path} — nothing to compare")
        return 0
    prev, new = rows[-2], rows[-1]
    regressions = []
    compared = 0
    for metric, (_f, _p, direction) in GATED.items():
        a, b = prev["benches"].get(metric), new["benches"].get(metric)
        if a is None or b is None:
            continue
        compared += 1
        if direction == "higher":
            bad = b < a * (1.0 - band)
        else:
            bad = b > a * (1.0 + band)
        arrow = "regressed" if bad else "ok"
        print(f"  {metric:42s} {a:12.6g} -> {b:12.6g}  [{arrow}]")
        if bad:
            regressions.append((metric, a, b))
    print(f"ledger: compared {compared} metric(s), "
          f"{new.get('git_sha', '?')} vs {prev.get('git_sha', '?')}")
    if regressions:
        for metric, a, b in regressions:
            print(f"ledger: REGRESSION {metric}: {a:.6g} -> {b:.6g} "
                  f"(>{band:.0%} in the bad direction)", file=sys.stderr)
        return 1
    print("ledger: no >20% regressions")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--record", action="store_true",
                    help="append a row from the BENCH artifacts on disk")
    ap.add_argument("--check", action="store_true",
                    help="newest vs previous row; exit 1 on regression")
    ap.add_argument("--path", default=HISTORY_PATH)
    args = ap.parse_args(argv)
    if not (args.record or args.check):
        ap.error("give --record and/or --check")
    rc = 0
    if args.record:
        record(args.path)
    if args.check:
        rc = check(args.path)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
