"""Cross-pod sync wire-format gate (the BENCH_sync.json methodology).

``benchmarks/overhead.sync_report`` prices the per-step cross-pod bytes of
the three sync schedules on the LLaMA-1B bucket structure: full-G fp32,
r-rank fp32 compressed, and the ``sync_codes`` int8 collective (codes +
per-block scales, refresh traffic amortized over T_u). The int8 path must
cut the wire >=3x vs fp32 compressed sync — gated here so a codec or
wire-model regression fails CI, not just the benchmark report.
"""


def test_sync_wire_int8_gate():
    from benchmarks.overhead import sync_report

    rep = sync_report()
    assert rep["int8_vs_fp32_compressed_ratio"] >= 3.0, rep
    # and compression beats full-G sync at all in the first place
    assert rep["full_vs_compressed_fp32_ratio"] > 1.0, rep
    assert rep["full_vs_compressed_int8_ratio"] > rep[
        "full_vs_compressed_fp32_ratio"], rep


def test_sync_report_structure():
    """The report prices every bucket and the totals are consistent with
    the per-bucket decomposition (no silently dropped buckets)."""
    from benchmarks.overhead import sync_report

    rep = sync_report()
    totals = rep["totals_bytes_per_step"]
    for key in ("full_fp32", "compressed_fp32", "compressed_int8"):
        got = sum(
            b["count"] * b["per_leaf_bytes_per_step"][key]
            for b in rep["buckets"]
        )
        assert abs(totals[key] - got) < 1e-6 * totals[key], key
    for b in rep["buckets"]:
        # int8 wire = 1B codes + fp32 block scales + amortized refresh;
        # scales must be priced (they are the honest part of the format)
        per = b["per_leaf_bytes_per_step"]
        assert per["int8_scale_bytes"] > 0
        assert per["compressed_int8"] < per["compressed_fp32"]
