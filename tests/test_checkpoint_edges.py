"""Checkpoint edge paths: async save ordering, keep= pruning, bf16 round
trip — under both per-leaf and stacked-state manifests.

The atomicity contract: a ``ckpt_<step>`` directory becomes visible ONLY
via the final ``os.rename`` of a fully-flushed ``.tmp`` directory, so no
reader (poller, restarted trainer, ``latest_step``) can ever observe a torn
checkpoint — asynchronous saves included.
"""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coap_adam import ProjectedAdamConfig, scale_by_projected_adam
from repro.core.projector import ProjectionRules
from repro.train import checkpoint as ckpt


def _params():
    p = {f"a{i}": {"w": jnp.zeros((64, 32))} for i in range(3)}
    p["bias"] = jnp.zeros((5,))
    return p


def _state(stacked: bool, state_dtype=jnp.float32, seed=0):
    params = _params()
    tx = scale_by_projected_adam(
        ProjectedAdamConfig(
            rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
            stacked_state=stacked, state_dtype=state_dtype,
        )
    )
    state = tx.init(params)
    key = jax.random.key(seed)
    flat, treedef = jax.tree_util.tree_flatten(params)
    g = jax.tree_util.tree_unflatten(
        treedef,
        [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)
        ],
    )
    _, state = jax.jit(lambda gg, s: tx.update(gg, s, None))(g, state)
    return tx, params, state


def _complete_dirs(d):
    out = []
    for name in sorted(os.listdir(d)):
        if not name.startswith("ckpt_") or name.endswith(".tmp"):
            continue
        cdir = os.path.join(d, name)
        mpath = os.path.join(cdir, "manifest.json")
        assert os.path.exists(mpath), f"torn checkpoint visible: {name}"
        with open(mpath) as f:
            manifest = json.load(f)
        for entry in manifest["leaves"] + manifest.get("stacked", []):
            assert os.path.exists(os.path.join(cdir, entry["file"])), (
                f"manifest references missing file in {name}"
            )
        out.append(name)
    return out


@pytest.mark.parametrize("stacked", [False, True])
def test_async_save_never_exposes_torn_checkpoint(tmp_path, stacked,
                                                  monkeypatch):
    """The rename that publishes ckpt_<step> must happen only after the
    manifest and every referenced array file exist in the tmp dir; while
    the async writer runs, any visible checkpoint must be complete."""
    _, _, state = _state(stacked)
    d = str(tmp_path)
    real_rename = os.rename
    renamed = []

    def checked_rename(src, dst, *a, **k):
        if str(dst).split(os.sep)[-1].startswith("ckpt_") and str(
            src
        ).endswith(".tmp"):
            mpath = os.path.join(src, "manifest.json")
            assert os.path.exists(mpath), "rename before manifest write"
            with open(mpath) as f:
                manifest = json.load(f)
            entries = manifest["leaves"] + manifest.get("stacked", [])
            assert entries
            for entry in entries:
                assert os.path.exists(os.path.join(src, entry["file"]))
            renamed.append(dst)
        return real_rename(src, dst, *a, **k)

    monkeypatch.setattr(os, "rename", checked_rename)
    try:
        path = ckpt.save(d, 1, state, async_=True)
        assert path.endswith("ckpt_00000001")
        # While the writer runs, pollers may only ever see complete ckpts.
        for _ in range(50):
            _complete_dirs(d)
    finally:
        ckpt.wait_pending()
    assert renamed, "atomic publish rename never happened"
    assert _complete_dirs(d) == ["ckpt_00000001"]
    assert ckpt.latest_step(d) == 1


@pytest.mark.parametrize("stacked", [False, True])
def test_async_save_ordering_and_wait(tmp_path, stacked):
    tx, params, state = _state(stacked)
    d = str(tmp_path)
    for step in (1, 2, 3):
        ckpt.save(d, step, state, keep=10, async_=True)
    ckpt.wait_pending()
    assert ckpt.latest_step(d) == 3
    assert _complete_dirs(d) == [
        "ckpt_00000001", "ckpt_00000002", "ckpt_00000003"
    ]
    template = jax.eval_shape(lambda: tx.init(params))
    restored = ckpt.restore(d, template)  # newest, readable
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("stacked", [False, True])
def test_keep_pruning(tmp_path, stacked):
    """keep= retains only the newest N complete checkpoints; pruning never
    touches the newest one and restore still works after GC."""
    tx, params, state = _state(stacked)
    d = str(tmp_path)
    for step in range(1, 6):
        ckpt.save(d, step, state, keep=2)
    kept = _complete_dirs(d)
    assert kept == ["ckpt_00000004", "ckpt_00000005"]
    assert ckpt.latest_step(d) == 5
    template = jax.eval_shape(lambda: tx.init(params))
    restored = ckpt.restore(d, template, step=4)
    np.testing.assert_array_equal(
        np.asarray(restored.count), np.asarray(state.count)
    )


@pytest.mark.parametrize("stacked", [False, True])
def test_bf16_as_uint16_roundtrip(tmp_path, stacked):
    """bf16 arrays are stored as uint16 views with the logical dtype in the
    manifest, for per-leaf AND stacked entries; restore recovers the exact
    bf16 bits."""
    tx, params, state = _state(stacked, state_dtype=jnp.bfloat16)
    d = str(tmp_path)
    ckpt.save(d, 1, state)
    # the manifest records bfloat16 logical dtypes somewhere
    with open(os.path.join(d, "ckpt_00000001", "manifest.json")) as f:
        manifest = json.load(f)
    entries = manifest["leaves"] + manifest.get("stacked", [])
    assert any(e["dtype"] == "bfloat16" for e in entries)
    if stacked:
        assert any(
            e["dtype"] == "bfloat16" for e in manifest["stacked"]
        ), "stacked bf16 arrays must go through the uint16 view too"
    # and the files on disk are uint16 (numpy has no bf16)
    bf16_entry = next(e for e in entries if e["dtype"] == "bfloat16")
    raw = np.load(os.path.join(d, "ckpt_00000001", bf16_entry["file"]))
    assert raw.dtype == np.uint16
    template = jax.eval_shape(lambda: tx.init(params))
    restored = ckpt.restore(d, template)
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(state)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a.astype(jnp.float32)),
            np.asarray(b.astype(jnp.float32)),
        )


def test_v1_manifest_still_restores(tmp_path):
    """Version-1 manifests (pre-codec: no version/stacked keys) keep
    restoring — forward compatibility for old checkpoints."""
    d = str(tmp_path)
    state = {"w": jnp.arange(8, dtype=jnp.bfloat16), "c": jnp.asarray(3)}
    ckpt.save(d, 1, state)
    cdir = os.path.join(d, "ckpt_00000001")
    with open(os.path.join(cdir, "manifest.json")) as f:
        manifest = json.load(f)
    del manifest["version"]
    del manifest["stacked"]
    with open(os.path.join(cdir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    restored = ckpt.restore(d, template)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["w"].astype(jnp.float32)),
        np.asarray(state["w"].astype(jnp.float32)),
    )
