"""Checkpoint edge paths: async save ordering, keep= pruning, bf16 round
trip — under both per-leaf and stacked-state manifests — plus the
cross-VERSION stacked-codec contract: a ``stacked-bucket/v1`` checkpoint
(conv states in the per-leaf TAIL) restores under v2 code and a v2
checkpoint (conv bucketed) restores into a v1-layout template, elastic
reshard included; unknown future codec versions still fail loudly.

The atomicity contract: a ``ckpt_<step>`` directory becomes visible ONLY
via the final ``os.rename`` of a fully-flushed ``.tmp`` directory, so no
reader (poller, restarted trainer, ``latest_step``) can ever observe a torn
checkpoint — asynchronous saves included.
"""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import stacked_state as ss
from repro.core.coap_adam import (
    ProjectedAdamConfig,
    ProjectedAdamState,
    scale_by_projected_adam,
)
from repro.core.projector import ProjectionRules
from repro.train import checkpoint as ckpt


def _params():
    p = {f"a{i}": {"w": jnp.zeros((64, 32))} for i in range(3)}
    p["bias"] = jnp.zeros((5,))
    return p


def _state(stacked: bool, state_dtype=jnp.float32, seed=0):
    params = _params()
    tx = scale_by_projected_adam(
        ProjectedAdamConfig(
            rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
            stacked_state=stacked, state_dtype=state_dtype,
        )
    )
    state = tx.init(params)
    key = jax.random.key(seed)
    flat, treedef = jax.tree_util.tree_flatten(params)
    g = jax.tree_util.tree_unflatten(
        treedef,
        [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)
        ],
    )
    _, state = jax.jit(lambda gg, s: tx.update(gg, s, None))(g, state)
    return tx, params, state


def _complete_dirs(d):
    out = []
    for name in sorted(os.listdir(d)):
        if not name.startswith("ckpt_") or name.endswith(".tmp"):
            continue
        cdir = os.path.join(d, name)
        mpath = os.path.join(cdir, "manifest.json")
        assert os.path.exists(mpath), f"torn checkpoint visible: {name}"
        with open(mpath) as f:
            manifest = json.load(f)
        for entry in manifest["leaves"] + manifest.get("stacked", []):
            assert os.path.exists(os.path.join(cdir, entry["file"])), (
                f"manifest references missing file in {name}"
            )
        out.append(name)
    return out


@pytest.mark.parametrize("stacked", [False, True])
def test_async_save_never_exposes_torn_checkpoint(tmp_path, stacked,
                                                  monkeypatch):
    """The rename that publishes ckpt_<step> must happen only after the
    manifest and every referenced array file exist in the tmp dir; while
    the async writer runs, any visible checkpoint must be complete."""
    _, _, state = _state(stacked)
    d = str(tmp_path)
    real_rename = os.rename
    renamed = []

    def checked_rename(src, dst, *a, **k):
        if str(dst).split(os.sep)[-1].startswith("ckpt_") and str(
            src
        ).endswith(".tmp"):
            mpath = os.path.join(src, "manifest.json")
            assert os.path.exists(mpath), "rename before manifest write"
            with open(mpath) as f:
                manifest = json.load(f)
            entries = manifest["leaves"] + manifest.get("stacked", [])
            assert entries
            for entry in entries:
                assert os.path.exists(os.path.join(src, entry["file"]))
            renamed.append(dst)
        return real_rename(src, dst, *a, **k)

    monkeypatch.setattr(os, "rename", checked_rename)
    try:
        path = ckpt.save(d, 1, state, async_=True)
        assert path.endswith("ckpt_00000001")
        # While the writer runs, pollers may only ever see complete ckpts.
        for _ in range(50):
            _complete_dirs(d)
    finally:
        ckpt.wait_pending()
    assert renamed, "atomic publish rename never happened"
    assert _complete_dirs(d) == ["ckpt_00000001"]
    assert ckpt.latest_step(d) == 1


@pytest.mark.parametrize("stacked", [False, True])
def test_async_save_ordering_and_wait(tmp_path, stacked):
    tx, params, state = _state(stacked)
    d = str(tmp_path)
    for step in (1, 2, 3):
        ckpt.save(d, step, state, keep=10, async_=True)
    ckpt.wait_pending()
    assert ckpt.latest_step(d) == 3
    assert _complete_dirs(d) == [
        "ckpt_00000001", "ckpt_00000002", "ckpt_00000003"
    ]
    template = jax.eval_shape(lambda: tx.init(params))
    restored = ckpt.restore(d, template)  # newest, readable
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("stacked", [False, True])
def test_keep_pruning(tmp_path, stacked):
    """keep= retains only the newest N complete checkpoints; pruning never
    touches the newest one and restore still works after GC."""
    tx, params, state = _state(stacked)
    d = str(tmp_path)
    for step in range(1, 6):
        ckpt.save(d, step, state, keep=2)
    kept = _complete_dirs(d)
    assert kept == ["ckpt_00000004", "ckpt_00000005"]
    assert ckpt.latest_step(d) == 5
    template = jax.eval_shape(lambda: tx.init(params))
    restored = ckpt.restore(d, template, step=4)
    np.testing.assert_array_equal(
        np.asarray(restored.count), np.asarray(state.count)
    )


@pytest.mark.parametrize("stacked", [False, True])
def test_bf16_as_uint16_roundtrip(tmp_path, stacked):
    """bf16 arrays are stored as uint16 views with the logical dtype in the
    manifest, for per-leaf AND stacked entries; restore recovers the exact
    bf16 bits."""
    tx, params, state = _state(stacked, state_dtype=jnp.bfloat16)
    d = str(tmp_path)
    ckpt.save(d, 1, state)
    # the manifest records bfloat16 logical dtypes somewhere
    with open(os.path.join(d, "ckpt_00000001", "manifest.json")) as f:
        manifest = json.load(f)
    entries = manifest["leaves"] + manifest.get("stacked", [])
    assert any(e["dtype"] == "bfloat16" for e in entries)
    if stacked:
        assert any(
            e["dtype"] == "bfloat16" for e in manifest["stacked"]
        ), "stacked bf16 arrays must go through the uint16 view too"
    # and the files on disk are uint16 (numpy has no bf16)
    bf16_entry = next(e for e in entries if e["dtype"] == "bfloat16")
    raw = np.load(os.path.join(d, "ckpt_00000001", bf16_entry["file"]))
    assert raw.dtype == np.uint16
    template = jax.eval_shape(lambda: tx.init(params))
    restored = ckpt.restore(d, template)
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(state)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a.astype(jnp.float32)),
            np.asarray(b.astype(jnp.float32)),
        )


# ---------------------------------------------------------------------------
# cross-version stacked codec (stacked-bucket/v1 <-> v2, conv leaves)
# ---------------------------------------------------------------------------
_RULES = ProjectionRules(rank=8, min_dim=8)


def _conv_state(stacked: bool, quantize: bool = False):
    """A mixed tree with a conv bucket (v2) and one jitted step of state."""
    params = {f"c{i}": 0.01 * jnp.ones((16, 12, 3, 3)) for i in range(3)}
    params["w"] = jnp.zeros((64, 32))
    params["bias"] = jnp.zeros((5,))
    tx = scale_by_projected_adam(
        ProjectedAdamConfig(rules=_RULES, t_update=2, lam=2,
                            quantize=quantize, stacked_state=stacked)
    )
    state = tx.init(params)
    key = jax.random.key(0)
    flat, treedef = jax.tree_util.tree_flatten(params)
    g = jax.tree_util.tree_unflatten(
        treedef,
        [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)
        ],
    )
    _, state = jax.jit(lambda gg, s: tx.update(gg, s, None))(g, state)
    return tx, params, state


def _encode_v1(params, per_leaf_state):
    """Re-express a per-leaf state in the LEGACY v1 stacked layout (conv in
    the per-leaf tail) — what a v1 writer would have produced."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    layout_v1 = ss.layout_for_flat(
        _RULES.spec_for, flat, classify=ss.classify_v1
    )
    assert layout_v1.tail, "v1 layout must keep conv per-leaf"
    flat_states = jax.tree_util.tree_structure(params).flatten_up_to(
        per_leaf_state.leaves
    )
    return ProjectedAdamState(
        count=per_leaf_state.count,
        leaves=ss.encode(layout_v1, flat_states),
    )


def _rewrite_stacked_codecs(cdir: str, codec: str):
    mpath = os.path.join(cdir, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["stacked"]
    for se in manifest["stacked"]:
        se["codec"] = codec
    with open(mpath, "w") as f:
        json.dump(manifest, f)


def _leaves_equal(got, want, treedef):
    if isinstance(got, ss.StackedLeaves):
        got = jax.tree_util.tree_unflatten(treedef, ss.decode(got))
    if isinstance(want, ss.StackedLeaves):
        want = jax.tree_util.tree_unflatten(treedef, ss.decode(want))
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a.astype(jnp.float32)),
            np.asarray(b.astype(jnp.float32)),
        )


@pytest.mark.parametrize("quantize", [False, True])
def test_v1_checkpoint_restores_under_v2(tmp_path, quantize):
    """A faithful stacked-bucket/v1 checkpoint — conv states as plain
    per-leaf entries, matrix buckets tagged with the v1 codec — restores
    under v2 code into BOTH a v2 stacked template (conv buckets assemble
    slot-by-slot via the logical-path namespace) and a per-leaf template."""
    tx_p, params, state_p = _conv_state(stacked=False, quantize=quantize)
    tx_s, _, _ = _conv_state(stacked=True, quantize=quantize)
    treedef = jax.tree_util.tree_structure(params)
    v1_state = _encode_v1(params, state_p)

    d = str(tmp_path)
    ckpt.save(d, 1, v1_state)
    cdir = os.path.join(d, "ckpt_00000001")
    _rewrite_stacked_codecs(cdir, ss.STACKED_CODEC_V1)
    with open(os.path.join(cdir, "manifest.json")) as f:
        manifest = json.load(f)
    # faithful v1 file: conv arrays are per-leaf 'leaves' entries
    assert any("/p_o" in e["path"] for e in manifest["leaves"])
    assert all(
        se["codec"] == ss.STACKED_CODEC_V1 for se in manifest["stacked"]
    )

    for tx_dst in (tx_s, tx_p):
        template = jax.eval_shape(lambda tx=tx_dst: tx.init(params))
        restored = ckpt.restore(d, template)
        _leaves_equal(restored.leaves, state_p.leaves, treedef)
        np.testing.assert_array_equal(
            np.asarray(restored.count), np.asarray(state_p.count)
        )


@pytest.mark.parametrize("quantize", [False, True])
def test_v2_checkpoint_restores_into_v1_layout_template(tmp_path, quantize):
    """The reverse direction: a v2 checkpoint (conv bucketed) restores into
    a LEGACY v1-layout template (conv in the tail) — conv leaves load as
    slices of their bucket files."""
    tx_s, params, state_s = _conv_state(stacked=True, quantize=quantize)
    _, _, state_p = _conv_state(stacked=False, quantize=quantize)
    treedef = jax.tree_util.tree_structure(params)
    d = str(tmp_path)
    ckpt.save(d, 2, state_s)
    with open(
        os.path.join(d, "ckpt_00000002", "manifest.json")
    ) as f:
        manifest = json.load(f)
    assert all(se["codec"] == ss.STACKED_CODEC for se in manifest["stacked"])
    # v2 file: conv states live inside stacked bucket entries
    assert any(
        any("/p_o" in sp for sp in se["slots"]) for se in manifest["stacked"]
    )

    template = jax.eval_shape(lambda: _encode_v1(params, state_p))
    restored = ckpt.restore(d, template)
    assert isinstance(restored.leaves, ss.StackedLeaves)
    assert restored.leaves.layout.tail, "template layout keeps conv per-leaf"
    _leaves_equal(restored.leaves, state_s.leaves, treedef)


def test_unknown_future_codec_fails_loudly(tmp_path):
    """A stacked-bucket/v3 entry must raise, never mis-slice."""
    tx_s, params, state_s = _conv_state(stacked=True)
    d = str(tmp_path)
    ckpt.save(d, 1, state_s)
    _rewrite_stacked_codecs(
        os.path.join(d, "ckpt_00000001"), "stacked-bucket/v3"
    )
    template = jax.eval_shape(lambda: tx_s.init(params))
    with pytest.raises(ValueError, match="codec"):
        ckpt.restore(d, template)


def test_elastic_reshard_v1_checkpoint_to_v2_template():
    """A v1-layout checkpoint saved on a 4-device mesh restores onto an
    8-device mesh into a v2 stacked template — cross-version logical paths
    plus elastic device_put in one motion."""
    import test_distributed

    test_distributed.run_sub("""
        import json, os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import stacked_state as ss
        from repro.core.coap_adam import (
            ProjectedAdamConfig, ProjectedAdamState, scale_by_projected_adam)
        from repro.core.projector import ProjectionRules
        from repro.train import checkpoint as ckpt

        rules = ProjectionRules(rank=8, min_dim=8)
        params = {f"c{i}": 0.01 * jnp.ones((16, 12, 3, 3)) for i in range(3)}
        params["w"] = jnp.zeros((64, 32))
        flat, treedef = jax.tree_util.tree_flatten(params)
        key = jax.random.key(0)
        g = jax.tree_util.tree_unflatten(treedef, [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)])

        def build(stacked):
            tx = scale_by_projected_adam(ProjectedAdamConfig(
                rules=rules, t_update=2, lam=2, stacked_state=stacked))
            st = tx.init(params)
            _, st = jax.jit(lambda gg, s: tx.update(gg, s, None))(g, st)
            return tx, st

        tx_p, st_p = build(False)
        tx_s, st_s = build(True)

        # legacy v1 layout: conv in the per-leaf tail
        fp, _ = jax.tree_util.tree_flatten_with_path(params)
        layout_v1 = ss.layout_for_flat(rules.spec_for, fp,
                                       classify=ss.classify_v1)
        st_v1 = ProjectedAdamState(
            count=st_p.count,
            leaves=ss.encode(
                layout_v1, treedef.flatten_up_to(st_p.leaves)),
        )
        mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
        st_sharded = jax.tree_util.tree_map(
            lambda x: jax.device_put(x, NamedSharding(mesh4, P())), st_v1)
        tmp = tempfile.mkdtemp()
        ckpt.save(tmp, 1, st_sharded)
        cdir = os.path.join(tmp, "ckpt_00000001")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)
        for se in manifest["stacked"]:
            se["codec"] = ss.STACKED_CODEC_V1
        with open(os.path.join(cdir, "manifest.json"), "w") as f:
            json.dump(manifest, f)

        mesh8 = jax.make_mesh((8,), ("data",))
        template = jax.eval_shape(lambda: tx_s.init(params))
        specs = jax.tree_util.tree_map(
            lambda _: P(), template, is_leaf=lambda x: hasattr(x, "shape"))
        restored = ckpt.restore(tmp, template, mesh=mesh8, spec_tree=specs)
        got = ss.decode(restored.leaves)
        want = ss.decode(st_s.leaves)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert len(restored.leaves.layout.conv_bucket_sizes()) == 1
        print("elastic v1->v2 reshard ok")
    """)


def test_v1_manifest_still_restores(tmp_path):
    """Version-1 manifests (pre-codec: no version/stacked keys) keep
    restoring — forward compatibility for old checkpoints."""
    d = str(tmp_path)
    state = {"w": jnp.arange(8, dtype=jnp.bfloat16), "c": jnp.asarray(3)}
    ckpt.save(d, 1, state)
    cdir = os.path.join(d, "ckpt_00000001")
    with open(os.path.join(cdir, "manifest.json")) as f:
        manifest = json.load(f)
    del manifest["version"]
    del manifest["stacked"]
    with open(os.path.join(cdir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    restored = ckpt.restore(d, template)
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["w"].astype(jnp.float32)),
        np.asarray(state["w"].astype(jnp.float32)),
    )


# ---------------------------------------------------------------------------
# Torn-checkpoint detection (crc32 integrity, manifest v2 optional field)
# ---------------------------------------------------------------------------
def _template_like(state):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)


@pytest.mark.parametrize("stacked", [False, True])
def test_crc32_recorded_and_clean_restore(tmp_path, stacked):
    """Every array row carries a crc32; an untouched checkpoint restores."""
    _, _, state = _state(stacked)
    d = str(tmp_path)
    ckpt.save(d, 3, state)
    with open(os.path.join(d, "ckpt_00000003", "manifest.json")) as f:
        manifest = json.load(f)
    rows = manifest["leaves"] + manifest.get("stacked", [])
    assert rows and all("crc32" in r for r in rows)
    restored = ckpt.restore(d, _template_like(state))
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("stacked", [False, True])
def test_torn_write_fails_loudly_naming_file(tmp_path, stacked):
    """Garbling one array file after the atomic rename (fault-injected
    partial copy) raises TornCheckpointError naming the offending file."""
    from repro.train.faults import FaultInjector, FaultSchedule

    _, _, state = _state(stacked)
    d = str(tmp_path)
    ckpt.save(d, 2, state)
    inj = FaultInjector(FaultSchedule(torn_write_at=(2,)), seed=1)
    inj.after_save(d, 2)
    assert inj.torn == 1
    with pytest.raises(ckpt.TornCheckpointError) as ei:
        ckpt.restore(d, _template_like(state))
    assert "ckpt_00000002" in str(ei.value)
    assert ".npy" in str(ei.value)


def test_manifest_without_crc32_still_restores(tmp_path):
    """crc32 is an OPTIONAL manifest field: stripping it (older v2
    writers) must not break restore — backward compatibility."""
    state = {"w": jnp.arange(12.0).reshape(3, 4), "c": jnp.asarray(7)}
    d = str(tmp_path)
    ckpt.save(d, 1, state)
    mpath = os.path.join(d, "ckpt_00000001", "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    for row in manifest["leaves"] + manifest.get("stacked", []):
        row.pop("crc32", None)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    restored = ckpt.restore(d, _template_like(state))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_meta_roundtrips_and_steps_listing(tmp_path):
    """save(meta=...) rides the manifest atomically; read_meta / steps
    expose it (the elastic supervisor stores the plan artifact here)."""
    state = {"w": jnp.ones((4,))}
    d = str(tmp_path)
    ckpt.save(d, 2, state, meta={"plan": {"answer": 42}})
    ckpt.save(d, 5, state)
    assert ckpt.steps(d) == [2, 5]
    assert ckpt.read_meta(d, 2) == {"plan": {"answer": 42}}
    assert ckpt.read_meta(d, 5) is None
    assert ckpt.read_meta(d) is None  # latest (5) has no meta
