"""Fault-tolerant loop: checkpoint/restart exactness, crash recovery,
straggler detection, CEU accounting, async checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.api import OptimizerConfig, make_optimizer
from repro.data.synthetic import SyntheticLM, synthetic_batch
from repro.models.model import build_model
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import Heartbeat, StragglerDetector, run_with_restart
from repro.train.loop import TrainLoop, TrainLoopConfig
from repro.train.train_state import TrainState


def _setup(tmp, stacked_state=False, **loop_kw):
    cfg = get_smoke("tinyllama-1.1b")
    model = build_model(cfg)
    tx = make_optimizer(OptimizerConfig(name="coap-adamw", learning_rate=1e-3,
                                        rank=8, t_update=4, lam=2, min_dim=16,
                                        stacked_state=stacked_state))
    data = SyntheticLM(vocab=cfg.vocab_size, order=1, noise=0.2)
    batch_fn = lambda step, host: data.batch(step, batch=4, seq=16, host=host)
    loop_cfg = TrainLoopConfig(ckpt_dir=os.path.join(tmp, "ckpt"),
                               metrics_path=os.path.join(tmp, "metrics.jsonl"),
                               **loop_kw)
    return TrainLoop(model, tx, batch_fn, loop_cfg), model, tx


@pytest.mark.parametrize("stacked", [False, True])
def test_checkpoint_restart_is_exact(tmp_path, stacked):
    """Train 8 steps straight vs 4 + restart + 4: final params identical —
    for per-leaf AND pre-stacked optimizer state (the restart restores a
    stacked TrainState through the codec-aware manifest)."""
    loopA, _, _ = _setup(str(tmp_path / "a"), total_steps=8, ckpt_every=100,
                         log_every=100, stacked_state=stacked)
    stateA = loopA.run()

    loopB1, _, _ = _setup(str(tmp_path / "b"), total_steps=4, ckpt_every=4,
                          log_every=100, stacked_state=stacked)
    loopB1.run()
    loopB2, _, _ = _setup(str(tmp_path / "b"), total_steps=8, ckpt_every=100,
                          log_every=100, stacked_state=stacked)
    stateB = loopB2.run()

    assert int(stateA.step) == int(stateB.step) == 8
    for a, b in zip(jax.tree_util.tree_leaves(stateA.params),
                    jax.tree_util.tree_leaves(stateB.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_crash_recovery_with_run_with_restart(tmp_path):
    """Induced crash at step 5 → auto-restart resumes from the checkpoint."""
    calls = []

    def attempt(i):
        crash = 5 if i == 0 else None
        loop, _, _ = _setup(str(tmp_path), total_steps=8, ckpt_every=2,
                            log_every=100, crash_at_step=crash)
        calls.append(i)
        return loop.run()

    state = run_with_restart(attempt, max_restarts=2)
    assert int(state.step) == 8
    assert calls == [0, 1]


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(z_threshold=3.0, warmup=5)
    for _ in range(30):
        assert not det.observe(0.10 + np.random.default_rng(0).normal(0, 0.002))
    assert det.observe(0.50)  # 5x step time -> straggler
    assert det.flagged == 1
    assert not det.observe(0.10)


def test_heartbeat_roundtrip(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb.json"), timeout=10.0)
    assert not hb.is_alive()
    hb.beat(3)
    assert hb.is_alive()


def test_checkpoint_atomicity_and_gc(tmp_path):
    state = {"w": jnp.arange(8, dtype=jnp.bfloat16), "c": jnp.asarray(3)}
    d = str(tmp_path)
    for s in [1, 2, 3, 4, 5]:
        ckpt.save(d, s, state, keep=2)
    assert ckpt.latest_step(d) == 5
    kept = sorted(x for x in os.listdir(d) if x.startswith("ckpt_"))
    assert len(kept) == 2
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state
    )
    restored = ckpt.restore(d, template)
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(state["w"], np.float32))
    assert restored["w"].dtype == jnp.bfloat16


def test_data_pipeline_deterministic_and_prefetches():
    from repro.data.pipeline import DataPipeline

    data = SyntheticLM(vocab=64, order=1)
    fn = lambda step, host: data.batch(step, 2, 8, host)
    p1 = DataPipeline(fn, start_step=0, host_index=0, host_count=1)
    got1 = [next(p1) for _ in range(4)]
    p1.close()
    p2 = DataPipeline(fn, start_step=0, host_index=0, host_count=1)
    got2 = [next(p2) for _ in range(4)]
    p2.close()
    for (s1, b1), (s2, b2) in zip(got1, got2):
        assert s1 == s2
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_synthetic_lm_ce_floor_reachable():
    """A tiny model should drive CE toward the known floor (sanity that the
    convergence benchmarks measure learning, not noise)."""
    data = SyntheticLM(vocab=32, order=1, noise=0.1)
    floor = data.ce_floor()
    assert 0.1 < floor < np.log(32)
