"""Conv/Tucker-2 bucketing + staggered refresh: differential parity suite.

The stacked-bucket/v2 contracts this module pins before the bucketed fast
path may replace the per-leaf Algorithm-3 loop:

  * bucketed vs per-leaf A/B at the established standard — quantized runs
    and int8 codes bit-exact, fp32 to XLA-fusion ulp, flora's per-leaf RNG
    stream identical (``bucket_leaves=False`` is the A/B lever);
  * differential oracle — with the synchronized schedule the bucketed
    update must reproduce the ORIGINAL per-leaf ``conv.update_conv_leaf``
    loop (the Algorithm-3 reference the fast path replaced), bit-exact on
    int8 states;
  * stagger cadence — conv factors refresh exactly at ``(count + phase) %
    T_u == 0`` and recalibrate at ``λ·T_u``, phases from the shipped
    ``stagger_phases`` allocator over proj+conv buckets; ``stagger=False``
    restores the synchronized schedule;
  * Eqn-7 t=0 initialization runs for every conv leaf regardless of phase
    group (both factors come out of the low-cost SVD orthonormal);
  * stacked-state storage parity and accounting byte-neutrality for conv
    buckets;
  * the adafactor layout is UNAFFECTED by the v2 bump (conv stays dense
    there — regression for the ``coap_adafactor`` conv note);
  * benchmark gate — ``benchmarks/overhead.conv_refresh_report`` must show
    a >=2x worst-step refresh-bytes cut and fewer launches for the
    bucketed+staggered conv path (the ``BENCH_conv.json`` methodology).

Runs under ``REPRO_PALLAS=interpret`` in the CI smoke (scripts/ci.sh) so
the quantized paths execute the actual Pallas codec bodies.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv as conv_mod
from repro.core import stacked_state as ss
from repro.core.accounting import optimizer_state_bytes
from repro.core.coap_adam import (
    ConvLeaf,
    ProjectedAdamConfig,
    scale_by_projected_adam,
    stagger_phases,
)
from repro.core.coap_adafactor import (
    DenseFactorLeaf,
    ProjectedAdafactorConfig,
    _af_layout,
    scale_by_projected_adafactor,
)
from repro.core.projector import ProjectionRules

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)


def _cfg(**kw):
    kw.setdefault("rules", ProjectionRules(rank=8, min_dim=8))
    return ProjectedAdamConfig(**kw)


def _conv_params():
    """Two congruent conv buckets (4x + 2x) + projected + dense leaves."""
    p = {f"conv_a{i}": 0.01 * jnp.ones((32, 16, 3, 3)) for i in range(4)}
    p.update({f"conv_b{i}": 0.01 * jnp.ones((24, 24, 3, 3)) for i in range(2)})
    p["w"] = jnp.zeros((96, 64))
    p["bias"] = jnp.zeros((7,))
    return p


def _grads(params, seed=0):
    key = jax.random.key(seed)
    flat, treedef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(
        treedef,
        [
            0.1 * jax.random.normal(jax.random.fold_in(key, i), p.shape)
            for i, p in enumerate(flat)
        ],
    )


def _run(cfg, params, g, steps=4):
    tx = scale_by_projected_adam(cfg)
    state = tx.init(params)
    step = jax.jit(lambda gg, s: tx.update(gg, s, None))
    for _ in range(steps):
        upd, state = step(g, state)
    return tx, upd, state


def _as_perleaf(state_leaves, treedef):
    if isinstance(state_leaves, ss.StackedLeaves):
        return jax.tree_util.tree_unflatten(treedef, ss.decode(state_leaves))
    return state_leaves


def _conv_factor_trajectories(tx, params, n_steps, seed=1):
    """Per conv leaf: the set of counts at which (p_o, p_i) changed."""
    state = tx.init(params)
    step = jax.jit(lambda g, s: tx.update(g, s, None))

    def factors(st):
        return [
            (x.p_o, x.p_i)
            for x in jax.tree_util.tree_leaves(
                st.leaves, is_leaf=lambda x: isinstance(x, ConvLeaf)
            )
            if isinstance(x, ConvLeaf)
        ]

    prev = factors(state)
    changed = [set() for _ in prev]
    for count in range(n_steps):
        _, state = step(_grads(params, seed=seed + count), state)
        now = factors(state)
        for i, ((ao, ai), (bo, bi)) in enumerate(zip(prev, now)):
            delta = max(
                float(jnp.max(jnp.abs(ao - bo))),
                float(jnp.max(jnp.abs(ai - bi))),
            )
            if delta > 1e-7:
                changed[i].add(count)
        prev = now
    return changed


# ---------------------------------------------------------------------------
# A/B parity: bucketed vs per-leaf execution (the established standard)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("strategy", ["coap", "galore", "flora"])
def test_conv_bucketed_matches_per_leaf(quantize, strategy):
    """One launch per conv bucket must equal the per-leaf slot loop:
    quantized runs and int8 codes bit-exact, fp32 to XLA-fusion ulp,
    flora's per-leaf RNG keys (7919*idx+mode fold) identical — under the
    staggered schedule."""
    params = _conv_params()
    g = _grads(params, seed=3)
    outs = {}
    for bucketed in (True, False):
        _, upd, state = _run(
            _cfg(strategy=strategy, quantize=quantize, t_update=3, lam=2,
                 stagger=True, bucket_leaves=bucketed),
            params, g,
        )
        outs[bucketed] = (upd, state.leaves)
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8 or quantize:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=2e-6)


@pytest.mark.parametrize("quantize", [False, True])
@pytest.mark.parametrize("strategy", ["coap", "flora"])
def test_conv_bucket_matches_per_leaf_oracle(quantize, strategy):
    """Differential oracle: with the synchronized schedule the bucketed
    fast path must reproduce the ORIGINAL per-leaf Algorithm-3 loop
    (``conv.update_conv_leaf``) — int8 codes bit-exact, fp32 to ulp,
    flora RNG identical (the oracle folds 7919*flat_idx+mode)."""
    params = {f"c{i}": 0.01 * jnp.ones((32, 16, 3, 3)) for i in range(4)}
    g = _grads(params, seed=5)
    cfg = _cfg(strategy=strategy, quantize=quantize, t_update=2, lam=2,
               stagger=False)
    tx, _, state = _run(cfg, params, g, steps=3)

    # Oracle: the per-leaf Python loop the bucketed path replaced.
    tx2 = scale_by_projected_adam(cfg)
    ostate = tx2.init(params)
    treedef = jax.tree_util.tree_structure(params)
    oleaves = treedef.flatten_up_to(ostate.leaves)
    flat_g = jax.tree_util.tree_leaves(g)
    count = jnp.zeros([], jnp.int32)
    for _ in range(3):
        new = []
        for i, (lf, gg) in enumerate(zip(oleaves, flat_g)):
            spec = cfg.rules.spec_for(f"c{i}", gg.shape)
            _, nl = jax.jit(
                lambda lf, gg, c, spec=spec, i=i: conv_mod.update_conv_leaf(
                    cfg, lf, gg, spec, c, c + 1, i
                )
            )(lf, gg, count)
            new.append(nl)
        oleaves = new
        count = count + 1
    got = treedef.flatten_up_to(state.leaves)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(oleaves)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8 or quantize:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# stagger cadence on the conv schedule
# ---------------------------------------------------------------------------
def test_conv_staggered_cadence_period_t_u():
    """Every conv leaf refreshes at count 0 (Eqn-7 init) and then exactly
    when (count + phase) % T_u == 0; phases come from the shipped allocator
    over proj+conv buckets, so bucketed and per-leaf agree."""
    t_u = 4
    params = _conv_params()
    tx = scale_by_projected_adam(_cfg(t_update=t_u, lam=2, stagger=True))
    n = 2 * 2 * t_u + 1
    changed = _conv_factor_trajectories(tx, params, n)
    # staggerable sizes: proj buckets [1 x (96,64)] then conv [4, 2]
    phase_lists = stagger_phases([1, 4, 2], t_u, 8)
    conv_phases = [ph for phases in phase_lists[1:] for ph in phases]
    assert len(changed) == len(conv_phases)
    for leaf_changed, ph in zip(changed, conv_phases):
        want = {c for c in range(n) if c == 0 or (c + ph) % t_u == 0}
        assert leaf_changed == want, (ph, leaf_changed, want)
    # staggering engaged across the 4-leaf conv bucket
    assert len({frozenset(c) for c in changed}) > 1


def test_conv_staggered_recalibration_cadence():
    """With eqn6_lr=0 the Eqn-6 factor refresh is a no-op, so conv factors
    change ONLY at Eqn-7 recalibration steps: count 0 and
    (count + phase) % (λ·T_u) == 0."""
    t_u, lam = 3, 2
    params = {f"c{i}": 0.01 * jnp.ones((32, 16, 3, 3)) for i in range(4)}
    tx = scale_by_projected_adam(
        _cfg(t_update=t_u, lam=lam, stagger=True, eqn6_lr=0.0)
    )
    n = 2 * lam * t_u + 1
    changed = _conv_factor_trajectories(tx, params, n)
    phase_lists = stagger_phases([4], t_u, 8)
    for leaf_changed, ph in zip(changed, phase_lists[0]):
        want = {
            c for c in range(n) if c == 0 or (c + ph) % (lam * t_u) == 0
        }
        assert leaf_changed == want, (ph, leaf_changed, want)


def test_conv_stagger_false_is_synchronized():
    t_u = 3
    params = _conv_params()
    tx = scale_by_projected_adam(_cfg(t_update=t_u, lam=2, stagger=False))
    n = 2 * t_u + 1
    changed = _conv_factor_trajectories(tx, params, n)
    want = {c for c in range(n) if c % t_u == 0}
    for leaf_changed in changed:
        assert leaf_changed == want, (leaf_changed, want)


def test_conv_eqn7_init_at_t0_all_phase_groups():
    """At count 0 every conv leaf's BOTH Tucker factors must come out of
    the Eqn-7 low-cost SVD with orthonormal columns — nonzero-phase groups
    included (the whole-bucket init branch of the lax.switch)."""
    params = _conv_params()
    tx = scale_by_projected_adam(_cfg(t_update=4, lam=2, stagger=True))
    state = tx.init(params)
    _, state = jax.jit(lambda g, s: tx.update(g, s, None))(
        _grads(params), state
    )
    convs = [
        x
        for x in jax.tree_util.tree_leaves(
            state.leaves, is_leaf=lambda x: isinstance(x, ConvLeaf)
        )
        if isinstance(x, ConvLeaf)
    ]
    assert convs
    for leaf in convs:
        for p in (leaf.p_o, leaf.p_i):
            ptp = np.asarray(jnp.einsum("nr,nk->rk", p, p))
            np.testing.assert_allclose(ptp, np.eye(p.shape[-1]), atol=1e-4)


# ---------------------------------------------------------------------------
# stacked storage + accounting with conv buckets
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("quantize", [False, True])
def test_conv_stacked_state_matches_per_leaf(quantize):
    """Conv moments stored PRE-STACKED (v2 layout) must produce the same
    run as per-leaf storage — quantized runs bit-exact, fp32 to ulp."""
    params = _conv_params()
    g = _grads(params, seed=7)
    treedef = jax.tree_util.tree_structure(params)
    outs = {}
    for stacked in (True, False):
        _, upd, state = _run(
            _cfg(quantize=quantize, t_update=2, lam=2, stagger=True,
                 stacked_state=stacked),
            params, g,
        )
        outs[stacked] = (upd, _as_perleaf(state.leaves, treedef))
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.int8 or quantize:
            np.testing.assert_array_equal(a, b)
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=2e-6)


def test_conv_bucket_in_stacked_layout_no_tail():
    """The adam layout buckets conv leaves (stacked-bucket/v2): stacked
    storage holds a ConvLeaf bucket with a (B,) leading axis and no
    residual tail; leaf_view slices recover per-leaf states."""
    params = _conv_params()
    tx = scale_by_projected_adam(_cfg(stacked_state=True))
    state = tx.init(params)
    leaves = state.leaves
    assert isinstance(leaves, ss.StackedLeaves)
    assert leaves.tail == ()
    conv_buckets = [
        (info, bucket)
        for info, bucket in zip(leaves.layout.buckets, leaves.buckets)
        if info.kind == ss.BUCKET_CONV
    ]
    assert [len(i.indices) for i, _ in conv_buckets] == [4, 2]
    for info, bucket in conv_buckets:
        assert isinstance(bucket, ConvLeaf)
        assert bucket.p_o.shape[0] == len(info.indices)
        for slot, idx in enumerate(info.indices):
            view = ss.leaf_view(leaves, idx)
            assert isinstance(view, ConvLeaf)
            np.testing.assert_array_equal(
                np.asarray(view.p_o), np.asarray(bucket.p_o[slot])
            )


@pytest.mark.parametrize("quantize", [False, True])
def test_conv_accounting_byte_neutral_across_layouts(quantize):
    """Byte tables identical for stacked (conv-bucketed) vs per-leaf
    storage — stacking B equal-shape ConvLeaf states is byte-neutral."""
    params = _conv_params()
    reports = {}
    for stacked in (True, False):
        tx = scale_by_projected_adam(
            _cfg(quantize=quantize, stacked_state=stacked)
        )
        reports[stacked] = optimizer_state_bytes(tx.init(params))
    assert reports[True].total_bytes == reports[False].total_bytes
    assert reports[True].by_category == reports[False].by_category
    assert "projection" in reports[True].by_category


def test_compressed_update_conv_stacked_matches_per_leaf():
    """Cross-pod compression on a conv tree: the Tucker-2 core reduction
    addressed through leaf_view (stacked mode) must match per-leaf state
    compression (floats to XLA-fusion ulp)."""
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.distributed.compression import compressed_update

    params = {f"c{i}": 0.01 * jnp.ones((32, 16, 3, 3)) for i in range(2)}
    params["w"] = jnp.zeros((96, 64))
    params["bias"] = jnp.zeros((16,))
    g = _grads(params, seed=2)
    treedef = jax.tree_util.tree_structure(params)
    mesh = jax.make_mesh((1,), ("pod",))
    outs = {}
    for stacked in (True, False):
        cfg = _cfg(t_update=2, lam=2, use_fused_kernel=False,
                   stacked_state=stacked)
        tx = scale_by_projected_adam(cfg)
        state = tx.init(params)

        def per_pod(gg, st):
            return compressed_update(cfg, gg, st, "pod")

        mapped = compat.shard_map(
            per_pod, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False, axis_names={"pod"},
        )
        for _ in range(3):
            upd, state = jax.jit(mapped)(g, state)
        outs[stacked] = (upd, _as_perleaf(state.leaves, treedef))
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=2e-6
        )


# ---------------------------------------------------------------------------
# adafactor regression: layout unaffected by the v2 bump
# ---------------------------------------------------------------------------
def test_adafactor_layout_unaffected_by_v2():
    """Algorithm 2 has no Tucker-2 path: conv leaves stay on the dense
    Adafactor path and its layout must contain NO conv buckets and no tail
    — the v1→v2 codec bump changed only the DEFAULT classification, not
    ``_af_classify``."""
    params = _conv_params()
    cfg = ProjectedAdafactorConfig(
        rules=ProjectionRules(rank=8, min_dim=8), t_update=2, lam=2,
    )
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    layout = _af_layout(cfg, flat)
    assert layout.tail == ()
    assert not [b for b in layout.buckets if b.kind == ss.BUCKET_CONV]
    assert layout.version == ss.STACKED_STATE_VERSION  # rides the codec

    # and the transform still runs conv leaves as dense factored states,
    # bit-identically across storage modes
    g = _grads(params, seed=9)
    treedef = jax.tree_util.tree_structure(params)
    outs = {}
    for stacked in (True, False):
        tx = scale_by_projected_adafactor(
            ProjectedAdafactorConfig(
                rules=ProjectionRules(rank=8, min_dim=8), t_update=2,
                lam=2, stacked_state=stacked,
            )
        )
        state = tx.init(params)
        step = jax.jit(lambda gg, s: tx.update(gg, s, None))
        for _ in range(3):
            upd, state = step(g, state)
        outs[stacked] = (upd, _as_perleaf(state.leaves, treedef))
    flat_states = treedef.flatten_up_to(outs[True][1])
    assert isinstance(flat_states[0], DenseFactorLeaf)  # conv_a0 is dense
    for a, b in zip(jax.tree_util.tree_leaves(outs[True]),
                    jax.tree_util.tree_leaves(outs[False])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# benchmark gate (acceptance criteria)
# ---------------------------------------------------------------------------
def test_conv_refresh_gate():
    """Bucketed+staggered conv refresh must cut the worst-step refresh
    bytes >=2x vs the synchronized per-leaf schedule on the conv-heavy
    reference tree, with strictly fewer per-step launches — the
    BENCH_conv.json methodology, gated here."""
    from benchmarks.overhead import conv_refresh_report

    rep = conv_refresh_report(measure=False)
    assert rep["worst_step_bytes_ratio"] >= 2.0, rep["worst_step_bytes_ratio"]
    assert (
        rep["launches_per_step_bucketed"] < rep["launches_per_step_per_leaf"]
    )
    # staggering redistributes, never adds, refresh work
    assert (
        rep["synchronized_per_leaf"]["total_bytes_per_period"]
        == rep["staggered_bucketed"]["total_bytes_per_period"]
    )
