"""Per-kernel validation: Pallas (interpret mode) vs the ref.py jnp oracles.

Sweeps shapes/dtypes with hypothesis per the assignment; every kernel must
match its oracle to fp32 tolerance, including ragged (non-multiple) shapes
and stacked leading axes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.coap_update import coap_fused_update_pallas
from repro.kernels.quant8 import (
    dequantize_blockwise_pallas,
    quantize_blockwise_pallas,
    quantized_adam_update_pallas,
)
from repro.kernels.rmsnorm import rmsnorm_pallas


def _rand(shape, seed, dtype=jnp.float32):
    return jax.random.normal(jax.random.key(seed), shape).astype(dtype)


# ---------------------------------------------------------------------------
# coap_update kernel
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    m=st.integers(16, 520),
    n=st.integers(128, 700),
    r=st.sampled_from([16, 64, 128]),
    count=st.integers(1, 1000),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_coap_fused_update_matches_ref(m, n, r, count, dtype):
    g = _rand((m, n), 0, dtype)
    p = _rand((n, r), 1) / np.sqrt(r)
    mm = 0.1 * _rand((m, r), 2)
    vv = jnp.abs(0.01 * _rand((m, r), 3))
    cnt = jnp.asarray(count, jnp.int32)
    got = coap_fused_update_pallas(g, p, mm, vv, cnt, interpret=True, bm=128, bn=256)
    want = ref.coap_fused_update(g, p, mm, vv, cnt)
    for a, b, name in zip(got, want, ["m", "v", "delta"]):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5, err_msg=name)


def test_coap_fused_update_stacked_axes():
    g = _rand((2, 3, 130, 260), 0)
    p = _rand((2, 3, 260, 32), 1) / np.sqrt(32)
    mm = jnp.zeros((2, 3, 130, 32))
    vv = jnp.zeros((2, 3, 130, 32))
    cnt = jnp.asarray(7, jnp.int32)
    got = coap_fused_update_pallas(g, p, mm, vv, cnt, interpret=True, bm=64, bn=128)
    want = ref.coap_fused_update(g, p, mm, vv, cnt)
    np.testing.assert_allclose(got[2], want[2], rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# quant8 kernels
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    numel=st.integers(1, 5000),
    scale_pow=st.integers(-6, 3),
    seed=st.integers(0, 100),
)
def test_quantize_roundtrip_matches_ref(numel, scale_pow, seed):
    x = (10.0**scale_pow) * _rand((numel,), seed)
    q_k, s_k = quantize_blockwise_pallas(x, interpret=True)
    q_r, s_r = ref.quantize_blockwise(x)
    np.testing.assert_array_equal(q_k, q_r)
    np.testing.assert_allclose(s_k, s_r, rtol=1e-6)
    x_k = dequantize_blockwise_pallas(q_k, s_k, (numel,), interpret=True)
    x_r = ref.dequantize_blockwise(q_r, s_r, (numel,))
    np.testing.assert_allclose(x_k, x_r, rtol=1e-6)
    # quantization error bound: |x - dq| <= scale/2 per block element
    err = np.abs(np.asarray(x) - np.asarray(x_k))
    per_block_bound = np.repeat(np.asarray(s_r), ref.QUANT_BLOCK)[:numel] * 0.5 + 1e-12
    assert (err <= per_block_bound + 1e-9).all()


def test_quantize_zero_block_safe():
    x = jnp.zeros((512,))
    q, s = quantize_blockwise_pallas(x, interpret=True)
    assert bool(jnp.all(q == 0)) and bool(jnp.all(s == 0))
    back = dequantize_blockwise_pallas(q, s, (512,), interpret=True)
    assert bool(jnp.all(back == 0))


@settings(max_examples=5, deadline=None)
@given(m=st.integers(8, 200), r=st.sampled_from([16, 64]), seed=st.integers(0, 50))
def test_quantized_adam_update_matches_ref(m, r, seed):
    g = 0.1 * _rand((m, r), seed)
    m0 = 0.05 * _rand((m, r), seed + 1)
    v0 = jnp.abs(0.01 * _rand((m, r), seed + 2))
    mq, ms = ref.quantize_blockwise(m0)
    vq, vs = ref.quantize_blockwise(v0)
    cnt = jnp.asarray(3, jnp.int32)
    got = quantized_adam_update_pallas(g, mq, ms, vq, vs, cnt, interpret=True)
    want = ref.quantized_adam_update(g, mq, ms, vq, vs, cnt)
    for a, b, name in zip(got, want, ["mq", "ms", "vq", "vs", "delta"]):
        if a.dtype == jnp.int8:
            # rounding at the exact .5 boundary may differ by 1 code
            assert int(jnp.max(jnp.abs(a.astype(jnp.int32) - b.astype(jnp.int32)))) <= 1
        else:
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6, err_msg=name)


# ---------------------------------------------------------------------------
# rmsnorm kernel
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    rows=st.integers(1, 300),
    d=st.sampled_from([128, 256, 1024]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 20),
)
def test_rmsnorm_matches_ref(rows, d, dtype, seed):
    x = _rand((rows, d), seed, dtype)
    scale = 1.0 + 0.1 * _rand((d,), seed + 1)
    got = rmsnorm_pallas(x, scale, interpret=True, bm=64)
    want = ref.rmsnorm(x, scale)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )


def test_rmsnorm_3d_shape():
    x = _rand((4, 7, 256), 0)
    scale = jnp.ones((256,))
    got = rmsnorm_pallas(x, scale, interpret=True, bm=8)
    want = ref.rmsnorm(x, scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
